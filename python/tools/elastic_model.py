#!/usr/bin/env python3
"""Executable models of the elastic-sharding state machines.

Dependency-free mirrors of the three deterministic cores behind
``rust/src/coordinator/{migrate,admission}.rs``, checked exhaustively
where the Rust unit tests can only spot-check:

1. **Migration × durability crash windows** — the hand-off protocol is
   replayed as a sequence of durable WAL events; a crash is injected
   after *every* prefix (and inside the unsynced buffer tail), recovery
   is run per the epoch-dedupe rule in ``service.rs``, and the model
   asserts the stream recovers **exactly once**, on the correct side of
   the commit point, with recovery idempotent (a second restart agrees).
   The racing-close branch is enumerated too.

2. **AIMD admission** — an integer-exact mirror of ``AimdController``
   (milli-job fixed point, additive increase, cooldown-absorbed
   multiplicative decrease), replaying the Rust unit-test vectors and
   then sweeping thousands of random outcome sequences for the global
   invariants (window bounds, monotone growth under health, floor under
   collapse).

3. **The elastic controller policy** — mirrors of ``scale_decision``
   and ``sustained_imbalance`` checked for hysteresis (no action
   without N consecutive signals), bound-respect, and trigger algebra.

CI runs this file (see ``.github/workflows/ci.yml``); it is also the
container-side validation stand-in when no Rust toolchain is present.
"""

from __future__ import annotations

import itertools
import random
import sys
from dataclasses import dataclass, field

FAILURES: list[str] = []


def check(cond: bool, msg: str) -> None:
    if not cond:
        FAILURES.append(msg)
        print(f"FAIL: {msg}")


# ---------------------------------------------------------------------
# 1. Migration × durability: crash-window enumeration
# ---------------------------------------------------------------------
#
# Durable-event alphabet (what can be on disk, per shard directory):
#   ("open", shard, epoch)      stream Open record
#   ("close", shard)            stream Close record
# The protocol appends records to an in-memory buffer per shard and
# syncs explicitly — exactly like WalOptions{sync:false} plus the
# migration's one fsync.  A crash keeps only synced bytes, plus any
# prefix of the unsynced tail (the OS may have flushed part of it).


@dataclass
class ShardDir:
    synced: list = field(default_factory=list)
    tail: list = field(default_factory=list)

    def log(self, ev) -> None:
        self.tail.append(ev)

    def sync(self) -> None:
        self.synced.extend(self.tail)
        self.tail.clear()

    def crash_images(self):
        """Every on-disk state a crash at this instant can leave."""
        for keep in range(len(self.tail) + 1):
            yield list(self.synced) + self.tail[:keep]


def recover(images: list[list]) -> dict:
    """The epoch-dedupe recovery of ``service.rs`` phases 2–3.

    Per shard: the stream is live iff an Open is not followed by a
    Close; its epoch is the latest Open's.  Across shards: the highest
    epoch wins; losers get a durable Close appended (finishing the
    migration's intent).  Returns {"homes": {shard}, "epoch": e} for
    the single stream being modeled, mutating ``images`` the way the
    real recovery mutates the directories.
    """
    live: dict[int, int] = {}
    for k, img in enumerate(images):
        alive, epoch = False, None
        for ev in img:
            if ev[0] == "open":
                alive, epoch = True, ev[2]
            elif ev[0] == "close":
                alive = False
        if alive:
            live[k] = epoch
    if not live:
        return {"homes": set(), "epoch": None}
    winner = max(live, key=lambda k: live[k])
    for k in live:
        if k != winner:
            images[k].append(("close", k))  # durable loser close
    return {"homes": {winner}, "epoch": live[winner]}


def migration_events(race_close: bool):
    """The migration hand-off as (action, commit_point_reached) steps.

    Mirrors ``run_migration``: target Open+Snapshot synced FIRST, then
    the routing flip (the in-memory commit point), then the source
    Close (written, NOT synced — WalOptions{sync:false}).  With
    ``race_close`` the stream is closed by a client in the fsync gap,
    so the migration undoes its target pre-log and the CLOSE wins.
    """
    SRC, TGT = 0, 1
    steps = []  # (fn(dirs), committed_to_target: bool)
    steps.append((lambda d: d[TGT].log(("open", TGT, 2)), False))
    # the Snapshot record rides in the same synced batch as the Open —
    # its payload does not change liveness, so the Open stands in for it
    steps.append((lambda d: d[TGT].sync(), False))
    if race_close:
        # close_stream won the fsync gap: Close on the source (its own
        # WAL), then the migration's undo Close on the target
        steps.append((lambda d: d[SRC].log(("close", SRC)), False))
        steps.append((lambda d: d[TGT].log(("close", TGT)), False))
        steps.append((lambda d: d[SRC].sync(), False))
        steps.append((lambda d: d[TGT].sync(), False))
    else:
        # routing flip = the commit point, then the source Close
        steps.append((lambda d: None, True))
        steps.append((lambda d: d[SRC].log(("close", SRC)), True))
    return steps


def model_crash_windows() -> None:
    for race_close in (False, True):
        steps = migration_events(race_close)
        for crash_after in range(len(steps) + 1):
            dirs = [ShardDir(), ShardDir()]
            dirs[0].log(("open", 0, 1))
            dirs[0].sync()  # the stream existed durably before the hop
            committed = False
            for fn, commit in steps[:crash_after]:
                fn(dirs)
                committed = commit or committed
            # enumerate every partial-tail image combination
            for img0, img1 in itertools.product(
                dirs[0].crash_images(), dirs[1].crash_images()
            ):
                images = [list(img0), list(img1)]
                got = recover(images)
                tag = f"race_close={race_close} crash_after={crash_after}"
                if race_close and crash_after >= 3:
                    # the client's Close records exist (durably or in a
                    # partially-flushed tail): liveness depends on which
                    # survived the crash, but never TWO live copies
                    check(len(got["homes"]) <= 1, f"{tag}: duplicated after close")
                else:
                    check(
                        len(got["homes"]) == 1,
                        f"{tag}: stream recovered {len(got['homes'])} times",
                    )
                if got["homes"] == {1}:
                    # target can only win once its records are durable
                    check(
                        crash_after >= 2 or len(img1) > 0,
                        f"{tag}: target won without durable records",
                    )
                    check(got["epoch"] == 2, f"{tag}: target won with stale epoch")
                if committed and crash_after >= len(steps) and not race_close:
                    # clean completion: the target must be the home even
                    # though the source Close may not have hit the disk
                    check(
                        got["homes"] == {1},
                        f"{tag}: completed migration recovered on the source",
                    )
                # recovery is idempotent: a second restart on the
                # directories recovery just repaired agrees exactly
                again = recover([list(i) for i in images])
                check(
                    again["homes"] == got["homes"] and again["epoch"] == got["epoch"],
                    f"{tag}: second restart disagreed "
                    f"({again['homes']} vs {got['homes']})",
                )
    print("migration crash-window model: every crash point exactly-once, idempotent")


# ---------------------------------------------------------------------
# 2. AIMD admission: integer-exact mirror of AimdController
# ---------------------------------------------------------------------

MILLI = 1000


@dataclass
class Aimd:
    initial_cwnd: int = 8
    min_cwnd: int = 1
    max_cwnd: int = 64
    latency_target: float = 0.100
    decrease_pct: int = 50
    cooldown_acks: int = 4

    def __post_init__(self):
        # AdmissionConfig::normalized
        self.min_cwnd = max(self.min_cwnd, 1)
        self.max_cwnd = max(self.max_cwnd, self.min_cwnd)
        self.initial_cwnd = min(max(self.initial_cwnd, self.min_cwnd), self.max_cwnd)
        self.decrease_pct = min(max(self.decrease_pct, 1), 99)
        self.cwnd_milli = self.initial_cwnd * MILLI
        self.cooldown = 0

    def try_acquire(self, in_flight: int) -> bool:
        return in_flight * MILLI < self.cwnd_milli

    def on_outcome(self, latency: float) -> None:
        if latency <= self.latency_target:
            if self.cooldown > 0:
                self.cooldown -= 1
            grown = self.cwnd_milli + max(MILLI * MILLI // max(self.cwnd_milli, 1), 1)
            self.cwnd_milli = min(grown, self.max_cwnd * MILLI)
        else:
            self._decrease()

    def on_congestion(self) -> None:
        self._decrease()

    def _decrease(self) -> None:
        if self.cooldown > 0:  # absorbed: same congestion event
            self.cooldown -= 1
            return
        self.cwnd_milli = max(
            self.cwnd_milli * self.decrease_pct // 100, self.min_cwnd * MILLI
        )
        self.cooldown = self.cooldown_acks


OK, SLOW = 0.010, 0.500


def model_aimd() -> None:
    # --- the Rust unit-test vectors, value for value ---
    a = Aimd()
    check(a.try_acquire(0) and a.try_acquire(7), "initial window admits under 8")
    check(not a.try_acquire(8), "initial window rejects at 8")
    a = Aimd()
    for _ in range(8):
        a.on_outcome(OK)
    check(8900 <= a.cwnd_milli <= 9100, f"full window of acks ≈ +1 job: {a.cwnd_milli}")
    check(a.try_acquire(8), "grown window admits one more")
    a = Aimd()
    a.on_outcome(SLOW)
    check(a.cwnd_milli == 4000, f"first breach halves 8→4: {a.cwnd_milli}")
    for _ in range(4):
        a.on_outcome(SLOW)
    check(a.cwnd_milli == 4000, "cooldown absorbs the breach burst")
    a.on_outcome(SLOW)
    check(a.cwnd_milli == 2000, "post-cooldown breach bites again")
    a = Aimd()
    for _ in range(100):
        for _ in range(5):
            a.on_congestion()
    check(a.cwnd_milli == 1000, f"floor holds at min_cwnd: {a.cwnd_milli}")
    check(a.try_acquire(0) and not a.try_acquire(1), "min window admits exactly one")
    a = Aimd()
    for _ in range(40):
        a.on_outcome(SLOW)
    collapsed = a.cwnd_milli
    check(collapsed < 8000, "overload shrinks the window")
    for _ in range(2000):
        a.on_outcome(OK)
    check(a.cwnd_milli >= 8000, "window reopens on healthy traffic")
    a = Aimd(max_cwnd=9)
    for _ in range(10_000):
        a.on_outcome(OK)
    check(a.cwnd_milli == 9000, f"growth caps at max_cwnd: {a.cwnd_milli}")
    a = Aimd()
    a.on_outcome(SLOW)
    for _ in range(4):
        a.on_outcome(OK)
    before = a.cwnd_milli
    a.on_outcome(SLOW)
    check(a.cwnd_milli < before, "successes burn cooldown too")

    # --- randomized sweep for the global invariants ---
    rng = random.Random(0x9A75A)
    for trial in range(2000):
        cfg = dict(
            initial_cwnd=rng.randint(1, 64),
            min_cwnd=rng.randint(0, 8),
            max_cwnd=rng.randint(0, 128),
            decrease_pct=rng.randint(0, 120),
            cooldown_acks=rng.randint(0, 8),
        )
        a = Aimd(**cfg)
        lo, hi = a.min_cwnd * MILLI, a.max_cwnd * MILLI
        for step in range(200):
            r = rng.random()
            if r < 0.4:
                a.on_outcome(OK)
            elif r < 0.8:
                a.on_outcome(SLOW)
            else:
                a.on_congestion()
            if not lo <= a.cwnd_milli <= hi:
                check(False, f"trial {trial} step {step}: window {a.cwnd_milli} escaped [{lo},{hi}]")
                break
        # whatever happened, sustained health must re-open the window
        for _ in range(a.max_cwnd * a.max_cwnd + a.cooldown_acks + 1):
            a.on_outcome(OK)
        check(a.cwnd_milli == hi, f"trial {trial}: window did not fully reopen")
    print("AIMD model: Rust vectors match, 2000 random traces hold the invariants")


# ---------------------------------------------------------------------
# 3. Controller policy: scale_decision + sustained_imbalance mirrors
# ---------------------------------------------------------------------


@dataclass
class ElasticCfg:
    min_workers: int = 1
    max_workers: int = 4
    grow_backlog: int = 4
    shrink_backlog: int = 1
    hysteresis_ticks: int = 3
    migrate_ratio: int = 4
    migrate_slack: int = 8
    migrate_ticks: int = 3


def scale_decision(backlog, size, target, cfg, streaks):
    per_worker = backlog // max(size, 1)
    if per_worker >= cfg.grow_backlog:
        streaks[0] += 1
        streaks[1] = 0
    elif per_worker <= cfg.shrink_backlog:
        streaks[1] += 1
        streaks[0] = 0
    else:
        streaks[0] = streaks[1] = 0
    if streaks[0] >= cfg.hysteresis_ticks and target < cfg.max_workers:
        streaks[0] = 0
        return "grow"
    if streaks[1] >= cfg.hysteresis_ticks and target > cfg.min_workers:
        streaks[1] = 0
        return "shrink"
    return "hold"


def sustained_imbalance(loads, cfg, streak):
    hot = max(range(len(loads)), key=lambda k: loads[k])
    cold = min(range(len(loads)), key=lambda k: loads[k])
    armed = hot != cold and loads[hot] > loads[cold] * cfg.migrate_ratio + cfg.migrate_slack
    if not armed:
        streak[0] = 0
        return None
    streak[0] += 1
    if streak[0] < cfg.migrate_ticks:
        return None
    streak[0] = 0
    return (hot, cold)


def model_controller_policy() -> None:
    cfg = ElasticCfg()
    # hysteresis: N-1 hot ticks then one calm tick never act
    streaks = [0, 0]
    for _ in range(cfg.hysteresis_ticks - 1):
        check(scale_decision(100, 1, 1, cfg, streaks) == "hold", "acted early")
    check(scale_decision(2, 1, 1, cfg, streaks) == "hold", "calm tick resets")
    for _ in range(cfg.hysteresis_ticks - 1):
        check(scale_decision(100, 1, 1, cfg, streaks) == "hold", "streak restarted")
    check(scale_decision(100, 1, 1, cfg, streaks) == "grow", "sustained signal grows")

    # random walk: target always within bounds, actions need streaks
    rng = random.Random(7)
    for trial in range(500):
        streaks = [0, 0]
        target = size = rng.randint(cfg.min_workers, cfg.max_workers)
        consec = 0
        for _ in range(300):
            backlog = rng.choice([0, 0, 1, 2, 5, 8, 50])
            act = scale_decision(backlog, size, target, cfg, streaks)
            per = backlog // max(size, 1)
            if per >= cfg.grow_backlog or per <= cfg.shrink_backlog:
                consec += 1
            else:
                consec = 0
            if act == "grow":
                check(consec >= cfg.hysteresis_ticks, f"trial {trial}: grew without streak")
                target += 1
                size += 1
                consec = 0
            elif act == "shrink":
                check(consec >= cfg.hysteresis_ticks, f"trial {trial}: shrank without streak")
                target -= 1
                size -= 1  # model the worker exiting at its job boundary
                consec = 0
            if not cfg.min_workers <= target <= cfg.max_workers:
                check(False, f"trial {trial}: target {target} escaped bounds")
                break

    # migration trigger algebra: ratio+slack, persistence, reset
    streak = [0]
    check(sustained_imbalance([8, 8], cfg, streak) is None, "balanced never arms")
    check(sustained_imbalance([40, 8], cfg, streak) is None, "at the boundary never arms")
    streak = [0]
    for _ in range(cfg.migrate_ticks - 1):
        check(sustained_imbalance([41, 8], cfg, streak) is None, "fires early")
    check(sustained_imbalance([41, 8], cfg, streak) == (0, 1), "sustained imbalance fires")
    check(streak[0] == 0, "firing resets the streak")
    streak = [0]
    sustained_imbalance([41, 8], cfg, streak)
    check(sustained_imbalance([9, 8], cfg, streak) is None, "calm tick resets the streak")
    check(streak[0] == 0, "reset observed")
    # the pair is always (argmax, argmin) and they differ when armed
    rng = random.Random(21)
    streak = [0]
    for _ in range(2000):
        loads = [rng.randint(0, 60) for _ in range(4)]
        got = sustained_imbalance(loads, cfg, streak)
        if got is not None:
            hot, cold = got
            check(loads[hot] == max(loads) and loads[cold] == min(loads), "wrong pair")
            check(
                loads[hot] > loads[cold] * cfg.migrate_ratio + cfg.migrate_slack,
                "fired unarmed",
            )
    print("controller policy model: hysteresis, bounds, and trigger algebra hold")


def main() -> int:
    model_crash_windows()
    model_aimd()
    model_controller_policy()
    if FAILURES:
        print(f"\nelastic_model: {len(FAILURES)} failure(s)")
        return 1
    print("\nelastic_model: all models hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
