#!/usr/bin/env python3
"""Line-for-line Python mirror of ``tools/lint`` (natsa-lint v2).

The Rust analyzer is the CI gate; this mirror exists because several of
this repo's build containers have no Rust toolchain, and the project's
verification record for those sessions is "the Python mirror ran the
same algorithm over the same tree and agreed".  Every function here
ports its namesake in ``tools/lint/src/main.rs`` one-for-one — same
tokenizer states, same per-function model, same pass order, same
messages, same sort/dedup — so a finding list produced by either tool
is byte-comparable with the other's.

Usage:
    python3 python/tools/lint_mirror.py [--json] [ROOT]   # scan a tree
    python3 python/tools/lint_mirror.py --selftest        # planted tests

Exit status mirrors the Rust tool: 0 clean, 1 findings, 2 I/O error.
"""

from __future__ import annotations

import json
import os
import sys

# --- constants (verbatim from main.rs) -------------------------------

SCAN_DIRS = ["rust/src", "rust/tests", "benches", "examples", "tools/lint/src"]

LOCK_CLASSES = [
    ("streams", 10),
    ("submit_seq", 20),
    ("state", 30),
    ("subs", 40),
    ("slots", 50),
    ("route_table", 60),
]

LOCK_ORDER_FILES = [
    "rust/src/coordinator/service.rs",
    "rust/src/coordinator/router.rs",
    "rust/src/coordinator/migrate.rs",
    "rust/src/coordinator/admission.rs",
]

FP_FILES = [
    "rust/src/mp/kernel.rs",
    "rust/src/mp/stampi.rs",
    "rust/src/coordinator/migrate.rs",
]

WAL_FILES = ["rust/src/coordinator/service.rs", "rust/src/coordinator/migrate.rs"]

METRICS_FILE = "rust/src/coordinator/metrics.rs"
METRICS_USAGE_FILES = [
    "rust/src/coordinator/metrics.rs",
    "rust/src/coordinator/service.rs",
    "rust/src/coordinator/migrate.rs",
]
RECON_FILE = "rust/tests/service_shard.rs"
RECON_FN = "assert_reconciled"

RULES = [
    ("naked_lock", "NL001"),
    ("naked_wait", "NL002"),
    ("lock_order", "NL003"),
    ("instant_arith", "NL004"),
    ("hot_sqrt", "NL005"),
    ("fp_determinism", "NL006"),
    ("wal_order", "NL007"),
    ("metrics_coverage", "NL008"),
    ("suppression", "NL009"),
]
RULE_ID = dict(RULES)

TRANSCENDENTALS = [
    ".powf(", ".powi(", ".exp(", ".exp2(", ".exp_m1(", ".ln(", ".ln_1p(",
    ".log(", ".log2(", ".log10(", ".sin(", ".cos(", ".tan(", ".asin(",
    ".acos(", ".atan(", ".atan2(", ".sinh(", ".cosh(", ".tanh(", ".cbrt(",
    ".hypot(",
]

OPAQUE_CALLEES = [
    "new", "default", "fmt", "clone", "remove", "len", "is_empty", "extend", "drop",
]

# Built from parts so this file's own text never contains the marker.
MARKER = "natsa-lint" + ": allow("


class Finding:
    def __init__(self, file, line, rule, msg):
        self.file = file
        self.line = line
        self.rule = rule
        self.msg = msg

    def id(self):
        return RULE_ID.get(self.rule, "NL???")

    def __str__(self):
        return f"{self.file}:{self.line}: [{self.id()} {self.rule}] {self.msg}"

    def __repr__(self):
        return str(self)


# --- tokenizer -------------------------------------------------------


def is_ident(c):
    return c.isalnum() or c == "_"


class Line:
    __slots__ = ("code", "comment", "allows")

    def __init__(self, code, comment, allows):
        self.code = code
        self.comment = comment
        self.allows = allows


def parse_allows(comment):
    out = []
    rest = comment
    while True:
        pos = rest.find(MARKER)
        if pos < 0:
            break
        after = rest[pos + len(MARKER):]
        end = after.find(")")
        if end < 0:
            break
        out.append({"rule": after[:end].strip(), "justified": False})
        rest = after[end:]
    return out


def strip_markers(comment):
    out = []
    rest = comment
    while True:
        pos = rest.find(MARKER)
        if pos < 0:
            break
        out.append(rest[:pos])
        after = rest[pos + len(MARKER):]
        end = after.find(")")
        if end < 0:
            rest = ""
            break
        rest = after[end + 1:]
    out.append(rest)
    return "".join(out)


CODE, BLOCK, STR, RAWSTR = 0, 1, 2, 3


def sanitize(content):
    st = CODE
    depth = 0  # BLOCK nesting / RAWSTR hash count
    out = []
    for raw in content.split("\n"):
        chars = raw
        n = len(chars)
        code = []
        comment = []
        i = 0
        while i < n:
            if st == BLOCK:
                if chars[i] == "/" and i + 1 < n and chars[i + 1] == "*":
                    depth += 1
                    comment.append("/*")
                    i += 2
                elif chars[i] == "*" and i + 1 < n and chars[i + 1] == "/":
                    if depth == 1:
                        st = CODE
                    else:
                        depth -= 1
                        comment.append("*/")
                    i += 2
                else:
                    comment.append(chars[i])
                    i += 1
            elif st == STR:
                if chars[i] == "\\":
                    i += 2
                elif chars[i] == '"':
                    code.append('"')
                    st = CODE
                    i += 1
                else:
                    i += 1
            elif st == RAWSTR:
                h = depth
                if chars[i] == '"' and all(
                    i + 1 + k < n and chars[i + 1 + k] == "#" for k in range(h)
                ):
                    code.append('"' + "#" * h)
                    st = CODE
                    i += h + 1
                else:
                    i += 1
            else:  # CODE
                c = chars[i]
                if c == "/" and i + 1 < n and chars[i + 1] == "/":
                    comment.append(chars[i + 2:])
                    i = n
                elif c == "/" and i + 1 < n and chars[i + 1] == "*":
                    st = BLOCK
                    depth = 1
                    i += 2
                elif c == '"':
                    code.append('"')
                    st = STR
                    i += 1
                elif c == "r":
                    # raw-string start candidate: same prev-ident test as
                    # the Rust tokenizer (an `r` glued to an identifier is
                    # part of that identifier, not a literal prefix)
                    joined = "".join(code)
                    if joined and is_ident(joined[-1]):
                        code.append(c)
                        i += 1
                        continue
                    h = 0
                    while i + 1 + h < n and chars[i + 1 + h] == "#":
                        h += 1
                    if i + 1 + h < n and chars[i + 1 + h] == '"':
                        code.append("r" + "#" * h + '"')
                        st = RAWSTR
                        depth = h
                        i += h + 2
                    else:
                        code.append(c)
                        i += 1
                elif c == "'":
                    if i + 1 < n and chars[i + 1] == "\\":
                        code.append("' '")
                        j = i + 2
                        while j < n and chars[j] != "'":
                            j += 1
                        i = j + 1
                    elif i + 2 < n and chars[i + 2] == "'":
                        code.append("' '")
                        i += 3
                    else:
                        code.append("'")
                        i += 1
                else:
                    code.append(c)
                    i += 1
        comment_s = "".join(comment)
        out.append(Line("".join(code), comment_s, parse_allows(comment_s)))
    for i, line in enumerate(out):
        if not line.allows:
            continue
        own = any(ch.isalnum() for ch in strip_markers(line.comment))
        prev = i > 0 and any(ch.isalnum() for ch in out[i - 1].comment)
        for a in line.allows:
            a["justified"] = own or prev
    return out


def test_region_mask(lines):
    mask = [False] * len(lines)
    i = 0
    while i < len(lines):
        code = lines[i].code
        if "#[cfg(test)]" in code or "#[cfg(all(test" in code:
            depth = 0
            opened = False
            j = i
            while j < len(lines):
                mask[j] = True
                for c in lines[j].code:
                    if c == "{":
                        depth += 1
                        opened = True
                    elif c == "}":
                        depth -= 1
                if opened and depth <= 0:
                    break
                j += 1
            i = j + 1
        else:
            i += 1
    return mask


# --- per-function model ----------------------------------------------


class Func:
    __slots__ = ("name", "body_start", "end")

    def __init__(self, name, body_start, end):
        self.name = name
        self.body_start = body_start
        self.end = end


class Model:
    __slots__ = ("rel", "lines", "mask", "funcs")

    def __init__(self, rel, content):
        self.rel = rel
        self.lines = sanitize(content)
        self.mask = test_region_mask(self.lines)
        self.funcs = extract_funcs(self.lines)


def extract_funcs(lines):
    out = []
    for i in range(len(lines)):
        chars = lines[i].code
        n = len(chars)
        k = 0
        while k + 1 < n:
            word_fn = (
                chars[k] == "f"
                and chars[k + 1] == "n"
                and (k == 0 or not is_ident(chars[k - 1]))
                and (k + 2 >= n or not is_ident(chars[k + 2]))
            )
            if not word_fn:
                k += 1
                continue
            j = k + 2
            while j < n and chars[j].isspace():
                j += 1
            ns = j
            while j < n and is_ident(chars[j]):
                j += 1
            if j > ns:
                name = chars[ns:j]
                span = body_span(lines, i, j)
                if span is not None:
                    out.append(Func(name, span[0], span[1]))
            k = max(j, k + 1)
    return out


def body_span(lines, li, ci):
    paren = 0
    brace = 0
    body_start = None
    l, c = li, ci
    while l < len(lines):
        chars = lines[l].code
        while c < len(chars):
            ch = chars[c]
            if ch == "(":
                paren += 1
            elif ch == ")":
                paren -= 1
            elif ch == "{":
                if body_start is not None:
                    brace += 1
                elif paren == 0:
                    body_start = l
                    brace = 1
            elif ch == "}":
                if body_start is not None:
                    brace -= 1
                    if brace == 0:
                        return (body_start, l)
            elif ch == ";":
                if body_start is None and paren == 0:
                    return None
            c += 1
        l += 1
        c = 0
    return None


# --- shared helpers --------------------------------------------------


def squash(s):
    return "".join(c for c in s if not c.isspace())


def find_all(hay, needle):
    out = []
    start = 0
    while True:
        p = hay.find(needle, start)
        if p < 0:
            break
        out.append(p)
        start = p + 1
    return out


def matches_window(lines, i, pat):
    cur = squash(lines[i].code)
    nxt = squash(lines[i + 1].code) if i + 1 < len(lines) else ""
    win = cur + nxt
    return any(p < len(cur) for p in find_all(win, pat))


def has_word(hay, word):
    wlen = len(word)
    for p in find_all(hay, word):
        pre = p == 0 or not is_ident(hay[p - 1])
        post = p + wlen >= len(hay) or not is_ident(hay[p + wlen])
        if pre and post:
            return True
    return False


def call_idents(sq):
    out = []
    i = 0
    n = len(sq)
    while i < n:
        if is_ident(sq[i]) and not sq[i].isdigit():
            start = i
            while i < n and is_ident(sq[i]):
                i += 1
            if i < n and sq[i] == "(":
                out.append(sq[start:i])
        else:
            i += 1
    return out


def allowed(lines, i, rule):
    if any(a["rule"] == rule for a in lines[i].allows):
        return i
    if i > 0 and any(a["rule"] == rule for a in lines[i - 1].allows):
        return i - 1
    return None


def report(m, i, rule, msg, findings, used):
    j = allowed(m.lines, i, rule)
    if j is not None:
        used.add((m.rel, j, rule))
    else:
        findings.append(Finding(m.rel, i + 1, rule, msg))


# --- the analysis ----------------------------------------------------


def scan_files(files):
    models = [Model(rel, src) for rel, src in files]
    findings = []
    used = set()
    for m in models:
        scan_local(m, findings, used)
    scan_lock_order(models, findings, used)
    scan_wal_order(models, findings, used)
    scan_metrics_coverage(models, findings, used)
    scan_suppressions(models, used, findings)
    findings.sort(key=lambda f: (f.file, f.line, f.rule))
    deduped = []
    for f in findings:
        if deduped and (
            deduped[-1].file == f.file
            and deduped[-1].line == f.line
            and deduped[-1].rule == f.rule
            and deduped[-1].msg == f.msg
        ):
            continue
        deduped.append(f)
    return deduped


def scan_local(m, findings, used):
    in_src = m.rel.startswith("rust/src/")
    naked_scope = in_src and m.rel != "rust/src/sync.rs"
    hot_scope = m.rel in ("rust/src/mp/kernel.rs", "rust/src/mp/stampi.rs")
    fp_scope = m.rel in FP_FILES
    for i in range(len(m.lines)):
        if naked_scope and not m.mask[i]:
            for pat in [
                ".lock().unwrap()",
                ".lock().expect(",
                ".read().unwrap()",
                ".write().unwrap()",
            ]:
                if matches_window(m.lines, i, pat):
                    report(
                        m, i, "naked_lock",
                        f"`{pat}` — acquire through crate::sync::lock_ok so the "
                        "poison policy (and the loom swap) lives in one place",
                        findings, used,
                    )
                    break
        if naked_scope and not m.mask[i]:
            cur = squash(m.lines[i].code)
            nxt = squash(m.lines[i + 1].code) if i + 1 < len(m.lines) else ""
            win = cur + nxt
            hit = any(
                any(p < len(cur) and ".unwrap()" in win[p:] for p in find_all(win, pat))
                for pat in [".wait(", ".wait_timeout("]
            )
            if hit:
                report(
                    m, i, "naked_wait",
                    "Condvar wait unwrap — use crate::sync::wait_ok / wait_timeout_ok",
                    findings, used,
                )
        cur = squash(m.lines[i].code)
        for pat in [
            ".duration_since(", "Instant::now()+", "Instant::now()-",
            "+Instant::now()", "-Instant::now()",
        ]:
            if pat in cur:
                report(
                    m, i, "instant_arith",
                    f"`{pat}` — raw Instant arithmetic panics on underflow/overflow; "
                    "use checked_add / saturating_duration_since",
                    findings, used,
                )
                break
        if hot_scope and not m.mask[i] and matches_window(m.lines, i, ".sqrt()"):
            report(
                m, i, "hot_sqrt",
                "sqrt on a kernel hot path — the deferred-sqrt contract keeps "
                "distances squared (one sqrt per snapshot via sqrt_in_place)",
                findings, used,
            )
        if fp_scope and not m.mask[i]:
            scan_fp_line(m, i, findings, used)


def scan_fp_line(m, i, findings, used):
    cur = squash(m.lines[i].code)
    if ".mul_add(" in cur:
        report(
            m, i, "fp_determinism",
            "`mul_add` — FMA contraction rounds differently from mul-then-add; "
            "bit-identity surfaces must not fuse",
            findings, used,
        )
        return
    for t in TRANSCENDENTALS:
        if t in cur:
            report(
                m, i, "fp_determinism",
                f"`{t}…)` — transcendental with platform-dependent rounding on a "
                "bit-identity surface",
                findings, used,
            )
            return
    for w in ["HashMap", "HashSet"]:
        if has_word(cur, w):
            report(
                m, i, "fp_determinism",
                f"`{w}` — hashed iteration order is nondeterministic; feeding FP "
                "accumulation or profile merges breaks bit-identity (use a sorted "
                "or indexed container)",
                findings, used,
            )
            return
    tgt = float_cast(m.lines[i].code)
    if tgt is not None:
        report(
            m, i, "fp_determinism",
            f"`as {tgt}` cast of a computed value on a bit-identity surface — "
            "precision reshaping must stay at the sanctioned conversion sites "
            "(integer-identifier casts are exact and exempt)",
            findings, used,
        )


def float_cast(code):
    chars = code
    n = len(chars)
    k = 0
    while k + 1 < n:
        word_as = (
            chars[k] == "a"
            and chars[k + 1] == "s"
            and (k == 0 or not is_ident(chars[k - 1]))
            and k + 2 < n
            and chars[k + 2].isspace()
        )
        if not word_as:
            k += 1
            continue
        j = k + 2
        while j < n and chars[j].isspace():
            j += 1
        ts = j
        while j < n and is_ident(chars[j]):
            j += 1
        tgt = chars[ts:j]
        p = k
        while p > 0 and chars[p - 1].isspace():
            p -= 1
        computed = p > 0 and chars[p - 1] == ")"
        q = p
        while q > 0 and (is_ident(chars[q - 1]) or chars[q - 1] == "."):
            q -= 1
        tok = chars[q:p]
        float_lit = bool(tok) and tok[0].isdigit() and "." in tok
        if tgt == "f32":
            return "f32"
        if tgt == "f64" and (computed or float_lit):
            return "f64"
        k = j
    return None


# --- NL003 lock_order ------------------------------------------------


def class_name(cls):
    for n, c in LOCK_CLASSES:
        if c == cls:
            return n
    return "?"


def scan_lock_order(models, findings, used):
    universe = [k for k in range(len(models)) if models[k].rel in LOCK_ORDER_FILES]
    names = {f.name for k in universe for f in models[k].funcs}
    acquires = {}
    calls_of = {}
    sites = []
    for mi in universe:
        m = models[mi]
        for f in m.funcs:
            scan_fn_locks(m, mi, f, names, acquires, calls_of, sites, findings, used)
    trans = {k: set(v) for k, v in acquires.items()}
    while True:
        changed = False
        for name, callees in calls_of.items():
            add = set()
            for callee in callees:
                add |= trans.get(callee, set())
            cur = trans.setdefault(name, set())
            for c in add:
                if c not in cur:
                    cur.add(c)
                    changed = True
        if not changed:
            break
    for s in sites:
        t = trans.get(s["callee"])
        if t is None:
            continue
        worst = None
        for h in s["held"]:
            for c in sorted(t):
                if h[1] >= c and (worst is None or h[1] > worst[0][1]):
                    worst = (h, c)
        if worst is not None:
            (gname, gclass), c = worst
            report(
                models[s["model"]], s["line"], "lock_order",
                f"calls `{s['callee']}`, which transitively acquires "
                f"`{class_name(c)}` (class {c}), while `{gname}` (class {gclass}) "
                "is held — cross-function hierarchy descent (docs/CONCURRENCY.md)",
                findings, used,
            )


def scan_fn_locks(m, mi, f, names, acquires, calls_of, sites, findings, used):
    depth = 0
    held = []  # [name, class, depth]
    hi = min(f.end, len(m.lines) - 1)
    for i in range(f.body_start, hi + 1):
        code = squash(m.lines[i].code)
        for p in find_all(code, "drop("):
            if p > 0 and (code[p - 1].isalnum() or code[p - 1] == "_"):
                continue
            end = code.find(")", p + 5)
            if end >= 0:
                name = code[p + 5:end]
                held = [g for g in held if g[0] != name]
        for p in find_all(code, "lock_ok("):
            if p > 0 and (code[p - 1].isalnum() or code[p - 1] == "_"):
                continue
            arg_start = p + len("lock_ok(")
            rel_end = code.find(")", arg_start)
            if rel_end < 0:
                continue
            arg_end = rel_end
            field = code[arg_start:arg_end].lstrip("&")
            # rsplit over both '.' and ':' like Rust's rsplit(['.', ':'])
            for sep_pos in range(len(field) - 1, -1, -1):
                if field[sep_pos] in ".:":
                    field = field[sep_pos + 1:]
                    break
            hit = next(((n, c) for n, c in LOCK_CLASSES if n == field), None)
            if hit is None:
                continue
            cname, cls = hit
            if not m.mask[i]:
                acquires.setdefault(f.name, set()).add(cls)
                worst = None
                for g in held:
                    if g[1] >= cls and (worst is None or g[1] > worst[1]):
                        worst = g
                if worst is not None:
                    report(
                        m, i, "lock_order",
                        f"acquires `{cname}` (class {cls}) while `{worst[0]}` "
                        f"(class {worst[1]}) is held — hierarchy is streams < "
                        "submit_seq < state < subs, slots and route_table leaves "
                        "(docs/CONCURRENCY.md)",
                        findings, used,
                    )
            if code[arg_end:arg_end + 2] == ");":
                name = binding_name(code[:p])
                if name is not None:
                    held.append([name, cls, depth])
        if not m.mask[i]:
            for callee in call_idents(code):
                if callee != f.name and callee in names and callee not in OPAQUE_CALLEES:
                    calls_of.setdefault(f.name, set()).add(callee)
                    if held:
                        sites.append({
                            "model": mi,
                            "line": i,
                            "callee": callee,
                            "held": [(g[0], g[1]) for g in held],
                        })
        for c in code:
            if c == "{":
                depth += 1
            elif c == "}":
                depth -= 1
        held = [g for g in held if g[2] <= depth]


def binding_name(before):
    if not before.startswith("let"):
        return None
    rest = before[3:]
    if rest.startswith("mut"):
        rest = rest[3:]
    if not rest.endswith("="):
        return None
    name = rest[:-1]
    if not name or not all(c.isalnum() or c == "_" for c in name):
        return None
    return name


# --- NL007 wal_order -------------------------------------------------


def first_arg(sq, after):
    rest = sq[after:]
    end = len(rest)
    for stop in (",", ")"):
        p = rest.find(stop)
        if p >= 0:
            end = min(end, p)
    return rest[:end].lstrip("*&")


def scan_wal_order(models, findings, used):
    universe = [k for k in range(len(models)) if models[k].rel in WAL_FILES]
    names = {f.name for k in universe for f in models[k].funcs}
    direct_close = set()
    calls_of = {}
    for mi in universe:
        m = models[mi]
        for f in m.funcs:
            hi = min(f.end, len(m.lines) - 1)
            for i in range(f.body_start, hi + 1):
                if m.mask[i]:
                    continue
                sq = squash(m.lines[i].code)
                if "log_close(" in sq:
                    direct_close.add(f.name)
                for callee in call_idents(sq):
                    if callee != f.name and callee in names and callee not in OPAQUE_CALLEES:
                        calls_of.setdefault(f.name, set()).add(callee)
    closes = set(direct_close)
    while True:
        changed = False
        for name, callees in calls_of.items():
            if name not in closes and any(c in closes for c in callees):
                closes.add(name)
                changed = True
        if not changed:
            break
    for mi in universe:
        m = models[mi]
        for f in m.funcs:
            seen_open = False
            seen_append = False
            seen_state = False
            closed_args = []
            hi = min(f.end, len(m.lines) - 1)
            for i in range(f.body_start, hi + 1):
                if m.mask[i]:
                    continue
                sq = squash(m.lines[i].code)
                for op, flag in [("log_open(", True), ("log_append(", False), ("log_snapshot(", False)]:
                    for p in find_all(sq, op):
                        if flag:
                            seen_open = True
                        elif op == "log_append(":
                            seen_append = True
                        arg = first_arg(sq, p + len(op))
                        if arg in closed_args:
                            report(
                                m, i, "wal_order",
                                f"`{op}…)` after `log_close` for the same stream "
                                f"(`{arg}`) — records after Close are unreachable "
                                "on replay",
                                findings, used,
                            )
                for p in find_all(sq, "log_close("):
                    closed_args.append(first_arg(sq, p + len("log_close(")))
                for p in find_all(sq, "lock_ok("):
                    arg_start = p + len("lock_ok(")
                    rel_end = sq.find(")", arg_start)
                    if rel_end >= 0:
                        field = sq[arg_start:rel_end].lstrip("&")
                        for sep_pos in range(len(field) - 1, -1, -1):
                            if field[sep_pos] in ".:":
                                field = field[sep_pos + 1:]
                                break
                        if field == "state":
                            seen_state = True
                if "session.extend(" in sq or "append_group(" in sq:
                    if not seen_append:
                        report(
                            m, i, "wal_order",
                            "session mutation is not write-ahead logged — no "
                            "`log_append` dominates it in this function (WAL "
                            "contract: log, then mutate, inside the state-lock "
                            "region)",
                            findings, used,
                        )
                    elif not seen_state:
                        report(
                            m, i, "wal_order",
                            "session mutation before any state-lock acquisition — "
                            "WAL ordering is only atomic inside the stream's "
                            "state-lock region",
                            findings, used,
                        )
                if "streams).insert(" in sq and not seen_open:
                    report(
                        m, i, "wal_order",
                        "stream install without a dominating `log_open` — the WAL "
                        "must know the stream before the map does",
                        findings, used,
                    )
                if (".closed=true" in sq or ".moved=true" in sq) and f.name not in closes:
                    report(
                        m, i, "wal_order",
                        "close/move mark without a `log_close` in this function or "
                        "its callees — replay would resurrect the stream",
                        findings, used,
                    )


# --- NL008 metrics_coverage ------------------------------------------


def field_use(sq, prefix, field):
    pat = prefix + field
    plen = len(pat)
    for p in find_all(sq, pat):
        pre = prefix.startswith(".") or p == 0 or not is_ident(sq[p - 1])
        post = p + plen >= len(sq) or not is_ident(sq[p + plen])
        if pre and post:
            return True
    return False


def scan_metrics_coverage(models, findings, used):
    mm = next((m for m in models if m.rel == METRICS_FILE), None)
    if mm is None:
        return
    fields = []
    def_range = None
    in_struct = False
    start = 0
    for i in range(len(mm.lines)):
        if mm.mask[i]:
            continue
        sq = squash(mm.lines[i].code)
        if not in_struct and sq.startswith("pubstructServiceMetrics{"):
            in_struct = True
            start = i
            continue
        if in_struct:
            if sq == "}":
                def_range = (start, i)
                break
            if sq.startswith("pub"):
                rest = sq[3:]
                cp = rest.find(":")
                if cp >= 0:
                    name = rest[:cp]
                    if name and all(is_ident(c) for c in name):
                        fields.append((name, i))
    if def_range is None:
        findings.append(Finding(
            mm.rel, 1, "metrics_coverage",
            "ServiceMetrics struct not found — the coverage pass has nothing to check",
        ))
        return
    recon = next((m for m in models if m.rel == RECON_FILE), None)
    recon_fn = None
    if recon is not None:
        rf = next((f for f in recon.funcs if f.name == RECON_FN), None)
        if rf is not None:
            recon_fn = (recon, rf)
    if recon_fn is None:
        findings.append(Finding(
            mm.rel, def_range[0] + 1, "metrics_coverage",
            f"reconciliation test `{RECON_FN}` not found in {RECON_FILE} — every "
            "ServiceMetrics field must be covered by the Σ-reconciliation test",
        ))
    for fname, fline in fields:
        any_use = False
        shard = False
        agg = False
        for m in models:
            if m.rel not in METRICS_USAGE_FILES:
                continue
            for i in range(len(m.lines)):
                if m.mask[i]:
                    continue
                if m.rel == METRICS_FILE and def_range[0] <= i <= def_range[1]:
                    continue
                sq = squash(m.lines[i].code)
                if field_use(sq, ".", fname):
                    any_use = True
                if field_use(sq, "metrics.", fname):
                    shard = True
                if field_use(sq, "aggregate.", fname):
                    agg = True
        if not any_use:
            report(
                mm, fline, "metrics_coverage",
                f"`{fname}` is never recorded in the coordinator — dead or "
                "unreconcilable metric field",
                findings, used,
            )
        elif shard != agg:
            side = "shard, no aggregate" if shard else "aggregate, no shard"
            report(
                mm, fline, "metrics_coverage",
                f"`{fname}` is ticked on only one side ({side}) — shard and "
                "aggregate must move in step or Σ-reconciliation cannot hold",
                findings, used,
            )
        if recon_fn is not None:
            rm, rf = recon_fn
            hi = min(rf.end, len(rm.lines) - 1)
            covered = any(
                field_use(squash(rm.lines[i].code), ".", fname)
                for i in range(rf.body_start, hi + 1)
            )
            if not covered:
                report(
                    mm, fline, "metrics_coverage",
                    f"`{fname}` is missing from `{RECON_FN}` ({RECON_FILE}) — new "
                    "counters must join the Σ-reconciliation test",
                    findings, used,
                )


# --- NL009 suppression -----------------------------------------------


def scan_suppressions(models, used, findings):
    known = {r for r, _ in RULES}
    for m in models:
        for i, line in enumerate(m.lines):
            for a in line.allows:
                if a["rule"] not in known:
                    findings.append(Finding(
                        m.rel, i + 1, "suppression",
                        f"allow marker names unknown rule `{a['rule']}`",
                    ))
                elif (m.rel, i, a["rule"]) not in used:
                    findings.append(Finding(
                        m.rel, i + 1, "suppression",
                        f"stale allow marker — no `{a['rule']}` finding is "
                        "suppressed here; delete it or it will mask a future "
                        "regression",
                    ))
                elif not a["justified"]:
                    findings.append(Finding(
                        m.rel, i + 1, "suppression",
                        f"allow marker for `{a['rule']}` lacks a justification "
                        "comment (same comment or the line above)",
                    ))


# --- tree walk / CLI -------------------------------------------------


def scan_tree(root):
    paths = []
    for d in SCAN_DIRS:
        base = os.path.join(root, d)
        if not os.path.isdir(base):
            continue
        for dirpath, _dirnames, filenames in os.walk(base):
            for fn in filenames:
                if fn.endswith(".rs"):
                    paths.append(os.path.join(dirpath, fn))
    paths.sort()
    files = []
    for path in paths:
        with open(path, encoding="utf-8") as fh:
            content = fh.read()
        rel = os.path.relpath(path, root).replace("\\", "/")
        files.append((rel, content))
    return scan_files(files), len(files)


def render_json(findings, files_scanned):
    return json.dumps(
        {
            "schema": "natsa-lint/v2",
            "files_scanned": files_scanned,
            "clean": not findings,
            "findings": [
                {"file": f.file, "line": f.line, "id": f.id(), "rule": f.rule, "msg": f.msg}
                for f in findings
            ],
        },
        indent=2,
        ensure_ascii=False,
    )


# --- self-tests (ports of the Rust #[cfg(test)] module) --------------


def _rules(rel, src):
    return [f.rule for f in scan_files([(rel, src)])]


def _repo_root():
    return os.path.normpath(os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", ".."))


def selftest():
    checks = 0

    def ok(cond, what):
        nonlocal checks
        checks += 1
        if not cond:
            raise AssertionError(what)

    # naked_lock
    src = "fn f() {\n    let _ = m.lock().unwrap();\n}"
    ok(_rules("rust/src/coordinator/fanout.rs", src) == ["naked_lock"], "naked_lock caught")
    ok(_rules("rust/src/sync.rs", src) == [], "sync.rs exempt")
    ok(_rules("rust/tests/x.rs", src) == [], "tests scope exempt")
    split = "fn f() {\n    let _ = m.lock()\n        .unwrap();\n}"
    ok(_rules("rust/src/a.rs", split) == ["naked_lock"], "split chain caught")
    rw = "fn f() {\n    let _ = m.read().unwrap();\n}"
    ok(_rules("rust/src/a.rs", rw) == ["naked_lock"], "rwlock caught")
    marked = (
        "fn f() {\n    // " + MARKER + "naked_lock) planted case\n"
        "    let _ = m.lock().unwrap();\n}"
    )
    ok(_rules("rust/src/a.rs", marked) == [], "marker exempts")
    tested = "#[cfg(test)]\nmod tests {\n    fn f() { let _ = m.lock().unwrap(); }\n}"
    ok(_rules("rust/src/a.rs", tested) == [], "test mod exempt")
    tested2 = "#[cfg(all(test, not(loom)))]\nmod tests {\n    fn f() { let _ = m.lock().unwrap(); }\n}"
    ok(_rules("rust/src/a.rs", tested2) == [], "cfg(all(test..)) exempt")

    # naked_wait
    ok(_rules("rust/src/a.rs", "fn f() {\n    let g = cv.wait(g).unwrap();\n}") == ["naked_wait"], "wait caught")
    ok(_rules("rust/src/a.rs", "fn f() {\n    let (g, _) = cv.wait_timeout(g, d).unwrap();\n}") == ["naked_wait"], "wait_timeout caught")
    ok(_rules("rust/src/a.rs", "fn f() {\n    let g = wait_ok(&cv, g);\n}") == [], "wait_ok clean")

    # lock_order: intra
    descent = "fn f() {\n    let st = lock_ok(&e.state);\n    let g = lock_ok(&e.submit_seq);\n}"
    ok(_rules("rust/src/coordinator/service.rs", descent) == ["lock_order"], "descent caught")
    ascent = "fn f() {\n    let g = lock_ok(&e.submit_seq);\n    let st = lock_ok(&e.state);\n}"
    ok(_rules("rust/src/coordinator/service.rs", ascent) == [], "ascent clean")
    ok(_rules("rust/src/coordinator/mod.rs", descent) == [], "out-of-universe clean")
    dropped = "fn f() {\n    let st = lock_ok(&e.state);\n    drop(st);\n    let g = lock_ok(&e.submit_seq);\n}"
    ok(_rules("rust/src/coordinator/service.rs", dropped) == [], "drop releases")
    scoped = "fn f() {\n    {\n        let st = lock_ok(&e.state);\n    }\n    let g = lock_ok(&e.submit_seq);\n}"
    ok(_rules("rust/src/coordinator/service.rs", scoped) == [], "scope releases")
    try_exempt = "fn f() {\n    let st = lock_ok(&e.state);\n    let g = try_lock_ok(&e.submit_seq);\n}"
    ok(_rules("rust/src/coordinator/service.rs", try_exempt) == [], "try_lock exempt")
    temp = (
        "fn f() {\n    w.log_open(id, meta);\n"
        "    lock_ok(&shard.streams).insert(id, entry);\n"
        "    let st = lock_ok(&e.state);\n    let _n = lock_ok(&shard.subs).len();\n}"
    )
    ok(_rules("rust/src/coordinator/service.rs", temp) == [], "temporaries not held")
    temp_descent = "fn f() {\n    let st = lock_ok(&e.state);\n    lock_ok(&shard.streams).remove(&id);\n}"
    ok(_rules("rust/src/coordinator/service.rs", temp_descent) == ["lock_order"], "temp descent caught")
    rt_descent = "fn f() {\n    let t = lock_ok(&self.route_table);\n    let st = lock_ok(&e.state);\n}"
    ok(_rules("rust/src/coordinator/router.rs", rt_descent) == ["lock_order"], "route_table top")
    rt_ascent = "fn f() {\n    let st = lock_ok(&e.state);\n    let t = lock_ok(&self.route_table);\n}"
    ok(_rules("rust/src/coordinator/router.rs", rt_ascent) == [], "route_table under state ok")
    ok(_rules("rust/src/coordinator/migrate.rs", rt_descent) == ["lock_order"], "migrate in universe")
    ok(_rules("rust/src/coordinator/admission.rs", rt_descent) == ["lock_order"], "admission in universe")
    naked_inv = (
        "fn f(w: &W) {\n    w.log_open(id, meta);\n    let st = lock_ok(&e.state);\n"
        "    lock_ok(&target.streams).insert(id, entry);\n}"
    )
    ok(_rules("rust/src/coordinator/migrate.rs", naked_inv) == ["lock_order"], "inversion caught")
    marked_inv = (
        "fn f(w: &W) {\n    w.log_open(id, meta);\n    let st = lock_ok(&e.state);\n"
        "    // " + MARKER + "lock_order) planted sanctioned inversion\n"
        "    lock_ok(&target.streams).insert(id, entry);\n}"
    )
    ok(_rules("rust/src/coordinator/migrate.rs", marked_inv) == [], "inversion marker ok")

    # lock_order: interprocedural
    cross = (
        "fn helper(e: &E) {\n    let st = lock_ok(&e.state);\n    st.touch();\n}\n"
        "fn caller(shard: &S, e: &E) {\n    let g = lock_ok(&shard.subs);\n    helper(e);\n    drop(g);\n}"
    )
    fs = scan_files([("rust/src/coordinator/service.rs", cross)])
    ok([f.rule for f in fs] == ["lock_order"], "cross-function chain caught")
    ok(fs[0].line == 7, "flagged at call site")
    ok("helper" in fs[0].msg, "names the callee")
    asc = (
        "fn helper(e: &E) {\n    let st = lock_ok(&e.state);\n}\n"
        "fn caller(e: &E) {\n    let g = lock_ok(&e.submit_seq);\n    helper(e);\n}"
    )
    ok(_rules("rust/src/coordinator/service.rs", asc) == [], "cross-function ascent clean")
    two_hop = (
        "fn c(e: &E) {\n    let st = lock_ok(&e.state);\n}\n"
        "fn b(e: &E) {\n    c(e);\n}\n"
        "fn a(shard: &S, e: &E) {\n    let g = lock_ok(&shard.subs);\n    b(e);\n}"
    )
    ok(_rules("rust/src/coordinator/service.rs", two_hop) == ["lock_order"], "two-hop transitive caught")
    marked_cross = (
        "fn helper(e: &E) {\n    let st = lock_ok(&e.state);\n}\n"
        "fn caller(shard: &S, e: &E) {\n    let g = lock_ok(&shard.subs);\n"
        "    // " + MARKER + "lock_order) planted cross-function case\n    helper(e);\n}"
    )
    ok(_rules("rust/src/coordinator/service.rs", marked_cross) == [], "cross-function marker ok")

    # instant_arith
    add = "fn f() {\n    let d = Instant::now() + Duration::from_secs(30);\n}"
    ok(_rules("rust/tests/x.rs", add) == ["instant_arith"], "instant add caught in tests")
    ok(_rules("benches/y.rs", add) == ["instant_arith"], "instant add caught in benches")
    ok(_rules("rust/src/a.rs", "fn f() {\n    let d = a.duration_since(b);\n}") == ["instant_arith"], "duration_since caught")
    ok(_rules("rust/src/a.rs", "fn f() {\n    let d = a.saturating_duration_since(b);\n}") == [], "saturating clean")
    ok(_rules("rust/src/a.rs", 'fn f() {\n    let d = Instant::now().checked_add(t).expect("x");\n}') == [], "checked clean")

    # hot_sqrt
    sq = "fn f(x: f64) -> f64 {\n    x.sqrt()\n}"
    ok(_rules("rust/src/mp/kernel.rs", sq) == ["hot_sqrt"], "sqrt caught in kernel")
    ok(_rules("rust/src/mp/stampi.rs", sq) == ["hot_sqrt"], "sqrt caught in stampi")
    ok(_rules("rust/src/mp/mod.rs", sq) == [], "sqrt_in_place home clean")
    msq = "fn f(x: f64) -> f64 {\n    x.sqrt() // " + MARKER + "hot_sqrt) planted\n}"
    ok(_rules("rust/src/mp/kernel.rs", msq) == [], "sqrt marker ok")

    # fp_determinism
    fma = "fn f(a: f64, b: f64, c: f64) -> f64 {\n    a.mul_add(b, c)\n}"
    ok(_rules("rust/src/mp/kernel.rs", fma) == ["fp_determinism"], "mul_add caught")
    ok(_rules("rust/src/mp/mod.rs", fma) == [], "fp scope limited")
    fma_t = "#[cfg(test)]\nmod tests {\n    fn f(a: f64) -> f64 { a.mul_add(a, a) }\n}"
    ok(_rules("rust/src/mp/kernel.rs", fma_t) == [], "fp test mod exempt")
    ok(_rules("rust/src/mp/kernel.rs", "fn f(x: f64) -> f64 {\n    x.powf(2.0)\n}") == ["fp_determinism"], "powf caught")
    ok(_rules("rust/src/mp/stampi.rs", "fn f() {\n    let mut h = HashMap::with_capacity(4);\n}") == ["fp_determinism"], "HashMap caught")
    ok(_rules("rust/src/mp/kernel.rs", "fn f(x: f64) -> f32 {\n    x as f32\n}") == ["fp_determinism"], "as f32 caught")
    ok(_rules("rust/src/mp/kernel.rs", "fn f(a: f64, b: f64) -> f64 {\n    (a + b) as f64\n}") == ["fp_determinism"], "computed as f64 caught")
    ok(_rules("rust/src/mp/kernel.rs", "fn f() -> f64 {\n    2.5 as f64\n}") == ["fp_determinism"], "float literal cast caught")
    ok(_rules("rust/src/mp/kernel.rs", "fn f(m: usize) -> f64 {\n    2.0 * m as f64\n}") == [], "ident as f64 clean")

    # wal_order
    unlogged = "fn f(e: &E) {\n    let mut st = lock_ok(&e.state);\n    st.session.extend(samples);\n}"
    ok(_rules("rust/src/coordinator/service.rs", unlogged) == ["wal_order"], "unlogged extend caught")
    logged = (
        "fn f(e: &E) {\n    let mut st = lock_ok(&e.state);\n"
        "    w.log_append(stream, seq, samples);\n    st.session.extend(samples);\n}"
    )
    ok(_rules("rust/src/coordinator/service.rs", logged) == [], "logged extend clean")
    no_region = "fn f(w: &W) {\n    w.log_append(stream, seq, samples);\n    session.extend(samples);\n}"
    ok(_rules("rust/src/coordinator/service.rs", no_region) == ["wal_order"], "extend outside region caught")
    ok(_rules("rust/src/coordinator/slots.rs", unlogged) == [], "wal scope limited")
    g_unlogged = "fn f(e: &E) {\n    let g = try_lock_ok(&e.state);\n    let r = append_group(&mut sess);\n}"
    ok(_rules("rust/src/coordinator/service.rs", g_unlogged) == ["wal_order"], "unlogged group caught")
    g_logged = (
        "fn f(e: &E) {\n    let g = try_lock_ok(&e.state);\n"
        "    w.log_append(stream, seq, samples);\n    let r = append_group(&mut sess);\n}"
    )
    ok(_rules("rust/src/coordinator/service.rs", g_logged) == [], "logged group clean")
    install = "fn f() {\n    lock_ok(&shard.streams).insert(id, entry);\n}"
    ok(_rules("rust/src/coordinator/service.rs", install) == ["wal_order"], "unopened install caught")
    opened = "fn f(w: &W) {\n    w.log_open(id, meta);\n    lock_ok(&shard.streams).insert(id, entry);\n}"
    ok(_rules("rust/src/coordinator/service.rs", opened) == [], "opened install clean")
    close_un = "fn f(e: &E) {\n    let mut st = lock_ok(&e.state);\n    st.closed = true;\n}"
    ok(_rules("rust/src/coordinator/service.rs", close_un) == ["wal_order"], "unlogged close caught")
    close_ok = (
        "fn f(e: &E) {\n    let mut st = lock_ok(&e.state);\n    st.closed = true;\n"
        "    w.log_close(stream);\n}"
    )
    ok(_rules("rust/src/coordinator/service.rs", close_ok) == [], "direct close clean")
    via_callee = (
        "fn quarantine(w: &W) {\n    w.log_close(stream);\n}\n"
        "fn f(e: &E, w: &W) {\n    let mut st = lock_ok(&e.state);\n    st.closed = true;\n"
        "    quarantine(w);\n}"
    )
    ok(_rules("rust/src/coordinator/service.rs", via_callee) == [], "close via callee clean")
    moved = "fn f(e: &E) {\n    let mut st = lock_ok(&e.state);\n    st.moved = true;\n}"
    ok(_rules("rust/src/coordinator/migrate.rs", moved) == ["wal_order"], "unlogged move caught")
    after_close = "fn f(w: &W) {\n    w.log_close(stream);\n    w.log_open(stream, meta);\n}"
    ok(_rules("rust/src/coordinator/service.rs", after_close) == ["wal_order"], "record after close caught")
    other_stream = "fn f(w: &W) {\n    w.log_close(dropped);\n    w.log_open(stream, meta);\n}"
    ok(_rules("rust/src/coordinator/service.rs", other_stream) == [], "other stream after close clean")

    # metrics_coverage (synthetic)
    met = (
        "pub struct ServiceMetrics {\n    pub a: AtomicU64,\n    pub b: AtomicU64,\n}\n"
        "impl ServiceMetrics {\n    pub fn tick(&self) {\n"
        "        self.a.fetch_add(1, Ordering::Relaxed);\n"
        "        self.b.fetch_add(1, Ordering::Relaxed);\n    }\n}"
    )
    recon_ok = (
        "fn assert_reconciled(svc: &S) {\n    assert_eq!(agg.a.load(O), sum.a);\n"
        "    assert_eq!(agg.b.load(O), sum.b);\n}"
    )
    ok(scan_files([(METRICS_FILE, met), (RECON_FILE, recon_ok)]) == [] or
       not scan_files([(METRICS_FILE, met), (RECON_FILE, recon_ok)]), "synthetic clean")
    recon_partial = "fn assert_reconciled(svc: &S) {\n    assert_eq!(agg.a.load(O), sum.a);\n}"
    fs = scan_files([(METRICS_FILE, met), (RECON_FILE, recon_partial)])
    ok([f.rule for f in fs] == ["metrics_coverage"] and "`b`" in fs[0].msg, "missing-from-recon caught")
    dead = (
        "pub struct ServiceMetrics {\n    pub a: AtomicU64,\n    pub c: AtomicU64,\n}\n"
        "impl ServiceMetrics {\n    pub fn tick(&self) {\n"
        "        self.a.fetch_add(1, Ordering::Relaxed);\n    }\n}"
    )
    recon_ac = (
        "fn assert_reconciled(svc: &S) {\n    assert_eq!(agg.a.load(O), sum.a);\n"
        "    assert_eq!(agg.c.load(O), sum.c);\n}"
    )
    fs = scan_files([(METRICS_FILE, dead), (RECON_FILE, recon_ac)])
    ok([f.rule for f in fs] == ["metrics_coverage"] and "never recorded" in fs[0].msg, "dead field caught")
    svc_one = "fn f(shard: &S) {\n    shard.metrics.a.fetch_add(1, Ordering::Relaxed);\n}"
    fs = scan_files([(METRICS_FILE, met), ("rust/src/coordinator/service.rs", svc_one), (RECON_FILE, recon_ok)])
    ok([f.rule for f in fs] == ["metrics_coverage"] and "only one side" in fs[0].msg, "one-sided tick caught")
    fs = scan_files([(METRICS_FILE, met)])
    ok([f.rule for f in fs] == ["metrics_coverage"], "missing recon fn caught")

    # metrics_coverage fails closed on the real tree's twin scratch field
    root = _repo_root()
    real = {}
    for rel in [METRICS_FILE, "rust/src/coordinator/service.rs",
                "rust/src/coordinator/migrate.rs", RECON_FILE]:
        with open(os.path.join(root, rel), encoding="utf-8") as fh:
            real[rel] = fh.read()
    base = scan_files(list(real.items()))
    ok(base == [] or not base, "real metrics surface clean: " + "; ".join(map(str, base)))
    scratch = next(
        l for l in real[METRICS_FILE].split("\n") if "scratch_unreconciled" in l
    )
    spiked = dict(real)
    spiked[METRICS_FILE] = real[METRICS_FILE].replace(
        "pub struct ServiceMetrics {", "pub struct ServiceMetrics {\n" + scratch
    )
    fs = scan_files(list(spiked.items()))
    ok(fs and all(f.rule == "metrics_coverage" for f in fs), "spiked twin field flagged")
    ok(any("scratch_unreconciled" in f.msg for f in fs), "spike names the field")

    # suppression hygiene
    stale = "fn f() {\n    // " + MARKER + "naked_lock) says it is needed here\n    let x = compute();\n}"
    ok(_rules("rust/src/a.rs", stale) == ["suppression"], "stale marker caught")
    unknown = "fn f() {\n    // " + MARKER + "bogus_rule) oops\n    let x = compute();\n}"
    ok(_rules("rust/src/a.rs", unknown) == ["suppression"], "unknown rule caught")
    bare = "fn f() {\n    // " + MARKER + "naked_lock)\n    let _ = m.lock().unwrap();\n}"
    ok(_rules("rust/src/a.rs", bare) == ["suppression"], "unjustified marker caught")
    above = (
        "fn f() {\n    // single-threaded startup, poison impossible\n"
        "    // " + MARKER + "naked_lock)\n    let _ = m.lock().unwrap();\n}"
    )
    ok(_rules("rust/src/a.rs", above) == [], "line-above justification ok")

    # tokenizer: raw strings
    fp_raw = 'fn f() {\n    let s = r#"say "hi" then m.lock().unwrap()"#;\n}'
    ok(_rules("rust/src/a.rs", fp_raw) == [], "raw string false positive pinned")
    fn_raw = 'fn f() {\n    let s = r"ends with \\";\n    let _ = m.lock().unwrap();\n}'
    ok(_rules("rust/src/a.rs", fn_raw) == ["naked_lock"], "raw string false negative pinned")
    ml_raw = 'fn f() {\n    let s = r#"first\n.lock().unwrap()\nlast"#;\n}'
    ok(_rules("rust/src/a.rs", ml_raw) == [], "multi-line raw string blanked")

    # tokenizer: nested block comments
    nested = (
        "fn f() {}\n/* outer /* inner */ let _ = m.lock().unwrap(); /* x */ "
        "still comment */\nfn g() {}"
    )
    ok(_rules("rust/src/a.rs", nested) == [], "nested block comment pinned")
    nested_ml = "fn f() {}\n/* outer\n/* inner\n*/\nlet _ = m.lock().unwrap();\n*/\nfn g() {}"
    ok(_rules("rust/src/a.rs", nested_ml) == [], "multi-line nested comment pinned")
    strings = (
        "//! docs say never write .lock().unwrap() by hand\nfn f() {\n"
        '    let s = ".sqrt() and .lock().unwrap() and Instant::now() + d";\n'
        "    /* .wait(g).unwrap() */\n}"
    )
    ok(_rules("rust/src/mp/kernel.rs", strings) == [], "comments and strings inert")

    # ids and json
    fs = scan_files([("rust/src/a.rs", "fn f() {\n    let _ = m.lock().unwrap();\n}")])
    ok(fs[0].id() == "NL001", "stable id")
    js = render_json(fs, 1)
    ok('"id": "NL001"' in js and '"clean": false' in js, "json report")
    ok('"clean": true' in render_json([], 3), "clean json report")

    # whole tree
    findings, files = scan_tree(root)
    ok(files > 20, "tree walk found the sources")
    ok(findings == [] or not findings,
       "repo must be natsa-lint clean:\n" + "\n".join(map(str, findings)))

    print(f"lint_mirror selftest: {checks} checks passed")


def main(argv):
    as_json = False
    do_selftest = False
    root = "."
    for arg in argv[1:]:
        if arg == "--json":
            as_json = True
        elif arg == "--selftest":
            do_selftest = True
        else:
            root = arg
    if do_selftest:
        selftest()
        return 0
    try:
        findings, files_scanned = scan_tree(root)
    except OSError as e:
        print(f"natsa-lint: {e}", file=sys.stderr)
        return 2
    if as_json:
        print(render_json(findings, files_scanned))
    else:
        for f in findings:
            print(f)
        if not findings:
            print(f"natsa-lint: tree clean ({files_scanned} files)")
    if findings:
        print(f"natsa-lint: {len(findings)} violation(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
