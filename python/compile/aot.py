"""AOT compiler: lower every Layer-2 graph to HLO text in ``artifacts/``.

This is the single build-time entry point (``make artifacts``).  It lowers
each (kind, dtype, m) variant of the Layer-2 graphs with jax.jit, converts
the StableHLO to an XlaComputation, and dumps **HLO text**:

    the interchange format is HLO text, NOT ``lowered.compile()`` or a
    serialized HloModuleProto — jax >= 0.5 emits protos with 64-bit
    instruction ids that the rust side's xla_extension 0.5.1 rejects
    (``proto.id() <= INT_MAX``); the text parser reassigns ids and
    round-trips cleanly (see /opt/xla-example/README.md).

Alongside the ``.hlo.txt`` files it writes ``manifest.tsv`` — one line per
artifact with its static parameters — which the rust runtime parses to
discover available kernel variants (no JSON: the offline vendor set has no
serde, and a TSV is all the information there is).

Usage:  cd python && python -m compile.aot [--outdir ../artifacts] [--force]
"""

from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)  # before any jnp use: f64 designs

import argparse
import hashlib
import os
import sys

import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.diagonal import DEFAULT_CHUNK

# Kernel variant grid.  m values cover the paper's sensitivity range scaled
# to the artifact budget; rust picks the largest m' <= requested m... no —
# m is exact: the runtime selects the artifact matching the requested window
# or falls back to the native path.
WINDOW_SIZES = (32, 64, 128, 256)
CHUNK = DEFAULT_CHUNK
# Larger chunk variant: fewer kernel invocations per diagonal on the rust
# side (the per-call PJRT+interpret overhead dominates at V=512; the
# coordinator picks the largest available V).
CHUNK_LARGE = 2048
STATS_N = 8192
TILE_N, TILE_M = 1024, 64


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_plan():
    """Yield (name, fn, example_args, meta) for every artifact."""
    for dname, dtype in model.DTYPES.items():
        for m in WINDOW_SIZES:
            for v in (CHUNK, CHUNK_LARGE):
                yield (
                    f"diag_chunk_{dname}_m{m}_v{v}",
                    model.diag_chunk_fn(m, v),
                    (
                        _spec((v + m,), dtype), _spec((v + m,), dtype),
                        _spec((v,), dtype), _spec((v,), dtype),
                        _spec((v,), dtype), _spec((v,), dtype),
                        _spec((1,), dtype), _spec((1,), jnp.int32),
                    ),
                    {"kind": "diag_chunk", "dtype": dname, "m": m, "v": v, "n": 0},
                )
            yield (
                f"dot_init_{dname}_m{m}",
                model.dot_init_fn(m),
                (_spec((m,), dtype), _spec((m,), dtype)),
                {"kind": "dot_init", "dtype": dname, "m": m, "v": 0, "n": 0},
            )
        yield (
            f"stats_{dname}_m128_n{STATS_N}",
            model.stats_fn(128),
            (_spec((STATS_N,), dtype),),
            {"kind": "stats", "dtype": dname, "m": 128, "v": 0, "n": STATS_N},
        )
        yield (
            f"mp_tile_{dname}_n{TILE_N}_m{TILE_M}",
            model.mp_tile_fn(TILE_N, TILE_M),
            (_spec((TILE_N,), dtype),),
            {"kind": "mp_tile", "dtype": dname, "m": TILE_M, "v": 0, "n": TILE_N},
        )


def input_fingerprint() -> str:
    """Hash of the compile-path sources: skip relowering when unchanged."""
    h = hashlib.sha256()
    base = os.path.dirname(os.path.abspath(__file__))
    for root, _, files in os.walk(base):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--force", action="store_true", help="relower even if fresh")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    args = ap.parse_args(argv)

    os.makedirs(args.outdir, exist_ok=True)
    stamp = os.path.join(args.outdir, ".fingerprint")
    fp = input_fingerprint()
    if not args.force and not args.only and os.path.exists(stamp):
        with open(stamp) as fh:
            if fh.read().strip() == fp:
                print("artifacts: fresh (fingerprint match), nothing to do")
                return 0

    manifest = []
    for name, fn, specs, meta in build_plan():
        if args.only and args.only not in name:
            continue
        path = os.path.join(args.outdir, f"{name}.hlo.txt")
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as fh:
            fh.write(text)
        ins = ";".join(f"{'x'.join(map(str, s.shape))}:{s.dtype}" for s in specs)
        manifest.append(
            f"{name}\t{name}.hlo.txt\t{meta['kind']}\t{meta['dtype']}"
            f"\t{meta['m']}\t{meta['v']}\t{meta['n']}\t{ins}"
        )
        print(f"  lowered {name}  ({len(text) / 1024:.0f} KiB)")

    if not args.only:
        with open(os.path.join(args.outdir, "manifest.tsv"), "w") as fh:
            fh.write("# name\tfile\tkind\tdtype\tm\tv\tn\tinputs\n")
            fh.write("\n".join(manifest) + "\n")
        with open(stamp, "w") as fh:
            fh.write(fp)
    print(f"wrote {len(manifest)} artifacts to {args.outdir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
