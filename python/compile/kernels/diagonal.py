"""Layer-1 Pallas kernels: the NATSA PU datapath.

The paper's PU (Section 4.1, Fig. 5) is a four-stage pipeline:

  DPU   — first dot product of a diagonal (step 1),
  DCU   — z-norm Euclidean distance, Eq. 1 (steps 2, 5),
  DPUU  — incremental dot-product update, Eq. 2 (step 4),
  PUU   — profile min/argmin update (steps 3, 6).

TPU adaptation (DESIGN.md §Hardware-Adaptation): a diagonal *chunk* of V
cells is one VMEM tile.  The DPUU's serial chain

    q_k = q_{k-1} - t[i+k-1] t[j+k-1] + t[i+k+m-1] t[j+k+m-1]

is an associative add-scan over the product deltas, so it vectorizes on the
VPU instead of being a 1-element/cycle recurrence; the PUU becomes a
per-chunk min/argmin pre-reduction so only O(1) update candidates leave the
kernel per chunk.  There is no matmul here — matrix profile is a VPU
workload (the paper's roofline, Fig. 4, puts it far left of the ridge) —
so BlockSpec tiling targets VMEM residency, not the MXU.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers to plain HLO that the rust
runtime loads.  Correctness versus ``ref.py`` is enforced by pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["diag_chunk", "dot_init", "DEFAULT_CHUNK"]

# Chunk length V: cells of one diagonal processed per kernel invocation.
# 512 keeps the f64 tile (2*(V+m)+5*V doubles ~ 30 KB at m=256) comfortably
# inside a single VMEM block while amortizing scan startup.
DEFAULT_CHUNK = 512


def _diag_chunk_kernel(
    ta_ref, tb_ref, mu_a_ref, sig_a_ref, mu_b_ref, sig_b_ref, q0_ref, nvalid_ref,
    dists_ref, qlast_ref, minval_ref, minidx_ref,
    *, m: int, v: int,
):
    """Fused DPUU -> DCU -> PUU over one diagonal chunk.

    Refs (all VMEM-resident for the whole invocation):
      ta, tb   : (V+m,) series slices starting one point before the chunk's
                 first windows (Eq. 2 needs t[i-1] and t[i+m-1]).
      mu_*,sig_*: (V,) precomputed window statistics (host-side, Alg. 2 l.2).
      q0       : (1,) dot product of the chunk's first window pair (from the
                 DPU kernel or the previous chunk's q_last).
      nvalid   : (1,) int32 — live cells; the tail chunk of a diagonal is
                 padded to V and masked here.
    Outputs:
      dists    : (V,) z-norm distances (+inf on masked lanes),
      q_last   : (1,) dot product at the last *valid* cell (chunk chaining),
      min_val/min_idx : (1,) PUU pre-reduction over the chunk.
    """
    ta = ta_ref[...]
    tb = tb_ref[...]
    nvalid = nvalid_ref[0]
    k = jax.lax.iota(jnp.int32, v)
    live = k < nvalid

    # --- DPUU: product deltas, then an associative add-scan.  delta_0 = 0
    # (cell 0's q is q0); masked lanes contribute 0 so q_last lands on the
    # last valid cell.
    # ta[x] = t[i0-1+x], so cell k's Eq. 2 terms are
    #   subtract t[i0+k-1] = ta[k]   and   add t[i0+k+m-1] = ta[k+m].
    lo = ta[:v] * tb[:v]
    hi = ta[m : m + v] * tb[m : m + v]
    delta = jnp.where((k >= 1) & live, hi - lo, jnp.zeros_like(lo))
    qs = q0_ref[0] + jnp.cumsum(delta)

    # --- DCU: Eq. 1, clamped for numeric safety; sig==0 (constant window)
    # degenerates to correlation 0 => distance sqrt(2m), as in ref.py.
    mu_a = mu_a_ref[...]
    mu_b = mu_b_ref[...]
    denom = m * sig_a_ref[...] * sig_b_ref[...]
    corr = jnp.where(denom > 0, (qs - m * mu_a * mu_b) / denom, jnp.zeros_like(qs))
    d = jnp.sqrt(jnp.maximum(2.0 * m * (1.0 - corr), 0.0))
    d = jnp.where(live, d, jnp.full_like(d, jnp.inf))

    # --- PUU pre-reduction: the L3 coordinator applies the surviving
    # candidate to both the row and column private profiles.
    midx = jnp.argmin(d).astype(jnp.int32)

    dists_ref[...] = d
    qlast_ref[0] = qs[v - 1]
    minval_ref[0] = d[midx]
    minidx_ref[0] = midx


@functools.partial(jax.jit, static_argnames=("m", "v"))
def diag_chunk(ta, tb, mu_a, sig_a, mu_b, sig_b, q0, nvalid, *, m: int, v: int = DEFAULT_CHUNK):
    """Compute one V-cell diagonal chunk (distances + PUU pre-reduction).

    See ``_diag_chunk_kernel`` for the argument contract and
    ``ref.diag_chunk_ref`` for the semantics oracle.
    """
    dtype = ta.dtype
    return pl.pallas_call(
        functools.partial(_diag_chunk_kernel, m=m, v=v),
        out_shape=(
            jax.ShapeDtypeStruct((v,), dtype),
            jax.ShapeDtypeStruct((1,), dtype),
            jax.ShapeDtypeStruct((1,), dtype),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ),
        interpret=True,
    )(ta, tb, mu_a, sig_a, mu_b, sig_b, q0, nvalid)


def _dot_init_kernel(ta_ref, tb_ref, q_ref):
    """DPU: the O(m) first dot product of a diagonal (Alg. 1 line 7)."""
    q_ref[0] = jnp.sum(ta_ref[...] * tb_ref[...])


@functools.partial(jax.jit, static_argnames=("m",))
def dot_init(ta, tb, *, m: int):
    """Dot product of two length-m windows (the DPU hardware component)."""
    assert ta.shape == (m,) and tb.shape == (m,)
    return pl.pallas_call(
        _dot_init_kernel,
        out_shape=jax.ShapeDtypeStruct((1,), ta.dtype),
        interpret=True,
    )(ta, tb)
