"""Layer-1 Pallas kernel: batched window dot products for a profile tile.

The diagonal kernel (diagonal.py) mirrors NATSA's PU pipeline.  This kernel
is the *other* natural TPU mapping of the same math (DESIGN.md
§Hardware-Adaptation): instead of walking diagonals with a scan, compute a
(TI x TJ) tile of the dot-product matrix as a matmul between two window
matrices — an MXU-shaped formulation used by the quickstart demo artifact
``mp_tile`` and by the design-space ablation (bench `ablate_formulation`).

For a tile anchored at (i0, j0):

    Q[a, b] = W_i[a, :] . W_j[b, :]     (W rows are length-m windows)

which is a (TI, m) x (m, TJ) matmul — MXU work, fp32 accumulation — followed
by the same Eq. 1 distance and an exclusion-zone mask.  The paper's PU has no
use for this shape (its HBM channel feeds 5 GB/s, far below what an MXU
needs), which is exactly the ablation's point: on TPU the crossover moves.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dot_tile", "TILE_I", "TILE_J"]

TILE_I = 128  # MXU-friendly tile edges
TILE_J = 128


def _dot_tile_kernel(wi_ref, wj_ref, q_ref):
    """Q = W_i @ W_j^T with fp32 (or fp64) accumulation on the MXU."""
    q_ref[...] = jnp.dot(
        wi_ref[...], wj_ref[...].T, preferred_element_type=q_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("ti", "tj"))
def dot_tile(wi, wj, *, ti: int = TILE_I, tj: int = TILE_J):
    """(ti, m) x (tj, m) -> (ti, tj) window dot-product tile."""
    assert wi.shape[0] == ti and wj.shape[0] == tj and wi.shape[1] == wj.shape[1]
    return pl.pallas_call(
        _dot_tile_kernel,
        out_shape=jax.ShapeDtypeStruct((ti, tj), wi.dtype),
        interpret=True,
    )(wi, wj)
