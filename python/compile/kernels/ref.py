"""Pure-jnp reference oracle for the matrix profile and its building blocks.

Everything here is deliberately simple and allocation-heavy: it exists only
to check the Pallas kernels (diagonal.py, tile.py) and the L2 model graph at
build time.  Nothing in this file is lowered into artifacts.

Conventions (match the paper, Section 2.1):
  * window (subsequence) length ``m``; a series of length ``n`` has
    ``nw = n - m + 1`` windows.
  * z-normalized Euclidean distance (Eq. 1)::

        d_ij = sqrt(2 m (1 - (q_ij - m mu_i mu_j) / (m sig_i sig_j)))

    with ``q_ij`` the plain dot product of the two windows and ``sigma`` the
    *population* standard deviation (ddof = 0), as in SCRIMP.
  * exclusion zone: ``|i - j| < excl`` is skipped; the paper's default is
    ``excl = m / 4`` (and the main diagonal is always excluded).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "sliding_stats",
    "znorm_distance",
    "distance_matrix",
    "matrix_profile_ref",
    "diag_chunk_ref",
    "dot_init_ref",
    "default_exclusion",
]


def default_exclusion(m: int) -> int:
    """Paper default exclusion zone: m/4 (at least 1 — the main diagonal)."""
    return max(1, m // 4)


def sliding_stats(t, m: int):
    """Mean and population std-dev of every length-``m`` window of ``t``.

    O(n) cumulative-sum formulation, matching the host-side
    ``precalculateMeansDevs`` of Algorithm 1 (line 1).
    Returns ``(mu, sig)`` each of length ``n - m + 1``.
    """
    t = jnp.asarray(t)
    csum = jnp.concatenate([jnp.zeros(1, t.dtype), jnp.cumsum(t)])
    csum2 = jnp.concatenate([jnp.zeros(1, t.dtype), jnp.cumsum(t * t)])
    s = csum[m:] - csum[:-m]
    s2 = csum2[m:] - csum2[:-m]
    mu = s / m
    var = jnp.maximum(s2 / m - mu * mu, 0.0)
    return mu, jnp.sqrt(var)


def znorm_distance(q, m: int, mu_i, sig_i, mu_j, sig_j):
    """Eq. 1 of the paper, numerically clamped at zero.

    ``q`` is the raw dot product of the two windows.  Degenerate (constant)
    windows have ``sig == 0``; following SCAMP convention we define the
    correlation term as 0 there, giving distance ``sqrt(2m)``.
    """
    denom = m * sig_i * sig_j
    corr = jnp.where(denom > 0, (q - m * mu_i * mu_j) / denom, 0.0)
    return jnp.sqrt(jnp.maximum(2.0 * m * (1.0 - corr), 0.0))


def distance_matrix(t, m: int, excl: int | None = None):
    """Full (nw x nw) z-norm distance matrix with the exclusion zone set to
    +inf.  O(n^2 m) memory/compute — small inputs only."""
    t = jnp.asarray(t)
    nw = t.shape[0] - m + 1
    if excl is None:
        excl = default_exclusion(m)
    idx = jnp.arange(nw)
    windows = t[idx[:, None] + jnp.arange(m)[None, :]]  # (nw, m)
    q = windows @ windows.T
    mu, sig = sliding_stats(t, m)
    d = znorm_distance(q, m, mu[:, None], sig[:, None], mu[None, :], sig[None, :])
    ban = jnp.abs(idx[:, None] - idx[None, :]) < excl
    return jnp.where(ban, jnp.inf, d)


def matrix_profile_ref(t, m: int, excl: int | None = None):
    """Brute-force exact matrix profile: ``(P, I)`` per Section 2.1."""
    d = distance_matrix(t, m, excl)
    return jnp.min(d, axis=1), jnp.argmin(d, axis=1)


def dot_init_ref(ta, tb):
    """DPU reference: plain dot product of two length-m windows."""
    return jnp.sum(jnp.asarray(ta) * jnp.asarray(tb))


def diag_chunk_ref(ta, tb, mu_a, sig_a, mu_b, sig_b, q0, m: int, nvalid: int):
    """Reference for the DPUU+DCU+PUU diagonal-chunk kernel.

    Computes ``V = len(mu_a)`` consecutive cells of one diagonal.  Cell ``k``
    is the window pair ``(i0+k, j0+k)``; ``q0`` is the dot product at cell 0;
    ``ta``/``tb`` are the series slices starting at ``i0-1``/``j0-1`` with
    length ``V+m`` (Eq. 2 needs ``t[i-1]`` and ``t[i+m-1]``).

    Returns ``(dists, q_last, min_val, min_idx)`` where cells ``k >= nvalid``
    are masked to +inf and do not advance the dot product.
    """
    ta = jnp.asarray(ta)
    tb = jnp.asarray(tb)
    v = mu_a.shape[0]
    k = jnp.arange(v)
    # delta_k advances q from cell k-1 to cell k (delta_0 = 0: q_0 = q0).
    # With ta[x] = t[i0-1+x], Eq. 2 for cell k subtracts t[i0+k-1] = ta[k]
    # and adds t[i0+k+m-1] = ta[k+m].
    delta = jnp.where(
        (k >= 1) & (k < nvalid),
        ta[k + m] * tb[k + m] - ta[k] * tb[k],
        0.0,
    )
    qs = q0 + jnp.cumsum(delta)
    dists = znorm_distance(qs, m, mu_a, sig_a, mu_b, sig_b)
    dists = jnp.where(k < nvalid, dists, jnp.inf)
    q_last = qs[v - 1]  # deltas beyond nvalid are zeroed => q at last valid cell
    min_idx = jnp.argmin(dists)
    return dists, q_last, dists[min_idx], min_idx
