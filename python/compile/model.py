"""Layer-2 JAX compute graphs — what the host offloads to NATSA.

Each public ``*_fn`` builder returns a jax-jittable function with *concrete*
shapes, ready for ``aot.py`` to lower to HLO text.  The functions call the
Layer-1 Pallas kernels (``kernels.diagonal``, ``kernels.tile``) so the kernel
lowers into the same HLO module the rust runtime loads.

Graphs:
  * ``diag_chunk_fn``  — one PU pipeline step over a V-cell diagonal chunk
                         (the hot-path artifact; one variant per (m, dtype)).
  * ``dot_init_fn``    — the DPU first-dot-product of a diagonal.
  * ``stats_fn``       — host-side mean/std precompute (Alg. 2 line 2) as an
                         offloadable graph for the demo path.
  * ``mp_tile_fn``     — a self-contained small matrix profile built from
                         MXU-shaped dot tiles (quickstart + ablation).

Python here runs at *build time only* (``make artifacts``); the rust binary
never imports it.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import diagonal, tile
from .kernels.ref import default_exclusion, sliding_stats, znorm_distance

__all__ = ["diag_chunk_fn", "dot_init_fn", "stats_fn", "mp_tile_fn", "DTYPES"]

DTYPES = {"f32": jnp.float32, "f64": jnp.float64}


def diag_chunk_fn(m: int, v: int = diagonal.DEFAULT_CHUNK):
    """Builder for the per-chunk PU step.  Signature of the built fn:

    (ta[v+m], tb[v+m], mu_a[v], sig_a[v], mu_b[v], sig_b[v], q0[1],
     nvalid[1]:i32) -> (dists[v], q_last[1], min_val[1], min_idx[1]:i32)
    """

    def fn(ta, tb, mu_a, sig_a, mu_b, sig_b, q0, nvalid):
        return diagonal.diag_chunk(
            ta, tb, mu_a, sig_a, mu_b, sig_b, q0, nvalid, m=m, v=v
        )

    return fn


def dot_init_fn(m: int):
    """Builder for the DPU: (ta[m], tb[m]) -> (q[1],)."""

    def fn(ta, tb):
        return (diagonal.dot_init(ta, tb, m=m),)

    return fn


def stats_fn(m: int):
    """Builder for the window-statistics precompute: T[n] -> (mu, sig)."""

    def fn(t):
        return sliding_stats(t, m)

    return fn


def mp_tile_fn(n: int, m: int, excl: int | None = None, tile_edge: int = tile.TILE_I):
    """Builder for a complete small matrix profile from MXU dot tiles.

    T[n] -> (P[nw_pad], I[nw_pad]:i32) with nw_pad = ceil(nw / tile_edge) *
    tile_edge; padded lanes carry +inf / -1.  The tile loop is unrolled at
    trace time (shapes are static), producing one fused HLO module.
    """
    if excl is None:
        excl = default_exclusion(m)
    nw = n - m + 1
    nt = -(-nw // tile_edge)  # ceil
    nw_pad = nt * tile_edge

    def fn(t):
        dtype = t.dtype
        # Window matrix, padded by clamping starts beyond nw (masked below).
        idx = jnp.arange(nw_pad)
        starts = jnp.minimum(idx, nw - 1)
        w = t[starts[:, None] + jnp.arange(m)[None, :]]
        mu, sig = sliding_stats(t, m)
        mu = mu[starts]
        sig = sig[starts]

        p = jnp.full((nw_pad,), jnp.inf, dtype)
        i_out = jnp.full((nw_pad,), -1, jnp.int32)
        for a in range(nt):
            ra = slice(a * tile_edge, (a + 1) * tile_edge)
            ia = idx[ra]
            best = jnp.full((tile_edge,), jnp.inf, dtype)
            besti = jnp.full((tile_edge,), -1, jnp.int32)
            for b in range(nt):
                rb = slice(b * tile_edge, (b + 1) * tile_edge)
                ib = idx[rb]
                q = tile.dot_tile(w[ra], w[rb], ti=tile_edge, tj=tile_edge)
                d = znorm_distance(
                    q, m,
                    mu[ra][:, None], sig[ra][:, None],
                    mu[rb][None, :], sig[rb][None, :],
                )
                ban = (
                    (jnp.abs(ia[:, None] - ib[None, :]) < excl)
                    | (ia[:, None] >= nw)
                    | (ib[None, :] >= nw)
                )
                d = jnp.where(ban, jnp.inf, d)
                bmin = jnp.min(d, axis=1)
                barg = ib[jnp.argmin(d, axis=1)].astype(jnp.int32)
                upd = bmin < best
                best = jnp.where(upd, bmin, best)
                besti = jnp.where(upd, barg, besti)
            p = p.at[ra].set(best)
            i_out = i_out.at[ra].set(besti)
        return p, i_out

    return fn
