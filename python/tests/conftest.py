"""Shared pytest config: enable x64 before anything imports jax.numpy."""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0xA75A)  # NATSA
