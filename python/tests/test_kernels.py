"""Pallas kernels vs the ref.py oracle — the core L1 correctness signal.

Hypothesis sweeps shapes, dtypes, chunk validity masks and degenerate inputs;
every case asserts allclose against the pure-jnp reference.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import diagonal, ref, tile

DTYPES = [np.float32, np.float64]


def tol(dtype):
    return dict(rtol=2e-5, atol=2e-5) if dtype == np.float32 else dict(rtol=1e-9, atol=1e-9)


def make_chunk_case(rng, n, m, v, diag, i0, nvalid, dtype):
    """Slice a random series into the diag_chunk argument tuple."""
    t = rng.standard_normal(n).astype(dtype)
    mu, sig = ref.sliding_stats(t, m)
    j0 = i0 + diag
    ta = t[i0 - 1 : i0 - 1 + v + m]
    tb = t[j0 - 1 : j0 - 1 + v + m]
    # pad tail slices to fixed kernel shape
    ta = np.pad(ta, (0, v + m - len(ta)))
    tb = np.pad(tb, (0, v + m - len(tb)))
    pad = lambda x: np.pad(np.asarray(x, dtype), (0, max(0, v - len(x))))[:v]
    mu_a, sig_a = pad(mu[i0 : i0 + v]), pad(sig[i0 : i0 + v])
    mu_b, sig_b = pad(mu[j0 : j0 + v]), pad(sig[j0 : j0 + v])
    q0 = np.array([t[i0 : i0 + m] @ t[j0 : j0 + m]], dtype)
    return t, (
        jnp.asarray(ta), jnp.asarray(tb),
        jnp.asarray(mu_a), jnp.asarray(sig_a),
        jnp.asarray(mu_b), jnp.asarray(sig_b),
        jnp.asarray(q0), jnp.asarray([nvalid], jnp.int32),
    )


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("m,v", [(8, 32), (16, 64), (32, 128)])
def test_diag_chunk_matches_ref(rng, dtype, m, v):
    n = v + 3 * m + 10
    i0, diag = 1, m  # j0 = i0 + m, outside exclusion
    nvalid = v
    _, args = make_chunk_case(rng, n, m, v, diag, i0, nvalid, dtype)
    got = diagonal.diag_chunk(*args, m=m, v=v)
    want = ref.diag_chunk_ref(*args[:7], m=m, nvalid=nvalid)
    for g, w in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(g).ravel(), np.asarray(w).ravel(), **tol(dtype)
        )


@pytest.mark.parametrize("dtype", DTYPES)
def test_diag_chunk_distances_match_bruteforce(rng, dtype):
    """End-to-end: chunk distances equal the explicit z-norm distances."""
    m, v = 16, 64
    n = 3 * v
    t = rng.standard_normal(n).astype(dtype)
    mu, sig = ref.sliding_stats(t, m)
    i0, diag = 1, 40
    j0 = i0 + diag
    nv = min(v, (n - m + 1) - j0)
    _, args = make_chunk_case_from(t, mu, sig, m, v, i0, j0, nv, dtype)
    dists = np.asarray(diagonal.diag_chunk(*args, m=m, v=v)[0])
    d_full = np.asarray(ref.distance_matrix(t, m, excl=1))
    for k in range(nv):
        np.testing.assert_allclose(dists[k], d_full[i0 + k, j0 + k], **tol(dtype))
    assert np.all(np.isinf(dists[nv:]))


def make_chunk_case_from(t, mu, sig, m, v, i0, j0, nvalid, dtype):
    ta = np.pad(t[i0 - 1 : i0 - 1 + v + m], (0, 0))
    tb = np.pad(t[j0 - 1 : j0 - 1 + v + m], (0, 0))
    ta = np.pad(ta, (0, v + m - len(ta)))
    tb = np.pad(tb, (0, v + m - len(tb)))
    pad = lambda x: np.pad(np.asarray(x, dtype), (0, max(0, v - len(x))))[:v]
    return None, (
        jnp.asarray(ta), jnp.asarray(tb),
        pad(mu[i0 : i0 + v]), pad(sig[i0 : i0 + v]),
        pad(mu[j0 : j0 + v]), pad(sig[j0 : j0 + v]),
        jnp.asarray([t[i0 : i0 + m] @ t[j0 : j0 + m]], dtype),
        jnp.asarray([nvalid], jnp.int32),
    )


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([4, 8, 16]),
    v=st.sampled_from([16, 32, 64]),
    nvalid_frac=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**31 - 1),
    f64=st.booleans(),
)
def test_diag_chunk_hypothesis(m, v, nvalid_frac, seed, f64):
    """Property sweep: arbitrary (m, v, mask, dtype) chunks match the oracle."""
    dtype = np.float64 if f64 else np.float32
    rng = np.random.default_rng(seed)
    nvalid = max(1, int(v * nvalid_frac))
    n = v + 3 * m + 8
    _, args = make_chunk_case(rng, n, m, v, m, 1, nvalid, dtype)
    got = diagonal.diag_chunk(*args, m=m, v=v)
    want = ref.diag_chunk_ref(*args[:7], m=m, nvalid=nvalid)
    np.testing.assert_allclose(
        np.asarray(got[0])[:nvalid], np.asarray(want[0])[:nvalid], **tol(dtype)
    )
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]), **tol(dtype))
    assert int(got[3][0]) == int(want[3])


def test_diag_chunk_qlast_chains_chunks(rng):
    """q_last of chunk k must equal q0 of chunk k+1 computed from scratch."""
    m, v = 16, 32
    dtype = np.float64
    n = 4 * v + 2 * m
    t = rng.standard_normal(n).astype(dtype)
    mu, sig = ref.sliding_stats(t, m)
    i0, j0 = 1, 1 + m
    _, args = make_chunk_case_from(t, mu, sig, m, v, i0, j0, v, dtype)
    q_last = float(diagonal.diag_chunk(*args, m=m, v=v)[1][0])
    # q at cell v-1 is the dot product of windows (i0+v-1, j0+v-1);
    # the next chunk starts at (i0+v, j0+v) whose q0 is one Eq.2 step away.
    i1, j1 = i0 + v - 1, j0 + v - 1
    q_direct = t[i1 : i1 + m] @ t[j1 : j1 + m]
    np.testing.assert_allclose(q_last, q_direct, rtol=1e-9)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("m", [4, 16, 64, 256])
def test_dot_init_matches_ref(rng, dtype, m):
    ta = jnp.asarray(rng.standard_normal(m).astype(dtype))
    tb = jnp.asarray(rng.standard_normal(m).astype(dtype))
    got = np.asarray(diagonal.dot_init(ta, tb, m=m))[0]
    want = float(ref.dot_init_ref(ta, tb))
    np.testing.assert_allclose(got, want, **tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
def test_dot_tile_matches_matmul(rng, dtype):
    m = 32
    wi = jnp.asarray(rng.standard_normal((tile.TILE_I, m)).astype(dtype))
    wj = jnp.asarray(rng.standard_normal((tile.TILE_J, m)).astype(dtype))
    got = np.asarray(tile.dot_tile(wi, wj))
    want = np.asarray(wi) @ np.asarray(wj).T
    np.testing.assert_allclose(got, want, **tol(dtype))


def test_diag_chunk_constant_window_safe(rng):
    """A zero-variance window inside the chunk must not produce NaN."""
    m, v = 8, 32
    dtype = np.float64
    n = v + 3 * m + 8
    t = rng.standard_normal(n)
    t[5 : 5 + m + 4] = 1.5  # flat region spanning several windows
    t = t.astype(dtype)
    mu, sig = ref.sliding_stats(t, m)
    _, args = make_chunk_case_from(t, mu, sig, m, v, 1, 1 + m, v, dtype)
    dists = np.asarray(diagonal.diag_chunk(*args, m=m, v=v)[0])
    assert not np.any(np.isnan(dists))
