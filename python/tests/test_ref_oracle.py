"""Self-checks of the pure-jnp oracle (ref.py) against a from-scratch numpy
implementation.  If the oracle is wrong everything downstream is wrong, so it
gets its own independently-written cross-check."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref


def numpy_stats(t, m):
    nw = len(t) - m + 1
    mu = np.array([t[i : i + m].mean() for i in range(nw)])
    sig = np.array([t[i : i + m].std() for i in range(nw)])
    return mu, sig


def numpy_profile(t, m, excl):
    """Textbook O(n^2 m): z-normalize every window pair explicitly."""
    nw = len(t) - m + 1
    p = np.full(nw, np.inf)
    idx = np.full(nw, -1)
    for i in range(nw):
        wi = t[i : i + m]
        si = wi.std()
        zi = (wi - wi.mean()) / si if si > 0 else np.zeros(m)
        for j in range(nw):
            if abs(i - j) < excl:
                continue
            wj = t[j : j + m]
            sj = wj.std()
            zj = (wj - wj.mean()) / sj if sj > 0 else np.zeros(m)
            d = np.sqrt(((zi - zj) ** 2).sum())
            if d < p[i]:
                p[i] = d
                idx[i] = j
    return p, idx


@pytest.mark.parametrize("n,m", [(64, 8), (100, 12), (128, 16)])
def test_sliding_stats_match_numpy(rng, n, m):
    t = rng.standard_normal(n)
    mu, sig = ref.sliding_stats(t, m)
    mu_np, sig_np = numpy_stats(t, m)
    np.testing.assert_allclose(np.asarray(mu), mu_np, rtol=1e-10)
    np.testing.assert_allclose(np.asarray(sig), sig_np, rtol=1e-8)


@pytest.mark.parametrize("n,m", [(64, 8), (96, 16)])
def test_profile_matches_textbook(rng, n, m):
    t = rng.standard_normal(n)
    excl = ref.default_exclusion(m)
    p, i = ref.matrix_profile_ref(t, m)
    p_np, i_np = numpy_profile(t, m, excl)
    np.testing.assert_allclose(np.asarray(p), p_np, rtol=1e-6, atol=1e-8)
    # argmin ties can differ; require the distances at the chosen indices match
    d = np.asarray(ref.distance_matrix(t, m))
    np.testing.assert_allclose(
        d[np.arange(len(p)), np.asarray(i)], p_np, rtol=1e-6, atol=1e-8
    )


def test_profile_symmetric_envelope(rng):
    """P_i is a min over a symmetric matrix => P is invariant to transposition."""
    t = rng.standard_normal(80)
    d = np.asarray(ref.distance_matrix(t, 8))
    np.testing.assert_allclose(d, d.T, rtol=1e-8, atol=1e-10)


def test_exclusion_zone_is_banned(rng):
    t = rng.standard_normal(64)
    m = 8
    excl = ref.default_exclusion(m)
    d = np.asarray(ref.distance_matrix(t, m, excl))
    nw = 64 - m + 1
    ii, jj = np.meshgrid(np.arange(nw), np.arange(nw), indexing="ij")
    assert np.all(np.isinf(d[np.abs(ii - jj) < excl]))


def test_constant_window_degenerates_to_sqrt_2m(rng):
    """sig == 0 windows take correlation 0 => distance sqrt(2m)."""
    m = 8
    t = rng.standard_normal(48)
    t[10 : 10 + m] = 3.0  # constant window at index 10
    d = np.asarray(ref.distance_matrix(t, m))
    row = d[10]
    finite = row[np.isfinite(row)]
    np.testing.assert_allclose(finite, np.sqrt(2 * m), rtol=1e-6)


def test_motif_pair_is_found(rng):
    """Planting an identical pair of windows must produce ~0 profile there."""
    t = rng.standard_normal(200)
    m = 16
    t[120 : 120 + m] = t[30 : 30 + m]  # plant exact motif
    p, i = ref.matrix_profile_ref(t, m)
    p = np.asarray(p)
    i = np.asarray(i)
    assert p[30] < 1e-5 and p[120] < 1e-5
    assert i[30] == 120 and i[120] == 30


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(40, 120),
    m=st.integers(4, 20),
    seed=st.integers(0, 2**31 - 1),
)
def test_incremental_dot_product_identity(n, m, seed):
    """Eq. 2: Q_{i,j} = Q_{i-1,j-1} - t_{i-1} t_{j-1} + t_{i+m-1} t_{j+m-1}."""
    t = np.random.default_rng(seed).standard_normal(n)
    nw = n - m + 1
    for i, j in [(1, 5), (2, nw - 1), (3, m)]:
        if j >= nw or i >= nw or j < 1 or i < 1:
            continue
        q_prev = t[i - 1 : i - 1 + m] @ t[j - 1 : j - 1 + m]
        q_inc = q_prev - t[i - 1] * t[j - 1] + t[i + m - 1] * t[j + m - 1]
        q_dir = t[i : i + m] @ t[j : j + m]
        np.testing.assert_allclose(q_inc, q_dir, rtol=1e-9, atol=1e-9)
