"""Layer-2 graph checks: model.py functions vs the oracle, shape contracts,
and the AOT plan's internal consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


@pytest.mark.parametrize("dname", ["f32", "f64"])
def test_stats_fn(rng, dname):
    dtype = model.DTYPES[dname]
    t = jnp.asarray(rng.standard_normal(512), dtype)
    mu, sig = jax.jit(model.stats_fn(64))(t)
    mu_r, sig_r = ref.sliding_stats(t, 64)
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mu_r), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(sig), np.asarray(sig_r), rtol=1e-4)


@pytest.mark.parametrize("dname", ["f32", "f64"])
def test_mp_tile_matches_bruteforce(rng, dname):
    """The MXU-tile full profile equals the brute-force oracle on small n."""
    dtype = model.DTYPES[dname]
    n, m, edge = 300, 16, 64
    t = jnp.asarray(rng.standard_normal(n), dtype)
    p, i = jax.jit(model.mp_tile_fn(n, m, tile_edge=edge))(t)
    p_ref, _ = ref.matrix_profile_ref(t, m)
    nw = n - m + 1
    rtol = 1e-3 if dname == "f32" else 1e-8
    np.testing.assert_allclose(
        np.asarray(p)[:nw], np.asarray(p_ref), rtol=rtol, atol=1e-4
    )
    # padded lanes must be inert
    assert np.all(np.isinf(np.asarray(p)[nw:]))
    assert np.all(np.asarray(i)[nw:] == -1)
    # indices respect the exclusion zone
    ii = np.asarray(i)[:nw]
    excl = ref.default_exclusion(m)
    assert np.all(np.abs(ii - np.arange(nw)) >= excl)


def test_mp_tile_finds_planted_motif(rng):
    n, m = 300, 16
    t = rng.standard_normal(n)
    t[200 : 200 + m] = t[50 : 50 + m]
    p, i = jax.jit(model.mp_tile_fn(n, m, tile_edge=64))(jnp.asarray(t))
    p = np.asarray(p)
    i = np.asarray(i)
    assert p[50] < 1e-4 and p[200] < 1e-4
    assert i[50] == 200 and i[200] == 50


def test_diag_chunk_fn_signature():
    """The AOT'd chunk signature must match what the rust runtime feeds."""
    m, v = 32, 512
    fn = jax.jit(model.diag_chunk_fn(m, v))
    specs = (
        jax.ShapeDtypeStruct((v + m,), jnp.float32),
        jax.ShapeDtypeStruct((v + m,), jnp.float32),
        jax.ShapeDtypeStruct((v,), jnp.float32),
        jax.ShapeDtypeStruct((v,), jnp.float32),
        jax.ShapeDtypeStruct((v,), jnp.float32),
        jax.ShapeDtypeStruct((v,), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.int32),
    )
    out = jax.eval_shape(fn, *specs)
    assert out[0].shape == (v,) and out[0].dtype == jnp.float32
    assert out[1].shape == (1,)
    assert out[2].shape == (1,)
    assert out[3].shape == (1,) and out[3].dtype == jnp.int32


def test_aot_plan_complete_and_unique():
    plan = list(aot.build_plan())
    names = [p[0] for p in plan]
    assert len(names) == len(set(names))
    kinds = {p[3]["kind"] for p in plan}
    assert kinds == {"diag_chunk", "dot_init", "stats", "mp_tile"}
    # every (dtype, m) pair present for the hot-path kernel
    for dname in model.DTYPES:
        for m in aot.WINDOW_SIZES:
            assert any(n.startswith(f"diag_chunk_{dname}_m{m}_v") for n in names)
            assert f"dot_init_{dname}_m{m}" in names


def test_aot_hlo_text_is_parseable_text():
    """Lower the smallest artifact and sanity-check the HLO text format."""
    m = 32
    fn = model.dot_init_fn(m)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((m,), jnp.float32),
        jax.ShapeDtypeStruct((m,), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text and "ENTRY" in text
    assert "f32[32]" in text
