//! `natsa-lint` — the repo's custom concurrency-invariant scanner.
//!
//! CI runs it over the tree (`cargo run --manifest-path
//! tools/lint/Cargo.toml -- .` from the repo root) and fails the build
//! on any finding.  Five rule classes, each guarding an invariant the
//! loom models and `docs/CONCURRENCY.md` rely on:
//!
//! * **naked_lock** — no `.lock().unwrap()` / `.lock().expect(` /
//!   RwLock unwraps in `rust/src` outside `rust/src/sync.rs`: every
//!   acquisition goes through `crate::sync::lock_ok` so the poison
//!   policy (and the loom swap) lives in exactly one place.
//! * **naked_wait** — same for Condvar waits: `wait_ok` /
//!   `wait_timeout_ok` only.
//! * **lock_order** — in the coordinator's locking modules
//!   (`service.rs`, `router.rs`, `migrate.rs`, `admission.rs`),
//!   classified locks must be acquired in strictly ascending hierarchy
//!   order (`streams` map → `entry.submit_seq` → `entry.state` → shard
//!   `subs` index; `slots`, the WAL cell, and the router's
//!   `route_table` are leaves — `route_table` is the highest class, so
//!   it may be taken under anything but nothing under it).
//!   `try_lock_ok` is exempt — it cannot deadlock, which is exactly
//!   why the group pass uses it.
//! * **instant_arith** — no raw `Instant` arithmetic (`+`/`-`,
//!   `.duration_since(`): only `checked_add` /
//!   `saturating_duration_since`, so a stale deadline times out instead
//!   of panicking on underflow.
//! * **hot_sqrt** — no `.sqrt()` in the non-test code of
//!   `mp/kernel.rs` / `mp/stampi.rs`: the deferred-sqrt contract keeps
//!   hot-path distances squared (one sqrt per *snapshot*, never per
//!   cell).
//!
//! Suppression: a `natsa-lint: allow(rule_name)` comment on the
//! finding's line or the line above skips it (use sparingly, with a
//! why-comment — `mp/stampi.rs` stats seeding is the precedent).
//! `#[cfg(test)]` / `#[cfg(all(test, ...))]` module bodies are exempt
//! from every rule except `instant_arith`.
//!
//! Design note: this is a line-level scanner over comment-stripped,
//! string-blanked source, not a `syn` AST pass — the build container
//! has no network, so the tool must compile from std alone.  The
//! patterns are chosen so that false positives are impossible on the
//! current tree (see the `whole_tree_is_clean` self-test) and false
//! negatives require actively obfuscated code, which review catches.
//! Known limits: string literals spanning lines, and a guard bound and
//! scope-closed on one line, are not modeled.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

/// Directories scanned, relative to the repo root.
const SCAN_DIRS: &[&str] = &["rust/src", "rust/tests", "benches", "examples", "tools/lint/src"];

/// The service lock hierarchy: acquisition order must be strictly
/// ascending in class.  Field names are how acquisitions are
/// classified (`lock_ok(&shard.streams)` → `streams`); unlisted names
/// (`cell`, `rx`, ...) are unclassified and ignored.
const LOCK_CLASSES: &[(&str, u8)] = &[
    ("streams", 10),
    ("submit_seq", 20),
    ("state", 30),
    ("subs", 40),
    ("slots", 50),       // leaf: never held across another classified acquire
    ("route_table", 60), // router leaf: taken under anything, nothing under it
];

/// Files the `lock_order` rule runs over: every module that acquires
/// classified coordinator locks.
const LOCK_ORDER_FILES: &[&str] = &[
    "rust/src/coordinator/service.rs",
    "rust/src/coordinator/router.rs",
    "rust/src/coordinator/migrate.rs",
    "rust/src/coordinator/admission.rs",
];

#[derive(Debug)]
struct Finding {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

fn main() {
    let root = std::env::args().nth(1).map(PathBuf::from).unwrap_or_else(|| PathBuf::from("."));
    match scan_tree(&root) {
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            if findings.is_empty() {
                println!("natsa-lint: tree clean");
            } else {
                eprintln!("natsa-lint: {} violation(s)", findings.len());
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("natsa-lint: {e}");
            std::process::exit(2);
        }
    }
}

fn scan_tree(root: &Path) -> std::io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    for dir in SCAN_DIRS {
        collect_rs(&root.join(dir), &mut files)?;
    }
    files.sort();
    let mut findings = Vec::new();
    for path in files {
        let content = fs::read_to_string(&path)?;
        let rel = path.strip_prefix(root).unwrap_or(&path).to_string_lossy().replace('\\', "/");
        findings.extend(scan_source(&rel, &content));
    }
    Ok(findings)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    if !dir.exists() {
        return Ok(());
    }
    for entry in fs::read_dir(dir)? {
        let p = entry?.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Sanitization: comments out, string/char contents blanked, allow
// markers extracted.
// ---------------------------------------------------------------------

struct Line {
    /// Source with comments removed and literal contents blanked — all
    /// pattern matching runs on this.
    code: String,
    /// Rules allowed on (this line or the next): `natsa-lint: allow(x)`.
    allows: Vec<String>,
}

fn sanitize(content: &str) -> Vec<Line> {
    let mut out = Vec::new();
    let mut in_block_comment = false;
    for raw in content.lines() {
        let mut allows = Vec::new();
        extract_allows(raw, &mut allows);
        let chars: Vec<char> = raw.chars().collect();
        let mut code = String::with_capacity(raw.len());
        let mut i = 0;
        while i < chars.len() {
            if in_block_comment {
                if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    in_block_comment = false;
                    i += 2;
                } else {
                    i += 1;
                }
                continue;
            }
            match chars[i] {
                '/' if chars.get(i + 1) == Some(&'/') => break,
                '/' if chars.get(i + 1) == Some(&'*') => {
                    in_block_comment = true;
                    i += 2;
                }
                '"' => {
                    // blank the contents, keep the quotes
                    code.push('"');
                    i += 1;
                    while i < chars.len() {
                        match chars[i] {
                            '\\' => i += 2,
                            '"' => break,
                            _ => i += 1,
                        }
                    }
                    code.push('"');
                    i += 1;
                }
                '\'' => {
                    // char literal ('x' / '\n') vs lifetime ('a): only
                    // the literal closes within a few chars
                    if chars.get(i + 1) == Some(&'\\') {
                        code.push_str("' '");
                        i += 4;
                    } else if chars.get(i + 2) == Some(&'\'') {
                        code.push_str("' '");
                        i += 3;
                    } else {
                        code.push('\'');
                        i += 1;
                    }
                }
                c => {
                    code.push(c);
                    i += 1;
                }
            }
        }
        out.push(Line { code, allows });
    }
    out
}

fn extract_allows(raw: &str, out: &mut Vec<String>) {
    const MARKER: &str = "natsa-lint: allow(";
    let mut rest = raw;
    while let Some(pos) = rest.find(MARKER) {
        let after = &rest[pos + MARKER.len()..];
        match after.find(')') {
            Some(end) => {
                out.push(after[..end].trim().to_string());
                rest = &after[end..];
            }
            None => break,
        }
    }
}

/// Lines inside `#[cfg(test)]` / `#[cfg(all(test, ...))]` items.
fn test_region_mask(lines: &[Line]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        let code = &lines[i].code;
        if code.contains("#[cfg(test)]") || code.contains("#[cfg(all(test") {
            let mut depth = 0i32;
            let mut opened = false;
            let mut j = i;
            while j < lines.len() {
                mask[j] = true;
                for c in lines[j].code.chars() {
                    match c {
                        '{' => {
                            depth += 1;
                            opened = true;
                        }
                        '}' => depth -= 1,
                        _ => {}
                    }
                }
                if opened && depth <= 0 {
                    break;
                }
                j += 1;
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    mask
}

fn allowed(lines: &[Line], i: usize, rule: &str) -> bool {
    lines[i].allows.iter().any(|a| a == rule)
        || (i > 0 && lines[i - 1].allows.iter().any(|a| a == rule))
}

fn squash(s: &str) -> String {
    s.chars().filter(|c| !c.is_whitespace()).collect()
}

fn find_all(hay: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut start = 0;
    while let Some(p) = hay.get(start..).and_then(|h| h.find(needle)) {
        out.push(start + p);
        start += p + 1;
    }
    out
}

/// True when `pat` occurs starting within line `i` (rustfmt may split a
/// method chain, so the window extends into line `i + 1`).
fn matches_window(lines: &[Line], i: usize, pat: &str) -> bool {
    let cur = squash(&lines[i].code);
    let next = lines.get(i + 1).map(|l| squash(&l.code)).unwrap_or_default();
    let win = format!("{cur}{next}");
    find_all(&win, pat).iter().any(|&p| p < cur.len())
}

// ---------------------------------------------------------------------
// The rules.
// ---------------------------------------------------------------------

fn scan_source(rel: &str, content: &str) -> Vec<Finding> {
    let lines = sanitize(content);
    let mask = test_region_mask(&lines);
    let mut findings = Vec::new();

    let in_src = rel.starts_with("rust/src/");
    let naked_scope = in_src && rel != "rust/src/sync.rs";
    let hot_scope = rel == "rust/src/mp/kernel.rs" || rel == "rust/src/mp/stampi.rs";

    for (i, line) in lines.iter().enumerate() {
        if naked_scope && !mask[i] && !allowed(&lines, i, "naked_lock") {
            for pat in [".lock().unwrap()", ".lock().expect(", ".read().unwrap()", ".write().unwrap()"]
            {
                if matches_window(&lines, i, pat) {
                    findings.push(Finding {
                        file: rel.to_string(),
                        line: i + 1,
                        rule: "naked_lock",
                        msg: format!(
                            "`{pat}` — acquire through crate::sync::lock_ok so the poison \
                             policy (and the loom swap) lives in one place"
                        ),
                    });
                    break;
                }
            }
        }
        if naked_scope && !mask[i] && !allowed(&lines, i, "naked_wait") {
            let cur = squash(&line.code);
            let next = lines.get(i + 1).map(|l| squash(&l.code)).unwrap_or_default();
            let win = format!("{cur}{next}");
            let hit = [".wait(", ".wait_timeout("].iter().any(|pat| {
                find_all(&win, pat).iter().any(|&p| {
                    p < cur.len() && win.get(p..).is_some_and(|t| t.contains(".unwrap()"))
                })
            });
            if hit {
                findings.push(Finding {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: "naked_wait",
                    msg: "Condvar wait unwrap — use crate::sync::wait_ok / wait_timeout_ok"
                        .to_string(),
                });
            }
        }
        if !allowed(&lines, i, "instant_arith") {
            let cur = squash(&line.code);
            for pat in
                [".duration_since(", "Instant::now()+", "Instant::now()-", "+Instant::now()", "-Instant::now()"]
            {
                if cur.contains(pat) {
                    findings.push(Finding {
                        file: rel.to_string(),
                        line: i + 1,
                        rule: "instant_arith",
                        msg: format!(
                            "`{pat}` — raw Instant arithmetic panics on underflow/overflow; \
                             use checked_add / saturating_duration_since"
                        ),
                    });
                    break;
                }
            }
        }
        if hot_scope
            && !mask[i]
            && !allowed(&lines, i, "hot_sqrt")
            && matches_window(&lines, i, ".sqrt()")
        {
            findings.push(Finding {
                file: rel.to_string(),
                line: i + 1,
                rule: "hot_sqrt",
                msg: "sqrt on a kernel hot path — the deferred-sqrt contract keeps distances \
                      squared (one sqrt per snapshot via sqrt_in_place)"
                    .to_string(),
            });
        }
    }

    if LOCK_ORDER_FILES.contains(&rel) {
        scan_lock_order(rel, &lines, &mask, &mut findings);
    }

    findings.sort_by_key(|f| f.line);
    findings
}

struct Guard {
    name: String,
    class: u8,
    depth: i32,
}

/// Linear scan of the service for hierarchy-descending acquisitions.
///
/// A *guard binding* is a line of the exact shape
/// `let [mut] name = lock_ok(&path);` — the guard is considered held
/// until `drop(name)` or the end of its brace scope.  Chained
/// temporaries (`lock_ok(&x).get(..)`) acquire and release within the
/// statement: they are order-checked but never held.  `try_lock_ok` is
/// exempt by construction (the pattern requires a word boundary).
fn scan_lock_order(rel: &str, lines: &[Line], mask: &[bool], findings: &mut Vec<Finding>) {
    let mut depth = 0i32;
    let mut held: Vec<Guard> = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        let code = squash(&line.code);
        for p in find_all(&code, "drop(") {
            if p > 0 {
                let prev = code.as_bytes()[p - 1];
                if prev.is_ascii_alphanumeric() || prev == b'_' {
                    continue;
                }
            }
            if let Some(end) = code[p + 5..].find(')') {
                let name = &code[p + 5..p + 5 + end];
                held.retain(|g| g.name != name);
            }
        }
        for p in find_all(&code, "lock_ok(") {
            if p > 0 {
                let prev = code.as_bytes()[p - 1];
                if prev.is_ascii_alphanumeric() || prev == b'_' {
                    continue; // try_lock_ok(...) or another identifier
                }
            }
            let arg_start = p + "lock_ok(".len();
            let Some(rel_end) = code[arg_start..].find(')') else { continue };
            let arg_end = arg_start + rel_end;
            let field = code[arg_start..arg_end]
                .trim_start_matches('&')
                .rsplit(['.', ':'])
                .next()
                .unwrap_or("")
                .to_string();
            let Some(&(cname, class)) = LOCK_CLASSES.iter().find(|&&(n, _)| n == field) else {
                continue;
            };
            if !mask[i] && !allowed(lines, i, "lock_order") {
                if let Some(worst) = held.iter().filter(|g| g.class >= class).max_by_key(|g| g.class)
                {
                    findings.push(Finding {
                        file: rel.to_string(),
                        line: i + 1,
                        rule: "lock_order",
                        msg: format!(
                            "acquires `{cname}` (class {class}) while `{}` (class {}) is held — \
                             hierarchy is streams < submit_seq < state < subs, slots and \
                             route_table leaves (docs/CONCURRENCY.md)",
                            worst.name, worst.class
                        ),
                    });
                }
            }
            // held only when the lock_ok call is the entire rhs of a let
            if code.get(arg_end..) == Some(");") {
                if let Some(name) = binding_name(&code[..p]) {
                    held.push(Guard { name, class, depth });
                }
            }
        }
        for c in code.chars() {
            match c {
                '{' => depth += 1,
                '}' => depth -= 1,
                _ => {}
            }
        }
        held.retain(|g| g.depth <= depth);
    }
}

/// `let[mut]NAME=` (squashed) → `NAME`.
fn binding_name(before: &str) -> Option<String> {
    let rest = before.strip_prefix("let")?;
    let rest = rest.strip_prefix("mut").unwrap_or(rest);
    let name = rest.strip_suffix('=')?;
    if name.is_empty() || !name.chars().all(|c| c.is_alphanumeric() || c == '_') {
        return None;
    }
    Some(name.to_string())
}

// ---------------------------------------------------------------------
// Self-tests: one deliberate violation per rule class must be caught,
// exemptions must hold, and the repo tree must scan clean.
// ---------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(rel: &str, src: &str) -> Vec<&'static str> {
        scan_source(rel, src).iter().map(|f| f.rule).collect()
    }

    #[test]
    fn naked_lock_caught_outside_sync_facade() {
        let src = "fn f() {\n    let _ = m.lock().unwrap();\n}";
        assert_eq!(rules("rust/src/coordinator/metrics.rs", src), vec!["naked_lock"]);
        assert!(rules("rust/src/sync.rs", src).is_empty(), "sync.rs owns the poison policy");
        assert!(rules("rust/tests/x.rs", src).is_empty(), "scope is rust/src only");
        let split = "fn f() {\n    let _ = m.lock()\n        .unwrap();\n}";
        assert_eq!(rules("rust/src/a.rs", split), vec!["naked_lock"], "rustfmt-split chain");
        let rw = "fn f() {\n    let _ = m.read().unwrap();\n}";
        assert_eq!(rules("rust/src/a.rs", rw), vec!["naked_lock"]);
    }

    #[test]
    fn naked_lock_marker_and_test_mod_exempt() {
        let marked = "fn f() {\n    // natsa-lint: allow(naked_lock)\n    let _ = m.lock().unwrap();\n}";
        assert!(rules("rust/src/a.rs", marked).is_empty());
        let tested = "#[cfg(test)]\nmod tests {\n    fn f() { let _ = m.lock().unwrap(); }\n}";
        assert!(rules("rust/src/a.rs", tested).is_empty());
        let tested2 =
            "#[cfg(all(test, not(loom)))]\nmod tests {\n    fn f() { let _ = m.lock().unwrap(); }\n}";
        assert!(rules("rust/src/a.rs", tested2).is_empty());
    }

    #[test]
    fn naked_wait_caught() {
        let src = "fn f() {\n    let g = cv.wait(g).unwrap();\n}";
        assert_eq!(rules("rust/src/a.rs", src), vec!["naked_wait"]);
        let to = "fn f() {\n    let (g, _) = cv.wait_timeout(g, d).unwrap();\n}";
        assert_eq!(rules("rust/src/a.rs", to), vec!["naked_wait"]);
        let ok = "fn f() {\n    let g = wait_ok(&cv, g);\n}";
        assert!(rules("rust/src/a.rs", ok).is_empty());
    }

    #[test]
    fn lock_order_descent_caught_ascent_clean() {
        let descent = "fn f() {\n    let st = lock_ok(&e.state);\n    let g = lock_ok(&e.submit_seq);\n}";
        assert_eq!(rules("rust/src/coordinator/service.rs", descent), vec!["lock_order"]);
        let ascent = "fn f() {\n    let g = lock_ok(&e.submit_seq);\n    let st = lock_ok(&e.state);\n}";
        assert!(rules("rust/src/coordinator/service.rs", ascent).is_empty());
        // the same text is not the service's protocol elsewhere
        assert!(rules("rust/src/coordinator/mod.rs", descent).is_empty());
    }

    #[test]
    fn lock_order_release_paths_clean() {
        let dropped = "fn f() {\n    let st = lock_ok(&e.state);\n    drop(st);\n    let g = lock_ok(&e.submit_seq);\n}";
        assert!(rules("rust/src/coordinator/service.rs", dropped).is_empty());
        let scoped = "fn f() {\n    {\n        let st = lock_ok(&e.state);\n    }\n    let g = lock_ok(&e.submit_seq);\n}";
        assert!(rules("rust/src/coordinator/service.rs", scoped).is_empty());
        let try_exempt = "fn f() {\n    let st = lock_ok(&e.state);\n    let g = try_lock_ok(&e.submit_seq);\n}";
        assert!(rules("rust/src/coordinator/service.rs", try_exempt).is_empty());
        // chained temporaries are order-checked but not held
        let temp = "fn f() {\n    lock_ok(&shard.streams).insert(id, entry);\n    let st = lock_ok(&e.state);\n    let _n = lock_ok(&shard.subs).len();\n}";
        assert!(rules("rust/src/coordinator/service.rs", temp).is_empty());
        let temp_descent = "fn f() {\n    let st = lock_ok(&e.state);\n    lock_ok(&shard.streams).remove(&id);\n}";
        assert_eq!(rules("rust/src/coordinator/service.rs", temp_descent), vec!["lock_order"]);
    }

    #[test]
    fn route_table_is_the_top_of_the_hierarchy() {
        // nothing may be acquired while the route table is held …
        let descent =
            "fn f() {\n    let t = lock_ok(&self.route_table);\n    let st = lock_ok(&e.state);\n}";
        assert_eq!(rules("rust/src/coordinator/router.rs", descent), vec!["lock_order"]);
        // … but it may be taken under anything (it is the leaf)
        let ascent =
            "fn f() {\n    let st = lock_ok(&e.state);\n    let t = lock_ok(&self.route_table);\n}";
        assert!(rules("rust/src/coordinator/router.rs", ascent).is_empty());
        // the rule covers every coordinator locking module, not just
        // the service
        assert_eq!(rules("rust/src/coordinator/migrate.rs", descent), vec!["lock_order"]);
        assert_eq!(rules("rust/src/coordinator/admission.rs", descent), vec!["lock_order"]);
        assert!(rules("rust/src/coordinator/mod.rs", descent).is_empty());
    }

    #[test]
    fn migration_cross_shard_insert_needs_its_marker() {
        // the migration's one sanctioned inversion: the target's streams
        // map under the source's state lock — flagged without the
        // marker, clean with it on the line above
        let naked = "fn f() {\n    let st = lock_ok(&e.state);\n    lock_ok(&target.streams).insert(id, entry);\n}";
        assert_eq!(rules("rust/src/coordinator/migrate.rs", naked), vec!["lock_order"]);
        let marked = "fn f() {\n    let st = lock_ok(&e.state);\n    // natsa-lint: allow(lock_order)\n    lock_ok(&target.streams).insert(id, entry);\n}";
        assert!(rules("rust/src/coordinator/migrate.rs", marked).is_empty());
    }

    #[test]
    fn instant_arith_caught_everywhere() {
        let add = "fn f() {\n    let d = Instant::now() + Duration::from_secs(30);\n}";
        assert_eq!(rules("rust/tests/x.rs", add), vec!["instant_arith"]);
        assert_eq!(rules("benches/y.rs", add), vec!["instant_arith"]);
        let since = "fn f() {\n    let d = a.duration_since(b);\n}";
        assert_eq!(rules("rust/src/a.rs", since), vec!["instant_arith"]);
        let sat = "fn f() {\n    let d = a.saturating_duration_since(b);\n}";
        assert!(rules("rust/src/a.rs", sat).is_empty());
        let checked = "fn f() {\n    let d = Instant::now().checked_add(t).expect(\"x\");\n}";
        assert!(rules("rust/src/a.rs", checked).is_empty());
    }

    #[test]
    fn hot_sqrt_caught_in_kernels_only() {
        let src = "fn f(x: f64) -> f64 {\n    x.sqrt()\n}";
        assert_eq!(rules("rust/src/mp/kernel.rs", src), vec!["hot_sqrt"]);
        assert_eq!(rules("rust/src/mp/stampi.rs", src), vec!["hot_sqrt"]);
        assert!(rules("rust/src/mp/mod.rs", src).is_empty(), "sqrt_in_place lives here");
        let marked = "fn f(x: f64) -> f64 {\n    x.sqrt() // natsa-lint: allow(hot_sqrt)\n}";
        assert!(rules("rust/src/mp/kernel.rs", marked).is_empty());
    }

    #[test]
    fn comments_and_strings_do_not_trip_rules() {
        let src = "//! docs say never write .lock().unwrap() by hand\nfn f() {\n    let s = \".sqrt() and .lock().unwrap() and Instant::now() + d\";\n    /* .wait(g).unwrap() */\n}";
        assert!(rules("rust/src/mp/kernel.rs", src).is_empty());
    }

    #[test]
    fn whole_tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
        let findings = scan_tree(&root).expect("repo tree readable");
        assert!(
            findings.is_empty(),
            "repo must be natsa-lint clean:\n{}",
            findings.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
        );
    }
}
