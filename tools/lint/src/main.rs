//! `natsa-lint` — the repo's custom invariant analyzer.
//!
//! CI runs it over the tree (`cargo run --manifest-path
//! tools/lint/Cargo.toml -- .` from the repo root, add `--json` for the
//! machine-readable report) and fails the build on any finding.  Nine
//! rules, each with a stable id, guarding contracts no compiler checks
//! (see `docs/INVARIANTS.md` for the full catalog):
//!
//! * **NL001 naked_lock** — no `.lock().unwrap()` / `.lock().expect(` /
//!   RwLock unwraps in `rust/src` outside `rust/src/sync.rs`: every
//!   acquisition goes through `crate::sync::lock_ok` so the poison
//!   policy (and the loom swap) lives in exactly one place.
//! * **NL002 naked_wait** — same for Condvar waits: `wait_ok` /
//!   `wait_timeout_ok` only.
//! * **NL003 lock_order** — in the coordinator's locking modules,
//!   classified locks must be acquired in strictly ascending hierarchy
//!   order (`streams` < `submit_seq` < `state` < `subs`; `slots` and
//!   `route_table` are leaves).  v2 is interprocedural: each function
//!   gets a summary (classes acquired, classes held at each call site)
//!   propagated across the call graph of the same four files, so a
//!   helper that takes `state` while its caller holds `subs` is flagged
//!   even though neither function is locally wrong.  `try_lock_ok` is
//!   exempt — it cannot deadlock.
//! * **NL004 instant_arith** — no raw `Instant` arithmetic: only
//!   `checked_add` / `saturating_duration_since`.
//! * **NL005 hot_sqrt** — no `.sqrt()` in non-test `mp/kernel.rs` /
//!   `mp/stampi.rs`: the deferred-sqrt contract keeps hot-path
//!   distances squared (one sqrt per *snapshot*, never per cell).
//! * **NL006 fp_determinism** — on the bit-identity surfaces
//!   (`mp/kernel.rs`, `mp/stampi.rs`, `coordinator/migrate.rs`): no
//!   `mul_add`/FMA, no transcendental method calls, no hashed-container
//!   iteration feeding FP state, no float `as` casts of computed
//!   values (integer-to-float casts of plain identifiers are exact and
//!   stay legal).
//! * **NL007 wal_order** — in `service.rs`/`migrate.rs`, every session
//!   mutation (`extend` / `append_group` / stream install / close or
//!   move mark) must be dominated by its matching `log_*` call inside
//!   the same function's state-lock region, and no `log_*` record may
//!   follow a `log_close` for the same stream.  The close-mark check is
//!   interprocedural (a callee that logs Close counts).
//! * **NL008 metrics_coverage** — every `ServiceMetrics` field must be
//!   recorded (shard and aggregate sides in step) and appear in the
//!   Σ-reconciliation test (`assert_reconciled` in
//!   `rust/tests/service_shard.rs`), so a new counter can't ship
//!   unreconciled.
//! * **NL009 suppression** — every allow marker must actually suppress
//!   a finding (stale markers are errors), must name a known rule, and
//!   must carry a justification comment (same comment or line above).
//!
//! Suppression: an `allow(<rule>)` comment prefixed with the tool's
//! name, on the finding's line or the line above, skips it.  Markers
//! are read from comment text only, so string literals can't create or
//! suppress findings.  `#[cfg(test)]` / `#[cfg(all(test, ...))]` item
//! bodies are exempt from every rule except `instant_arith`.
//!
//! Design note: this is a tokenizer + per-function model over
//! comment-stripped, string-blanked source, not a `syn` AST pass — the
//! build container has no network, so the tool must compile from std
//! alone.  The tokenizer handles nested block comments, raw strings
//! (`r"…"`, `r#"…"#`) and multi-line string literals.  Known limits:
//! turbofish call sites (`f::<T>(…)`) are not resolved as calls, and
//! universe functions whose names shadow std collection methods
//! (`remove`, `len`, …) are opaque at call sites — their bodies are
//! still checked directly.

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

/// Directories scanned, relative to the repo root.
const SCAN_DIRS: &[&str] = &["rust/src", "rust/tests", "benches", "examples", "tools/lint/src"];

/// The service lock hierarchy: acquisition order must be strictly
/// ascending in class.  Field names are how acquisitions are
/// classified (`lock_ok(&shard.streams)` → `streams`); unlisted names
/// (`cell`, `rx`, ...) are unclassified and ignored.
const LOCK_CLASSES: &[(&str, u8)] = &[
    ("streams", 10),
    ("submit_seq", 20),
    ("state", 30),
    ("subs", 40),
    ("slots", 50),       // leaf: never held across another classified acquire
    ("route_table", 60), // router leaf: taken under anything, nothing under it
];

/// Files the `lock_order` rule runs over — the interprocedural
/// universe.  Deliberately NOT all of `coordinator/`: `slots.rs` and
/// `fanout.rs` have private mutexes that happen to be named `state`,
/// and pulling them in would misclassify those as hierarchy class 30.
const LOCK_ORDER_FILES: &[&str] = &[
    "rust/src/coordinator/service.rs",
    "rust/src/coordinator/router.rs",
    "rust/src/coordinator/migrate.rs",
    "rust/src/coordinator/admission.rs",
];

/// Bit-identity surfaces the `fp_determinism` rule runs over.
const FP_FILES: &[&str] = &[
    "rust/src/mp/kernel.rs",
    "rust/src/mp/stampi.rs",
    "rust/src/coordinator/migrate.rs",
];

/// Files the `wal_order` rule runs over: every module that both logs
/// to the WAL and mutates session state.
const WAL_FILES: &[&str] =
    &["rust/src/coordinator/service.rs", "rust/src/coordinator/migrate.rs"];

const METRICS_FILE: &str = "rust/src/coordinator/metrics.rs";
/// Where `ServiceMetrics` fields are ticked; `mod.rs` is excluded on
/// purpose (its `metrics.*` lines belong to the unrelated `PuMetrics`).
const METRICS_USAGE_FILES: &[&str] = &[
    "rust/src/coordinator/metrics.rs",
    "rust/src/coordinator/service.rs",
    "rust/src/coordinator/migrate.rs",
];
const RECON_FILE: &str = "rust/tests/service_shard.rs";
const RECON_FN: &str = "assert_reconciled";

/// Stable rule ids, in severity-agnostic registration order.
const RULES: &[(&str, &str)] = &[
    ("naked_lock", "NL001"),
    ("naked_wait", "NL002"),
    ("lock_order", "NL003"),
    ("instant_arith", "NL004"),
    ("hot_sqrt", "NL005"),
    ("fp_determinism", "NL006"),
    ("wal_order", "NL007"),
    ("metrics_coverage", "NL008"),
    ("suppression", "NL009"),
];

/// Transcendental float methods with platform/libm-dependent rounding.
const TRANSCENDENTALS: &[&str] = &[
    ".powf(", ".powi(", ".exp(", ".exp2(", ".exp_m1(", ".ln(", ".ln_1p(", ".log(", ".log2(",
    ".log10(", ".sin(", ".cos(", ".tan(", ".asin(", ".acos(", ".atan(", ".atan2(", ".sinh(",
    ".cosh(", ".tanh(", ".cbrt(", ".hypot(",
];

/// Universe function names NOT resolved at call sites because they
/// shadow ubiquitous std collection/trait methods (`map.remove(..)`
/// would otherwise resolve to `Router::remove`).  Their bodies are
/// still scanned directly.
const OPAQUE_CALLEES: &[&str] =
    &["new", "default", "fmt", "clone", "remove", "len", "is_empty", "extend", "drop"];

#[derive(Debug)]
struct Finding {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

impl Finding {
    fn id(&self) -> &'static str {
        RULES.iter().find(|(r, _)| *r == self.rule).map_or("NL???", |(_, i)| *i)
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{} {}] {}", self.file, self.line, self.id(), self.rule, self.msg)
    }
}

fn main() {
    let mut root = PathBuf::from(".");
    let mut json = false;
    for arg in std::env::args().skip(1) {
        if arg == "--json" {
            json = true;
        } else {
            root = PathBuf::from(arg);
        }
    }
    match scan_tree(&root) {
        Ok((findings, files_scanned)) => {
            if json {
                println!("{}", render_json(&findings, files_scanned));
            } else {
                for f in &findings {
                    println!("{f}");
                }
                if findings.is_empty() {
                    println!("natsa-lint: tree clean ({files_scanned} files)");
                }
            }
            if !findings.is_empty() {
                eprintln!("natsa-lint: {} violation(s)", findings.len());
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("natsa-lint: {e}");
            std::process::exit(2);
        }
    }
}

fn scan_tree(root: &Path) -> std::io::Result<(Vec<Finding>, usize)> {
    let mut paths = Vec::new();
    for dir in SCAN_DIRS {
        collect_rs(&root.join(dir), &mut paths)?;
    }
    paths.sort();
    let mut files = Vec::new();
    for path in paths {
        let content = fs::read_to_string(&path)?;
        let rel = path.strip_prefix(root).unwrap_or(&path).to_string_lossy().replace('\\', "/");
        files.push((rel, content));
    }
    let n = files.len();
    Ok((scan_files(&files), n))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    if !dir.exists() {
        return Ok(());
    }
    for entry in fs::read_dir(dir)? {
        let p = entry?.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn render_json(findings: &[Finding], files_scanned: usize) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"schema\": \"natsa-lint/v2\",\n");
    s.push_str(&format!("  \"files_scanned\": {files_scanned},\n"));
    s.push_str(&format!("  \"clean\": {},\n", findings.is_empty()));
    s.push_str("  \"findings\": [\n");
    for (k, f) in findings.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"file\": \"{}\", \"line\": {}, \"id\": \"{}\", \"rule\": \"{}\", \"msg\": \"{}\"}}{}\n",
            json_escape(&f.file),
            f.line,
            f.id(),
            f.rule,
            json_escape(&f.msg),
            if k + 1 < findings.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}");
    s
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

// ---------------------------------------------------------------------
// Tokenizer: comments out (their text kept for markers), string/char
// contents blanked.  Handles nested block comments, raw strings and
// multi-line string literals — all state persists across lines.
// ---------------------------------------------------------------------

struct Allow {
    rule: String,
    justified: bool,
}

struct Line {
    /// Source with comments removed and literal contents blanked — all
    /// pattern matching runs on this.
    code: String,
    /// The line's comment text (line-comment tail + block-comment
    /// interior) — allow markers and justifications are read from here.
    comment: String,
    /// Rules allowed on (this line or the next).
    allows: Vec<Allow>,
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

fn sanitize(content: &str) -> Vec<Line> {
    #[derive(Clone, Copy)]
    enum St {
        Code,
        Block(u32),
        Str,
        RawStr(usize),
    }
    let mut st = St::Code;
    let mut out: Vec<Line> = Vec::new();
    for raw in content.lines() {
        let chars: Vec<char> = raw.chars().collect();
        let mut code = String::with_capacity(raw.len());
        let mut comment = String::new();
        let mut i = 0;
        while i < chars.len() {
            match st {
                St::Block(d) => {
                    if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                        st = St::Block(d + 1);
                        comment.push_str("/*");
                        i += 2;
                    } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                        if d == 1 {
                            st = St::Code;
                        } else {
                            st = St::Block(d - 1);
                            comment.push_str("*/");
                        }
                        i += 2;
                    } else {
                        comment.push(chars[i]);
                        i += 1;
                    }
                }
                St::Str => {
                    if chars[i] == '\\' {
                        i += 2;
                    } else if chars[i] == '"' {
                        code.push('"');
                        st = St::Code;
                        i += 1;
                    } else {
                        i += 1;
                    }
                }
                St::RawStr(h) => {
                    if chars[i] == '"' && (0..h).all(|k| chars.get(i + 1 + k) == Some(&'#')) {
                        code.push('"');
                        for _ in 0..h {
                            code.push('#');
                        }
                        st = St::Code;
                        i += h + 1;
                    } else {
                        i += 1;
                    }
                }
                St::Code => {
                    let c = chars[i];
                    if c == '/' && chars.get(i + 1) == Some(&'/') {
                        comment.extend(&chars[i + 2..]);
                        i = chars.len();
                    } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                        st = St::Block(1);
                        i += 2;
                    } else if c == '"' {
                        code.push('"');
                        st = St::Str;
                        i += 1;
                    } else if c == 'r' && !code.chars().next_back().is_some_and(is_ident) {
                        // r"…" / r#"…"# raw string start (br"…" is not
                        // modeled; none in the tree)
                        let mut h = 0;
                        while chars.get(i + 1 + h) == Some(&'#') {
                            h += 1;
                        }
                        if chars.get(i + 1 + h) == Some(&'"') {
                            code.push('r');
                            for _ in 0..h {
                                code.push('#');
                            }
                            code.push('"');
                            st = St::RawStr(h);
                            i += h + 2;
                        } else {
                            code.push(c);
                            i += 1;
                        }
                    } else if c == '\'' {
                        // char literal ('x' / '\n' / '\u{..}') vs
                        // lifetime ('a): only the literal closes
                        if chars.get(i + 1) == Some(&'\\') {
                            code.push_str("' '");
                            let mut j = i + 2;
                            while j < chars.len() && chars[j] != '\'' {
                                j += 1;
                            }
                            i = j + 1;
                        } else if chars.get(i + 2) == Some(&'\'') {
                            code.push_str("' '");
                            i += 3;
                        } else {
                            code.push('\'');
                            i += 1;
                        }
                    } else {
                        code.push(c);
                        i += 1;
                    }
                }
            }
        }
        let allows = parse_allows(&comment);
        out.push(Line { code, comment, allows });
    }
    // Justification: residual text in the marker's own comment, or any
    // comment on the line above.
    for i in 0..out.len() {
        if out[i].allows.is_empty() {
            continue;
        }
        let own = strip_markers(&out[i].comment).chars().any(char::is_alphanumeric);
        let prev = i > 0 && out[i - 1].comment.chars().any(char::is_alphanumeric);
        let justified = own || prev;
        for a in &mut out[i].allows {
            a.justified = justified;
        }
    }
    out
}

const MARKER: &str = "natsa-lint: allow(";

fn parse_allows(comment: &str) -> Vec<Allow> {
    let mut out = Vec::new();
    let mut rest = comment;
    while let Some(pos) = rest.find(MARKER) {
        let after = &rest[pos + MARKER.len()..];
        match after.find(')') {
            Some(end) => {
                out.push(Allow { rule: after[..end].trim().to_string(), justified: false });
                rest = &after[end..];
            }
            None => break,
        }
    }
    out
}

/// The comment with every allow-marker span removed — what's left is
/// the justification text.
fn strip_markers(comment: &str) -> String {
    let mut out = String::new();
    let mut rest = comment;
    while let Some(pos) = rest.find(MARKER) {
        out.push_str(&rest[..pos]);
        let after = &rest[pos + MARKER.len()..];
        match after.find(')') {
            Some(end) => rest = &after[end + 1..],
            None => {
                rest = "";
            }
        }
    }
    out.push_str(rest);
    out
}

/// Lines inside `#[cfg(test)]` / `#[cfg(all(test, ...))]` items.
fn test_region_mask(lines: &[Line]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        let code = &lines[i].code;
        if code.contains("#[cfg(test)]") || code.contains("#[cfg(all(test") {
            let mut depth = 0i32;
            let mut opened = false;
            let mut j = i;
            while j < lines.len() {
                mask[j] = true;
                for c in lines[j].code.chars() {
                    match c {
                        '{' => {
                            depth += 1;
                            opened = true;
                        }
                        '}' => depth -= 1,
                        _ => {}
                    }
                }
                if opened && depth <= 0 {
                    break;
                }
                j += 1;
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    mask
}

// ---------------------------------------------------------------------
// Per-function model.
// ---------------------------------------------------------------------

struct Func {
    name: String,
    /// Line of the body's opening brace (signature may span lines).
    body_start: usize,
    /// Line of the body's closing brace, inclusive.
    end: usize,
}

struct Model {
    rel: String,
    lines: Vec<Line>,
    mask: Vec<bool>,
    funcs: Vec<Func>,
}

fn build_model(rel: &str, content: &str) -> Model {
    let lines = sanitize(content);
    let mask = test_region_mask(&lines);
    let funcs = extract_funcs(&lines);
    Model { rel: rel.to_string(), lines, mask, funcs }
}

fn extract_funcs(lines: &[Line]) -> Vec<Func> {
    let mut out = Vec::new();
    for i in 0..lines.len() {
        let chars: Vec<char> = lines[i].code.chars().collect();
        let mut k = 0;
        while k + 1 < chars.len() {
            let word_fn = chars[k] == 'f'
                && chars[k + 1] == 'n'
                && (k == 0 || !is_ident(chars[k - 1]))
                && chars.get(k + 2).copied().is_none_or(|c| !is_ident(c));
            if !word_fn {
                k += 1;
                continue;
            }
            let mut j = k + 2;
            while j < chars.len() && chars[j].is_whitespace() {
                j += 1;
            }
            let ns = j;
            while j < chars.len() && is_ident(chars[j]) {
                j += 1;
            }
            if j > ns {
                let name: String = chars[ns..j].iter().collect();
                if let Some((bs, be)) = body_span(lines, i, j) {
                    out.push(Func { name, body_start: bs, end: be });
                }
            }
            k = j.max(k + 1);
        }
    }
    out
}

/// From just after the function name, find the body's brace span: the
/// first `{` at paren depth 0 opens it (a `;` there instead means a
/// bodyless trait declaration).
fn body_span(lines: &[Line], li: usize, ci: usize) -> Option<(usize, usize)> {
    let mut paren = 0i32;
    let mut brace = 0i32;
    let mut body_start: Option<usize> = None;
    let mut l = li;
    let mut c = ci;
    while l < lines.len() {
        let chars: Vec<char> = lines[l].code.chars().collect();
        while c < chars.len() {
            match chars[c] {
                '(' => paren += 1,
                ')' => paren -= 1,
                '{' => {
                    if body_start.is_some() {
                        brace += 1;
                    } else if paren == 0 {
                        body_start = Some(l);
                        brace = 1;
                    }
                }
                '}' => {
                    if body_start.is_some() {
                        brace -= 1;
                        if brace == 0 {
                            return Some((body_start.unwrap(), l));
                        }
                    }
                }
                ';' => {
                    if body_start.is_none() && paren == 0 {
                        return None;
                    }
                }
                _ => {}
            }
            c += 1;
        }
        l += 1;
        c = 0;
    }
    None
}

// ---------------------------------------------------------------------
// Shared matching helpers.
// ---------------------------------------------------------------------

fn squash(s: &str) -> String {
    s.chars().filter(|c| !c.is_whitespace()).collect()
}

fn find_all(hay: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut start = 0;
    while let Some(p) = hay.get(start..).and_then(|h| h.find(needle)) {
        out.push(start + p);
        start += p + 1;
    }
    out
}

/// True when `pat` occurs starting within line `i` (rustfmt may split a
/// method chain, so the window extends into line `i + 1`).
fn matches_window(lines: &[Line], i: usize, pat: &str) -> bool {
    let cur = squash(&lines[i].code);
    let next = lines.get(i + 1).map(|l| squash(&l.code)).unwrap_or_default();
    let win = format!("{cur}{next}");
    find_all(&win, pat).iter().any(|&p| p < cur.len())
}

/// Word occurrence with identifier boundaries on both sides.
fn has_word(hay: &str, word: &str) -> bool {
    let chars: Vec<char> = hay.chars().collect();
    let wlen = word.chars().count();
    for p in find_all(hay, word) {
        // byte offset == char offset only for ASCII; squashed code in
        // this repo is ASCII on the lines that matter, but recompute
        // defensively via char positions.
        let cp = hay[..p].chars().count();
        let pre = cp == 0 || !is_ident(chars[cp - 1]);
        let post = cp + wlen >= chars.len() || !is_ident(chars[cp + wlen]);
        if pre && post {
            return true;
        }
    }
    false
}

/// Identifier runs immediately followed by `(` — call-site candidates.
fn call_idents(sq: &str) -> Vec<String> {
    let chars: Vec<char> = sq.chars().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < chars.len() {
        if is_ident(chars[i]) && !chars[i].is_numeric() {
            let start = i;
            while i < chars.len() && is_ident(chars[i]) {
                i += 1;
            }
            if chars.get(i) == Some(&'(') {
                out.push(chars[start..i].iter().collect());
            }
        } else {
            i += 1;
        }
    }
    out
}

/// Marker lookup: line `i` or the line above.  Returns the marker's
/// line index so the suppression pass can tell used from stale.
fn allowed(lines: &[Line], i: usize, rule: &str) -> Option<usize> {
    if lines[i].allows.iter().any(|a| a.rule == rule) {
        return Some(i);
    }
    if i > 0 && lines[i - 1].allows.iter().any(|a| a.rule == rule) {
        return Some(i - 1);
    }
    None
}

/// (file, marker line, rule) triples that suppressed a finding.
type Used = HashSet<(String, usize, String)>;

/// Emit a finding at line `i` unless an allow marker suppresses it (in
/// which case the marker is recorded as used).
fn report(
    m: &Model,
    i: usize,
    rule: &'static str,
    msg: String,
    findings: &mut Vec<Finding>,
    used: &mut Used,
) {
    match allowed(&m.lines, i, rule) {
        Some(j) => {
            used.insert((m.rel.clone(), j, rule.to_string()));
        }
        None => findings.push(Finding { file: m.rel.clone(), line: i + 1, rule, msg }),
    }
}

// ---------------------------------------------------------------------
// The analysis: local passes, then the cross-file passes, then
// suppression hygiene over everything the other passes recorded.
// ---------------------------------------------------------------------

fn scan_files(files: &[(String, String)]) -> Vec<Finding> {
    let models: Vec<Model> = files.iter().map(|(rel, src)| build_model(rel, src)).collect();
    let mut findings = Vec::new();
    let mut used: Used = HashSet::new();
    for m in &models {
        scan_local(m, &mut findings, &mut used);
    }
    scan_lock_order(&models, &mut findings, &mut used);
    scan_wal_order(&models, &mut findings, &mut used);
    scan_metrics_coverage(&models, &mut findings, &mut used);
    scan_suppressions(&models, &used, &mut findings);
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    findings.dedup_by(|a, b| a.file == b.file && a.line == b.line && a.rule == b.rule && a.msg == b.msg);
    findings
}

fn scan_local(m: &Model, findings: &mut Vec<Finding>, used: &mut Used) {
    let in_src = m.rel.starts_with("rust/src/");
    let naked_scope = in_src && m.rel != "rust/src/sync.rs";
    let hot_scope = m.rel == "rust/src/mp/kernel.rs" || m.rel == "rust/src/mp/stampi.rs";
    let fp_scope = FP_FILES.contains(&m.rel.as_str());
    for i in 0..m.lines.len() {
        if naked_scope && !m.mask[i] {
            for pat in [".lock().unwrap()", ".lock().expect(", ".read().unwrap()", ".write().unwrap()"]
            {
                if matches_window(&m.lines, i, pat) {
                    report(
                        m,
                        i,
                        "naked_lock",
                        format!(
                            "`{pat}` — acquire through crate::sync::lock_ok so the poison \
                             policy (and the loom swap) lives in one place"
                        ),
                        findings,
                        used,
                    );
                    break;
                }
            }
        }
        if naked_scope && !m.mask[i] {
            let cur = squash(&m.lines[i].code);
            let next = m.lines.get(i + 1).map(|l| squash(&l.code)).unwrap_or_default();
            let win = format!("{cur}{next}");
            let hit = [".wait(", ".wait_timeout("].iter().any(|pat| {
                find_all(&win, pat)
                    .iter()
                    .any(|&p| p < cur.len() && win.get(p..).is_some_and(|t| t.contains(".unwrap()")))
            });
            if hit {
                report(
                    m,
                    i,
                    "naked_wait",
                    "Condvar wait unwrap — use crate::sync::wait_ok / wait_timeout_ok".to_string(),
                    findings,
                    used,
                );
            }
        }
        {
            let cur = squash(&m.lines[i].code);
            for pat in
                [".duration_since(", "Instant::now()+", "Instant::now()-", "+Instant::now()", "-Instant::now()"]
            {
                if cur.contains(pat) {
                    report(
                        m,
                        i,
                        "instant_arith",
                        format!(
                            "`{pat}` — raw Instant arithmetic panics on underflow/overflow; \
                             use checked_add / saturating_duration_since"
                        ),
                        findings,
                        used,
                    );
                    break;
                }
            }
        }
        if hot_scope && !m.mask[i] && matches_window(&m.lines, i, ".sqrt()") {
            report(
                m,
                i,
                "hot_sqrt",
                "sqrt on a kernel hot path — the deferred-sqrt contract keeps distances \
                 squared (one sqrt per snapshot via sqrt_in_place)"
                    .to_string(),
                findings,
                used,
            );
        }
        if fp_scope && !m.mask[i] {
            scan_fp_line(m, i, findings, used);
        }
    }
}

fn scan_fp_line(m: &Model, i: usize, findings: &mut Vec<Finding>, used: &mut Used) {
    let cur = squash(&m.lines[i].code);
    if cur.contains(".mul_add(") {
        report(
            m,
            i,
            "fp_determinism",
            "`mul_add` — FMA contraction rounds differently from mul-then-add; \
             bit-identity surfaces must not fuse"
                .to_string(),
            findings,
            used,
        );
        return;
    }
    for t in TRANSCENDENTALS {
        if cur.contains(t) {
            report(
                m,
                i,
                "fp_determinism",
                format!(
                    "`{}…)` — transcendental with platform-dependent rounding on a \
                     bit-identity surface",
                    t
                ),
                findings,
                used,
            );
            return;
        }
    }
    for w in ["HashMap", "HashSet"] {
        if has_word(&cur, w) {
            report(
                m,
                i,
                "fp_determinism",
                format!(
                    "`{w}` — hashed iteration order is nondeterministic; feeding FP \
                     accumulation or profile merges breaks bit-identity (use a sorted or \
                     indexed container)"
                ),
                findings,
                used,
            );
            return;
        }
    }
    if let Some(tgt) = float_cast(&m.lines[i].code) {
        report(
            m,
            i,
            "fp_determinism",
            format!(
                "`as {tgt}` cast of a computed value on a bit-identity surface — \
                 precision reshaping must stay at the sanctioned conversion sites \
                 (integer-identifier casts are exact and exempt)"
            ),
            findings,
            used,
        );
    }
}

/// A float `as` cast that can change a computed value: any `as f32`,
/// or `as f64` whose source token is a parenthesized expression or a
/// float literal.  Plain identifier/int casts (`m as f64`) are exact
/// for every index magnitude this repo uses and stay legal.
fn float_cast(code: &str) -> Option<&'static str> {
    let chars: Vec<char> = code.chars().collect();
    let n = chars.len();
    let mut k = 0;
    while k + 1 < n {
        let word_as = chars[k] == 'a'
            && chars[k + 1] == 's'
            && (k == 0 || !is_ident(chars[k - 1]))
            && k + 2 < n
            && chars[k + 2].is_whitespace();
        if !word_as {
            k += 1;
            continue;
        }
        let mut j = k + 2;
        while j < n && chars[j].is_whitespace() {
            j += 1;
        }
        let ts = j;
        while j < n && is_ident(chars[j]) {
            j += 1;
        }
        let tgt: String = chars[ts..j].iter().collect();
        let mut p = k;
        while p > 0 && chars[p - 1].is_whitespace() {
            p -= 1;
        }
        let computed = p > 0 && chars[p - 1] == ')';
        let float_lit = {
            let mut q = p;
            while q > 0 && (is_ident(chars[q - 1]) || chars[q - 1] == '.') {
                q -= 1;
            }
            let tok: String = chars[q..p].iter().collect();
            tok.starts_with(|c: char| c.is_ascii_digit()) && tok.contains('.')
        };
        if tgt == "f32" {
            return Some("f32");
        }
        if tgt == "f64" && (computed || float_lit) {
            return Some("f64");
        }
        k = j;
    }
    None
}

// ---------------------------------------------------------------------
// NL003 lock_order: intra-function linear scan plus interprocedural
// summaries over the LOCK_ORDER_FILES call graph.
// ---------------------------------------------------------------------

struct Guard {
    name: String,
    class: u8,
    depth: i32,
}

struct CallSite {
    model: usize,
    line: usize,
    callee: String,
    /// (guard name, class) snapshot at the call.
    held: Vec<(String, u8)>,
}

fn class_name(class: u8) -> &'static str {
    LOCK_CLASSES.iter().find(|&&(_, c)| c == class).map_or("?", |&(n, _)| n)
}

fn scan_lock_order(models: &[Model], findings: &mut Vec<Finding>, used: &mut Used) {
    let universe: Vec<usize> = (0..models.len())
        .filter(|&k| LOCK_ORDER_FILES.contains(&models[k].rel.as_str()))
        .collect();
    let names: HashSet<String> = universe
        .iter()
        .flat_map(|&k| models[k].funcs.iter().map(|f| f.name.clone()))
        .collect();
    // Per-function direct summaries (merged by name across the
    // universe) + every call site with its held-set.
    let mut acquires: HashMap<String, HashSet<u8>> = HashMap::new();
    let mut calls_of: HashMap<String, HashSet<String>> = HashMap::new();
    let mut sites: Vec<CallSite> = Vec::new();
    for &mi in &universe {
        let m = &models[mi];
        for f in &m.funcs {
            scan_fn_locks(m, mi, f, &names, &mut acquires, &mut calls_of, &mut sites, findings, used);
        }
    }
    // Fixpoint: transitive acquisition sets across the call graph.
    let mut trans = acquires.clone();
    loop {
        let mut changed = false;
        for (name, callees) in &calls_of {
            let mut add: HashSet<u8> = HashSet::new();
            for callee in callees {
                if let Some(t) = trans.get(callee) {
                    add.extend(t.iter().copied());
                }
            }
            let cur = trans.entry(name.clone()).or_default();
            for c in add {
                if cur.insert(c) {
                    changed = true;
                }
            }
        }
        if !changed {
            break;
        }
    }
    // A call while holding class H to a function that (transitively)
    // acquires class C with H >= C is a hierarchy descent the old
    // line scanner could never see.
    for s in &sites {
        let Some(t) = trans.get(&s.callee) else { continue };
        let mut worst: Option<(&(String, u8), u8)> = None;
        for h in &s.held {
            for &c in t {
                if h.1 >= c && worst.is_none_or(|(wh, _)| h.1 > wh.1) {
                    worst = Some((h, c));
                }
            }
        }
        if let Some(((gname, gclass), c)) = worst {
            report(
                &models[s.model],
                s.line,
                "lock_order",
                format!(
                    "calls `{}`, which transitively acquires `{}` (class {}), while `{}` \
                     (class {}) is held — cross-function hierarchy descent \
                     (docs/CONCURRENCY.md)",
                    s.callee,
                    class_name(c),
                    c,
                    gname,
                    gclass
                ),
                findings,
                used,
            );
        }
    }
}

/// Linear scan of one function for hierarchy-descending acquisitions;
/// also records the function's summary and its call sites.
///
/// A *guard binding* is a line of the exact shape
/// `let [mut] name = lock_ok(&path);` — the guard is considered held
/// until `drop(name)` or the end of its brace scope.  Chained
/// temporaries (`lock_ok(&x).get(..)`) acquire and release within the
/// statement: they are order-checked but never held.  `try_lock_ok` is
/// exempt by construction (the pattern requires a word boundary).
#[allow(clippy::too_many_arguments)]
fn scan_fn_locks(
    m: &Model,
    mi: usize,
    f: &Func,
    names: &HashSet<String>,
    acquires: &mut HashMap<String, HashSet<u8>>,
    calls_of: &mut HashMap<String, HashSet<String>>,
    sites: &mut Vec<CallSite>,
    findings: &mut Vec<Finding>,
    used: &mut Used,
) {
    let mut depth = 0i32;
    let mut held: Vec<Guard> = Vec::new();
    let hi = f.end.min(m.lines.len().saturating_sub(1));
    for i in f.body_start..=hi {
        let code = squash(&m.lines[i].code);
        for p in find_all(&code, "drop(") {
            if p > 0 {
                let prev = code.as_bytes()[p - 1];
                if prev.is_ascii_alphanumeric() || prev == b'_' {
                    continue;
                }
            }
            if let Some(end) = code[p + 5..].find(')') {
                let name = &code[p + 5..p + 5 + end];
                held.retain(|g| g.name != name);
            }
        }
        for p in find_all(&code, "lock_ok(") {
            if p > 0 {
                let prev = code.as_bytes()[p - 1];
                if prev.is_ascii_alphanumeric() || prev == b'_' {
                    continue; // try_lock_ok(...) or another identifier
                }
            }
            let arg_start = p + "lock_ok(".len();
            let Some(rel_end) = code[arg_start..].find(')') else { continue };
            let arg_end = arg_start + rel_end;
            let field = code[arg_start..arg_end]
                .trim_start_matches('&')
                .rsplit(['.', ':'])
                .next()
                .unwrap_or("")
                .to_string();
            let Some(&(cname, class)) = LOCK_CLASSES.iter().find(|&&(n, _)| n == field) else {
                continue;
            };
            if !m.mask[i] {
                acquires.entry(f.name.clone()).or_default().insert(class);
                if let Some(worst) =
                    held.iter().filter(|g| g.class >= class).max_by_key(|g| g.class)
                {
                    report(
                        m,
                        i,
                        "lock_order",
                        format!(
                            "acquires `{cname}` (class {class}) while `{}` (class {}) is held — \
                             hierarchy is streams < submit_seq < state < subs, slots and \
                             route_table leaves (docs/CONCURRENCY.md)",
                            worst.name, worst.class
                        ),
                        findings,
                        used,
                    );
                }
            }
            // held only when the lock_ok call is the entire rhs of a let
            if code.get(arg_end..) == Some(");") {
                if let Some(name) = binding_name(&code[..p]) {
                    held.push(Guard { name, class, depth });
                }
            }
        }
        if !m.mask[i] {
            for callee in call_idents(&code) {
                if callee != f.name
                    && names.contains(&callee)
                    && !OPAQUE_CALLEES.contains(&callee.as_str())
                {
                    calls_of.entry(f.name.clone()).or_default().insert(callee.clone());
                    if !held.is_empty() {
                        sites.push(CallSite {
                            model: mi,
                            line: i,
                            callee,
                            held: held.iter().map(|g| (g.name.clone(), g.class)).collect(),
                        });
                    }
                }
            }
        }
        for c in code.chars() {
            match c {
                '{' => depth += 1,
                '}' => depth -= 1,
                _ => {}
            }
        }
        held.retain(|g| g.depth <= depth);
    }
}

/// `let[mut]NAME=` (squashed) → `NAME`.
fn binding_name(before: &str) -> Option<String> {
    let rest = before.strip_prefix("let")?;
    let rest = rest.strip_prefix("mut").unwrap_or(rest);
    let name = rest.strip_suffix('=')?;
    if name.is_empty() || !name.chars().all(|c| c.is_alphanumeric() || c == '_') {
        return None;
    }
    Some(name.to_string())
}

// ---------------------------------------------------------------------
// NL007 wal_order: write-ahead ordering inside service.rs/migrate.rs.
// ---------------------------------------------------------------------

fn first_arg(sq: &str, after: usize) -> String {
    let rest = &sq[after..];
    let end = rest.find([',', ')']).unwrap_or(rest.len());
    rest[..end].trim_start_matches(['*', '&']).to_string()
}

fn scan_wal_order(models: &[Model], findings: &mut Vec<Finding>, used: &mut Used) {
    let universe: Vec<usize> = (0..models.len())
        .filter(|&k| WAL_FILES.contains(&models[k].rel.as_str()))
        .collect();
    let names: HashSet<String> = universe
        .iter()
        .flat_map(|&k| models[k].funcs.iter().map(|f| f.name.clone()))
        .collect();
    // "logs a Close record" effect, propagated transitively so a close
    // mark may delegate its log_close to a callee (quarantine path).
    let mut direct_close: HashSet<String> = HashSet::new();
    let mut calls_of: HashMap<String, HashSet<String>> = HashMap::new();
    for &mi in &universe {
        let m = &models[mi];
        for f in &m.funcs {
            let hi = f.end.min(m.lines.len().saturating_sub(1));
            for i in f.body_start..=hi {
                if m.mask[i] {
                    continue;
                }
                let sq = squash(&m.lines[i].code);
                if sq.contains("log_close(") {
                    direct_close.insert(f.name.clone());
                }
                for callee in call_idents(&sq) {
                    if callee != f.name
                        && names.contains(&callee)
                        && !OPAQUE_CALLEES.contains(&callee.as_str())
                    {
                        calls_of.entry(f.name.clone()).or_default().insert(callee);
                    }
                }
            }
        }
    }
    let mut closes = direct_close;
    loop {
        let mut changed = false;
        for (name, callees) in &calls_of {
            if !closes.contains(name) && callees.iter().any(|c| closes.contains(c)) {
                closes.insert(name.clone());
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    for &mi in &universe {
        let m = &models[mi];
        for f in &m.funcs {
            let mut seen_open = false;
            let mut seen_append = false;
            let mut seen_state = false;
            let mut closed_args: Vec<String> = Vec::new();
            let hi = f.end.min(m.lines.len().saturating_sub(1));
            for i in f.body_start..=hi {
                if m.mask[i] {
                    continue;
                }
                let sq = squash(&m.lines[i].code);
                // log_* records first: a log on the mutation's own line
                // still dominates it.
                for (op, flag) in
                    [("log_open(", true), ("log_append(", false), ("log_snapshot(", false)]
                {
                    for p in find_all(&sq, op) {
                        if flag {
                            seen_open = true;
                        } else if op == "log_append(" {
                            seen_append = true;
                        }
                        let arg = first_arg(&sq, p + op.len());
                        if closed_args.contains(&arg) {
                            report(
                                m,
                                i,
                                "wal_order",
                                format!(
                                    "`{op}…)` after `log_close` for the same stream (`{arg}`) — \
                                     records after Close are unreachable on replay"
                                ),
                                findings,
                                used,
                            );
                        }
                    }
                }
                for p in find_all(&sq, "log_close(") {
                    closed_args.push(first_arg(&sq, p + "log_close(".len()));
                }
                // Any state-lock acquisition (lock_ok or try_lock_ok)
                // opens the region session mutations must live in.
                for p in find_all(&sq, "lock_ok(") {
                    let arg_start = p + "lock_ok(".len();
                    if let Some(rel_end) = sq[arg_start..].find(')') {
                        let field = sq[arg_start..arg_start + rel_end]
                            .trim_start_matches('&')
                            .rsplit(['.', ':'])
                            .next()
                            .unwrap_or("");
                        if field == "state" {
                            seen_state = true;
                        }
                    }
                }
                // Session mutations.
                if sq.contains("session.extend(") || sq.contains("append_group(") {
                    if !seen_append {
                        report(
                            m,
                            i,
                            "wal_order",
                            "session mutation is not write-ahead logged — no `log_append` \
                             dominates it in this function (WAL contract: log, then mutate, \
                             inside the state-lock region)"
                                .to_string(),
                            findings,
                            used,
                        );
                    } else if !seen_state {
                        report(
                            m,
                            i,
                            "wal_order",
                            "session mutation before any state-lock acquisition — WAL \
                             ordering is only atomic inside the stream's state-lock region"
                                .to_string(),
                            findings,
                            used,
                        );
                    }
                }
                if sq.contains("streams).insert(") && !seen_open {
                    report(
                        m,
                        i,
                        "wal_order",
                        "stream install without a dominating `log_open` — the WAL must \
                         know the stream before the map does"
                            .to_string(),
                        findings,
                        used,
                    );
                }
                if (sq.contains(".closed=true") || sq.contains(".moved=true"))
                    && !closes.contains(&f.name)
                {
                    report(
                        m,
                        i,
                        "wal_order",
                        "close/move mark without a `log_close` in this function or its \
                         callees — replay would resurrect the stream"
                            .to_string(),
                        findings,
                        used,
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// NL008 metrics_coverage: every ServiceMetrics field recorded in step
// (shard and aggregate) and present in the Σ-reconciliation test.
// ---------------------------------------------------------------------

fn field_use(sq: &str, prefix: &str, field: &str) -> bool {
    let pat = format!("{prefix}{field}");
    let chars: Vec<char> = sq.chars().collect();
    let plen = pat.chars().count();
    for p in find_all(sq, &pat) {
        let cp = sq[..p].chars().count();
        let pre = prefix.starts_with('.') || cp == 0 || !is_ident(chars[cp - 1]);
        let post = cp + plen >= chars.len() || !is_ident(chars[cp + plen]);
        if pre && post {
            return true;
        }
    }
    false
}

fn scan_metrics_coverage(models: &[Model], findings: &mut Vec<Finding>, used: &mut Used) {
    let Some(mm) = models.iter().find(|m| m.rel == METRICS_FILE) else { return };
    // Parse the live struct's fields (the #[cfg(test)] twin is masked
    // and thereby exempt — the self-tests splice its scratch field into
    // the live struct to prove the pass fails closed).
    let mut fields: Vec<(String, usize)> = Vec::new();
    let mut def_range: Option<(usize, usize)> = None;
    let mut in_struct = false;
    let mut start = 0;
    for i in 0..mm.lines.len() {
        if mm.mask[i] {
            continue;
        }
        let sq = squash(&mm.lines[i].code);
        if !in_struct && sq.starts_with("pubstructServiceMetrics{") {
            in_struct = true;
            start = i;
            continue;
        }
        if in_struct {
            if sq == "}" {
                def_range = Some((start, i));
                break;
            }
            if let Some(rest) = sq.strip_prefix("pub") {
                if let Some(cp) = rest.find(':') {
                    let name = &rest[..cp];
                    if !name.is_empty() && name.chars().all(is_ident) {
                        fields.push((name.to_string(), i));
                    }
                }
            }
        }
    }
    let Some(def_range) = def_range else {
        findings.push(Finding {
            file: mm.rel.clone(),
            line: 1,
            rule: "metrics_coverage",
            msg: "ServiceMetrics struct not found — the coverage pass has nothing to check"
                .to_string(),
        });
        return;
    };
    // Where the Σ test lives.
    let recon = models.iter().find(|m| m.rel == RECON_FILE);
    let recon_fn = recon.and_then(|rm| rm.funcs.iter().find(|f| f.name == RECON_FN).map(|f| (rm, f)));
    if recon_fn.is_none() {
        findings.push(Finding {
            file: mm.rel.clone(),
            line: def_range.0 + 1,
            rule: "metrics_coverage",
            msg: format!(
                "reconciliation test `{RECON_FN}` not found in {RECON_FILE} — every \
                 ServiceMetrics field must be covered by the Σ-reconciliation test"
            ),
        });
    }
    for (fname, fline) in &fields {
        let mut any = false;
        let mut shard = false;
        let mut agg = false;
        for m in models.iter().filter(|m| METRICS_USAGE_FILES.contains(&m.rel.as_str())) {
            for i in 0..m.lines.len() {
                if m.mask[i] {
                    continue;
                }
                if m.rel == METRICS_FILE && i >= def_range.0 && i <= def_range.1 {
                    continue;
                }
                let sq = squash(&m.lines[i].code);
                if field_use(&sq, ".", fname) {
                    any = true;
                }
                if field_use(&sq, "metrics.", fname) {
                    shard = true;
                }
                if field_use(&sq, "aggregate.", fname) {
                    agg = true;
                }
            }
        }
        if !any {
            report(
                mm,
                *fline,
                "metrics_coverage",
                format!("`{fname}` is never recorded in the coordinator — dead or \
                         unreconcilable metric field"),
                findings,
                used,
            );
        } else if shard != agg {
            report(
                mm,
                *fline,
                "metrics_coverage",
                format!(
                    "`{fname}` is ticked on only one side ({}) — shard and aggregate \
                     must move in step or Σ-reconciliation cannot hold",
                    if shard { "shard, no aggregate" } else { "aggregate, no shard" }
                ),
                findings,
                used,
            );
        }
        if let Some((rm, rf)) = recon_fn {
            let hi = rf.end.min(rm.lines.len().saturating_sub(1));
            let covered = (rf.body_start..=hi)
                .any(|i| field_use(&squash(&rm.lines[i].code), ".", fname));
            if !covered {
                report(
                    mm,
                    *fline,
                    "metrics_coverage",
                    format!(
                        "`{fname}` is missing from `{RECON_FN}` ({RECON_FILE}) — new \
                         counters must join the Σ-reconciliation test"
                    ),
                    findings,
                    used,
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// NL009 suppression: every allow marker must name a known rule, must
// have suppressed something, and must carry a justification.  No
// marker can suppress a suppression finding.
// ---------------------------------------------------------------------

fn scan_suppressions(models: &[Model], used: &Used, findings: &mut Vec<Finding>) {
    let known: HashSet<&str> = RULES.iter().map(|(r, _)| *r).collect();
    for m in models {
        for (i, line) in m.lines.iter().enumerate() {
            for a in &line.allows {
                if !known.contains(a.rule.as_str()) {
                    findings.push(Finding {
                        file: m.rel.clone(),
                        line: i + 1,
                        rule: "suppression",
                        msg: format!("allow marker names unknown rule `{}`", a.rule),
                    });
                } else if !used.contains(&(m.rel.clone(), i, a.rule.clone())) {
                    findings.push(Finding {
                        file: m.rel.clone(),
                        line: i + 1,
                        rule: "suppression",
                        msg: format!(
                            "stale allow marker — no `{}` finding is suppressed here; \
                             delete it or it will mask a future regression",
                            a.rule
                        ),
                    });
                } else if !a.justified {
                    findings.push(Finding {
                        file: m.rel.clone(),
                        line: i + 1,
                        rule: "suppression",
                        msg: format!(
                            "allow marker for `{}` lacks a justification comment (same \
                             comment or the line above)",
                            a.rule
                        ),
                    });
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Self-tests: every pass must catch its planted violation, every
// exemption must hold, and the repo tree must scan clean.
// ---------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn scan_pair(files: &[(&str, &str)]) -> Vec<Finding> {
        let owned: Vec<(String, String)> =
            files.iter().map(|(r, s)| ((*r).to_string(), (*s).to_string())).collect();
        scan_files(&owned)
    }

    fn rules(rel: &str, src: &str) -> Vec<&'static str> {
        scan_pair(&[(rel, src)]).iter().map(|f| f.rule).collect()
    }

    #[test]
    fn naked_lock_caught_outside_sync_facade() {
        let src = "fn f() {\n    let _ = m.lock().unwrap();\n}";
        assert_eq!(rules("rust/src/coordinator/fanout.rs", src), vec!["naked_lock"]);
        assert!(rules("rust/src/sync.rs", src).is_empty(), "sync.rs owns the poison policy");
        assert!(rules("rust/tests/x.rs", src).is_empty(), "scope is rust/src only");
        let split = "fn f() {\n    let _ = m.lock()\n        .unwrap();\n}";
        assert_eq!(rules("rust/src/a.rs", split), vec!["naked_lock"], "rustfmt-split chain");
        let rw = "fn f() {\n    let _ = m.read().unwrap();\n}";
        assert_eq!(rules("rust/src/a.rs", rw), vec!["naked_lock"]);
    }

    #[test]
    fn naked_lock_marker_and_test_mod_exempt() {
        let marked =
            "fn f() {\n    // natsa-lint: allow(naked_lock) planted case\n    let _ = m.lock().unwrap();\n}";
        assert!(rules("rust/src/a.rs", marked).is_empty());
        let tested = "#[cfg(test)]\nmod tests {\n    fn f() { let _ = m.lock().unwrap(); }\n}";
        assert!(rules("rust/src/a.rs", tested).is_empty());
        let tested2 =
            "#[cfg(all(test, not(loom)))]\nmod tests {\n    fn f() { let _ = m.lock().unwrap(); }\n}";
        assert!(rules("rust/src/a.rs", tested2).is_empty());
    }

    #[test]
    fn naked_wait_caught() {
        let src = "fn f() {\n    let g = cv.wait(g).unwrap();\n}";
        assert_eq!(rules("rust/src/a.rs", src), vec!["naked_wait"]);
        let to = "fn f() {\n    let (g, _) = cv.wait_timeout(g, d).unwrap();\n}";
        assert_eq!(rules("rust/src/a.rs", to), vec!["naked_wait"]);
        let ok = "fn f() {\n    let g = wait_ok(&cv, g);\n}";
        assert!(rules("rust/src/a.rs", ok).is_empty());
    }

    #[test]
    fn lock_order_descent_caught_ascent_clean() {
        let descent =
            "fn f() {\n    let st = lock_ok(&e.state);\n    let g = lock_ok(&e.submit_seq);\n}";
        assert_eq!(rules("rust/src/coordinator/service.rs", descent), vec!["lock_order"]);
        let ascent =
            "fn f() {\n    let g = lock_ok(&e.submit_seq);\n    let st = lock_ok(&e.state);\n}";
        assert!(rules("rust/src/coordinator/service.rs", ascent).is_empty());
        // the same text is not the service's protocol elsewhere
        assert!(rules("rust/src/coordinator/mod.rs", descent).is_empty());
    }

    #[test]
    fn lock_order_release_paths_clean() {
        let dropped = "fn f() {\n    let st = lock_ok(&e.state);\n    drop(st);\n    let g = lock_ok(&e.submit_seq);\n}";
        assert!(rules("rust/src/coordinator/service.rs", dropped).is_empty());
        let scoped = "fn f() {\n    {\n        let st = lock_ok(&e.state);\n    }\n    let g = lock_ok(&e.submit_seq);\n}";
        assert!(rules("rust/src/coordinator/service.rs", scoped).is_empty());
        let try_exempt = "fn f() {\n    let st = lock_ok(&e.state);\n    let g = try_lock_ok(&e.submit_seq);\n}";
        assert!(rules("rust/src/coordinator/service.rs", try_exempt).is_empty());
        // chained temporaries are order-checked but not held
        let temp = "fn f() {\n    w.log_open(id, meta);\n    lock_ok(&shard.streams).insert(id, entry);\n    let st = lock_ok(&e.state);\n    let _n = lock_ok(&shard.subs).len();\n}";
        assert!(rules("rust/src/coordinator/service.rs", temp).is_empty());
        let temp_descent = "fn f() {\n    let st = lock_ok(&e.state);\n    lock_ok(&shard.streams).remove(&id);\n}";
        assert_eq!(rules("rust/src/coordinator/service.rs", temp_descent), vec!["lock_order"]);
    }

    #[test]
    fn route_table_is_the_top_of_the_hierarchy() {
        // nothing may be acquired while the route table is held …
        let descent =
            "fn f() {\n    let t = lock_ok(&self.route_table);\n    let st = lock_ok(&e.state);\n}";
        assert_eq!(rules("rust/src/coordinator/router.rs", descent), vec!["lock_order"]);
        // … but it may be taken under anything (it is the leaf)
        let ascent =
            "fn f() {\n    let st = lock_ok(&e.state);\n    let t = lock_ok(&self.route_table);\n}";
        assert!(rules("rust/src/coordinator/router.rs", ascent).is_empty());
        // the rule covers every coordinator locking module
        assert_eq!(rules("rust/src/coordinator/migrate.rs", descent), vec!["lock_order"]);
        assert_eq!(rules("rust/src/coordinator/admission.rs", descent), vec!["lock_order"]);
        assert!(rules("rust/src/coordinator/mod.rs", descent).is_empty());
    }

    #[test]
    fn migration_cross_shard_insert_needs_its_marker() {
        // the migration's one sanctioned inversion: the target's streams
        // map under the source's state lock — flagged without the
        // marker, clean with it on the line above
        let naked = "fn f(w: &W) {\n    w.log_open(id, meta);\n    let st = lock_ok(&e.state);\n    lock_ok(&target.streams).insert(id, entry);\n}";
        assert_eq!(rules("rust/src/coordinator/migrate.rs", naked), vec!["lock_order"]);
        let marked = "fn f(w: &W) {\n    w.log_open(id, meta);\n    let st = lock_ok(&e.state);\n    // natsa-lint: allow(lock_order) planted sanctioned inversion\n    lock_ok(&target.streams).insert(id, entry);\n}";
        assert!(rules("rust/src/coordinator/migrate.rs", marked).is_empty());
    }

    #[test]
    fn interproc_lock_order_flags_cross_function_chain() {
        // Neither function is locally wrong — the helper takes `state`
        // cleanly, the caller takes `subs` cleanly — but the call under
        // `subs` descends the hierarchy.  The PR 8 line scanner had no
        // cross-function view and missed exactly this shape.
        let src = "fn helper(e: &E) {\n    let st = lock_ok(&e.state);\n    st.touch();\n}\nfn caller(shard: &S, e: &E) {\n    let g = lock_ok(&shard.subs);\n    helper(e);\n    drop(g);\n}";
        let fs = scan_pair(&[("rust/src/coordinator/service.rs", src)]);
        assert_eq!(fs.iter().map(|f| f.rule).collect::<Vec<_>>(), vec!["lock_order"]);
        assert_eq!(fs[0].line, 7, "flagged at the call site");
        assert!(fs[0].msg.contains("helper"), "names the callee: {}", fs[0].msg);
        // ascending cross-function chains stay clean
        let asc = "fn helper(e: &E) {\n    let st = lock_ok(&e.state);\n}\nfn caller(e: &E) {\n    let g = lock_ok(&e.submit_seq);\n    helper(e);\n}";
        assert!(rules("rust/src/coordinator/service.rs", asc).is_empty());
    }

    #[test]
    fn interproc_lock_order_is_transitive_and_allowable() {
        let two_hop = "fn c(e: &E) {\n    let st = lock_ok(&e.state);\n}\nfn b(e: &E) {\n    c(e);\n}\nfn a(shard: &S, e: &E) {\n    let g = lock_ok(&shard.subs);\n    b(e);\n}";
        assert_eq!(rules("rust/src/coordinator/service.rs", two_hop), vec!["lock_order"]);
        let marked = "fn helper(e: &E) {\n    let st = lock_ok(&e.state);\n}\nfn caller(shard: &S, e: &E) {\n    let g = lock_ok(&shard.subs);\n    // natsa-lint: allow(lock_order) planted cross-function case\n    helper(e);\n}";
        assert!(rules("rust/src/coordinator/service.rs", marked).is_empty());
    }

    #[test]
    fn instant_arith_caught_everywhere() {
        let add = "fn f() {\n    let d = Instant::now() + Duration::from_secs(30);\n}";
        assert_eq!(rules("rust/tests/x.rs", add), vec!["instant_arith"]);
        assert_eq!(rules("benches/y.rs", add), vec!["instant_arith"]);
        let since = "fn f() {\n    let d = a.duration_since(b);\n}";
        assert_eq!(rules("rust/src/a.rs", since), vec!["instant_arith"]);
        let sat = "fn f() {\n    let d = a.saturating_duration_since(b);\n}";
        assert!(rules("rust/src/a.rs", sat).is_empty());
        let checked = "fn f() {\n    let d = Instant::now().checked_add(t).expect(\"x\");\n}";
        assert!(rules("rust/src/a.rs", checked).is_empty());
    }

    #[test]
    fn hot_sqrt_caught_in_kernels_only() {
        let src = "fn f(x: f64) -> f64 {\n    x.sqrt()\n}";
        assert_eq!(rules("rust/src/mp/kernel.rs", src), vec!["hot_sqrt"]);
        assert_eq!(rules("rust/src/mp/stampi.rs", src), vec!["hot_sqrt"]);
        assert!(rules("rust/src/mp/mod.rs", src).is_empty(), "sqrt_in_place lives here");
        let marked =
            "fn f(x: f64) -> f64 {\n    x.sqrt() // natsa-lint: allow(hot_sqrt) planted\n}";
        assert!(rules("rust/src/mp/kernel.rs", marked).is_empty());
    }

    #[test]
    fn fp_determinism_planted_violations_caught() {
        let fma = "fn f(a: f64, b: f64, c: f64) -> f64 {\n    a.mul_add(b, c)\n}";
        assert_eq!(rules("rust/src/mp/kernel.rs", fma), vec!["fp_determinism"]);
        assert!(rules("rust/src/mp/mod.rs", fma).is_empty(), "scope is the identity surfaces");
        let tested = "#[cfg(test)]\nmod tests {\n    fn f(a: f64) -> f64 { a.mul_add(a, a) }\n}";
        assert!(rules("rust/src/mp/kernel.rs", tested).is_empty());
        let tx = "fn f(x: f64) -> f64 {\n    x.powf(2.0)\n}";
        assert_eq!(rules("rust/src/mp/kernel.rs", tx), vec!["fp_determinism"]);
        let hashed = "fn f() {\n    let mut h = HashMap::with_capacity(4);\n}";
        assert_eq!(rules("rust/src/mp/stampi.rs", hashed), vec!["fp_determinism"]);
    }

    #[test]
    fn fp_determinism_cast_rules() {
        let narrowing = "fn f(x: f64) -> f32 {\n    x as f32\n}";
        assert_eq!(rules("rust/src/mp/kernel.rs", narrowing), vec!["fp_determinism"]);
        let computed = "fn f(a: f64, b: f64) -> f64 {\n    (a + b) as f64\n}";
        assert_eq!(rules("rust/src/mp/kernel.rs", computed), vec!["fp_determinism"]);
        let lit = "fn f() -> f64 {\n    2.5 as f64\n}";
        assert_eq!(rules("rust/src/mp/kernel.rs", lit), vec!["fp_determinism"]);
        // integer-identifier casts are exact and stay legal (`m as f64`
        // is the stats-seeding idiom in kernel.rs/stampi.rs)
        let exact = "fn f(m: usize) -> f64 {\n    2.0 * m as f64\n}";
        assert!(rules("rust/src/mp/kernel.rs", exact).is_empty());
    }

    #[test]
    fn wal_order_extend_must_be_logged_inside_state_region() {
        let unlogged = "fn f(e: &E) {\n    let mut st = lock_ok(&e.state);\n    st.session.extend(samples);\n}";
        assert_eq!(rules("rust/src/coordinator/service.rs", unlogged), vec!["wal_order"]);
        let logged = "fn f(e: &E) {\n    let mut st = lock_ok(&e.state);\n    w.log_append(stream, seq, samples);\n    st.session.extend(samples);\n}";
        assert!(rules("rust/src/coordinator/service.rs", logged).is_empty());
        let no_region = "fn f(w: &W) {\n    w.log_append(stream, seq, samples);\n    session.extend(samples);\n}";
        assert_eq!(rules("rust/src/coordinator/service.rs", no_region), vec!["wal_order"]);
        // scope: only the WAL-owning modules
        assert!(rules("rust/src/coordinator/slots.rs", unlogged).is_empty());
    }

    #[test]
    fn wal_order_group_pass_and_install() {
        let unlogged = "fn f(e: &E) {\n    let g = try_lock_ok(&e.state);\n    let r = append_group(&mut sess);\n}";
        assert_eq!(rules("rust/src/coordinator/service.rs", unlogged), vec!["wal_order"]);
        let logged = "fn f(e: &E) {\n    let g = try_lock_ok(&e.state);\n    w.log_append(stream, seq, samples);\n    let r = append_group(&mut sess);\n}";
        assert!(rules("rust/src/coordinator/service.rs", logged).is_empty());
        let install = "fn f() {\n    lock_ok(&shard.streams).insert(id, entry);\n}";
        assert_eq!(rules("rust/src/coordinator/service.rs", install), vec!["wal_order"]);
        let opened = "fn f(w: &W) {\n    w.log_open(id, meta);\n    lock_ok(&shard.streams).insert(id, entry);\n}";
        assert!(rules("rust/src/coordinator/service.rs", opened).is_empty());
    }

    #[test]
    fn wal_order_close_marks_need_log_close_direct_or_via_callee() {
        let unlogged = "fn f(e: &E) {\n    let mut st = lock_ok(&e.state);\n    st.closed = true;\n}";
        assert_eq!(rules("rust/src/coordinator/service.rs", unlogged), vec!["wal_order"]);
        let direct = "fn f(e: &E) {\n    let mut st = lock_ok(&e.state);\n    st.closed = true;\n    w.log_close(stream);\n}";
        assert!(rules("rust/src/coordinator/service.rs", direct).is_empty());
        // the quarantine shape: the close mark's log_close lives in a
        // callee — the effect propagates across the call graph
        let via_callee = "fn quarantine(w: &W) {\n    w.log_close(stream);\n}\nfn f(e: &E, w: &W) {\n    let mut st = lock_ok(&e.state);\n    st.closed = true;\n    quarantine(w);\n}";
        assert!(rules("rust/src/coordinator/service.rs", via_callee).is_empty());
        let moved = "fn f(e: &E) {\n    let mut st = lock_ok(&e.state);\n    st.moved = true;\n}";
        assert_eq!(rules("rust/src/coordinator/migrate.rs", moved), vec!["wal_order"]);
    }

    #[test]
    fn wal_order_no_records_after_close_for_same_stream() {
        let bad = "fn f(w: &W) {\n    w.log_close(stream);\n    w.log_open(stream, meta);\n}";
        assert_eq!(rules("rust/src/coordinator/service.rs", bad), vec!["wal_order"]);
        let other = "fn f(w: &W) {\n    w.log_close(dropped);\n    w.log_open(stream, meta);\n}";
        assert!(rules("rust/src/coordinator/service.rs", other).is_empty());
    }

    #[test]
    fn metrics_coverage_synthetic_struct() {
        let met = "pub struct ServiceMetrics {\n    pub a: AtomicU64,\n    pub b: AtomicU64,\n}\nimpl ServiceMetrics {\n    pub fn tick(&self) {\n        self.a.fetch_add(1, Ordering::Relaxed);\n        self.b.fetch_add(1, Ordering::Relaxed);\n    }\n}";
        let recon_ok = "fn assert_reconciled(svc: &S) {\n    assert_eq!(agg.a.load(O), sum.a);\n    assert_eq!(agg.b.load(O), sum.b);\n}";
        assert!(scan_pair(&[(METRICS_FILE, met), (RECON_FILE, recon_ok)]).is_empty());
        // a field missing from the Σ test is flagged
        let recon_partial = "fn assert_reconciled(svc: &S) {\n    assert_eq!(agg.a.load(O), sum.a);\n}";
        let fs = scan_pair(&[(METRICS_FILE, met), (RECON_FILE, recon_partial)]);
        assert_eq!(fs.iter().map(|f| f.rule).collect::<Vec<_>>(), vec!["metrics_coverage"]);
        assert!(fs[0].msg.contains("`b`"), "{}", fs[0].msg);
        // a field recorded nowhere is flagged
        let dead = "pub struct ServiceMetrics {\n    pub a: AtomicU64,\n    pub c: AtomicU64,\n}\nimpl ServiceMetrics {\n    pub fn tick(&self) {\n        self.a.fetch_add(1, Ordering::Relaxed);\n    }\n}";
        let recon_ac = "fn assert_reconciled(svc: &S) {\n    assert_eq!(agg.a.load(O), sum.a);\n    assert_eq!(agg.c.load(O), sum.c);\n}";
        let fs = scan_pair(&[(METRICS_FILE, dead), (RECON_FILE, recon_ac)]);
        assert_eq!(fs.iter().map(|f| f.rule).collect::<Vec<_>>(), vec!["metrics_coverage"]);
        assert!(fs[0].msg.contains("never recorded"), "{}", fs[0].msg);
        // a shard-side tick with no aggregate twin is flagged
        let svc = "fn f(shard: &S) {\n    shard.metrics.a.fetch_add(1, Ordering::Relaxed);\n}";
        let fs = scan_pair(&[
            (METRICS_FILE, met),
            ("rust/src/coordinator/service.rs", svc),
            (RECON_FILE, recon_ok),
        ]);
        assert_eq!(fs.iter().map(|f| f.rule).collect::<Vec<_>>(), vec!["metrics_coverage"]);
        assert!(fs[0].msg.contains("only one side"), "{}", fs[0].msg);
        // no reconciliation test at all fails closed
        let fs = scan_pair(&[(METRICS_FILE, met)]);
        assert_eq!(fs.iter().map(|f| f.rule).collect::<Vec<_>>(), vec!["metrics_coverage"]);
    }

    #[test]
    fn metrics_coverage_fails_closed_on_real_tree_twin() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
        let met = fs::read_to_string(root.join(METRICS_FILE)).unwrap();
        let svc = fs::read_to_string(root.join("rust/src/coordinator/service.rs")).unwrap();
        let mig = fs::read_to_string(root.join("rust/src/coordinator/migrate.rs")).unwrap();
        let rec = fs::read_to_string(root.join(RECON_FILE)).unwrap();
        let base = scan_pair(&[
            (METRICS_FILE, met.as_str()),
            ("rust/src/coordinator/service.rs", svc.as_str()),
            ("rust/src/coordinator/migrate.rs", mig.as_str()),
            (RECON_FILE, rec.as_str()),
        ]);
        assert!(
            base.is_empty(),
            "real metrics surface must be clean:\n{}",
            base.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
        );
        // The #[cfg(test)] twin struct's scratch field is exempt while
        // masked; splicing it into the live struct must be caught —
        // the pass fails closed on exactly the ship-an-unreconciled-
        // counter mistake.
        let scratch = met
            .lines()
            .find(|l| l.contains("scratch_unreconciled"))
            .expect("metrics.rs twin struct carries the scratch field");
        let spiked = met.replace(
            "pub struct ServiceMetrics {",
            &format!("pub struct ServiceMetrics {{\n{scratch}"),
        );
        let fs = scan_pair(&[
            (METRICS_FILE, spiked.as_str()),
            ("rust/src/coordinator/service.rs", svc.as_str()),
            ("rust/src/coordinator/migrate.rs", mig.as_str()),
            (RECON_FILE, rec.as_str()),
        ]);
        assert!(!fs.is_empty(), "spiked scratch field must be flagged");
        assert!(fs.iter().all(|f| f.rule == "metrics_coverage"));
        assert!(fs.iter().any(|f| f.msg.contains("scratch_unreconciled")));
    }

    #[test]
    fn suppression_hygiene() {
        // a marker that suppresses nothing is itself a finding
        let stale = "fn f() {\n    // natsa-lint: allow(naked_lock) says it is needed here\n    let x = compute();\n}";
        assert_eq!(rules("rust/src/a.rs", stale), vec!["suppression"]);
        // unknown rule names are findings
        let unknown = "fn f() {\n    // natsa-lint: allow(bogus_rule) oops\n    let x = compute();\n}";
        assert_eq!(rules("rust/src/a.rs", unknown), vec!["suppression"]);
        // a used marker still needs a justification comment
        let bare = "fn f() {\n    // natsa-lint: allow(naked_lock)\n    let _ = m.lock().unwrap();\n}";
        assert_eq!(rules("rust/src/a.rs", bare), vec!["suppression"]);
        // justification on the line above counts
        let above = "fn f() {\n    // single-threaded startup, poison impossible\n    // natsa-lint: allow(naked_lock)\n    let _ = m.lock().unwrap();\n}";
        assert!(rules("rust/src/a.rs", above).is_empty());
    }

    #[test]
    fn tokenizer_raw_strings() {
        // a raw string containing quotes must not leak its tail into
        // code (the old blanker false-positived here)
        let fp = "fn f() {\n    let s = r#\"say \"hi\" then m.lock().unwrap()\"#;\n}";
        assert!(rules("rust/src/a.rs", fp).is_empty());
        // a raw string ending in a backslash must not swallow the next
        // statement (the old blanker treated \" as an escape and missed
        // the real violation)
        let fnx = "fn f() {\n    let s = r\"ends with \\\";\n    let _ = m.lock().unwrap();\n}";
        assert_eq!(rules("rust/src/a.rs", fnx), vec!["naked_lock"]);
        // multi-line raw strings stay blanked across lines
        let ml = "fn f() {\n    let s = r#\"first\n.lock().unwrap()\nlast\"#;\n}";
        assert!(rules("rust/src/a.rs", ml).is_empty());
    }

    #[test]
    fn tokenizer_nested_block_comments() {
        // the old stripper closed the whole comment at the first */,
        // false-positiving on commented-out code after an inner comment
        let src = "fn f() {}\n/* outer /* inner */ let _ = m.lock().unwrap(); /* x */ still comment */\nfn g() {}";
        assert!(rules("rust/src/a.rs", src).is_empty());
        let multi = "fn f() {}\n/* outer\n/* inner\n*/\nlet _ = m.lock().unwrap();\n*/\nfn g() {}";
        assert!(rules("rust/src/a.rs", multi).is_empty());
    }

    #[test]
    fn comments_and_strings_do_not_trip_rules() {
        let src = "//! docs say never write .lock().unwrap() by hand\nfn f() {\n    let s = \".sqrt() and .lock().unwrap() and Instant::now() + d\";\n    /* .wait(g).unwrap() */\n}";
        assert!(rules("rust/src/mp/kernel.rs", src).is_empty());
    }

    #[test]
    fn rule_ids_and_json_report() {
        let fs = scan_pair(&[("rust/src/a.rs", "fn f() {\n    let _ = m.lock().unwrap();\n}")]);
        assert_eq!(fs[0].id(), "NL001");
        let js = render_json(&fs, 1);
        assert!(js.contains("\"id\": \"NL001\""), "{js}");
        assert!(js.contains("\"clean\": false"), "{js}");
        assert_eq!(json_escape("a\"b\\c\n"), "a\\\"b\\\\c\\n");
        let clean = render_json(&[], 3);
        assert!(clean.contains("\"clean\": true"), "{clean}");
    }

    #[test]
    fn whole_tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
        let (findings, files) = scan_tree(&root).expect("repo tree readable");
        assert!(files > 20, "tree walk found the sources");
        assert!(
            findings.is_empty(),
            "repo must be natsa-lint clean:\n{}",
            findings.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
        );
    }
}
