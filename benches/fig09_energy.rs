//! Fig. 9: energy per platform (rand_512K DP), with compute/memory
//! decomposition and ratios vs NATSA (paper: 27.2x max / 19.4x avg vs
//! baseline, 10.2x vs HBM-inOrder, 1.7x/4.1x/11x vs K40c/GTX1050/KNL).
fn main() {
    println!("{}", natsa::report::run("fig9").unwrap());
}
