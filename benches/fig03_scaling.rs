//! Fig. 3: SCRIMP thread scaling and bandwidth saturation.
//!
//! Two panels: (a) the calibrated KNL model reproducing the paper's
//! series (saturation at ~32 threads on DDR4, ~128 on MCDRAM), and
//! (b) a *measured* thread-scaling run of our rust SCRIMP on this host,
//! which must show the same shape: near-linear scaling until a memory
//! or core ceiling, then a plateau.

use natsa::benchmark::{black_box, time_budget, Table};
use natsa::mp::parallel::{self, Partition};
use natsa::mp::MpConfig;
use natsa::sim::platform::KnlModel;
use natsa::timeseries::generator::{generate, Pattern};

fn main() {
    // (a) model: the paper's figure
    let ddr = KnlModel::ddr4();
    let hbm = KnlModel::mcdram();
    let mut t = Table::new(&["threads", "DDR4 perf", "DDR4 GB/s", "HBM perf", "HBM GB/s"]);
    for threads in [1usize, 2, 4, 8, 16, 32, 64, 128, 256] {
        let (pd, bd) = ddr.scaling_point(threads);
        let (ph, bh) = hbm.scaling_point(threads);
        t.row(&[
            threads.to_string(),
            format!("{pd:.1}x"),
            format!("{bd:.1}"),
            format!("{ph:.1}x"),
            format!("{bh:.1}"),
        ]);
    }
    t.print("Fig. 3 (model): KNL SCRIMP scaling, normalized to 1 thread");
    println!(
        "knees: DDR4 ~{} threads, HBM ~{} threads (paper: 32 / 128)",
        ddr.saturation_threads(),
        hbm.saturation_threads()
    );

    // (b) measured on this host
    let n = 48_000;
    let m = 128;
    let series = generate::<f64>(Pattern::RandomWalk, n, 1);
    let cfg = MpConfig::new(m);
    let host = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    let mut t = Table::new(&["threads", "median", "speedup", "cells/s"]);
    let mut base = 0.0f64;
    let cells = natsa::mp::total_cells(n - m + 1, m / 4);
    for threads in [1usize, 2, 4, 8, 16] {
        if threads > 2 * host {
            break;
        }
        let s = time_budget(1.5, || {
            black_box(
                parallel::with_stats(&series, cfg, threads, Partition::BalancedPairs).unwrap(),
            );
        });
        if threads == 1 {
            base = s.median;
        }
        t.row(&[
            threads.to_string(),
            natsa::benchmark::fmt_time(s.median),
            format!("{:.2}x", base / s.median),
            format!("{:.2e}", s.throughput(cells)),
        ]);
    }
    t.print(&format!(
        "Fig. 3 (measured): rust SCRIMP on this host ({host} hw threads), n={n}, m={m}"
    ));
}
