//! Fig. 10: area comparison (NATSA smallest at the largest node), plus
//! the bottom-up Table 3 per-PU area reconstruction.
use natsa::natsa::pu::PuDesign;
use natsa::sim::area::ComponentAreas;
use natsa::sim::Precision;

fn main() {
    println!("{}", natsa::report::run("fig10").unwrap());
    for (label, prec, d) in [
        ("DP", Precision::Dp, PuDesign::dp()),
        ("SP", Precision::Sp, PuDesign::sp()),
    ] {
        let a = ComponentAreas::at_45nm(prec).pu_area_mm2(&d);
        println!(
            "bottom-up PU-{label} area: {a:.2} mm^2 (Table 3: {:.2} mm^2)",
            d.area_mm2
        );
    }
}
