//! Ablation: NATSA's balanced diagonal-pair partitioning (Section 4.2)
//! vs naive contiguous and strided splits — the design choice DESIGN.md
//! flags.  Reports both the *static* load imbalance and the *measured*
//! wall-clock of the parallel engine under each scheme.

use natsa::benchmark::{black_box, fmt_time, time_budget, Table};
use natsa::mp::parallel::{assign, with_stats, Partition};
use natsa::mp::MpConfig;
use natsa::timeseries::generator::{generate, Pattern};

fn main() {
    let n = 65_536;
    let m = 256;
    let nw = n - m + 1;
    let excl = m / 4;
    let threads = std::thread::available_parallelism()
        .map(|p| p.get().min(8))
        .unwrap_or(4);
    let series = generate::<f64>(Pattern::RandomWalk, n, 12);
    let cfg = MpConfig::new(m);

    let mut t = Table::new(&["partition", "imbalance", "median", "vs banded"]);
    let mut balanced = 0.0f64;
    for part in [
        Partition::BandedPairs,
        Partition::BalancedPairs,
        Partition::Strided,
        Partition::Contiguous,
    ] {
        // static imbalance: max/min thread load in cells
        let lists = assign(nw, excl, threads, part);
        let loads: Vec<u64> = lists
            .iter()
            .map(|l| l.iter().map(|&d| (nw - d) as u64).sum())
            .collect();
        let imb = *loads.iter().max().unwrap() as f64 / (*loads.iter().min().unwrap()).max(1) as f64;

        let s = time_budget(2.0, || {
            black_box(with_stats(&series, cfg, threads, part).unwrap());
        });
        if part == Partition::BandedPairs {
            balanced = s.median;
        }
        t.row(&[
            format!("{part:?}"),
            format!("{imb:.3}"),
            fmt_time(s.median),
            format!("{:+.1}%", (s.median / balanced - 1.0) * 100.0),
        ]);
    }
    t.print(&format!(
        "partitioning ablation: n={n}, m={m}, {threads} threads"
    ));
    println!(
        "\nContiguous puts all long diagonals on the first thread (its\n\
         owner straggles); NATSA's pair scheme is balanced by construction\n\
         and preserves the anytime property, unlike sorting-based fixes."
    );
}
