//! Hot-path benchmark — the acceptance instrument for the unified
//! diagonal kernel (PR 2) and the L3 perf-pass trajectory record.
//!
//! Headline measurement: full single-thread matrix profile at n = 65536,
//! m = 256 (f64) through five paths sharing one statistics precompute:
//!
//! * `scalar`      — the retained pre-kernel per-cell loop
//!   (`kernel::scalar_diagonal`): the baseline every speedup is quoted
//!   against (the acceptance bar is >= 2x for `kernel-band`);
//! * `kernel-diag` — the per-diagonal delta-form path
//!   (`kernel::compute_diagonal`);
//! * `kernel-band` — the BAND-lane SIMD path sequential sweeps use
//!   (`kernel::compute_triangle`);
//! * `fleet-diag`  — the 48-PU work lists of the LEGACY per-diagonal
//!   scheduler (`scheduler::schedule`), executed serially: what every
//!   scheduled/anytime engine ran before band-granular scheduling;
//! * `fleet-band`  — the 48-PU band-tile work lists
//!   (`scheduler::schedule_banded` + `kernel::compute_band_n`): the
//!   fleet's new hot path.  `fleet-band` vs `fleet-diag` isolates what
//!   band-granular scheduling buys the fleet.
//!
//! Pass `--json` to (re)write `BENCH_hotpath.json` with the measured
//! rows so future PRs have a trajectory to compare against.

use natsa::benchmark::{black_box, fmt_time, isa, time_budget, Table};
use natsa::mp::kernel::scalar_diagonal;
use natsa::mp::{kernel, scrimp, MatrixProfile, MpConfig, WorkStats};
use natsa::natsa::scheduler;
use natsa::timeseries::generator::{generate, Pattern};
use natsa::timeseries::sliding_stats;
use natsa::timeseries::stats::sliding_stats_exact;
use natsa::Real;

/// One measured engine row at the headline shape.
struct Row {
    engine: &'static str,
    dtype: &'static str,
    ns_per_cell: f64,
    speedup_vs_scalar: f64,
}

/// A per-diagonal kernel entry point (`compute_diagonal` / `scalar_diagonal`).
type DiagFn<T> = fn(
    &[T],
    &natsa::timeseries::WindowStats<T>,
    usize,
    &mut MatrixProfile<T>,
    &mut WorkStats,
);

fn profile_cells(n: usize, m: usize) -> u64 {
    let cfg = MpConfig::new(m);
    natsa::mp::total_cells(n - m + 1, cfg.exclusion())
}

/// Full single-thread profile through a per-diagonal function.
fn diag_profile<T: Real>(t: &[T], m: usize, f: DiagFn<T>) -> MatrixProfile<T> {
    let cfg = MpConfig::new(m);
    let nw = cfg.validate(t.len()).unwrap();
    let excl = cfg.exclusion();
    let st = sliding_stats(t, m);
    let mut mp = MatrixProfile::new_inf(nw, m, excl);
    let mut work = WorkStats::default();
    for d in excl..nw {
        f(t, &st, d, &mut mp, &mut work);
    }
    mp.sqrt_in_place();
    mp
}

/// Full single-thread profile through the banded sequential driver —
/// exactly `scrimp::matrix_profile` (SCRIMP sequential order IS the
/// band path), so the bench measures the engine users actually call.
fn band_profile<T: Real>(t: &[T], m: usize) -> MatrixProfile<T> {
    scrimp::matrix_profile(t, MpConfig::new(m)).unwrap()
}

/// Full profile through the 48-PU fleet work lists, executed serially on
/// one thread so the rows isolate *schedule shape* (per-diagonal vs
/// band-tile) from thread scaling.  `banded=false` walks the legacy
/// per-diagonal schedule; `banded=true` walks `schedule_banded` tiles
/// through the variable-width band kernel.
fn fleet_profile<T: Real>(t: &[T], m: usize, banded: bool) -> MatrixProfile<T> {
    let cfg = MpConfig::new(m);
    let nw = cfg.validate(t.len()).unwrap();
    let excl = cfg.exclusion();
    let st = sliding_stats(t, m);
    let mut mp = MatrixProfile::new_inf(nw, m, excl);
    let mut work = WorkStats::default();
    if banded {
        let sched = scheduler::schedule_banded(nw, excl, 48);
        for tiles in &sched.per_pu {
            for tile in tiles {
                kernel::compute_band_n(t, &st, tile.d0, tile.width, &mut mp, &mut work);
            }
        }
    } else {
        let sched = scheduler::schedule(nw, excl, 48);
        for diags in &sched.per_pu {
            for &d in diags {
                kernel::compute_diagonal(t, &st, d, &mut mp, &mut work);
            }
        }
    }
    mp.sqrt_in_place();
    mp
}

/// Record one engine row: table line + JSON entry; returns ns/cell.
fn push_row(
    table: &mut Table,
    rows: &mut Vec<Row>,
    engine: &'static str,
    dtype: &'static str,
    median: f64,
    cells: u64,
    scalar_ns: Option<f64>,
) -> f64 {
    let ns = median / cells as f64 * 1e9;
    let speedup = scalar_ns.map_or(1.0, |s| s / ns);
    table.row(&[
        engine.to_string(),
        dtype.to_string(),
        fmt_time(median),
        format!("{ns:.3}"),
        format!("{speedup:.2}x"),
    ]);
    rows.push(Row { engine, dtype, ns_per_cell: ns, speedup_vs_scalar: speedup });
    ns
}

fn main() {
    let json = std::env::args().any(|a| a == "--json");
    let n = 65_536;
    let m = 256;
    let cells = profile_cells(n, m);
    let t64 = generate::<f64>(Pattern::RandomWalk, n, 9);
    let t32: Vec<f32> = t64.iter().map(|&x| x as f32).collect();

    let mut table = Table::new(&["engine", "dtype", "median", "ns/cell", "vs scalar"]);
    let mut rows: Vec<Row> = Vec::new();

    // f64: the acceptance shape.
    let s = time_budget(4.0, || {
        black_box(diag_profile(&t64, m, scalar_diagonal));
    });
    let scalar_ns = push_row(&mut table, &mut rows, "scalar", "f64", s.median, cells, None);
    let s = time_budget(4.0, || {
        black_box(diag_profile(&t64, m, kernel::compute_diagonal));
    });
    push_row(&mut table, &mut rows, "kernel-diag", "f64", s.median, cells, Some(scalar_ns));
    let s = time_budget(4.0, || {
        black_box(band_profile(&t64, m));
    });
    push_row(&mut table, &mut rows, "kernel-band", "f64", s.median, cells, Some(scalar_ns));

    // Fleet-scheduled rows: 48-PU work lists executed serially, so the
    // delta between them is purely per-diagonal vs band-tile dealing.
    let s = time_budget(4.0, || {
        black_box(fleet_profile(&t64, m, false));
    });
    push_row(&mut table, &mut rows, "fleet-diag", "f64", s.median, cells, Some(scalar_ns));
    let s = time_budget(4.0, || {
        black_box(fleet_profile(&t64, m, true));
    });
    push_row(&mut table, &mut rows, "fleet-band", "f64", s.median, cells, Some(scalar_ns));

    // f32: the SP design point.
    let s = time_budget(3.0, || {
        black_box(diag_profile(&t32, m, scalar_diagonal));
    });
    let scalar32 = push_row(&mut table, &mut rows, "scalar", "f32", s.median, cells, None);
    let s = time_budget(3.0, || {
        black_box(band_profile(&t32, m));
    });
    push_row(&mut table, &mut rows, "kernel-band", "f32", s.median, cells, Some(scalar32));

    table.print(&format!("unified kernel vs scalar (n={n}, m={m}, single thread)"));

    // Supporting micro rows: precompute, scheduling, reduction.
    let mut aux = Table::new(&["kernel", "median", "items/s"]);
    let nw = n - m + 1;
    let s = time_budget(1.0, || {
        black_box(sliding_stats(&t64, m));
    });
    aux.row(&[
        "stats cumsum".into(),
        fmt_time(s.median),
        format!("{:.2e}", s.throughput(n as u64)),
    ]);
    let s = time_budget(1.0, || {
        black_box(sliding_stats_exact(&t64[..32_768], m));
    });
    aux.row(&[
        "stats exact (32K)".into(),
        fmt_time(s.median),
        format!("{:.2e}", s.throughput(32_768)),
    ]);
    let s = time_budget(1.0, || {
        black_box(scheduler::schedule(nw, m / 4, 48));
    });
    aux.row(&[
        "schedule 48 PUs".into(),
        fmt_time(s.median),
        format!("{:.2e}", s.throughput((nw - m / 4) as u64)),
    ]);
    let mut a = MatrixProfile::<f64>::new_inf(nw, m, m / 4);
    let b = MatrixProfile::<f64>::new_inf(nw, m, m / 4);
    let s = time_budget(1.0, || {
        a.merge(black_box(&b));
    });
    aux.row(&[
        "profile merge".into(),
        fmt_time(s.median),
        format!("{:.2e}", s.throughput(nw as u64)),
    ]);
    aux.print("supporting hot paths");

    if json {
        let mut out = String::from(
            "{\n  \"bench\": \"hotpath\",\n  \
             \"harness\": \"cargo bench --bench hotpath -- --json\",\n  \
             \"entries\": [\n",
        );
        for (k, r) in rows.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"n\": {n}, \"m\": {m}, \"dtype\": \"{}\", \"engine\": \"{}\", \
                 \"isa\": \"{}\", \"ns_per_cell\": {:.3}, \"speedup_vs_scalar\": {:.2}}}{}\n",
                r.dtype,
                r.engine,
                isa(),
                r.ns_per_cell,
                r.speedup_vs_scalar,
                if k + 1 < rows.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        std::fs::write("BENCH_hotpath.json", &out).expect("write BENCH_hotpath.json");
        println!("\nwrote BENCH_hotpath.json");
    }
}
