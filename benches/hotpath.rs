//! Hot-path microbenchmarks — the L3 perf-pass instrument.
//!
//! Covers the kernels the profile shows hottest: the SCRIMP diagonal walk
//! (cells/s), the per-chunk batch size, the stats precompute, scheduling,
//! and profile reduction.  EXPERIMENTS.md §Perf records these before and
//! after each optimization step.

use natsa::benchmark::{black_box, fmt_time, time_budget, Table};
use natsa::mp::scrimp::compute_diagonal;
use natsa::mp::{MatrixProfile, MpConfig, WorkStats};
use natsa::natsa::scheduler;
use natsa::timeseries::generator::{generate, Pattern};
use natsa::timeseries::sliding_stats;
use natsa::timeseries::stats::sliding_stats_exact;

fn main() {
    let n = 262_144;
    let m = 256;
    let t64 = generate::<f64>(Pattern::RandomWalk, n, 9);
    let t32: Vec<f32> = t64.iter().map(|&x| x as f32).collect();
    let st64 = sliding_stats(&t64, m);
    let st32 = sliding_stats(&t32, m);
    let nw = st64.len();
    let excl = m / 4;

    // 1. diagonal walk throughput (the inner loop of everything)
    let mut table = Table::new(&["kernel", "median", "cells/s"]);
    {
        let mut mp = MatrixProfile::<f64>::new_inf(nw, m, excl);
        let mut work = WorkStats::default();
        let d = excl; // longest diagonal: nw - excl cells
        let cells = (nw - d) as u64;
        let s = time_budget(2.0, || {
            compute_diagonal(&t64, &st64, d, &mut mp, &mut work);
            black_box(&mp);
        });
        table.row(&[
            "diag walk f64".into(),
            fmt_time(s.median),
            format!("{:.2e}", s.throughput(cells)),
        ]);
    }
    {
        let mut mp = MatrixProfile::<f32>::new_inf(nw, m, excl);
        let mut work = WorkStats::default();
        let d = excl;
        let cells = (nw - d) as u64;
        let s = time_budget(2.0, || {
            compute_diagonal(&t32, &st32, d, &mut mp, &mut work);
            black_box(&mp);
        });
        table.row(&[
            "diag walk f32".into(),
            fmt_time(s.median),
            format!("{:.2e}", s.throughput(cells)),
        ]);
    }

    // 2. stats precompute: cumsum vs exact
    {
        let s = time_budget(1.0, || {
            black_box(sliding_stats(&t64, m));
        });
        table.row(&[
            "stats cumsum".into(),
            fmt_time(s.median),
            format!("{:.2e}", s.throughput(n as u64)),
        ]);
        let s = time_budget(1.0, || {
            black_box(sliding_stats_exact(&t64[..32_768], m));
        });
        table.row(&[
            "stats exact (32K)".into(),
            fmt_time(s.median),
            format!("{:.2e}", s.throughput(32_768)),
        ]);
    }

    // 3. scheduling + reduction
    {
        let s = time_budget(1.0, || {
            black_box(scheduler::schedule(nw, excl, 48));
        });
        table.row(&[
            "schedule 48 PUs".into(),
            fmt_time(s.median),
            format!("{:.2e}", s.throughput((nw - excl) as u64)),
        ]);
        let mut a = MatrixProfile::<f64>::new_inf(nw, m, excl);
        let b = MatrixProfile::<f64>::new_inf(nw, m, excl);
        let s = time_budget(1.0, || {
            a.merge(black_box(&b));
        });
        table.row(&[
            "profile merge".into(),
            fmt_time(s.median),
            format!("{:.2e}", s.throughput(nw as u64)),
        ]);
    }

    // 4. end-to-end small profile (scrimp serial), the workhorse number
    {
        let small = generate::<f64>(Pattern::RandomWalk, 32_768, 10);
        let cfg = MpConfig::new(m);
        let cells = natsa::mp::total_cells(32_768 - m + 1, excl);
        let s = time_budget(2.0, || {
            black_box(natsa::mp::scrimp::matrix_profile(&small, cfg).unwrap());
        });
        table.row(&[
            "scrimp 32K e2e".into(),
            fmt_time(s.median),
            format!("{:.2e}", s.throughput(cells)),
        ]);
    }
    table.print("hot paths (n=256K series context, m=256)");
}
