//! Table 3 + Section 6.3: design-space exploration of the PU count on
//! HBM and DDR4, cross-checking the closed-form model against the
//! chunk-level discrete-event simulator (and timing the DES itself).

use natsa::benchmark::{black_box, time, Table};
use natsa::sim::accel::{design_space, NatsaDesign};
use natsa::sim::dram::DramConfig;
use natsa::sim::{Precision, Workload};

fn main() {
    println!("{}", natsa::report::run("table3").unwrap());

    // DES cross-check + its own cost (it is part of the eval substrate).
    let w = Workload::new(524_288, 256);
    let mut t = Table::new(&["design", "closed(s)", "DES(s)", "delta", "events", "DES cost"]);
    for (label, d) in [
        ("DP 32PU", NatsaDesign::hbm(Precision::Dp).with_pus(32)),
        ("DP 48PU", NatsaDesign::hbm(Precision::Dp)),
        ("DP 64PU", NatsaDesign::hbm(Precision::Dp).with_pus(64)),
        ("SP 48PU", NatsaDesign::hbm(Precision::Sp)),
        ("DP 8PU DDR4", NatsaDesign::ddr4(Precision::Dp)),
    ] {
        let cf = d.estimate(&w);
        let mut events = 0;
        let mut des_time = 0.0;
        let s = time(0, 3, || {
            let (e, ev) = d.simulate(&w, None);
            events = ev;
            des_time = e.time_s;
            black_box(e);
        });
        t.row(&[
            label.to_string(),
            format!("{:.2}", cf.time_s),
            format!("{des_time:.2}"),
            format!("{:+.1}%", (des_time / cf.time_s - 1.0) * 100.0),
            events.to_string(),
            natsa::benchmark::fmt_time(s.median),
        ]);
    }
    t.print("closed form vs DES (rand_512K)");

    // PU-count sweep timing of the closed form (cheap, used everywhere)
    let s = time(1, 10, || {
        black_box(design_space(
            Precision::Dp,
            DramConfig::hbm2(),
            &[8, 16, 24, 32, 40, 48, 56, 64, 96, 128],
            &w,
        ));
    });
    println!(
        "\n10-point DSE sweep costs {} (closed form)",
        natsa::benchmark::fmt_time(s.median)
    );
}
