//! Fig. 7: NATSA speedup over the DDR4-OoO baseline (DP), all Table 1
//! sizes, via the calibrated models — plus a functional-plane measurement
//! comparing our serial SCRIMP against the NATSA engine (48 logical PUs
//! on host threads) to show the coordination layer itself scales.

use natsa::benchmark::{black_box, time_budget, Table};
use natsa::mp::{scrimp, MpConfig};
use natsa::natsa::{NatsaConfig, NatsaEngine};
use natsa::sim::accel::NatsaDesign;
use natsa::sim::platform::GpPlatform;
use natsa::sim::{Precision, Workload};
use natsa::timeseries::generator::{generate, Pattern};

fn main() {
    // (a) model: the paper's figure
    let base = GpPlatform::ddr4_ooo();
    let natsa = NatsaDesign::hbm(Precision::Dp);
    let mut t = Table::new(&["dataset", "baseline(s)", "NATSA-DP(s)", "speedup"]);
    let mut speedups = Vec::new();
    for (name, w) in Workload::table1() {
        let b = base.estimate(&w, Precision::Dp).time_s;
        let a = natsa.estimate(&w).time_s;
        speedups.push(b / a);
        t.row(&[
            name,
            format!("{b:.2}"),
            format!("{a:.2}"),
            format!("{:.1}x", b / a),
        ]);
    }
    t.print("Fig. 7 (model): NATSA-DP speedup vs DDR4-OoO");
    println!(
        "average {:.1}x, max {:.1}x   (paper: 9.9x avg, up to 14.2x)",
        speedups.iter().sum::<f64>() / speedups.len() as f64,
        speedups.iter().cloned().fold(0.0, f64::max)
    );

    // (b) measured: serial SCRIMP vs the NATSA engine on host threads
    let n = 48_000;
    let m = 256;
    let series = generate::<f64>(Pattern::RandomWalk, n, 3);
    let cfg = MpConfig::new(m);
    let serial = time_budget(2.0, || {
        black_box(scrimp::matrix_profile(&series, cfg).unwrap());
    });
    let engine = NatsaEngine::<f64>::new(NatsaConfig::default());
    let fleet = time_budget(2.0, || {
        black_box(engine.compute(&series, m).unwrap());
    });
    println!(
        "\nmeasured (n={n}, m={m}): serial SCRIMP {} vs NATSA engine {} -> {:.2}x",
        natsa::benchmark::fmt_time(serial.median),
        natsa::benchmark::fmt_time(fleet.median),
        serial.median / fleet.median
    );
}
