//! Streaming throughput: incremental STAMPI append vs recomputing the
//! batch profile from scratch on every new sample — the acceptance
//! benchmark for the streaming subsystem (>= 10x at n = 16384, m = 64;
//! the asymptotic gap is O(n) vs O(n²) per sample, so the measured ratio
//! lands orders of magnitude beyond the bar) — plus the **row-kernel
//! trajectory**: the pre-kernel per-cell walk (eager per-cell sqrt +
//! per-element ring asserts) against the retained scalar-row oracle, the
//! width-1 kernel path (`Stampi::append`), and the blocked multi-row
//! tile path (`Stampi::extend`, up to BAND rows per tile).  Acceptance
//! bar for this PR: blocked extend >= 1.5x over the old per-append
//! scalar row at the bench shape.  Section (g) measures the service's
//! cross-stream coalescing: a storm of single-sample appends from many
//! streams, serial worker vs the drain-and-group worker (report-only).
//!
//! Pass `--json` to (re)write `BENCH_streaming.json` with the measured
//! rows so future PRs have a trajectory to compare against.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use natsa::benchmark::{black_box, fmt_time, isa, time_budget, Table};
use natsa::coordinator::service::{AnalysisService, ServiceConfig, SubmitError};
use natsa::coordinator::wal::WalOptions;
use natsa::mp::kernel::{self, RowTile};
use natsa::mp::stampi::{Stampi, StampiConfig};
use natsa::mp::{scrimp, znorm_dist, MpConfig, WorkStats};
use natsa::natsa::NatsaConfig;
use natsa::timeseries::generator::{generate, Pattern};

/// Absolute-indexed buffer with the *old* RingVec-style per-element
/// asserted access — re-created here so the pre-kernel row walk keeps a
/// measurable baseline after the engine moved off it.
struct CheckedBuf {
    buf: Vec<f64>,
    first: usize,
}

impl CheckedBuf {
    fn new() -> Self {
        CheckedBuf { buf: Vec::new(), first: 0 }
    }

    fn push(&mut self, x: f64) {
        self.buf.push(x);
    }

    fn len(&self) -> usize {
        self.buf.len()
    }

    #[inline]
    fn get(&self, i: usize) -> f64 {
        assert!(
            i >= self.first && i < self.buf.len(),
            "index {i} outside retained range [{}, {})",
            self.first,
            self.buf.len()
        );
        self.buf[i]
    }

    #[inline]
    fn set(&mut self, i: usize, x: f64) {
        assert!(
            i >= self.first && i < self.buf.len(),
            "index {i} outside retained range [{}, {})",
            self.first,
            self.buf.len()
        );
        self.buf[i] = x;
    }
}

/// The pre-PR streaming row walk, verbatim in shape: per-element
/// asserted access on every cell, eager `znorm_dist` (a sqrt per cell),
/// branchy two-sided updates.  Perf baseline only — the engine itself
/// now runs the row kernel.
struct EagerRowStream {
    m: usize,
    excl: usize,
    t: CheckedBuf,
    mu: CheckedBuf,
    inv: CheckedBuf,
    q: CheckedBuf,
    p: CheckedBuf,
    i: Vec<i64>,
    s: f64,
    s2: f64,
}

impl EagerRowStream {
    fn new(m: usize, excl: usize) -> Self {
        EagerRowStream {
            m,
            excl,
            t: CheckedBuf::new(),
            mu: CheckedBuf::new(),
            inv: CheckedBuf::new(),
            q: CheckedBuf::new(),
            p: CheckedBuf::new(),
            i: Vec::new(),
            s: 0.0,
            s2: 0.0,
        }
    }

    fn append(&mut self, x: f64) {
        let m = self.m;
        self.t.push(x);
        let n = self.t.len();
        self.s += x;
        self.s2 += x * x;
        if n > m {
            let old = self.t.get(n - 1 - m);
            self.s -= old;
            self.s2 -= old * old;
        }
        if n < m {
            return;
        }
        let k = n - m;
        let mf = m as f64;
        let mean = self.s / mf;
        let var = (self.s2 / mf - mean * mean).max(0.0);
        let sd = var.sqrt();
        self.mu.push(mean);
        self.inv.push(if sd > 0.0 { 1.0 / (mf * sd) } else { 0.0 });
        self.p.push(f64::INFINITY);
        self.i.push(-1);
        if k == 0 {
            let d = (0..m).map(|r| self.t.get(r) * self.t.get(r)).sum();
            self.q.push(d);
            return;
        }
        self.q.push(0.0);
        let tk1 = self.t.get(k - 1);
        let tkm1 = self.t.get(k + m - 1);
        for j in (1..=k).rev() {
            let v = self.q.get(j - 1) - self.t.get(j - 1) * tk1 + self.t.get(j + m - 1) * tkm1;
            self.q.set(j, v);
        }
        let q0 = (0..m).map(|r| self.t.get(r) * self.t.get(k + r)).sum();
        self.q.set(0, q0);
        if k >= self.excl {
            let hi = k - self.excl;
            let mu_k = self.mu.get(k);
            let inv_k = self.inv.get(k);
            let mut pk = self.p.get(k);
            let mut ik = self.i[k];
            for j in 0..=hi {
                let d = znorm_dist(self.q.get(j), m, self.mu.get(j), self.inv.get(j), mu_k, inv_k);
                if d < self.p.get(j) {
                    self.p.set(j, d);
                    self.i[j] = k as i64;
                }
                if d < pk {
                    pk = d;
                    ik = j as i64;
                }
            }
            self.p.set(k, pk);
            self.i[k] = ik;
        }
    }
}

/// The retained scalar-row oracle (`kernel::scalar_row`) driven over
/// plain vectors — per-cell branchy walk, but deferred sqrt and
/// hoisted bounds, isolating what the per-cell drag alone cost.
struct OracleRowStream {
    m: usize,
    excl: usize,
    t: Vec<f64>,
    za: Vec<f64>,
    zb: Vec<f64>,
    q: Vec<f64>,
    p: Vec<f64>,
    i: Vec<i64>,
    s: f64,
    s2: f64,
    work: WorkStats,
}

impl OracleRowStream {
    fn new(m: usize, excl: usize) -> Self {
        OracleRowStream {
            m,
            excl,
            t: Vec::new(),
            za: Vec::new(),
            zb: Vec::new(),
            q: Vec::new(),
            p: Vec::new(),
            i: Vec::new(),
            s: 0.0,
            s2: 0.0,
            work: WorkStats::default(),
        }
    }

    fn append(&mut self, x: f64) {
        let m = self.m;
        self.t.push(x);
        let n = self.t.len();
        self.s += x;
        self.s2 += x * x;
        if n > m {
            let old = self.t[n - 1 - m];
            self.s -= old;
            self.s2 -= old * old;
        }
        if n < m {
            return;
        }
        let mf = m as f64;
        let mean = self.s / mf;
        let var = (self.s2 / mf - mean * mean).max(0.0);
        let sd = var.sqrt();
        if sd > 0.0 {
            self.za.push(std::f64::consts::SQRT_2 / sd);
            self.zb.push((2.0 * mf).sqrt() * mean / sd);
        } else {
            self.za.push(0.0);
            self.zb.push(0.0);
        }
        self.q.push(0.0);
        self.p.push(f64::INFINITY);
        self.i.push(-1);
        let nw = self.p.len();
        let tile = RowTile {
            t: &self.t[..nw + m - 1],
            za: &self.za,
            zb: &self.zb,
            q: &mut self.q,
            p: &mut self.p,
            i: &mut self.i,
            base: 0,
        };
        kernel::scalar_row(tile, m, self.excl, &mut self.work);
    }
}

struct Row {
    engine: &'static str,
    ns_per_cell: f64,
    speedup_vs_eager: f64,
}

fn main() {
    let json = std::env::args().any(|a| a == "--json");
    let n = 16_384;
    let m = 64;
    let extra = 2048; // steady-state appends measured beyond n
    let t = generate::<f64>(Pattern::RandomWalk, n + extra, 9);

    // (a) batch recompute at n: what a per-sample recompute would pay.
    let cfg = MpConfig::new(m);
    let batch = time_budget(3.0, || {
        black_box(scrimp::matrix_profile(&t[..n], cfg).unwrap());
    });

    // (b) build the stream to n (amortized per-sample build cost)...
    let mut eng = Stampi::<f64>::new(StampiConfig::new(m)).unwrap();
    let t0 = Instant::now();
    for &x in &t[..n] {
        eng.append(x);
    }
    let build_s = t0.elapsed().as_secs_f64();

    // ...then measure steady-state appends at length ~n.
    let cells_before = eng.work().cells;
    let t0 = Instant::now();
    for &x in &t[n..n + extra] {
        black_box(eng.append(x));
    }
    let append_s = t0.elapsed().as_secs_f64() / extra as f64;
    let measured_cells = eng.work().cells - cells_before;

    // (c) bounded history: constant-size state, constant append cost.
    let history = 4096;
    let mut bounded = Stampi::<f64>::new(
        StampiConfig::new(m).with_max_history(history),
    )
    .unwrap();
    for &x in &t[..n] {
        bounded.append(x);
    }
    let t0 = Instant::now();
    for &x in &t[n..n + extra] {
        black_box(bounded.append(x));
    }
    let bounded_append_s = t0.elapsed().as_secs_f64() / extra as f64;

    let mut table = Table::new(&["path", "per new sample", "samples/s"]);
    table.row(&[
        "batch recompute (scrimp)".into(),
        fmt_time(batch.median),
        format!("{:.2}", 1.0 / batch.median),
    ]);
    table.row(&[
        "STAMPI append (unbounded)".into(),
        fmt_time(append_s),
        format!("{:.0}", 1.0 / append_s),
    ]);
    table.row(&[
        format!("STAMPI append (history {history})"),
        fmt_time(bounded_append_s),
        format!("{:.0}", 1.0 / bounded_append_s),
    ]);
    table.print(&format!("streaming vs recompute-from-scratch (n={n}, m={m})"));

    println!(
        "\nstream build 0..{n}: {} total ({:.0} samples/s amortized)",
        fmt_time(build_s),
        n as f64 / build_s
    );
    let recompute_speedup = batch.median / append_s;
    println!(
        "incremental append speedup over full recompute: {recompute_speedup:.0}x \
         (acceptance bar: 10x)"
    );
    assert!(
        recompute_speedup >= 10.0,
        "streaming append must beat per-sample batch recompute by >= 10x, \
         got {recompute_speedup:.1}x"
    );

    // (d) the row-kernel trajectory: all four row paths executing the
    // SAME steady-state appends (t[n..n+extra] after a build to n), so
    // ns/cell isolates the hot-loop shape.  scalar-row-eager is the
    // pre-kernel engine loop; kernel-row-blocked is what the service's
    // batch-append jobs run.
    let mut rows: Vec<Row> = Vec::new();
    let mut row_table = Table::new(&["row path", "per append", "ns/cell", "vs eager"]);

    // exclusion must match the Stampi engines exactly — the four rows
    // share `measured_cells` as their ns/cell denominator
    let excl = StampiConfig::new(m).exclusion();
    let mut eager = EagerRowStream::new(m, excl);
    for &x in &t[..n] {
        eager.append(x);
    }
    let t0 = Instant::now();
    for &x in &t[n..n + extra] {
        eager.append(x);
    }
    black_box(&eager.p);
    let eager_ns = t0.elapsed().as_secs_f64() / measured_cells as f64 * 1e9;

    let mut oracle = OracleRowStream::new(m, excl);
    for &x in &t[..n] {
        oracle.append(x);
    }
    let t0 = Instant::now();
    for &x in &t[n..n + extra] {
        oracle.append(x);
    }
    black_box(&oracle.p);
    let oracle_ns = t0.elapsed().as_secs_f64() / measured_cells as f64 * 1e9;

    // kernel width-1: the Stampi::append path measured in (b).
    let kernel_row_ns = append_s * extra as f64 / measured_cells as f64 * 1e9;

    // blocked multi-row tiles: Stampi::extend on the same samples.
    let mut blocked = Stampi::<f64>::new(StampiConfig::new(m)).unwrap();
    for &x in &t[..n] {
        blocked.append(x);
    }
    let t0 = Instant::now();
    blocked.extend(&t[n..n + extra]);
    black_box(blocked.num_windows());
    let blocked_s = t0.elapsed().as_secs_f64();
    let blocked_ns = blocked_s / measured_cells as f64 * 1e9;

    for (engine, ns) in [
        ("scalar-row-eager", eager_ns),
        ("scalar-row", oracle_ns),
        ("kernel-row", kernel_row_ns),
        ("kernel-row-blocked", blocked_ns),
    ] {
        let speedup = eager_ns / ns;
        row_table.row(&[
            engine.into(),
            fmt_time(ns * measured_cells as f64 / extra as f64 / 1e9),
            format!("{ns:.3}"),
            format!("{speedup:.2}x"),
        ]);
        rows.push(Row { engine, ns_per_cell: ns, speedup_vs_eager: speedup });
    }
    row_table.print(&format!(
        "STAMPI row paths at steady state (n={n}, m={m}, {extra} appends, \
         {measured_cells} cells)"
    ));

    let blocked_speedup = eager_ns / blocked_ns;
    println!(
        "\nblocked multi-row extend speedup over the old per-append scalar row: \
         {blocked_speedup:.2}x (acceptance bar: 1.5x)"
    );
    assert!(
        blocked_speedup >= 1.5,
        "blocked extend must beat the pre-kernel per-append row by >= 1.5x, \
         got {blocked_speedup:.2}x"
    );

    // (e) the deployment face: S concurrent streams pipelining appends
    // through the sharded AnalysisService.  More shards = fewer streams
    // per queue and a private worker pool per shard, so one stream's
    // turn-waiting can't park the fleet (scaling is machine-dependent —
    // this section reports, it does not gate).
    let streams = 8usize;
    let packets = 16usize;
    let chunk = 256usize;
    let mut shard_table = Table::new(&["shards", "wall", "samples/s"]);
    for &shards in &[1usize, 2, 4] {
        let svc = Arc::new(AnalysisService::<f64>::start_sharded(
            NatsaConfig::default().with_threads(1),
            ServiceConfig::default()
                .with_shards(shards)
                .with_workers(2)
                .with_queue_depth(8),
        ));
        let t0 = Instant::now();
        let handles: Vec<_> = (0..streams)
            .map(|c| {
                let svc = svc.clone();
                std::thread::spawn(move || {
                    let t = generate::<f64>(Pattern::RandomWalk, packets * chunk, c as u64);
                    let stream = svc.submit_stream(m, None).unwrap();
                    let mut pending = VecDeque::new();
                    for packet in t.chunks(chunk) {
                        let _ = svc
                            .append_stream_pipelined(stream, packet, &mut pending)
                            .unwrap();
                    }
                    for id in pending {
                        let _ = svc.wait(id);
                    }
                    svc.close_stream(stream);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let total = (streams * packets * chunk) as f64;
        shard_table.row(&[
            format!("{shards}"),
            fmt_time(wall),
            format!("{:.0}", total / wall),
        ]);
        assert_eq!(svc.metrics().in_flight(), 0, "shard bench left jobs in flight");
        assert_eq!(svc.retained_results(), 0, "shard bench leaked results");
    }
    shard_table.print(&format!(
        "sharded service: {streams} concurrent streams x {packets} packets x {chunk} samples (m={m})"
    ));

    // (f) WAL overhead: the same single-stream feed with durability off,
    // on (buffered, the default), and on with fsync per record.  Report
    // only — disk characteristics vary wildly across machines, and the
    // durability knob is exactly the throughput trade the numbers show.
    let wal_packets = 64usize;
    let wal_chunk = 256usize;
    let feed = generate::<f64>(Pattern::RandomWalk, wal_packets * wal_chunk, 17);
    let mut wal_table = Table::new(&["durability", "per packet", "overhead"]);
    let mut wal_base = 0.0f64;
    for (k, (label, wal)) in [
        ("off", None),
        ("wal (buffered)", Some(false)),
        ("wal (fsync per record)", Some(true)),
    ]
    .into_iter()
    .enumerate()
    {
        let dir = std::env::temp_dir().join(format!(
            "natsa-bench-wal-{}-{k}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = ServiceConfig::default()
            .with_shards(1)
            .with_workers(1)
            .with_queue_depth(8);
        if let Some(sync) = wal {
            cfg = cfg
                .with_wal(dir.clone())
                .with_wal_options(WalOptions { sync, ..WalOptions::default() });
        }
        let svc =
            AnalysisService::<f64>::start_sharded(NatsaConfig::default().with_threads(1), cfg);
        let stream = svc.submit_stream(m, None).unwrap();
        let t0 = Instant::now();
        for packet in feed.chunks(wal_chunk) {
            let id = svc.append_stream(stream, packet).unwrap();
            svc.wait(id).unwrap().profile.unwrap();
        }
        let per_packet = t0.elapsed().as_secs_f64() / wal_packets as f64;
        svc.close_stream(stream);
        svc.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
        if wal.is_none() {
            wal_base = per_packet;
        }
        wal_table.row(&[
            label.into(),
            fmt_time(per_packet),
            format!("{:+.1}%", (per_packet / wal_base - 1.0) * 100.0),
        ]);
    }
    wal_table.print(&format!(
        "WAL overhead: 1 stream x {wal_packets} packets x {wal_chunk} samples (m={m}, report-only)"
    ));

    // (g) cross-stream coalescing: S streams each appending ONE sample
    // at a time — the worst case for the blocked path, since no client
    // ever hands the service a packet.  With the drain-and-group worker
    // the shard fuses concurrent singles into shared row tiles, so the
    // steady state rides the multi-lane kernel anyway.  Serial
    // (`with_coalesce(1)`) vs default drain, same feed, one shard, one
    // worker (report-only: the ratio tracks kernel-row vs blocked above,
    // minus queue bookkeeping).
    let storm_streams = 8usize;
    let storm_warm = 2048usize;
    let storm_rounds = 512usize;
    let storm = |coalesce: usize| -> (f64, f64) {
        let svc = AnalysisService::<f64>::start_sharded(
            NatsaConfig::default().with_threads(1),
            ServiceConfig::default()
                .with_shards(1)
                .with_workers(1)
                .with_queue_depth(256)
                .with_coalesce(coalesce),
        );
        let tapes: Vec<Vec<f64>> = (0..storm_streams)
            .map(|c| {
                generate::<f64>(Pattern::RandomWalk, storm_warm + storm_rounds, 40 + c as u64)
            })
            .collect();
        let ids: Vec<u64> = (0..storm_streams)
            .map(|_| svc.submit_stream(m, None).unwrap())
            .collect();
        for (w, &id) in ids.iter().enumerate() {
            let job = svc.append_stream(id, &tapes[w][..storm_warm]).unwrap();
            svc.wait(job).unwrap().profile.unwrap();
        }
        let mut pending = VecDeque::new();
        let t0 = Instant::now();
        for r in 0..storm_rounds {
            for (w, &id) in ids.iter().enumerate() {
                loop {
                    match svc.append_stream(id, &[tapes[w][storm_warm + r]]) {
                        Ok(j) => {
                            pending.push_back(j);
                            break;
                        }
                        Err(SubmitError::Backpressure) => {
                            let j = pending.pop_front().unwrap();
                            svc.wait(j).unwrap().profile.unwrap();
                        }
                        Err(e) => panic!("storm append: {e}"),
                    }
                }
            }
        }
        for j in pending {
            svc.wait(j).unwrap().profile.unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let mean_width = svc.metrics().coalesce_width.mean();
        for id in ids {
            svc.close_stream(id);
        }
        svc.shutdown();
        (wall, mean_width)
    };
    let (serial_wall, _) = storm(1);
    let (group_wall, mean_width) = storm(kernel::BAND);
    let storm_appends = (storm_streams * storm_rounds) as f64;
    let coalesce_speedup = serial_wall / group_wall;
    let mut storm_table = Table::new(&["worker path", "per append", "samples/s", "mean width"]);
    storm_table.row(&[
        "serial (coalesce=1)".into(),
        fmt_time(serial_wall / storm_appends),
        format!("{:.0}", storm_appends / serial_wall),
        "1.0".into(),
    ]);
    storm_table.row(&[
        "drain-and-group".into(),
        fmt_time(group_wall / storm_appends),
        format!("{:.0}", storm_appends / group_wall),
        format!("{mean_width:.1}"),
    ]);
    storm_table.print(&format!(
        "cross-stream coalescing: {storm_streams} streams x {storm_rounds} single appends \
         (m={m}, 1 shard, 1 worker)"
    ));
    println!(
        "coalesced single-append storm speedup over serial worker: {coalesce_speedup:.2}x \
         (report-only)"
    );

    if json {
        let mut out = String::from(
            "{\n  \"bench\": \"streaming\",\n  \
             \"harness\": \"cargo bench --bench streaming -- --json\",\n",
        );
        out.push_str(&format!(
            "  \"append_vs_recompute_speedup\": {recompute_speedup:.0},\n"
        ));
        out.push_str(&format!(
            "  \"coalesce_storm\": {{\"streams\": {storm_streams}, \"rounds\": {storm_rounds}, \
             \"serial_ns_per_append\": {:.0}, \"coalesced_ns_per_append\": {:.0}, \
             \"speedup\": {coalesce_speedup:.2}, \"mean_width\": {mean_width:.1}}},\n",
            serial_wall / storm_appends * 1e9,
            group_wall / storm_appends * 1e9,
        ));
        out.push_str("  \"entries\": [\n");
        for (k, r) in rows.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"n\": {n}, \"m\": {m}, \"extra\": {extra}, \"dtype\": \"f64\", \
                 \"engine\": \"{}\", \"isa\": \"{}\", \"ns_per_cell\": {:.3}, \
                 \"speedup_vs_eager\": {:.2}}}{}\n",
                r.engine,
                isa(),
                r.ns_per_cell,
                r.speedup_vs_eager,
                if k + 1 < rows.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        std::fs::write("BENCH_streaming.json", &out).expect("write BENCH_streaming.json");
        println!("\nwrote BENCH_streaming.json");
    }
}
