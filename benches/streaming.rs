//! Streaming throughput: incremental STAMPI append vs recomputing the
//! batch profile from scratch on every new sample — the acceptance
//! benchmark for the streaming subsystem (>= 10x at n = 16384, m = 64;
//! the asymptotic gap is O(n) vs O(n²) per sample, so the measured ratio
//! lands orders of magnitude beyond the bar).

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use natsa::benchmark::{black_box, fmt_time, time_budget, Table};
use natsa::coordinator::service::{AnalysisService, ServiceConfig};
use natsa::mp::stampi::{Stampi, StampiConfig};
use natsa::mp::{scrimp, MpConfig};
use natsa::natsa::NatsaConfig;
use natsa::timeseries::generator::{generate, Pattern};

fn main() {
    let n = 16_384;
    let m = 64;
    let extra = 1024; // steady-state appends measured beyond n
    let t = generate::<f64>(Pattern::RandomWalk, n + extra, 9);

    // (a) batch recompute at n: what a per-sample recompute would pay.
    let cfg = MpConfig::new(m);
    let batch = time_budget(3.0, || {
        black_box(scrimp::matrix_profile(&t[..n], cfg).unwrap());
    });

    // (b) build the stream to n (amortized per-sample build cost)...
    let mut eng = Stampi::<f64>::new(StampiConfig::new(m)).unwrap();
    let t0 = Instant::now();
    for &x in &t[..n] {
        eng.append(x);
    }
    let build_s = t0.elapsed().as_secs_f64();

    // ...then measure steady-state appends at length ~n.
    let t0 = Instant::now();
    for &x in &t[n..n + extra] {
        black_box(eng.append(x));
    }
    let append_s = t0.elapsed().as_secs_f64() / extra as f64;

    // (c) bounded history: constant-size state, constant append cost.
    let history = 4096;
    let mut bounded = Stampi::<f64>::new(
        StampiConfig::new(m).with_max_history(history),
    )
    .unwrap();
    for &x in &t[..n] {
        bounded.append(x);
    }
    let t0 = Instant::now();
    for &x in &t[n..n + extra] {
        black_box(bounded.append(x));
    }
    let bounded_append_s = t0.elapsed().as_secs_f64() / extra as f64;

    let mut table = Table::new(&["path", "per new sample", "samples/s"]);
    table.row(&[
        "batch recompute (scrimp)".into(),
        fmt_time(batch.median),
        format!("{:.2}", 1.0 / batch.median),
    ]);
    table.row(&[
        "STAMPI append (unbounded)".into(),
        fmt_time(append_s),
        format!("{:.0}", 1.0 / append_s),
    ]);
    table.row(&[
        format!("STAMPI append (history {history})"),
        fmt_time(bounded_append_s),
        format!("{:.0}", 1.0 / bounded_append_s),
    ]);
    table.print(&format!("streaming vs recompute-from-scratch (n={n}, m={m})"));

    println!(
        "\nstream build 0..{n}: {} total ({:.0} samples/s amortized)",
        fmt_time(build_s),
        n as f64 / build_s
    );
    let speedup = batch.median / append_s;
    println!(
        "incremental append speedup over full recompute: {speedup:.0}x (acceptance bar: 10x)"
    );
    assert!(
        speedup >= 10.0,
        "streaming append must beat per-sample batch recompute by >= 10x, got {speedup:.1}x"
    );

    // (d) the deployment face: S concurrent streams pipelining appends
    // through the sharded AnalysisService.  More shards = fewer streams
    // per queue and a private worker pool per shard, so one stream's
    // turn-waiting can't park the fleet (scaling is machine-dependent —
    // this section reports, it does not gate).
    let streams = 8usize;
    let packets = 16usize;
    let chunk = 256usize;
    let mut shard_table = Table::new(&["shards", "wall", "samples/s"]);
    for &shards in &[1usize, 2, 4] {
        let svc = Arc::new(AnalysisService::<f64>::start_sharded(
            NatsaConfig::default().with_threads(1),
            ServiceConfig::default()
                .with_shards(shards)
                .with_workers(2)
                .with_queue_depth(8),
        ));
        let t0 = Instant::now();
        let handles: Vec<_> = (0..streams)
            .map(|c| {
                let svc = svc.clone();
                std::thread::spawn(move || {
                    let t = generate::<f64>(Pattern::RandomWalk, packets * chunk, c as u64);
                    let stream = svc.submit_stream(m, None).unwrap();
                    let mut pending = VecDeque::new();
                    for packet in t.chunks(chunk) {
                        let _ = svc
                            .append_stream_pipelined(stream, packet, &mut pending)
                            .unwrap();
                    }
                    for id in pending {
                        let _ = svc.wait(id);
                    }
                    svc.close_stream(stream);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let total = (streams * packets * chunk) as f64;
        shard_table.row(&[
            format!("{shards}"),
            fmt_time(wall),
            format!("{:.0}", total / wall),
        ]);
        assert_eq!(svc.metrics().in_flight(), 0, "shard bench left jobs in flight");
        assert_eq!(svc.retained_results(), 0, "shard bench leaked results");
    }
    shard_table.print(&format!(
        "sharded service: {streams} concurrent streams x {packets} packets x {chunk} samples (m={m})"
    ));
}
