//! Table 2: execution time for SP and DP across six configs and the five
//! Table 1 sizes (model vs paper), plus wall-clock of the functional
//! engines on down-scaled workloads so the trends are also *measured*.

use natsa::benchmark::{black_box, fmt_time, time_budget, Table};
use natsa::mp::{scrimp, stomp, MpConfig};
use natsa::natsa::{NatsaConfig, NatsaEngine};
use natsa::sim::accel::NatsaDesign;
use natsa::sim::platform::GpPlatform;
use natsa::sim::{Precision, Workload};
use natsa::timeseries::generator::{generate, Pattern};

fn main() {
    // (a) the paper table, model vs paper rows
    println!("{}", natsa::report::run("table2").unwrap());

    // (b) measured trends on this host (sizes scaled down ~32x)
    let m = 256;
    let mut t = Table::new(&["n", "scrimp f64", "scrimp f32", "stomp f64", "natsa f64"]);
    for n in [16_384usize, 32_768, 49_152] {
        let t64 = generate::<f64>(Pattern::RandomWalk, n, 4);
        let t32: Vec<f32> = t64.iter().map(|&x| x as f32).collect();
        let cfg = MpConfig::new(m);
        let s64 = time_budget(1.0, || {
            black_box(scrimp::matrix_profile(&t64, cfg).unwrap());
        });
        let s32 = time_budget(1.0, || {
            black_box(scrimp::matrix_profile(&t32, cfg).unwrap());
        });
        let st = time_budget(1.0, || {
            black_box(stomp::matrix_profile(&t64, cfg).unwrap());
        });
        let engine = NatsaEngine::<f64>::new(NatsaConfig::default());
        let na = time_budget(1.0, || {
            black_box(engine.compute(&t64, m).unwrap());
        });
        t.row(&[
            n.to_string(),
            fmt_time(s64.median),
            fmt_time(s32.median),
            fmt_time(st.median),
            fmt_time(na.median),
        ]);
    }
    t.print("measured on this host (functional plane, m=256)");

    // quadratic scaling check, as in Table 2
    let w1 = Workload::new(16_384, m);
    let w2 = Workload::new(65_536, m);
    println!(
        "\ncell ratio 16K->64K: {:.1}x (time should scale ~the same; Table 2 scales ~16x per 4x n)",
        w2.cells as f64 / w1.cells as f64
    );
    let _ = GpPlatform::ddr4_ooo(); // keep model linkage for the reader
    let _ = NatsaDesign::hbm(Precision::Dp);
}
