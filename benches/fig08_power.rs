//! Fig. 8: dynamic power per platform (rand_512K DP).  Pure model/report
//! regeneration — power cannot be measured on this substrate.
fn main() {
    println!("{}", natsa::report::run("fig8").unwrap());
}
