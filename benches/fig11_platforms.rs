//! Fig. 11: general-purpose platform speedups over the baseline and their
//! memory bandwidth usage, all Table 1 sizes (model).
fn main() {
    println!("{}", natsa::report::run("fig11").unwrap());
}
