//! PJRT hot-path benchmark: throughput of the AOT diag_chunk kernel and
//! the end-to-end coordinator on a small workload.  Skips (with a clear
//! message) when `make artifacts` has not run.
//!
//! This is the L1/L2 perf-pass instrument: interpret-mode Pallas on CPU
//! measures *structure* (calls, per-call overhead), not TPU speed — the
//! TPU projection lives in DESIGN.md §7.

use natsa::benchmark::{black_box, fmt_time, time, time_budget, Table};
use natsa::coordinator::PjrtEngine;
use natsa::natsa::NatsaConfig;
use natsa::runtime::{default_artifact_dir, Runtime};
use natsa::timeseries::generator::{generate, Pattern};
use natsa::timeseries::sliding_stats;

fn main() {
    let dir = default_artifact_dir();
    let rt = match Runtime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP pjrt_kernel bench: {e}");
            return;
        }
    };

    let m = 128;
    // chunk length of the preferred lowered kernel (largest available V)
    let v = rt
        .manifest()
        .find(natsa::runtime::ArtifactKind::DiagChunk, "f64", m)
        .expect("diag_chunk artifact")
        .v;
    let n = 4 * v + 2 * m;
    let t64 = generate::<f64>(Pattern::RandomWalk, n, 13);
    let st = sliding_stats(&t64, m);

    // per-call kernel latency (dot_init, diag_chunk) for both dtypes
    let mut table = Table::new(&["kernel", "median/call", "cells/s"]);
    {
        let s = time_budget(1.5, || {
            black_box(rt.dot_init(m, &t64[..m], &t64[m..2 * m]).unwrap());
        });
        table.row(&["dot_init f64".into(), fmt_time(s.median), "-".into()]);
    }
    {
        let ta = &t64[0..v + m];
        let tb = &t64[m - 1..m - 1 + v + m];
        let mu_a = &st.mu[1..1 + v];
        let sig_a = &st.sig[1..1 + v];
        let mu_b = &st.mu[m..m + v];
        let sig_b = &st.sig[m..m + v];
        let q0 = t64[1..1 + m]
            .iter()
            .zip(&t64[m..2 * m])
            .map(|(a, b)| a * b)
            .sum::<f64>();
        let s = time_budget(2.0, || {
            black_box(
                rt.diag_chunk(m, Some(v), ta, tb, mu_a, sig_a, mu_b, sig_b, q0, v)
                    .unwrap(),
            );
        });
        table.row(&[
            format!("diag_chunk f64 ({v} cells)"),
            fmt_time(s.median),
            format!("{:.2e}", s.throughput(v as u64)),
        ]);
    }
    {
        let t32: Vec<f32> = t64.iter().map(|&x| x as f32).collect();
        let st32 = sliding_stats(&t32, m);
        let q0 = t32[1..1 + m]
            .iter()
            .zip(&t32[m..2 * m])
            .map(|(a, b)| a * b)
            .sum::<f32>();
        let s = time_budget(2.0, || {
            black_box(
                rt.diag_chunk(
                    m,
                    Some(v),
                    &t32[0..v + m],
                    &t32[m - 1..m - 1 + v + m],
                    &st32.mu[1..1 + v],
                    &st32.sig[1..1 + v],
                    &st32.mu[m..m + v],
                    &st32.sig[m..m + v],
                    q0,
                    v,
                )
                .unwrap(),
            );
        });
        table.row(&[
            format!("diag_chunk f32 ({v} cells)"),
            fmt_time(s.median),
            format!("{:.2e}", s.throughput(v as u64)),
        ]);
    }
    table.print("AOT kernel latency via PJRT (interpret-mode Pallas, CPU)");

    // end-to-end coordinator throughput, 1 vs 4 workers
    let n_e2e = 2048;
    let series = generate::<f64>(Pattern::RandomWalk, n_e2e, 14);
    let cells = natsa::mp::total_cells(n_e2e - m + 1, m / 4);
    let mut table = Table::new(&["workers", "median", "cells/s"]);
    for workers in [1usize, 2, 4] {
        let engine = PjrtEngine::<f64>::new(NatsaConfig::default(), dir.clone())
            .with_workers(workers);
        let s = time(0, 3, || {
            black_box(engine.compute(&series, m).unwrap());
        });
        table.row(&[
            workers.to_string(),
            fmt_time(s.median),
            format!("{:.2e}", s.throughput(cells)),
        ]);
    }
    table.print(&format!("PJRT coordinator end-to-end (n={n_e2e}, m={m})"));
}
