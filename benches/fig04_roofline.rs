//! Fig. 4: roofline analysis of SCRIMP.
//!
//! (a) the KNL model regenerating the paper's plot (AI far left of the
//! ridge, achieved a tiny fraction of peak), and (b) a measured point:
//! the achieved FLOP rate of our rust SCRIMP on this host against the
//! host's own crude roofline.

use natsa::benchmark::{black_box, time_budget, Table};
use natsa::mp::{scrimp, MpConfig};
use natsa::sim::roofline::{fig4_points, Roofline};
use natsa::sim::Workload;
use natsa::timeseries::generator::{generate, Pattern};

fn main() {
    // (a) model
    let w = Workload::new(1_048_576, 256);
    let roof = Roofline::knl7210();
    let mut t = Table::new(&["memory", "AI flop/B", "achieved GF/s", "attainable GF/s", "% peak"]);
    for (name, p) in fig4_points(&w) {
        t.row(&[
            name,
            format!("{:.3}", p.ai_flop_per_byte),
            format!("{:.1}", p.achieved_gflops),
            format!("{:.1}", p.attainable_gflops),
            format!("{:.2}%", p.peak_fraction * 100.0),
        ]);
    }
    t.print(&format!(
        "Fig. 4 (model): KNL roofline, peak {:.0} GFLOP/s, ridges {:.1} / {:.1} flop/B",
        roof.peak_gflops,
        roof.ridge(0),
        roof.ridge(1)
    ));

    // (b) measured: flops/s of rust SCRIMP on this host
    let n = 40_000;
    let m = 128;
    let series = generate::<f64>(Pattern::RandomWalk, n, 2);
    let cfg = MpConfig::new(m);
    let (_, work) = scrimp::with_stats(&series, cfg, scrimp::DiagOrder::Sequential).unwrap();
    let flops = work.flops(m);
    let s = time_budget(2.0, || {
        black_box(scrimp::matrix_profile(&series, cfg).unwrap());
    });
    println!(
        "\nmeasured (this host, 1 thread): {:.2} GFLOP/s over {:.2e} flops \
         ({} per cell model)",
        flops as f64 / s.median / 1e9,
        flops as f64,
        13
    );
    println!("paper's point: SCRIMP sits on the bandwidth roof, far below compute peak.");
}
