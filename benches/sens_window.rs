//! Section 6.5: sensitivity to the subsequence length m — model
//! regeneration plus a measured sweep of rust SCRIMP, which must show the
//! same effect: larger m reduces execution time, strongly when n/m is
//! small and weakly when n/m is large.

use natsa::benchmark::{black_box, fmt_time, time_budget, Table};
use natsa::mp::{scrimp, MpConfig};
use natsa::timeseries::generator::{generate, Pattern};

fn main() {
    println!("{}", natsa::report::run("sens-m").unwrap());

    let mut t = Table::new(&["n", "m", "median", "vs m=min"]);
    for n in [8_192usize, 49_152] {
        let series = generate::<f64>(Pattern::RandomWalk, n, 6);
        let ms: Vec<usize> = vec![64, 256, 1024, n / 8];
        let mut base = 0.0;
        for (k, &m) in ms.iter().enumerate() {
            let cfg = MpConfig::new(m);
            let s = time_budget(1.0, || {
                black_box(scrimp::matrix_profile(&series, cfg).unwrap());
            });
            if k == 0 {
                base = s.median;
            }
            t.row(&[
                n.to_string(),
                m.to_string(),
                fmt_time(s.median),
                format!("{:+.1}%", (s.median / base - 1.0) * 100.0),
            ]);
        }
    }
    t.print("measured: rust SCRIMP window-length sensitivity");
    println!(
        "\npaper: m 1K->16K cuts time 41% at n=128K but only 13% at n=2M\n\
         (shorter profiles + fewer diagonals; first-dot amortization)."
    );
}
