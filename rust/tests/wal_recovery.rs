//! Kill/restart differential for the per-shard WAL.
//!
//! The durability contract: a service restarted from its WAL directory
//! rebuilds every open session **bit-identically** — feeding half a
//! stream, restarting, and feeding the rest (same packet boundaries)
//! must produce exactly the profile of an uninterrupted run, for f32
//! and f64 alike.  Closed streams must stay closed across restarts, and
//! the directory's identity (dtype, shard count) is pinned at first use.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use natsa::coordinator::service::{AnalysisService, ServiceConfig, SubmitError};
use natsa::coordinator::wal::WalOptions;
use natsa::mp::MatrixProfile;
use natsa::natsa::NatsaConfig;
use natsa::timeseries::generator::{generate, Pattern};
use natsa::Real;

fn tempdir(tag: &str) -> PathBuf {
    static N: AtomicU64 = AtomicU64::new(0);
    let d = std::env::temp_dir().join(format!(
        "natsa-wal-recovery-{tag}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Bit-level equality — `max_abs_diff` tolerances would hide exactly the
/// class of bug (reordered float ops on replay) this test exists to catch.
fn assert_bit_identical<T: Real>(got: &MatrixProfile<T>, want: &MatrixProfile<T>) {
    assert_eq!(got.p.len(), want.p.len(), "profile length");
    for (k, (a, b)) in got.p.iter().zip(&want.p).enumerate() {
        assert_eq!(
            a.to_f64s().to_bits(),
            b.to_f64s().to_bits(),
            "profile bit mismatch at {k}: {a} vs {b}"
        );
    }
    assert_eq!(got.i, want.i, "index vector mismatch");
}

/// Deliberately uneven packet boundaries: replay re-applies packet by
/// packet, so boundary-dependent tile blocking is part of the contract.
fn packets<T: Real>(n: usize, seed: u64) -> Vec<Vec<T>> {
    let series = generate::<T>(Pattern::EcgLike, n, seed);
    let sizes = [97usize, 53, 128, 31];
    let mut out = Vec::new();
    let (mut at, mut k) = (0, 0);
    while at < n {
        let len = sizes[k % sizes.len()].min(n - at);
        out.push(series[at..at + len].to_vec());
        at += len;
        k += 1;
    }
    out
}

fn wal_config(dir: &Path) -> ServiceConfig {
    ServiceConfig::default()
        .with_shards(2)
        .with_workers(1)
        .with_queue_depth(32)
        .with_wal(dir)
        // tight knobs so one run crosses several snapshots, rotations
        // and compactions — not just the happy single-segment path
        .with_wal_options(WalOptions {
            snapshot_every: 3,
            segment_bytes: 2048,
            sync: false,
        })
}

fn feed<T: Real>(s: &AnalysisService<T>, stream: u64, packets: &[Vec<T>]) {
    for p in packets {
        let id = s.append_stream(stream, p).unwrap();
        s.wait(id).unwrap().profile.unwrap();
    }
}

fn kill_restart_differential<T: Real>() {
    let m = 32;
    let pk = packets::<T>(2400, 11);
    let half = pk.len() / 2;

    // uninterrupted reference: identical service code path, no WAL
    let reference = {
        let s = AnalysisService::<T>::start_sharded(
            NatsaConfig::default().with_threads(1),
            ServiceConfig::default()
                .with_shards(2)
                .with_workers(1)
                .with_queue_depth(32),
        );
        let stream = s.submit_stream(m, None).unwrap();
        feed(&s, stream, &pk);
        let snap = s.snapshot_stream(stream).unwrap();
        s.close_stream(stream);
        s.shutdown();
        snap
    };

    let dir = tempdir(T::DTYPE);

    // run 1: feed the first half, then stop WITHOUT closing the stream
    let stream = {
        let s = AnalysisService::<T>::try_start_sharded(
            NatsaConfig::default().with_threads(1),
            wal_config(&dir),
        )
        .unwrap();
        let stream = s.submit_stream(m, None).unwrap();
        feed(&s, stream, &pk[..half]);
        assert_eq!(s.metrics().wal_errors.load(Ordering::Relaxed), 0);
        s.shutdown(); // session survives only through the WAL now
        stream
    };

    // run 2: recover from the WAL, feed the remaining packets
    let got = {
        let s = AnalysisService::<T>::try_start_sharded(
            NatsaConfig::default().with_threads(1),
            wal_config(&dir),
        )
        .unwrap();
        // the session is back under its old id, resumed mid-stream
        let fed: usize = pk[..half].iter().map(Vec::len).sum();
        let snap = s.snapshot_stream(stream).expect("stream not recovered");
        assert_eq!(snap.len(), fed - m + 1, "recovered at the wrong length");
        feed(&s, stream, &pk[half..]);
        // fresh ids must not collide with recovered ones
        let fresh = s.submit_stream(m, None).unwrap();
        assert_ne!(fresh, stream, "stream id reused after restart");
        s.close_stream(fresh);
        let got = s.snapshot_stream(stream).unwrap();
        assert_eq!(s.metrics().wal_errors.load(Ordering::Relaxed), 0);
        s.close_stream(stream);
        s.shutdown();
        got
    };

    assert_bit_identical(&got, &reference);

    // run 3: the Close was logged — replay must not resurrect the stream
    let s = AnalysisService::<T>::try_start_sharded(
        NatsaConfig::default().with_threads(1),
        wal_config(&dir),
    )
    .unwrap();
    assert!(
        s.snapshot_stream(stream).is_none(),
        "closed stream resurrected by replay"
    );
    assert_eq!(
        s.append_stream(stream, &[T::of_f64(1.0)]),
        Err(SubmitError::UnknownStream)
    );
    s.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn kill_restart_differential_f64() {
    kill_restart_differential::<f64>();
}

#[test]
fn kill_restart_differential_f32() {
    kill_restart_differential::<f32>();
}

/// REVIEW.md: a closed stream's `Close` record is compacted away by the
/// very next startup checkpoint, so a second restart used to derive its
/// id floor only from the surviving streams — and could re-issue the
/// closed stream's id.  The segment-header high-water keeps retired ids
/// retired across any number of restart/compaction cycles.
#[test]
fn closed_stream_ids_stay_retired_across_restarts() {
    let dir = tempdir("retire");
    let cfg = || NatsaConfig::default().with_threads(1);

    // run 1: a long-lived stream plus a stream that gets closed
    let (keeper, retired) = {
        let s = AnalysisService::<f64>::try_start_sharded(cfg(), wal_config(&dir)).unwrap();
        let keeper = s.submit_stream(16, None).unwrap();
        let retired = s.submit_stream(16, None).unwrap();
        feed(&s, keeper, &packets::<f64>(200, 3));
        s.close_stream(retired);
        s.shutdown();
        (keeper, retired)
    };

    // run 2: the startup checkpoint compacts the Close record away
    {
        let s = AnalysisService::<f64>::try_start_sharded(cfg(), wal_config(&dir)).unwrap();
        assert!(s.snapshot_stream(keeper).is_some(), "keeper lost across restart");
        assert!(s.snapshot_stream(retired).is_none(), "closed stream resurrected");
        s.shutdown();
    }

    // run 3: no retained record mentions the retired id any more — only
    // the segment headers' high-water does.  Fresh ids must still not
    // collide with it (or with anything else ever issued).
    {
        let s = AnalysisService::<f64>::try_start_sharded(cfg(), wal_config(&dir)).unwrap();
        let fresh = s.submit_stream(16, None).unwrap();
        assert_ne!(fresh, retired, "retired stream id re-issued after compaction");
        assert_ne!(fresh, keeper, "live stream id re-issued");
        s.close_stream(fresh);
        s.close_stream(keeper);
        s.shutdown();
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Migration × durability, clean-shutdown flavor: a stream fed half its
/// packets, migrated to another shard, and restarted must come back
/// **exactly once**, at the **target** home, and finish bit-identically.
/// The source directory still carries the stream's original `Open` plus
/// every pre-hop append; only its logged `Close` (and the target's
/// higher placement epoch) keep the old incarnation from resurrecting.
fn migrated_stream_recovers_once_at_target<T: Real>() {
    let m = 32;
    let pk = packets::<T>(2400, 23);
    let half = pk.len() / 2;

    let reference = {
        let s = AnalysisService::<T>::start_sharded(
            NatsaConfig::default().with_threads(1),
            ServiceConfig::default()
                .with_shards(2)
                .with_workers(1)
                .with_queue_depth(32),
        );
        let stream = s.submit_stream(m, None).unwrap();
        feed(&s, stream, &pk);
        let snap = s.snapshot_stream(stream).unwrap();
        s.close_stream(stream);
        s.shutdown();
        snap
    };

    let dir = tempdir(&format!("mig-{}", T::DTYPE));

    // run 1: feed half on the minted home, migrate, stop
    let (stream, target) = {
        let s = AnalysisService::<T>::try_start_sharded(
            NatsaConfig::default().with_threads(1),
            wal_config(&dir),
        )
        .unwrap();
        let stream = s.submit_stream(m, None).unwrap();
        feed(&s, stream, &pk[..half]);
        let from = s.stream_home(stream).expect("open stream must route");
        let to = 1 - from;
        s.migrate_stream(stream, to).expect("migration failed");
        assert_eq!(s.stream_home(stream), Some(to));
        assert_eq!(s.metrics().wal_errors.load(Ordering::Relaxed), 0);
        s.shutdown();
        (stream, to)
    };

    // run 2: recovery must pick the target incarnation — and only it
    let got = {
        let s = AnalysisService::<T>::try_start_sharded(
            NatsaConfig::default().with_threads(1),
            wal_config(&dir),
        )
        .unwrap();
        assert_eq!(
            s.stream_home(stream),
            Some(target),
            "recovery re-homed the migrated stream"
        );
        let fed: usize = pk[..half].iter().map(Vec::len).sum();
        let snap = s.snapshot_stream(stream).expect("migrated stream not recovered");
        assert_eq!(snap.len(), fed - m + 1, "recovered at the wrong length");
        feed(&s, stream, &pk[half..]);
        let got = s.snapshot_stream(stream).unwrap();
        assert_eq!(s.metrics().wal_errors.load(Ordering::Relaxed), 0);
        s.close_stream(stream);
        s.shutdown();
        got
    };
    assert_bit_identical(&got, &reference);

    // run 3: closed on the target — no directory resurrects it
    let s = AnalysisService::<T>::try_start_sharded(
        NatsaConfig::default().with_threads(1),
        wal_config(&dir),
    )
    .unwrap();
    assert!(
        s.snapshot_stream(stream).is_none(),
        "closed migrated stream resurrected by replay"
    );
    s.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn migrated_stream_recovers_once_at_target_f64() {
    migrated_stream_recovers_once_at_target::<f64>();
}

#[test]
fn migrated_stream_recovers_once_at_target_f32() {
    migrated_stream_recovers_once_at_target::<f32>();
}

/// Migration × durability, crash-window flavor.  The migration protocol
/// syncs the target's `Open`+`Snapshot` **before** writing the source's
/// `Close`, so a crash inside that window leaves the stream Open in
/// BOTH shard directories with no `Close` anywhere.  This test
/// hand-crafts exactly those bytes with the public WAL writer (the same
/// calls the live protocol makes) and asserts recovery resolves the
/// race by placement epoch: one live incarnation, homed on the target,
/// continuing bit-identically — and the loser is closed durably, so a
/// second restart cannot bring it back either.
fn crash_window_recovers_exactly_once<T: Real>() {
    use natsa::coordinator::wal::{replay, StreamMeta, WalOptions, WalWriter};

    let m = 32;
    let pk = packets::<T>(2400, 31);
    let half = pk.len() / 2;
    let reference = {
        let s = AnalysisService::<T>::start_sharded(
            NatsaConfig::default().with_threads(1),
            ServiceConfig::default()
                .with_shards(2)
                .with_workers(1)
                .with_queue_depth(32),
        );
        let stream = s.submit_stream(m, None).unwrap();
        feed(&s, stream, &pk);
        let snap = s.snapshot_stream(stream).unwrap();
        s.close_stream(stream);
        s.shutdown();
        snap
    };

    // The directory a crash mid-commit-window leaves behind.  Stream id
    // 256 packs shard 0 in its low bits — the mint-time hint; recovery
    // must ignore it and trust the epochs.
    let dir = tempdir(&format!("window-{}", T::DTYPE));
    let stream = 256u64;
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("wal.meta"),
        format!("natsa-wal v1 dtype={} shards=2\n", T::DTYPE),
    )
    .unwrap();
    let opts = WalOptions {
        snapshot_every: 3,
        segment_bytes: 2048,
        sync: false,
    };
    let meta = |epoch| StreamMeta {
        m,
        excl: None,
        max_history: None,
        epoch,
    };
    // shard 0 — the source: Open at epoch 1, every pre-hop append, and
    // crucially NO Close (it never reached the disk).
    {
        let sdir = dir.join("shard-0");
        let mut w = WalWriter::<T>::resume(&sdir, opts.clone(), &replay(&sdir).unwrap()).unwrap();
        w.log_open(stream, meta(1)).unwrap();
        for (seq, p) in pk[..half].iter().enumerate() {
            w.log_append(stream, seq as u64, p).unwrap();
        }
        w.sync().unwrap();
    }
    // shard 1 — the target: the migration's synced hand-off at epoch 2.
    // (The live protocol logs Open + a state Snapshot; an Open plus the
    // same appends replays to the identical session state through the
    // already-pinned recovery path, without reaching into session
    // internals from an integration test.)
    {
        let sdir = dir.join("shard-1");
        let mut w = WalWriter::<T>::resume(&sdir, opts.clone(), &replay(&sdir).unwrap()).unwrap();
        w.log_open(stream, meta(2)).unwrap();
        for (seq, p) in pk[..half].iter().enumerate() {
            w.log_append(stream, seq as u64, p).unwrap();
        }
        w.sync().unwrap();
    }

    // Recovery: epoch 2 wins — the stream lives exactly once, on the
    // target, and picks up where the migration left off.
    let got = {
        let s = AnalysisService::<T>::try_start_sharded(
            NatsaConfig::default().with_threads(1),
            wal_config(&dir),
        )
        .unwrap();
        assert_eq!(
            s.stream_home(stream),
            Some(1),
            "crash-window recovery homed the stream on the stale source"
        );
        let fed: usize = pk[..half].iter().map(Vec::len).sum();
        let snap = s.snapshot_stream(stream).expect("stream lost in the crash window");
        assert_eq!(snap.len(), fed - m + 1, "recovered at the wrong length");
        // a fresh stream id must mint above the crashed one
        let fresh = s.submit_stream(m, None).unwrap();
        assert_ne!(fresh, stream, "stream id reused across the crash window");
        s.close_stream(fresh);
        feed(&s, stream, &pk[half..]);
        let got = s.snapshot_stream(stream).unwrap();
        assert_eq!(s.metrics().wal_errors.load(Ordering::Relaxed), 0);
        s.shutdown();
        got
    };
    assert_bit_identical(&got, &reference);

    // Second restart: the first recovery closed the stale source copy
    // durably, so the stream is still exactly once — never duplicated,
    // never flapped back to shard 0.
    let s = AnalysisService::<T>::try_start_sharded(
        NatsaConfig::default().with_threads(1),
        wal_config(&dir),
    )
    .unwrap();
    assert_eq!(s.stream_home(stream), Some(1), "stale incarnation resurrected");
    assert!(s.snapshot_stream(stream).is_some());
    s.close_stream(stream);
    s.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn crash_window_recovers_exactly_once_f64() {
    crash_window_recovers_exactly_once::<f64>();
}

#[test]
fn crash_window_recovers_exactly_once_f32() {
    crash_window_recovers_exactly_once::<f32>();
}

#[test]
fn wal_dir_pins_dtype_and_shard_count() {
    let dir = tempdir("meta");
    let s = AnalysisService::<f64>::try_start_sharded(
        NatsaConfig::default().with_threads(1),
        wal_config(&dir),
    )
    .unwrap();
    let stream = s.submit_stream(16, None).unwrap();
    feed(&s, stream, &packets::<f64>(200, 3));
    s.shutdown();

    // same directory opened under another dtype: refused, not garbage
    assert!(
        AnalysisService::<f32>::try_start_sharded(
            NatsaConfig::default().with_threads(1),
            wal_config(&dir),
        )
        .is_err(),
        "f32 service accepted an f64 WAL directory"
    );
    // another shard count would misroute every stream directory: refused
    assert!(
        AnalysisService::<f64>::try_start_sharded(
            NatsaConfig::default().with_threads(1),
            wal_config(&dir).with_shards(4),
        )
        .is_err(),
        "shard-count mismatch accepted"
    );
    // the matching shape still recovers
    let s = AnalysisService::<f64>::try_start_sharded(
        NatsaConfig::default().with_threads(1),
        wal_config(&dir),
    )
    .unwrap();
    assert!(s.snapshot_stream(stream).is_some());
    s.close_stream(stream);
    s.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
