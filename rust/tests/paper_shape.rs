//! Locks the *shape* of every headline claim in the paper's evaluation
//! (DESIGN.md §3).  These are the regression guards for the calibrated
//! models: if a constant drifts, the claim that breaks names the figure.

use natsa::sim::accel::{design_space, NatsaDesign};
use natsa::sim::dram::DramConfig;
use natsa::sim::platform::{GpPlatform, KnlModel, RefPlatform};
use natsa::sim::{Bound, Precision, Workload};

fn table1() -> Vec<Workload> {
    Workload::table1().into_iter().map(|(_, w)| w).collect()
}

#[test]
fn claim_speedup_up_to_14x_avg_10x() {
    // "NATSA improves performance by up to 14.2x (9.9x on average) over
    // the state-of-the-art multi-core implementation"
    let base = GpPlatform::ddr4_ooo();
    let natsa = NatsaDesign::hbm(Precision::Dp);
    let speedups: Vec<f64> = table1()
        .iter()
        .map(|w| base.estimate(w, Precision::Dp).time_s / natsa.estimate(w).time_s)
        .collect();
    let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
    let max = speedups.iter().cloned().fold(0.0, f64::max);
    assert!((7.0..15.0).contains(&avg), "avg speedup {avg} (paper 9.9)");
    assert!((10.0..18.0).contains(&max), "max speedup {max} (paper 14.2)");
}

#[test]
fn claim_speedup_grows_with_n() {
    // Fig. 7: "NATSA's speedup increases as the time series length
    // becomes larger"
    let base = GpPlatform::ddr4_ooo();
    let natsa = NatsaDesign::hbm(Precision::Dp);
    let mut last = 0.0;
    for w in table1() {
        let s = base.estimate(&w, Precision::Dp).time_s / natsa.estimate(&w).time_s;
        assert!(s > last, "speedup not monotone at n={}", w.n);
        last = s;
    }
}

#[test]
fn claim_6x_over_hbm_inorder() {
    // "NATSA also improves performance by 6.3x ... over a general-purpose
    // NDP platform with 64 in-order cores" (all sizes)
    let ndp = GpPlatform::hbm_inorder();
    let natsa = NatsaDesign::hbm(Precision::Dp);
    for w in table1() {
        let s = ndp.estimate(&w, Precision::Dp).time_s / natsa.estimate(&w).time_s;
        assert!((4.0..9.0).contains(&s), "NDP speedup {s} at n={} (paper 6.3x)", w.n);
    }
}

#[test]
fn claim_energy_ratios() {
    // "reduces energy by up to 27.2x (19.4x on average)" vs baseline and
    // "10.2x less energy" than HBM-inOrder (rand_512K is the pivot).
    let w = Workload::new(524_288, 256);
    let natsa = NatsaDesign::hbm(Precision::Dp).estimate(&w);
    let base = GpPlatform::ddr4_ooo().estimate(&w, Precision::Dp);
    let ndp = GpPlatform::hbm_inorder().estimate(&w, Precision::Dp);
    let r_base = base.energy_j / natsa.energy_j;
    let r_ndp = ndp.energy_j / natsa.energy_j;
    assert!((15.0..40.0).contains(&r_base), "baseline energy ratio {r_base} (paper 27.2)");
    assert!((6.0..16.0).contains(&r_ndp), "NDP energy ratio {r_ndp} (paper 10.2)");
}

#[test]
fn claim_gpu_knl_energy_ordering() {
    // "NATSA consumes 1.7x, 4.1x, and 11.0x less energy than K40c,
    // GTX 1050, and KNL" — enforce the ordering and rough magnitudes.
    let w = Workload::new(524_288, 256);
    let natsa_j = NatsaDesign::hbm(Precision::Dp).estimate(&w).energy_j;
    let refs = RefPlatform::all();
    let e = |n: &str| {
        refs.iter()
            .find(|r| r.name == n)
            .unwrap()
            .energy_512k_dp_j()
            / natsa_j
    };
    let k40 = e("Tesla K40c");
    let gtx = e("GTX 1050");
    let knl = e("Xeon Phi KNL");
    assert!(k40 < gtx && gtx < knl, "ordering {k40} {gtx} {knl}");
    assert!((1.0..3.5).contains(&k40), "K40c ratio {k40} (paper 1.7)");
    assert!((2.5..7.0).contains(&gtx), "GTX ratio {gtx} (paper 4.1)");
    assert!((7.0..16.0).contains(&knl), "KNL ratio {knl} (paper 11.0)");
}

#[test]
fn claim_natsa_sp_up_to_1_75x_over_dp() {
    let mut best: f64 = 0.0;
    for w in table1() {
        let dp = NatsaDesign::hbm(Precision::Dp).estimate(&w).time_s;
        let sp = NatsaDesign::hbm(Precision::Sp).estimate(&w).time_s;
        best = best.max(dp / sp);
    }
    assert!((1.4..2.1).contains(&best), "SP/DP {best} (paper up to 1.75)");
}

#[test]
fn claim_hbm_ooo_only_7pct() {
    // Fig. 11: HBM-OoO improves over the baseline by only ~7%.
    for w in table1() {
        let a = GpPlatform::ddr4_ooo().estimate(&w, Precision::Dp).time_s;
        let b = GpPlatform::hbm_ooo().estimate(&w, Precision::Dp).time_s;
        let gain = a / b;
        assert!((0.99..1.25).contains(&gain), "HBM-OoO gain {gain} at n={}", w.n);
    }
}

#[test]
fn claim_hbm_inorder_up_to_2_25x() {
    let mut best: f64 = 0.0;
    for w in table1() {
        let a = GpPlatform::ddr4_ooo().estimate(&w, Precision::Dp).time_s;
        let b = GpPlatform::hbm_inorder().estimate(&w, Precision::Dp).time_s;
        best = best.max(a / b);
    }
    assert!((1.7..3.0).contains(&best), "HBM-inOrder best {best} (paper 2.25)");
}

#[test]
fn claim_dse_balance() {
    // Section 6.3: 48 PUs balanced; 32 compute-bound; 64 memory-bound;
    // DDR4 saturated by 8 PUs (footnote 2).
    let w = Workload::new(524_288, 256);
    let pts = design_space(Precision::Dp, DramConfig::hbm2(), &[32, 48, 64], &w);
    assert_eq!(pts[0].bound, Bound::Compute);
    assert_eq!(pts[2].bound, Bound::Memory);
    let ddr = design_space(Precision::Dp, DramConfig::ddr4_2400_dual(), &[8, 16], &w);
    assert!(ddr[0].time_s / ddr[1].time_s < 1.1, "8 PUs should already saturate DDR4");
}

#[test]
fn claim_knl_saturation_knees() {
    assert!((24..=48).contains(&KnlModel::ddr4().saturation_threads()));
    assert!((96..=160).contains(&KnlModel::mcdram().saturation_threads()));
}

#[test]
fn claim_natsa_lowest_power_and_area() {
    let w = Workload::new(524_288, 256);
    let natsa = NatsaDesign::hbm(Precision::Dp);
    let p_natsa = natsa.estimate(&w).power_w;
    for gp in GpPlatform::all_simulated() {
        let p = gp.estimate(&w, Precision::Dp).power_w;
        assert!(p > p_natsa, "{} power {p} below NATSA {p_natsa}", gp.name);
    }
    for r in RefPlatform::all() {
        assert!(r.dyn_power_w > p_natsa, "{} power below NATSA", r.name);
        assert!(r.area_mm2 > natsa.area_mm2(), "{} area below NATSA", r.name);
    }
}

#[test]
fn table2_all_anchor_rows_within_30pct() {
    // Every Table 2 cell must be within +-30% of the paper's value.
    let rows: &[(&str, [f64; 5])] = &[
        ("DDR4-OoO-DP", [14.72, 77.55, 414.55, 2089.05, 9810.30]),
        ("DDR4-OoO-SP", [6.46, 44.47, 207.85, 1106.36, 5206.75]),
        ("HBM-inOrder-DP", [14.95, 64.20, 262.33, 1071.03, 4347.38]),
        ("HBM-inOrder-SP", [8.16, 35.68, 130.23, 625.27, 2466.69]),
        ("NATSA-DP", [2.47, 10.37, 42.45, 171.72, 690.65]),
        ("NATSA-SP", [1.41, 5.91, 24.19, 97.84, 393.45]),
    ];
    for (cfg, paper) in rows {
        for (k, w) in table1().iter().enumerate() {
            let model = match *cfg {
                "DDR4-OoO-DP" => GpPlatform::ddr4_ooo().estimate(w, Precision::Dp).time_s,
                "DDR4-OoO-SP" => GpPlatform::ddr4_ooo().estimate(w, Precision::Sp).time_s,
                "HBM-inOrder-DP" => GpPlatform::hbm_inorder().estimate(w, Precision::Dp).time_s,
                "HBM-inOrder-SP" => GpPlatform::hbm_inorder().estimate(w, Precision::Sp).time_s,
                "NATSA-DP" => NatsaDesign::hbm(Precision::Dp).estimate(w).time_s,
                "NATSA-SP" => NatsaDesign::hbm(Precision::Sp).estimate(w).time_s,
                _ => unreachable!(),
            };
            let ratio = model / paper[k];
            assert!(
                (0.65..1.45).contains(&ratio),
                "{cfg} at n={}: model {model:.1}s vs paper {:.1}s (x{ratio:.2})",
                w.n,
                paper[k]
            );
        }
    }
}
