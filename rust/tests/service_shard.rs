//! Cross-shard service tests: many concurrent streams with pipelined
//! appends routed across engine shards must each stay exact against the
//! batch engine, while batch jobs flow around stream storms instead of
//! queueing behind them — the head-of-line regression pin for the sharded
//! `AnalysisService`.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use natsa::coordinator::service::{shard_of, AnalysisService, ServiceConfig, SubmitError};
use natsa::mp::{stomp, MpConfig};
use natsa::natsa::NatsaConfig;
use natsa::timeseries::generator::{generate, Pattern};

/// Aggregate counters must always equal the sum of the per-shard ones.
fn assert_reconciled(svc: &AnalysisService<f64>) {
    let sum = |get: &dyn Fn(usize) -> u64| (0..svc.num_shards()).map(get).sum::<u64>();
    let agg = svc.metrics();
    assert_eq!(
        agg.jobs_submitted.load(Ordering::Relaxed),
        sum(&|k| svc.shard_metrics(k).jobs_submitted.load(Ordering::Relaxed)),
        "submitted skewed"
    );
    assert_eq!(
        agg.jobs_completed.load(Ordering::Relaxed),
        sum(&|k| svc.shard_metrics(k).jobs_completed.load(Ordering::Relaxed)),
        "completed skewed"
    );
    assert_eq!(
        agg.jobs_failed.load(Ordering::Relaxed),
        sum(&|k| svc.shard_metrics(k).jobs_failed.load(Ordering::Relaxed)),
        "failed skewed"
    );
    assert_eq!(
        agg.jobs_rejected.load(Ordering::Relaxed),
        sum(&|k| svc.shard_metrics(k).jobs_rejected.load(Ordering::Relaxed)),
        "rejected skewed"
    );
    assert_eq!(
        agg.jobs_panicked.load(Ordering::Relaxed),
        sum(&|k| svc.shard_metrics(k).jobs_panicked.load(Ordering::Relaxed)),
        "panicked skewed"
    );
    assert_eq!(
        agg.wal_errors.load(Ordering::Relaxed),
        sum(&|k| svc.shard_metrics(k).wal_errors.load(Ordering::Relaxed)),
        "wal_errors skewed"
    );
    assert_eq!(
        agg.queue_wait_ns.load(Ordering::Relaxed),
        sum(&|k| svc.shard_metrics(k).queue_wait_ns.load(Ordering::Relaxed)),
        "queue_wait_ns skewed"
    );
    assert_eq!(
        agg.exec_ns.load(Ordering::Relaxed),
        sum(&|k| svc.shard_metrics(k).exec_ns.load(Ordering::Relaxed)),
        "exec_ns skewed"
    );
    assert_eq!(
        agg.latency.count(),
        sum(&|k| svc.shard_metrics(k).latency.count()),
        "latency histogram skewed"
    );
    assert_eq!(
        agg.appends_coalesced.load(Ordering::Relaxed),
        sum(&|k| svc.shard_metrics(k).appends_coalesced.load(Ordering::Relaxed)),
        "appends_coalesced skewed"
    );
    assert_eq!(
        agg.fanout_delivered.load(Ordering::Relaxed),
        sum(&|k| svc.shard_metrics(k).fanout_delivered.load(Ordering::Relaxed)),
        "fanout_delivered skewed"
    );
    // the width histogram reconciles bucket by bucket, not just in total
    for w in 1..=natsa::mp::kernel::BAND {
        assert_eq!(
            agg.coalesce_width.at(w),
            sum(&|k| svc.shard_metrics(k).coalesce_width.at(w)),
            "coalesce_width bucket {w} skewed"
        );
    }
    // coalesced appends are exactly the width >= 2 population
    assert_eq!(
        agg.appends_coalesced.load(Ordering::Relaxed),
        agg.coalesce_width.coalesced(),
        "appends_coalesced != width>=2 histogram mass"
    );
    // elastic-sharding counters reconcile like every other counter …
    assert_eq!(
        agg.streams_migrated.load(Ordering::Relaxed),
        sum(&|k| svc.shard_metrics(k).streams_migrated.load(Ordering::Relaxed)),
        "streams_migrated skewed"
    );
    assert_eq!(
        agg.migration_failed.load(Ordering::Relaxed),
        sum(&|k| svc.shard_metrics(k).migration_failed.load(Ordering::Relaxed)),
        "migration_failed skewed"
    );
    assert_eq!(
        agg.admission_rejected.load(Ordering::Relaxed),
        sum(&|k| svc.shard_metrics(k).admission_rejected.load(Ordering::Relaxed)),
        "admission_rejected skewed"
    );
    // … and the gauges reconcile as Σ latest published shard values
    // (quiescent here, so the telescoped aggregate must equal the sum).
    assert_eq!(
        agg.cwnd_milli.load(Ordering::Relaxed),
        sum(&|k| svc.shard_metrics(k).cwnd_milli.load(Ordering::Relaxed)),
        "cwnd_milli gauge skewed"
    );
    assert_eq!(
        agg.pool_workers.load(Ordering::Relaxed),
        sum(&|k| svc.shard_metrics(k).pool_workers.load(Ordering::Relaxed)),
        "pool_workers gauge skewed"
    );
}

/// Pipeline every chunk of `t` into `stream` through the service's
/// shared feeding loop; waits the tail so every result is consumed, and
/// checks every drained result on the way.
fn pipeline_stream(svc: &AnalysisService<f64>, stream: u64, t: &[f64], chunk: usize) {
    let mut pending = std::collections::VecDeque::new();
    for packet in t.chunks(chunk) {
        let (id, drained) = svc
            .append_stream_pipelined(stream, packet, &mut pending)
            .expect("append rejected");
        // The job id packs the shard the append executes on; with no
        // migrations in flight that must be the router's current home
        // (NOT shard_of(stream) — the id bits are only a mint-time hint).
        assert_eq!(
            Some(shard_of(id)),
            svc.stream_home(stream),
            "append strayed off the stream's home shard"
        );
        for r in drained {
            r.profile.unwrap();
        }
    }
    for id in pending {
        svc.wait(id).expect("pending append vanished").profile.unwrap();
    }
}

#[test]
fn concurrent_streams_across_shards_match_batch_bit_for_bit_in_structure() {
    let svc = Arc::new(AnalysisService::<f64>::start_sharded(
        NatsaConfig::default().with_threads(1),
        ServiceConfig::default()
            .with_shards(3)
            .with_workers(2)
            .with_queue_depth(8),
    ));
    let m = 16;
    let n = 3000;
    let clients: Vec<_> = (0..6u64)
        .map(|c| {
            let svc = svc.clone();
            std::thread::spawn(move || {
                let t = generate::<f64>(Pattern::RandomWalk, n, c);
                let stream = svc.submit_stream(m, None).unwrap();
                pipeline_stream(&svc, stream, &t, 128);
                let got = svc.snapshot_stream(stream).expect("stream open");
                let want = stomp::matrix_profile(&t, MpConfig::new(m)).unwrap();
                assert_eq!(got.len(), want.len());
                assert!(
                    got.max_abs_diff(&want) < 1e-7,
                    "stream {stream} diverged: {}",
                    got.max_abs_diff(&want)
                );
                let home = svc.stream_home(stream).expect("open stream must route");
                assert_eq!(home, shard_of(stream), "static placement: hint == home");
                assert!(svc.close_stream(stream));
                home
            })
        })
        .collect();

    // a batch job submitted mid-storm keeps flowing (retry only if every
    // shard is momentarily full)
    let series = Arc::new(generate::<f64>(Pattern::PlantedMotif, 1024, 99));
    let batch = loop {
        match svc.submit(series.clone(), m) {
            Ok(id) => break id,
            Err(SubmitError::Backpressure) => std::thread::sleep(Duration::from_micros(200)),
            Err(e) => panic!("submit: {e}"),
        }
    };
    assert!(svc.wait(batch).unwrap().profile.is_ok());

    let shards_used: std::collections::HashSet<usize> =
        clients.into_iter().map(|c| c.join().unwrap()).collect();
    assert!(
        shards_used.len() >= 2,
        "6 streams landed on one shard: routing is not spreading"
    );

    // exercise the coalescing + fanout counters before reconciling: a
    // burst of single-sample appends (the coalescible population) and a
    // subscribed fanout append
    let stream = svc.submit_stream(m, None).unwrap();
    let sub = svc.subscribe_stream(stream).unwrap();
    let warm = generate::<f64>(Pattern::RandomWalk, 64, 123);
    svc.wait(svc.append_stream(stream, &warm).unwrap()).unwrap().profile.unwrap();
    let burst: Vec<u64> = (0..24)
        .map(|k| svc.append_stream(stream, &[k as f64 * 0.1]).unwrap())
        .collect();
    for id in burst {
        svc.wait(id).unwrap().profile.unwrap();
    }
    svc.wait(svc.append_stream_fanout(stream, &[0.5]).unwrap())
        .unwrap()
        .profile
        .unwrap();
    assert_eq!(svc.metrics().fanout_delivered.load(Ordering::Relaxed), 1);
    assert!(
        svc.metrics().coalesce_width.count() > 0,
        "no append recorded a tile width"
    );
    assert!(svc.unsubscribe(sub));
    assert!(svc.close_stream(stream));

    assert_eq!(svc.metrics().in_flight(), 0, "jobs unaccounted after drain");
    assert_eq!(svc.metrics().jobs_failed.load(Ordering::Relaxed), 0);
    assert_eq!(
        svc.retained_results(),
        0,
        "JobResults survived their consumers"
    );
    assert_reconciled(&svc);
}

#[test]
fn batch_jobs_are_not_head_of_line_blocked_by_a_stream_storm() {
    // THE regression pin: one client pipelines more appends than the
    // queue holds into a single stream; with >= 2 shards a batch job
    // submitted mid-storm must (a) be accepted first try — no
    // Backpressure, (b) route off the busy shard, and (c) complete while
    // the stream is still draining, i.e. without waiting its turn behind
    // the stream (the old single-queue service parked every worker).
    let depth = 4;
    let svc = Arc::new(AnalysisService::<f64>::start_sharded(
        NatsaConfig::default().with_threads(1),
        ServiceConfig::default()
            .with_shards(2)
            .with_workers(1)
            .with_queue_depth(depth),
    ));
    let m = 16;
    let stream = svc.submit_stream(m, None).unwrap();
    let busy = svc.stream_home(stream).expect("open stream must route");

    let t = generate::<f64>(Pattern::RandomWalk, 10_000, 7);
    let storm = {
        let svc = svc.clone();
        let t = t.clone();
        std::thread::spawn(move || {
            pipeline_stream(&svc, stream, &t, 1000);
        })
    };

    // wait until the stream owns its whole shard: >= queue-depth appends
    // in flight there
    let deadline = Instant::now()
        .checked_add(Duration::from_secs(30))
        .expect("deadline representable");
    while svc.shard_metrics(busy).in_flight() < depth as u64 {
        assert!(
            Instant::now() < deadline,
            "stream never saturated its shard"
        );
        std::thread::sleep(Duration::from_micros(200));
    }

    let series = Arc::new(generate::<f64>(Pattern::RandomWalk, 512, 9));
    let batch = svc
        .submit(series, m)
        .expect("batch job must not see backpressure while one shard is stormed");
    assert_ne!(
        shard_of(batch),
        busy,
        "least-loaded routing sent the batch job into the storm"
    );
    assert!(svc.wait(batch).unwrap().profile.is_ok());
    // the stream is still draining: the batch job did not wait for it
    assert!(
        svc.shard_metrics(busy).in_flight() >= 1,
        "batch job only completed after the stream drained — head-of-line blocked"
    );

    storm.join().unwrap();
    let got = svc.snapshot_stream(stream).expect("stream open");
    let want = stomp::matrix_profile(&t, MpConfig::new(m)).unwrap();
    assert!(got.max_abs_diff(&want) < 1e-7, "{}", got.max_abs_diff(&want));
    assert!(svc.close_stream(stream));

    assert_eq!(svc.metrics().in_flight(), 0);
    assert_eq!(svc.retained_results(), 0);
    assert_reconciled(&svc);
}

#[test]
fn per_shard_pu_fleets_still_compute_exact_profiles() {
    // the shard slice of the PU fleet (48 / 4 = 12 PUs per shard) is an
    // accounting split, never a numerical one
    let svc = AnalysisService::<f64>::start_sharded(
        NatsaConfig::default().with_pus(48).with_threads(1),
        ServiceConfig::default().with_shards(4).with_workers(1),
    );
    let t = generate::<f64>(Pattern::EcgLike, 2048, 21);
    let m = 32;
    let id = svc.submit(Arc::new(t.clone()), m).unwrap();
    let got = svc.wait(id).unwrap().profile.unwrap();
    let want = stomp::matrix_profile(&t, MpConfig::new(m)).unwrap();
    assert!(got.max_abs_diff(&want) < 1e-9, "{}", got.max_abs_diff(&want));
    svc.shutdown();
}
