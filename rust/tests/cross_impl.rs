//! Cross-implementation integration tests: every functional engine must
//! produce the same matrix profile on every workload family, precision,
//! and configuration — property-swept with the in-repo harness.

use natsa::mp::parallel::{self, Partition};
use natsa::mp::stampi::{Stampi, StampiConfig};
use natsa::mp::{brute, scrimp, stomp, MpConfig};
use natsa::natsa::anytime::{run_anytime, Budget};
use natsa::natsa::pu::{PuDatapath, PuDesign};
use natsa::natsa::{NatsaConfig, NatsaEngine, Order};
use natsa::prop::{check, Rng};
use natsa::timeseries::generator::{generate, generate_with_event, Pattern, PlantedEvent};
use natsa::timeseries::{num_windows, sliding_stats};

#[test]
fn all_engines_agree_on_all_patterns() {
    for pattern in Pattern::ALL {
        let t = generate::<f64>(pattern, 700, 17);
        let m = 24;
        let cfg = MpConfig::new(m);
        let reference = brute::matrix_profile(&t, cfg).unwrap();
        let engines: Vec<(&str, natsa::mp::MatrixProfile<f64>)> = vec![
            ("scrimp", scrimp::matrix_profile(&t, cfg).unwrap()),
            ("stomp", stomp::matrix_profile(&t, cfg).unwrap()),
            ("parallel", parallel::matrix_profile(&t, cfg, 4).unwrap()),
            (
                "natsa",
                NatsaEngine::new(NatsaConfig::default())
                    .compute(&t, m)
                    .unwrap()
                    .profile,
            ),
        ];
        for (name, mp) in engines {
            // incremental (Eq. 2) vs explicit dot products differ by FP
            // association; near an exact motif (d ~ 0) the cancellation
            // leaves O(1e-7) residue in f64.
            let d = mp.max_abs_diff(&reference);
            assert!(d < 1e-6, "{name} vs brute on {pattern:?}: {d}");
        }
    }
}

#[test]
fn prop_engines_agree_random_shapes() {
    check("cross-engine", 10, |rng: &mut Rng| {
        let n = rng.range(100, 600);
        let m = rng.range(4, 40);
        if n < 5 * m {
            return;
        }
        let t: Vec<f64> = rng.gauss_vec(n);
        let cfg = MpConfig::new(m);
        let a = scrimp::matrix_profile(&t, cfg).unwrap();
        let b = stomp::matrix_profile(&t, cfg).unwrap();
        let c = NatsaEngine::new(NatsaConfig::default().with_pus(rng.range(1, 64)))
            .compute(&t, m)
            .unwrap()
            .profile;
        assert!(a.max_abs_diff(&b) < 1e-9);
        assert!(a.max_abs_diff(&c) < 1e-9);
    });
}

#[test]
fn prop_f32_f64_consistent_event_detection() {
    // Fig. 12's claim as a property: same discord region in SP and DP.
    check("precision-detection", 6, |rng: &mut Rng| {
        let seed = rng.next_u64();
        for pattern in [Pattern::EcgLike, Pattern::SeismicLike] {
            let (t64, ev) = generate_with_event::<f64>(pattern, 4096, seed);
            let t32: Vec<f32> = t64.iter().map(|&x| x as f32).collect();
            let m = 64;
            let dp = scrimp::matrix_profile(&t64, MpConfig::new(m)).unwrap();
            let sp = scrimp::matrix_profile(&t32, MpConfig::new(m)).unwrap();
            let (pk_dp, _) = dp.discord().unwrap();
            let (pk_sp, _) = sp.discord().unwrap();
            let (start, len) = match ev {
                PlantedEvent::Anomaly { start, len } => (start, len),
                _ => unreachable!(),
            };
            let near = |pk: usize| pk + m >= start && pk < start + len + m;
            assert!(near(pk_dp), "{pattern:?} DP missed: {pk_dp} vs [{start},{})", start + len);
            assert!(near(pk_sp), "{pattern:?} SP missed: {pk_sp}");
        }
    });
}

#[test]
fn pu_datapath_full_equivalence_with_engine() {
    let t = generate::<f64>(Pattern::PlantedMotif, 900, 23);
    let m = 16;
    let st = sliding_stats(&t, m);
    let nw = st.len();
    let excl = m / 4;
    let dp = PuDatapath::new(PuDesign::dp(), &t, &st);
    let mut via_pu = natsa::mp::MatrixProfile::new_inf(nw, m, excl);
    for d in excl..nw {
        dp.run_diagonal(d, &mut via_pu);
    }
    via_pu.sqrt_in_place(); // the datapath defers the sqrt like every engine
    let engine = NatsaEngine::new(NatsaConfig::default())
        .compute(&t, m)
        .unwrap();
    // datapath and engine both execute the unified tiled kernel, so the
    // profile values must be identical to the bit, even at the planted
    // exact motif where FP-association residue used to show
    assert!(via_pu.max_abs_diff(&engine.profile) == 0.0);
}

#[test]
fn unified_kernel_engines_bit_identical_and_track_brute() {
    // The conformance bar: SCRIMP (ascending band tiles), STOMP
    // (descending single diagonals), the parallel fleet (banded and
    // per-diagonal partitions + min-merge), and the NATSA PU-fleet
    // engine (band-granular scheduled work lists, sequential AND random
    // tile orders, several fleet sizes — each picks a different tile
    // width) all drive mp::kernel under maximally different schedules,
    // so their profiles must agree to the BIT (values and neighbor
    // indices), and all must sit within 1e-9 of the independent
    // brute-force oracle (which shares no Eq. 1 / Eq. 2 code).
    let mut rng = Rng::new(71);
    let t: Vec<f64> = rng.gauss_vec(1500);
    let m = 32;
    let cfg = MpConfig::new(m);
    let reference = scrimp::matrix_profile(&t, cfg).unwrap();
    let mut engines: Vec<(String, natsa::mp::MatrixProfile<f64>)> = vec![
        ("stomp".into(), stomp::matrix_profile(&t, cfg).unwrap()),
        (
            "parallel-banded".into(),
            parallel::matrix_profile(&t, cfg, 4).unwrap(),
        ),
        (
            "parallel-per-diagonal".into(),
            parallel::with_stats(&t, cfg, 4, Partition::BalancedPairs)
                .unwrap()
                .0,
        ),
    ];
    for pus in [1usize, 7, 48] {
        for order in [Order::Sequential, Order::Random(5)] {
            let out = NatsaEngine::new(
                NatsaConfig::default().with_pus(pus).with_order(order),
            )
            .compute(&t, m)
            .unwrap();
            engines.push((format!("natsa-{pus}pu-{order:?}"), out.profile));
        }
    }
    let bits = |mp: &natsa::mp::MatrixProfile<f64>| -> Vec<u64> {
        mp.p.iter().map(|x| x.to_bits()).collect()
    };
    for (name, mp) in &engines {
        assert_eq!(bits(&reference), bits(mp), "{name} not bit-identical");
        assert_eq!(reference.i, mp.i, "{name} neighbor indices diverge");
    }
    let oracle = brute::matrix_profile(&t, cfg).unwrap();
    let d = reference.max_abs_diff(&oracle);
    assert!(d < 1e-9, "kernel engines vs brute oracle: {d}");
}

#[test]
fn banded_anytime_full_run_bit_identical_to_sequential_kernel() {
    // anytime execution now consumes band tiles as its budget quantum;
    // an uninterrupted run over randomized tile lists must still equal
    // the sequential band sweep to the bit
    let mut rng = Rng::new(72);
    let t: Vec<f64> = rng.gauss_vec(1200);
    let m = 24;
    let reference = scrimp::matrix_profile(&t, MpConfig::new(m)).unwrap();
    for seed in [1u64, 99] {
        let config = NatsaConfig::default().with_order(Order::Random(seed));
        let full = run_anytime(&t, m, &config, Budget::Unlimited).unwrap();
        assert_eq!(
            reference.p.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            full.profile.p.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "seed {seed}"
        );
        assert_eq!(reference.i, full.profile.i, "seed {seed}");
    }
}

#[test]
fn anytime_converges_to_exact_result() {
    let t = generate::<f64>(Pattern::SeismicLike, 2000, 29);
    let m = 32;
    let config = NatsaConfig::default().with_order(Order::Random(5));
    let full = run_anytime(&t, m, &config, Budget::Unlimited).unwrap();
    let exact = brute::matrix_profile(&t, MpConfig::new(m)).unwrap();
    assert!(full.profile.max_abs_diff(&exact) < 1e-7);
    assert!((full.progress - 1.0).abs() < 1e-12);
}

#[test]
fn prop_anytime_monotone_progress() {
    // more budget => profile everywhere <= (tighter), never looser
    check("anytime-monotone", 5, |rng: &mut Rng| {
        let t: Vec<f64> = rng.gauss_vec(800);
        let m = 16;
        let config = NatsaConfig::default().with_order(Order::Random(77));
        let p25 = run_anytime(&t, m, &config, Budget::Fraction(0.25)).unwrap();
        let p75 = run_anytime(&t, m, &config, Budget::Fraction(0.75)).unwrap();
        for k in 0..p25.profile.len() {
            assert!(
                p75.profile.p[k] <= p25.profile.p[k] + 1e-12,
                "budget increase loosened P[{k}]"
            );
        }
    });
}

#[test]
fn partitions_agree_under_stress() {
    let t = generate::<f64>(Pattern::RandomWalk, 3000, 31);
    let cfg = MpConfig::new(100);
    let want = scrimp::matrix_profile(&t, cfg).unwrap();
    for part in [
        Partition::Contiguous,
        Partition::Strided,
        Partition::BalancedPairs,
        Partition::BandedPairs,
    ] {
        for threads in [1, 3, 16] {
            let (got, _) = parallel::with_stats(&t, cfg, threads, part).unwrap();
            assert!(
                got.max_abs_diff(&want) < 1e-12,
                "{part:?} x{threads} diverged"
            );
        }
    }
}

#[test]
fn large_window_small_series_edge() {
    // m close to n/2: few windows, big exclusion — still exact.
    let t = generate::<f64>(Pattern::RandomWalk, 300, 37);
    let cfg = MpConfig::new(100); // nw = 201, excl = 25
    let a = brute::matrix_profile(&t, cfg).unwrap();
    let b = scrimp::matrix_profile(&t, cfg).unwrap();
    let c = NatsaEngine::new(NatsaConfig::default())
        .compute(&t, 100)
        .unwrap()
        .profile;
    assert!(a.max_abs_diff(&b) < 1e-8);
    assert!(a.max_abs_diff(&c) < 1e-8);
}

#[test]
fn prop_streaming_matches_batch_on_every_prefix() {
    // The STAMPI differential property: append samples one at a time and
    // the live profile must equal an independent batch run (the brute
    // oracle) over the full prefix, at every single step.
    check("stampi-vs-brute-every-prefix", 6, |rng: &mut Rng| {
        let n = rng.range(60, 140);
        let m = rng.range(4, 13);
        if n < 5 * m {
            return;
        }
        let t: Vec<f64> = rng.gauss_vec(n);
        let mut eng = Stampi::new(StampiConfig::new(m)).unwrap();
        let excl = eng.exclusion();
        for (s, &x) in t.iter().enumerate() {
            eng.append(x);
            let len = s + 1;
            if num_windows(len, m) <= excl {
                continue; // no admissible pair yet — batch would reject
            }
            let want = brute::matrix_profile(&t[..len], MpConfig::new(m)).unwrap();
            let got = eng.profile();
            assert_eq!(got.len(), want.len(), "prefix {len}");
            let d = got.max_abs_diff(&want);
            assert!(d < 1e-6, "n={n} m={m} prefix {len}: diff {d}");
        }
    });
}

#[test]
fn prop_blocked_extend_matches_batch_and_append_path() {
    // The blocked-extend differential: feeding the stream in arbitrary
    // chunks (multi-row kernel tiles inside `extend`) must leave (a) a
    // profile within the usual differential bound of the brute oracle at
    // every chunk boundary, and (b) the exact bit-level end state of
    // per-sample appends (the width-1 path) — so the streaming service's
    // batch-append jobs are conformant by construction.
    check("stampi-extend-vs-brute", 6, |rng: &mut Rng| {
        let n = rng.range(80, 260);
        let m = rng.range(4, 20);
        if n < 5 * m {
            return;
        }
        let t: Vec<f64> = rng.gauss_vec(n);
        let mut eng = Stampi::new(StampiConfig::new(m)).unwrap();
        let mut per_append = Stampi::new(StampiConfig::new(m)).unwrap();
        let excl = eng.exclusion();
        let mut len = 0usize;
        while len < n {
            let chunk = rng.range(1, 24).min(n - len);
            eng.extend(&t[len..len + chunk]);
            for &x in &t[len..len + chunk] {
                per_append.append(x);
            }
            len += chunk;
            if num_windows(len, m) <= excl {
                continue; // no admissible pair yet — batch would reject
            }
            let want = brute::matrix_profile(&t[..len], MpConfig::new(m)).unwrap();
            let got = eng.profile();
            assert_eq!(got.len(), want.len(), "prefix {len}");
            let d = got.max_abs_diff(&want);
            assert!(d < 1e-6, "n={n} m={m} prefix {len}: diff {d}");
        }
        let (a, b) = (eng.profile(), per_append.profile());
        assert_eq!(
            a.p.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.p.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "blocked extend diverged from per-sample appends (n={n} m={m})"
        );
        assert_eq!(a.i, b.i, "n={n} m={m}");
    });
}

#[test]
fn streaming_matches_every_batch_engine_on_larger_series() {
    // One bigger cross-check against the production batch engines (the
    // per-prefix property above uses small n to keep the oracle cheap).
    let t = generate::<f64>(Pattern::EcgLike, 1500, 19);
    let m = 48;
    let cfg = MpConfig::new(m);
    let mut eng = Stampi::new(StampiConfig::new(m)).unwrap();
    // packet-sized extends: excl = 12 >= BAND, so this rides full-width
    // multi-row kernel tiles, like the service's append jobs
    for packet in t.chunks(100) {
        eng.extend(packet);
    }
    let streamed = eng.profile();
    for (name, mp) in [
        ("scrimp", scrimp::matrix_profile(&t, cfg).unwrap()),
        ("stomp", stomp::matrix_profile(&t, cfg).unwrap()),
        (
            "natsa",
            NatsaEngine::new(NatsaConfig::default())
                .compute(&t, m)
                .unwrap()
                .profile,
        ),
    ] {
        let d = streamed.max_abs_diff(&mp);
        assert!(d < 1e-6, "stampi vs {name}: {d}");
    }
}

#[test]
fn constant_series_does_not_nan() {
    // fully degenerate input: all windows constant
    let t = vec![5.0f64; 256];
    let mp = scrimp::matrix_profile(&t, MpConfig::new(16)).unwrap();
    assert!(mp.p.iter().all(|d| d.is_finite()));
    // all distances are sqrt(2m) by the degeneracy convention
    let expect = (2.0 * 16.0f64).sqrt();
    assert!(mp.p.iter().all(|&d| (d - expect).abs() < 1e-9));
}
