//! Loom model-checking of the coordinator's four riskiest protocols.
//!
//! Compiled only under `RUSTFLAGS="--cfg loom"` (plain `cargo test`
//! sees an empty crate and needs no loom dependency):
//!
//! ```text
//! RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=2 \
//!     cargo test --release --test loom_service
//! ```
//!
//! Under `--cfg loom` the whole `natsa` library is built against
//! `loom::sync` through the [`natsa::sync`] facade, so the slot and
//! fanout models below exercise the *production* types
//! ([`natsa::coordinator::slots`], [`natsa::coordinator::fanout`]) —
//! not test doubles.  The group-pass and quarantine models replicate
//! `run_group_pass`'s locking protocol line-for-line on the same
//! primitives (the real function needs a full engine + WAL + channel
//! stack, far past loom's state-space budget; the protocol — try-lock
//! readiness, turn-waiting, closed-before-unlock — is what the checker
//! needs to see, and `docs/CONCURRENCY.md` pins the correspondence).
//!
//! Every interleaving within the preemption bound is explored; an
//! assertion failure or deadlock in ANY of them fails the test.
#![cfg(loom)]

use std::time::{Duration, Instant};

use natsa::coordinator::fanout::{self, SubBox, SubRecv};
use natsa::coordinator::slots::{SlotStore, TakeError};
use natsa::sync::{lock_ok, thread, try_lock_ok, wait_ok, Arc, Condvar, Mutex, MutexGuard};

/// Run `f` under loom with the bounded-preemption budget from
/// `LOOM_MAX_PREEMPTIONS` (default 2 — the CI `loom` job's setting).
fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    let mut builder = loom::model::Builder::new();
    builder.preemption_bound = Some(
        std::env::var("LOOM_MAX_PREEMPTIONS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(2),
    );
    builder.check(f);
}

// ---------------------------------------------------------------------
// Model 1: completion slots — reserve → fill → consume vs. eviction
// and wait_timeout.  Invariants: no lost wakeup (a waiter on a filled
// slot always returns), consume-exactly-once.
// ---------------------------------------------------------------------

#[test]
fn slot_consume_exactly_once_under_racing_takers() {
    model(|| {
        let store = Arc::new(Mutex::new(SlotStore::<u32>::new()));
        let slot = lock_ok(&store).reserve(1);

        let filler = {
            let store = store.clone();
            let slot = slot.clone();
            thread::spawn(move || {
                // finish_job ordering: mark_done BEFORE fill, so a fast
                // consumer can never decrement an uncounted result.
                lock_ok(&store).mark_done(1);
                slot.fill(42);
            })
        };
        let taker = |store: Arc<Mutex<SlotStore<u32>>>, slot: Arc<_>| {
            thread::spawn(move || match slot.take(None) {
                Ok(v) => {
                    lock_ok(&store).consumed(1);
                    Some(v)
                }
                Err(TakeError::Consumed) => None,
                Err(TakeError::Timeout) => unreachable!("no deadline given"),
            })
        };
        let t1 = taker(store.clone(), slot.clone());
        let t2 = taker(store.clone(), slot.clone());

        filler.join().unwrap();
        let r1 = t1.join().unwrap();
        let r2 = t2.join().unwrap();

        // Exactly one taker consumed; the other saw Consumed — never a
        // hang (lost wakeup) and never a double delivery.
        assert_eq!(
            (r1.is_some() as u8) + (r2.is_some() as u8),
            1,
            "consume-exactly-once violated: {r1:?} {r2:?}"
        );
        assert_eq!(r1.or(r2), Some(42));
        assert_eq!(lock_ok(&store).len(), 0, "consumed slot freed");
    });
}

#[test]
fn slot_eviction_never_loses_a_held_result() {
    model(|| {
        let store = Arc::new(Mutex::new(SlotStore::<u32>::new()));
        let slot = lock_ok(&store).reserve(1);

        // Worker: finish the job, then a later submit's eviction pass
        // with result_cap = 0 races the waiter for the result.
        let worker = {
            let store = store.clone();
            let slot = slot.clone();
            thread::spawn(move || {
                {
                    let mut st = lock_ok(&store);
                    st.mark_done(1);
                }
                slot.fill(7);
                lock_ok(&store).evict(0, None);
            })
        };
        // Waiter already holds the slot Arc: eviction may drop the
        // store's reference, never the result.
        let waiter = {
            let store = store.clone();
            let slot = slot.clone();
            thread::spawn(move || {
                let got = slot.take(None);
                lock_ok(&store).consumed(1);
                got
            })
        };
        worker.join().unwrap();
        let got = waiter.join().unwrap();
        assert_eq!(got, Ok(7), "held waiter must receive the result despite eviction");
        assert_eq!(lock_ok(&store).len(), 0);
    });
}

#[test]
fn slot_wait_timeout_then_rewait_delivers() {
    model(|| {
        let store = Arc::new(Mutex::new(SlotStore::<u32>::new()));
        let slot = lock_ok(&store).reserve(1);

        let filler = {
            let store = store.clone();
            let slot = slot.clone();
            thread::spawn(move || {
                lock_ok(&store).mark_done(1);
                slot.fill(9);
            })
        };
        // A deadline already in the past: take() reports Timeout
        // without ever blocking IF it observes Pending; the job stays
        // in flight and a later untimed take must deliver — the
        // wait_timeout contract ("can be waited on again").
        let past = Instant::now().checked_add(Duration::ZERO);
        match slot.take(past) {
            Err(TakeError::Timeout) | Ok(9) => {}
            other => panic!("unexpected first take outcome: {other:?}"),
        }
        filler.join().unwrap();
        match slot.take(None) {
            Ok(9) | Err(TakeError::Consumed) => {}
            other => panic!("refetch after timeout must find the result: {other:?}"),
        }
    });
}

// ---------------------------------------------------------------------
// Model 2: `run_group_pass` try-lock readiness.  Two workers, three
// streams.  Invariants: no deadlock (loom reports any), per-stream
// `submit_seq` order holds, first-key-wins group membership never
// drops a job.
//
// The replica below IS the service protocol (service.rs
// `run_group_pass` / `run_stream_append`): candidate streams resolved
// first, readiness checked with try_lock ONLY (a worker never blocks
// on a turn while holding other streams' locks), `seq == next_seq` and
// key agreement gate membership, members apply under held locks and
// bump `next_seq`, leftovers run the serial turn-waiting path after.
// ---------------------------------------------------------------------

struct Entry {
    state: Mutex<St>,
    cv: Condvar,
}

struct St {
    key: u32,
    next_seq: u64,
    closed: bool,
    /// Damaged-but-not-yet-quarantined window marker (model 4).
    damaged: bool,
    applied: Vec<u64>,
}

fn entry(key: u32) -> Arc<Entry> {
    Arc::new(Entry {
        state: Mutex::new(St { key, next_seq: 0, closed: false, damaged: false, applied: Vec::new() }),
        cv: Condvar::new(),
    })
}

/// The serial append path: wait the stream's turn, apply, bump, wake.
fn serial_apply(e: &Entry, seq: u64) -> bool {
    let mut st = lock_ok(&e.state);
    while !st.closed && st.next_seq != seq {
        st = wait_ok(&e.cv, st);
    }
    if st.closed {
        return false;
    }
    // The quarantine invariant (model 4): a turn-winner must never see
    // state a failed group apply damaged — `closed` is set before the
    // group's locks drop, so damaged implies closed from the outside.
    assert!(!st.damaged, "turn-winner observed damaged un-quarantined state");
    st.applied.push(seq);
    st.next_seq += 1;
    drop(st);
    e.cv.notify_all();
    true
}

/// The group pass replica: try-lock readiness + first-key-wins, group
/// apply under held locks, serial leftovers in drain order.
fn group_pass(batch: &[(Arc<Entry>, u64)]) {
    let mut member_idx: Vec<usize> = Vec::new();
    let mut guards: Vec<MutexGuard<'_, St>> = Vec::new();
    let mut key: Option<u32> = None;
    for (i, (e, seq)) in batch.iter().enumerate() {
        let Some(st) = try_lock_ok(&e.state) else { continue };
        if st.closed || st.next_seq != *seq {
            continue;
        }
        match key {
            None => key = Some(st.key),
            Some(k) if k == st.key => {}
            Some(_) => continue,
        }
        guards.push(st);
        member_idx.push(i);
    }
    if member_idx.len() >= 2 {
        for (g, &i) in guards.iter_mut().zip(&member_idx) {
            let seq = batch[i].1;
            assert_eq!(g.next_seq, seq, "a group member applies exactly its turn");
            g.applied.push(seq);
            g.next_seq += 1;
        }
        drop(guards);
        for &i in &member_idx {
            batch[i].0.cv.notify_all();
        }
    } else {
        member_idx.clear();
        drop(guards);
    }
    for (i, (e, seq)) in batch.iter().enumerate() {
        if member_idx.contains(&i) {
            continue;
        }
        serial_apply(e, *seq);
    }
}

#[test]
fn group_pass_keeps_per_stream_order_without_deadlock() {
    model(|| {
        let a = entry(1);
        let b = entry(1);
        let c = entry(2); // key mismatch: first-key-wins must not drop it
        let w1 = {
            let batch = vec![(a.clone(), 0u64), (c.clone(), 0), (b.clone(), 0)];
            thread::spawn(move || group_pass(&batch))
        };
        let w2 = {
            // The pipelined second append to stream a: whichever worker
            // dequeues it, it must apply strictly after a's seq 0.
            let batch = vec![(a.clone(), 1u64)];
            thread::spawn(move || group_pass(&batch))
        };
        w1.join().unwrap();
        w2.join().unwrap();
        assert_eq!(lock_ok(&a.state).applied, vec![0, 1], "per-stream submit order");
        assert_eq!(lock_ok(&b.state).applied, vec![0]);
        assert_eq!(
            lock_ok(&c.state).applied,
            vec![0],
            "key-mismatched job must fall to the serial path, not vanish"
        );
    });
}

// ---------------------------------------------------------------------
// Model 3: snapshot fanout — producer vs. slow-subscriber poll vs.
// unsubscribe.  Invariants: compute-once shared-`Arc` delivery, lag
// accounting exact (delivered == polled + dropped + still-queued), no
// producer stall, drain-then-Closed after unsubscribe.
// ---------------------------------------------------------------------

#[test]
fn fanout_delivery_is_shared_and_lag_exact() {
    model(|| {
        let fast = SubBox::<u32>::new();
        let slow = SubBox::<u32>::new();
        let subs = Arc::new(Mutex::new(vec![(1u64, fast.clone()), (2u64, slow.clone())]));

        let producer = {
            let subs = subs.clone();
            thread::spawn(move || {
                let mut delivered = 0u64;
                for v in 0..2u32 {
                    let payload = Arc::new(v);
                    // cap 1 on the slow box's behalf: evict-oldest, never
                    // block — the producer must always run to completion.
                    delivered += fanout::deliver(&mut lock_ok(&subs), &payload, 1);
                }
                delivered
            })
        };
        let poller = {
            let slow = slow.clone();
            thread::spawn(move || {
                let mut got: Vec<u32> = Vec::new();
                for _ in 0..2 {
                    if let SubRecv::Snapshot(p) = slow.poll() {
                        got.push(*p);
                    }
                }
                got
            })
        };
        let unsubscriber = {
            let fast = fast.clone();
            thread::spawn(move || fast.close())
        };

        let delivered = producer.join().unwrap();
        let polled = poller.join().unwrap();
        unsubscriber.join().unwrap();

        // Polled snapshots arrive in delivery order.
        assert!(polled.windows(2).all(|w| w[0] < w[1]), "out of order: {polled:?}");

        // Exact lag accounting on the slow box: every successful
        // delivery is polled, dropped, or still queued — no snapshot
        // is double-counted or lost.
        let mut queued = 0u64;
        while let SubRecv::Snapshot(_) = slow.poll() {
            queued += 1;
        }
        let slow_delivered = 2; // never closed: both deliveries land
        assert_eq!(
            polled.len() as u64 + slow.dropped() + queued,
            slow_delivered,
            "lag accounting leaked a snapshot"
        );
        // The closed box stops receiving and reports Closed once
        // drained; the total delivery count reflects exactly the
        // deliveries that returned true (queued or since-evicted).
        let mut fast_left = 0u64;
        loop {
            match fast.poll() {
                SubRecv::Snapshot(_) => fast_left += 1,
                SubRecv::Closed => break,
                SubRecv::Empty => unreachable!("closed box must report Closed when drained"),
            }
        }
        assert_eq!(
            delivered,
            slow_delivered + fast_left + fast.dropped(),
            "deliver() count drifted"
        );
    });
}

#[test]
fn fanout_payload_is_computed_once_and_shared() {
    model(|| {
        let x = SubBox::<u32>::new();
        let y = SubBox::<u32>::new();
        let subs = Arc::new(Mutex::new(vec![(1u64, x.clone()), (2u64, y.clone())]));
        let producer = {
            let subs = subs.clone();
            thread::spawn(move || {
                let payload = Arc::new(41u32);
                fanout::deliver(&mut lock_ok(&subs), &payload, 4);
                payload
            })
        };
        let payload = producer.join().unwrap();
        let (gx, gy) = match (x.poll(), y.poll()) {
            (SubRecv::Snapshot(gx), SubRecv::Snapshot(gy)) => (gx, gy),
            other => panic!("both live boxes receive: {other:?}"),
        };
        assert!(Arc::ptr_eq(&gx, &payload), "delivery clones the Arc, not the payload");
        assert!(Arc::ptr_eq(&gy, &payload));
    });
}

// ---------------------------------------------------------------------
// Model 4: panic-quarantine vs. concurrent append — the closed set
// must be visible BEFORE the failed group's locks are released, so no
// turn-winner can ever touch mid-tile damaged state.
//
// The group's Err branch in service.rs (`run_group_pass`): guards were
// taken OUTSIDE catch_unwind, every member's `closed` is set under the
// still-held guards, only then do the guards drop and waiters wake.
// ---------------------------------------------------------------------

/// A group pass whose shared tile fails mid-apply: members are damaged
/// mid-tile, then quarantined under the still-held guards (the
/// service's Err-branch ordering); jobs whose stream was not ready at
/// probe time fall to the serial path like any leftover — the worker
/// never strands a stream's turn.
fn failing_group_pass(batch: &[(Arc<Entry>, u64)]) {
    let mut member = vec![false; batch.len()];
    let mut guards: Vec<MutexGuard<'_, St>> = Vec::new();
    for (i, (e, seq)) in batch.iter().enumerate() {
        let Some(st) = try_lock_ok(&e.state) else { continue };
        if st.closed || st.next_seq != *seq {
            continue;
        }
        guards.push(st);
        member[i] = true;
    }
    // The shared tile panicked mid-apply: every member is mid-tile.
    for g in guards.iter_mut() {
        g.damaged = true;
    }
    // Quarantine BEFORE the locks drop — reordering this loop past the
    // `drop(guards)` is the seeded bug loom catches (see the ignored
    // regression test below).
    for g in guards.iter_mut() {
        g.closed = true;
    }
    drop(guards);
    for (i, (e, _)) in batch.iter().enumerate() {
        if member[i] {
            e.cv.notify_all();
        }
    }
    for (i, (e, seq)) in batch.iter().enumerate() {
        if !member[i] {
            serial_apply(e, *seq);
        }
    }
}

#[test]
fn quarantine_closes_before_unlock() {
    model(|| {
        let a = entry(1);
        let b = entry(1);
        // Failed group over streams a and b at seq 0 (a panicked apply
        // never bumps the turn).
        let group = {
            let batch = vec![(a.clone(), 0u64), (b.clone(), 0)];
            thread::spawn(move || failing_group_pass(&batch))
        };
        // The pipelined next append on stream a: turn-waits on seq 1.
        // `serial_apply` asserts the core invariant in every
        // interleaving: a turn-winner never sees damaged-but-open state.
        let appender = {
            let a = a.clone();
            thread::spawn(move || serial_apply(&a, 1))
        };
        group.join().unwrap();
        let applied = appender.join().unwrap();
        let st = lock_ok(&a.state);
        if st.closed {
            // a was a group member: quarantined before unlock, so the
            // follow-up append was rejected and nothing ever applied.
            assert!(!applied, "append onto a quarantined stream must be rejected");
            assert!(st.applied.is_empty());
        } else {
            // a's lock was busy at probe time (the appender got there
            // first): its seq-0 job fell to the serial path, applied
            // cleanly, and the follow-up append ran after it.
            assert!(applied);
            assert_eq!(st.applied, vec![0, 1]);
            assert!(!st.damaged);
        }
        drop(st);
        // b has no contender: always a member, always quarantined.
        let stb = lock_ok(&b.state);
        assert!(stb.closed && stb.damaged && stb.applied.is_empty());
    });
}

/// REGRESSION NOTE (seeded-bug demonstration, kept `#[ignore]`d):
/// reorder the quarantine write after the guard drop —
///
/// ```text
///     drop(guards);                  // BUG: unlock first
///     for e in members { lock_ok(&e.state).closed = true; }
/// ```
///
/// — and loom reports the violated assertion in `serial_apply`
/// ("turn-winner observed damaged un-quarantined state"): the
/// concurrent append wins the lock in the window between the drop and
/// the re-lock, finds `closed == false` with mid-tile state, and would
/// have applied a packet onto it.  Run it to watch the checker work:
///
/// ```text
/// RUSTFLAGS="--cfg loom" cargo test --release --test loom_service \
///     -- --ignored quarantine_seeded_bug_is_caught
/// ```
///
/// The test asserts the panic *happens* (the model run fails), so it
/// documents the bug class without failing the suite.
#[test]
#[ignore = "demonstrates the seeded bug loom catches; run explicitly"]
fn quarantine_seeded_bug_is_caught() {
    let violated = std::panic::catch_unwind(|| {
        model(|| {
            let a = entry(1);
            let group = {
                let a = a.clone();
                thread::spawn(move || {
                    let mut guards: Vec<MutexGuard<'_, St>> = Vec::new();
                    if let Some(st) = try_lock_ok(&a.state) {
                        guards.push(st);
                    }
                    for g in guards.iter_mut() {
                        g.damaged = true;
                    }
                    drop(guards); // seeded bug: unlock before quarantine
                    let mut st = lock_ok(&a.state);
                    st.closed = true;
                    drop(st);
                    a.cv.notify_all();
                })
            };
            let appender = {
                let a = a.clone();
                thread::spawn(move || serial_apply(&a, 0))
            };
            group.join().unwrap();
            // propagate the appender's assertion failure into the model
            // run so the checker reports it
            assert!(
                appender.join().is_ok(),
                "turn-winner observed damaged un-quarantined state"
            );
        });
    })
    .is_err();
    assert!(
        violated,
        "loom failed to catch the closed-set-after-unlock reordering"
    );
}
