//! Cross-stream append coalescing: a storm of concurrent single-sample
//! appends must ride shared multi-lane row tiles (the drain-and-group
//! worker path) while every stream's final profile stays bit-identical
//! to its isolated sequential run.

use std::sync::atomic::Ordering;

use natsa::coordinator::service::{AnalysisService, ServiceConfig};
use natsa::mp::MatrixProfile;
use natsa::natsa::{NatsaConfig, NatsaEngine};
use natsa::prop::Rng;
use natsa::Real;

/// Exact (bit-level) fingerprint of a profile: values and neighbors.
/// `f32 -> f64` widening is exact, so comparing the widened bits is the
/// same as comparing the native ones for either dtype.
fn bits<T: Real>(p: &MatrixProfile<T>) -> (Vec<u64>, Vec<i64>) {
    (
        p.p.iter().map(|&x| x.to_f64s().to_bits()).collect(),
        p.i.clone(),
    )
}

/// The ISSUE acceptance storm: N >= 8 streams on ONE shard, each
/// appending one sample at a time, submitted back to back so the single
/// worker's drain pass groups them into multi-lane tiles. The width
/// histogram must show a width > 1 majority and every stream must end
/// bit-identical to an isolated engine twin fed the same samples.
#[test]
fn single_append_storm_rides_multi_lane_tiles_bit_identically() {
    let n_streams = 8usize;
    let m = 16usize;
    let rounds = 16usize;
    let svc = AnalysisService::<f64>::start_sharded(
        NatsaConfig::default().with_threads(1),
        ServiceConfig::default()
            .with_shards(1)
            .with_workers(1)
            .with_queue_depth(256),
    );
    let engine = NatsaEngine::<f64>::new(NatsaConfig::default().with_threads(1));

    let mut rng = Rng::new(7);
    let warm: Vec<Vec<f64>> = (0..n_streams).map(|_| rng.gauss_vec(3 * m)).collect();
    let singles: Vec<Vec<f64>> = (0..n_streams).map(|_| rng.gauss_vec(rounds)).collect();

    let ids: Vec<u64> = (0..n_streams)
        .map(|_| svc.submit_stream(m, None).unwrap())
        .collect();
    for (w, &id) in ids.iter().enumerate() {
        let job = svc.append_stream(id, &warm[w]).unwrap();
        svc.wait(job).unwrap().profile.unwrap();
    }

    // round-major submission: any window of <= n_streams consecutive
    // queue entries covers distinct streams, each at its oldest pending
    // seq, so full drain passes form full-width groups
    let mut pending = Vec::with_capacity(n_streams * rounds);
    for r in 0..rounds {
        for (w, &id) in ids.iter().enumerate() {
            pending.push(svc.append_stream(id, &[singles[w][r]]).unwrap());
        }
    }
    for id in pending {
        svc.wait(id).unwrap().profile.unwrap();
    }

    // the storm rode shared tiles: width > 1 appends outnumber the
    // serial stragglers (submission races the first drain pass, so a few
    // width-1 executions at the front are expected)
    let h = &svc.shard_metrics(0).coalesce_width;
    assert!(
        h.coalesced() > h.at(1),
        "storm stayed serial: {} coalesced vs {} width-1 (of {})",
        h.coalesced(),
        h.at(1),
        h.count()
    );
    assert_eq!(
        svc.metrics().appends_coalesced.load(Ordering::Relaxed),
        h.coalesced(),
        "aggregate counter skewed from the single shard's histogram"
    );

    // bit-identity against isolated sequential twins
    for (w, &id) in ids.iter().enumerate() {
        let mut twin = engine.open_stream(m).unwrap();
        twin.extend(&warm[w]);
        for r in 0..rounds {
            twin.append(singles[w][r]);
        }
        let got = svc.snapshot_stream(id).unwrap();
        assert_eq!(bits(&got), bits(&twin.profile()), "stream {w} diverged");
        assert!(svc.close_stream(id));
    }
    svc.shutdown();
}

/// Randomized interleavings over streams with MIXED group keys (three
/// window lengths) plus constant plateau-tie streams and occasional
/// multi-sample packets. Group formation must filter by key, preserve
/// per-stream order, and stay bit-identical to sequential twins under
/// every interleaving — for both dtypes.
fn interleaved_case<T: Real>(seed: u64) {
    let mut rng = Rng::new(seed);
    let svc = AnalysisService::<T>::start_sharded(
        NatsaConfig::default().with_threads(1),
        ServiceConfig::default()
            .with_shards(1)
            .with_workers(2)
            .with_queue_depth(64),
    );
    let engine = NatsaEngine::<T>::new(NatsaConfig::default().with_threads(1));

    // (m, constant-series?) — constants drive plateau ties through the
    // strict-< merge, where any ordering drift would show up first
    let specs: [(usize, bool); 9] = [
        (8, false),
        (8, true),
        (8, false),
        (12, false),
        (12, true),
        (12, false),
        (21, false),
        (8, false),
        (12, false),
    ];
    let mut streams: Vec<(u64, natsa::natsa::StreamSession<T>, usize, bool)> = specs
        .iter()
        .map(|&(m, constant)| {
            let id = svc.submit_stream(m, None).unwrap();
            (id, engine.open_stream(m).unwrap(), m, constant)
        })
        .collect();

    let steps = 120usize;
    let mut pending: Vec<u64> = Vec::new();
    for _ in 0..steps {
        let mut order: Vec<usize> = (0..streams.len()).collect();
        rng.shuffle(&mut order);
        for &w in &order {
            if rng.range(0, 4) == 0 {
                continue; // this stream sits the step out
            }
            let (id, twin, _m, constant) = &mut streams[w];
            if rng.range(0, 16) == 0 {
                // occasional multi-sample packet: must stay on the
                // serial within-stream path, ordered among the singles
                let packet: Vec<T> = (0..rng.range(2, 5))
                    .map(|_| T::of_f64(if *constant { 1.5 } else { rng.gauss() }))
                    .collect();
                pending.push(svc.append_stream(*id, &packet).unwrap());
                twin.extend(&packet);
            } else {
                let x = T::of_f64(if *constant { 1.5 } else { rng.gauss() });
                pending.push(svc.append_stream(*id, &[x]).unwrap());
                twin.append(x);
            }
            if pending.len() >= 48 {
                for id in pending.drain(..) {
                    svc.wait(id).unwrap().profile.unwrap();
                }
            }
        }
    }
    for id in pending.drain(..) {
        svc.wait(id).unwrap().profile.unwrap();
    }

    for (id, twin, m, constant) in &streams {
        let got = svc.snapshot_stream(*id).unwrap();
        assert_eq!(
            bits(&got),
            bits(&twin.profile()),
            "m={m} constant={constant} diverged under interleaving"
        );
    }
    assert_eq!(svc.metrics().jobs_failed.load(Ordering::Relaxed), 0);
    svc.shutdown();
}

#[test]
fn randomized_interleavings_bit_identical_f64() {
    interleaved_case::<f64>(31);
    interleaved_case::<f64>(32);
}

#[test]
fn randomized_interleavings_bit_identical_f32() {
    interleaved_case::<f32>(41);
}

/// Back-to-back appends to the SAME stream landing in one drain batch:
/// only the stream's oldest pending append may join a group; the rest
/// fall back to the serial path after it, in drain order.
#[test]
fn back_to_back_appends_to_one_stream_survive_the_drain_pass() {
    let m = 8usize;
    let svc = AnalysisService::<f64>::start_sharded(
        NatsaConfig::default().with_threads(1),
        ServiceConfig::default()
            .with_shards(1)
            .with_workers(1)
            .with_queue_depth(32),
    );
    let engine = NatsaEngine::<f64>::new(NatsaConfig::default().with_threads(1));
    let mut rng = Rng::new(13);
    let warm = rng.gauss_vec(3 * m);

    let a = svc.submit_stream(m, None).unwrap();
    let b = svc.submit_stream(m, None).unwrap();
    for &id in &[a, b] {
        let job = svc.append_stream(id, &warm).unwrap();
        svc.wait(job).unwrap().profile.unwrap();
    }

    // stream-major submission: a drain batch holds duplicates of `a`
    // before it ever sees `b`
    let tape: Vec<f64> = rng.gauss_vec(4);
    let mut pending = Vec::new();
    for &id in &[a, b] {
        for &x in &tape {
            pending.push(svc.append_stream(id, &[x]).unwrap());
        }
    }
    for id in pending {
        svc.wait(id).unwrap().profile.unwrap();
    }

    for &id in &[a, b] {
        let mut twin = engine.open_stream(m).unwrap();
        twin.extend(&warm);
        for &x in &tape {
            twin.append(x);
        }
        let got = svc.snapshot_stream(id).unwrap();
        assert_eq!(bits(&got), bits(&twin.profile()), "duplicate-heavy drain reordered a stream");
    }
    svc.shutdown();
}

/// `with_coalesce(1)` turns the drain off: every append executes on the
/// serial path (width histogram records only width 1) and results are
/// unchanged.
#[test]
fn coalesce_disabled_runs_every_append_serially() {
    let m = 8usize;
    let svc = AnalysisService::<f64>::start_sharded(
        NatsaConfig::default().with_threads(1),
        ServiceConfig::default()
            .with_shards(1)
            .with_workers(1)
            .with_queue_depth(64)
            .with_coalesce(1),
    );
    let engine = NatsaEngine::<f64>::new(NatsaConfig::default().with_threads(1));
    let mut rng = Rng::new(29);
    let warm = rng.gauss_vec(3 * m);
    let singles = rng.gauss_vec(10);

    let ids: Vec<u64> = (0..4).map(|_| svc.submit_stream(m, None).unwrap()).collect();
    for &id in &ids {
        let job = svc.append_stream(id, &warm).unwrap();
        svc.wait(job).unwrap().profile.unwrap();
    }
    let mut pending = Vec::new();
    for &x in &singles {
        for &id in &ids {
            pending.push(svc.append_stream(id, &[x]).unwrap());
        }
    }
    for id in pending {
        svc.wait(id).unwrap().profile.unwrap();
    }

    let h = &svc.metrics().coalesce_width;
    assert_eq!(h.coalesced(), 0, "coalesce=1 still formed a group");
    assert_eq!(
        svc.metrics().appends_coalesced.load(Ordering::Relaxed),
        0
    );
    assert_eq!(h.at(1), h.count());

    for &id in &ids {
        let mut twin = engine.open_stream(m).unwrap();
        twin.extend(&warm);
        for &x in &singles {
            twin.append(x);
        }
        let got = svc.snapshot_stream(id).unwrap();
        assert_eq!(bits(&got), bits(&twin.profile()));
    }
    svc.shutdown();
}
