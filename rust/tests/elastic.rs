//! Elastic sharding contracts: stream migration is **bit-identical**
//! (f32 and f64), a skewed append storm makes the controller actually
//! migrate at least one hot stream with bounded tail latency,
//! subscribers survive the hop, worker pools autoscale under backlog,
//! and the opt-in AIMD admission window fast-fails overload and
//! re-opens afterwards.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use natsa::coordinator::admission::AdmissionConfig;
use natsa::coordinator::migrate::{ElasticConfig, MigrateError};
use natsa::coordinator::service::{AnalysisService, ServiceConfig, SubRecv};
use natsa::mp::MatrixProfile;
use natsa::natsa::NatsaConfig;
use natsa::timeseries::generator::{generate, Pattern};
use natsa::Real;

/// Bit-level equality — tolerances would hide exactly the class of bug
/// (reordered float ops across the shard hop) these tests exist to catch.
fn assert_bit_identical<T: Real>(got: &MatrixProfile<T>, want: &MatrixProfile<T>) {
    assert_eq!(got.p.len(), want.p.len(), "profile length");
    for (k, (a, b)) in got.p.iter().zip(&want.p).enumerate() {
        assert_eq!(
            a.to_f64s().to_bits(),
            b.to_f64s().to_bits(),
            "profile bit mismatch at {k}: {a} vs {b}"
        );
    }
    assert_eq!(got.i, want.i, "index vector mismatch");
}

/// Deliberately uneven packet boundaries: migration hands the session
/// over mid-sequence, so boundary-dependent tile blocking is part of
/// the bit-identity contract.
fn packets<T: Real>(n: usize, seed: u64) -> Vec<Vec<T>> {
    let series = generate::<T>(Pattern::EcgLike, n, seed);
    let sizes = [61usize, 24, 97, 33];
    let mut out = Vec::new();
    let (mut at, mut k) = (0, 0);
    while at < n {
        let len = sizes[k % sizes.len()].min(n - at);
        out.push(series[at..at + len].to_vec());
        at += len;
        k += 1;
    }
    out
}

fn feed<T: Real>(s: &AnalysisService<T>, stream: u64, packets: &[Vec<T>]) {
    for p in packets {
        let id = s.append_stream(stream, p).unwrap();
        s.wait(id).unwrap().profile.unwrap();
    }
}

/// Replay the identical packet prefix on a plain single-shard service:
/// the placement-independent reference profile.
fn reference_profile<T: Real>(m: usize, pk: &[Vec<T>]) -> MatrixProfile<T> {
    let s = AnalysisService::<T>::start_sharded(
        NatsaConfig::default().with_threads(1),
        ServiceConfig::default().with_shards(1).with_workers(1).with_queue_depth(32),
    );
    let stream = s.submit_stream(m, None).unwrap();
    feed(&s, stream, pk);
    let snap = s.snapshot_stream(stream).unwrap();
    s.close_stream(stream);
    s.shutdown();
    snap
}

// ---------------------------------------------------------------------
// Manual migration: protocol-level contract
// ---------------------------------------------------------------------

fn manual_migration_bit_identity<T: Real>() {
    let m = 16;
    let pk = packets::<T>(1600, 5);
    let half = pk.len() / 2;

    let svc = AnalysisService::<T>::start_sharded(
        NatsaConfig::default().with_threads(1),
        ServiceConfig::default().with_shards(3).with_workers(1).with_queue_depth(16),
    );
    let stream = svc.submit_stream_on(0, m, None).unwrap();
    assert_eq!(svc.stream_home(stream), Some(0));
    feed(&svc, stream, &pk[..half]);

    // Error surface first: the failed attempts must not disturb state.
    assert_eq!(svc.migrate_stream(stream, 0), Err(MigrateError::SameShard));
    assert_eq!(svc.migrate_stream(stream, 99), Err(MigrateError::InvalidShard(99)));
    assert_eq!(svc.migrate_stream(stream ^ 0x1000, 1), Err(MigrateError::UnknownStream));
    assert_eq!(svc.stream_home(stream), Some(0), "failed attempts re-homed the stream");

    svc.migrate_stream(stream, 2).expect("migration failed");
    assert_eq!(svc.stream_home(stream), Some(2), "router not repointed");
    assert_eq!(svc.shard_metrics(0).streams_migrated.load(Ordering::Relaxed), 1);
    assert_eq!(svc.metrics().streams_migrated.load(Ordering::Relaxed), 1);

    // The same id keeps working; appends now land on the new home.
    feed(&svc, stream, &pk[half..]);
    let got = svc.snapshot_stream(stream).expect("stream lost in migration");
    assert_bit_identical(&got, &reference_profile(m, &pk));

    // A closed stream is unknown to migration.
    assert!(svc.close_stream(stream));
    assert_eq!(svc.migrate_stream(stream, 1), Err(MigrateError::UnknownStream));
    assert_eq!(svc.metrics().in_flight(), 0);
    svc.shutdown();
}

#[test]
fn manual_migration_is_bit_identical_f64() {
    manual_migration_bit_identity::<f64>();
}

#[test]
fn manual_migration_is_bit_identical_f32() {
    manual_migration_bit_identity::<f32>();
}

#[test]
fn subscribers_survive_the_hop() {
    let m = 16;
    let svc = AnalysisService::<f64>::start_sharded(
        NatsaConfig::default().with_threads(1),
        ServiceConfig::default().with_shards(2).with_workers(1).with_queue_depth(16),
    );
    let stream = svc.submit_stream_on(0, m, None).unwrap();
    let warm = generate::<f64>(Pattern::RandomWalk, 4 * m, 9);
    svc.wait(svc.append_stream(stream, &warm).unwrap()).unwrap().profile.unwrap();

    let sub = svc.subscribe_stream(stream).unwrap();
    svc.wait(svc.append_stream_fanout(stream, &[0.25]).unwrap()).unwrap().profile.unwrap();
    let before = match svc.poll_subscription(sub) {
        SubRecv::Snapshot(p) => p,
        other => panic!("expected pre-hop snapshot, got {other:?}"),
    };

    svc.migrate_stream(stream, 1).expect("migration failed");

    // The mailbox moved with the stream: a post-hop fanout append still
    // delivers, in order, to the same subscription id.
    svc.wait(svc.append_stream_fanout(stream, &[0.75]).unwrap()).unwrap().profile.unwrap();
    let after = match svc.poll_subscription(sub) {
        SubRecv::Snapshot(p) => p,
        other => panic!("subscription lost in migration: {other:?}"),
    };
    assert_eq!(before.p.len() + 1, after.p.len(), "post-hop snapshot out of order");
    assert_eq!(svc.metrics().fanout_delivered.load(Ordering::Relaxed), 2);

    assert!(svc.unsubscribe(sub));
    assert!(svc.close_stream(stream));
    svc.shutdown();
}

// ---------------------------------------------------------------------
// The controller: skewed storm → migration, with bounded tail latency
// ---------------------------------------------------------------------

fn skewed_storm_migrates<T: Real>() {
    let m = 16;
    let hot_streams = 4;
    let base = 40; // packets fed before the "keep feeding" phase
    let cap = 600; // hard packet cap per stream (the deadline's budget)

    let svc = Arc::new(
        AnalysisService::<T>::try_start_sharded(
            NatsaConfig::default().with_threads(1),
            ServiceConfig::default()
                .with_shards(4)
                .with_workers(1)
                .with_queue_depth(8)
                .with_elastic(ElasticConfig {
                    min_workers: 1,
                    max_workers: 1, // isolate the migration actuator
                    tick: Duration::from_millis(1),
                    grow_backlog: u64::MAX, // pools never grow here
                    shrink_backlog: 0,
                    hysteresis_ticks: 1,
                    migrate_ratio: 2,
                    migrate_slack: 2,
                    migrate_ticks: 2,
                    cooldown_ticks: 2,
                }),
        )
        .unwrap(),
    );

    // 80/20 skew: every hot stream is pinned to shard 0; one background
    // stream sits on shard 1; shards 2..3 start idle.
    let stop = Arc::new(AtomicBool::new(false));
    let clients: Vec<_> = (0..hot_streams as u64)
        .map(|c| {
            let svc = svc.clone();
            let stop = stop.clone();
            std::thread::spawn(move || -> (u64, usize) {
                let pk = packets::<T>(cap * 24, c);
                let stream = svc.submit_stream_on(0, m, None).unwrap();
                let mut pending = VecDeque::new();
                let mut fed = 0usize;
                for p in &pk {
                    let (_, drained) =
                        svc.append_stream_pipelined(stream, p, &mut pending).unwrap();
                    for r in drained {
                        r.profile.unwrap();
                    }
                    fed += 1;
                    // Base load always goes in (the storm must form);
                    // past it, stop as soon as a migration happened.
                    if fed >= base && stop.load(Ordering::Relaxed) {
                        break;
                    }
                }
                for id in pending {
                    svc.wait(id).unwrap().profile.unwrap();
                }
                (stream, fed)
            })
        })
        .collect();
    let background = svc.submit_stream_on(1, m, None).unwrap();
    feed(&svc, background, &packets::<T>(400, 77));

    // The controller must commit at least one migration before the
    // feeders run out of packets.
    let deadline = Instant::now()
        .checked_add(Duration::from_secs(60))
        .expect("deadline representable");
    while svc.metrics().streams_migrated.load(Ordering::Relaxed) == 0 {
        assert!(Instant::now() < deadline, "no migration within the deadline");
        std::thread::sleep(Duration::from_millis(1));
    }
    stop.store(true, Ordering::Relaxed);
    let fed: Vec<(u64, usize)> = clients.into_iter().map(|c| c.join().unwrap()).collect();

    let migrated = svc.metrics().streams_migrated.load(Ordering::Relaxed);
    assert!(migrated >= 1, "controller never migrated");
    // At least one hot stream left shard 0 for a colder one.
    let moved: Vec<usize> = fed
        .iter()
        .filter_map(|&(s, _)| svc.stream_home(s))
        .filter(|&h| h != 0)
        .collect();
    assert!(!moved.is_empty(), "every stream still homes on the hot shard");

    // Bit-identity across the hop, under concurrency: each stream's
    // final profile equals the same packet prefix replayed on a plain
    // service, bit for bit.
    for &(stream, n) in &fed {
        let seed = fed.iter().position(|&(s, _)| s == stream).unwrap() as u64;
        let pk = packets::<T>(cap * 24, seed);
        let got = svc.snapshot_stream(stream).expect("hot stream lost");
        assert_bit_identical(&got, &reference_profile(m, &pk[..n]));
        assert!(svc.close_stream(stream));
    }
    assert!(svc.close_stream(background));

    // Tail latency stayed bounded through the storm (the queue is 8
    // deep and every append is small: seconds would mean a stall).
    let p99 = svc.metrics().latency.quantile(0.99);
    assert!(p99 < 10.0, "p99 {p99}s: storm latency unbounded");

    // Counters reconcile after the churn.
    assert_eq!(svc.metrics().in_flight(), 0);
    let sum = |get: &dyn Fn(usize) -> u64| (0..svc.num_shards()).map(get).sum::<u64>();
    assert_eq!(
        svc.metrics().streams_migrated.load(Ordering::Relaxed),
        sum(&|k| svc.shard_metrics(k).streams_migrated.load(Ordering::Relaxed)),
        "streams_migrated skewed"
    );
    assert_eq!(
        svc.metrics().migration_failed.load(Ordering::Relaxed),
        sum(&|k| svc.shard_metrics(k).migration_failed.load(Ordering::Relaxed)),
        "migration_failed skewed"
    );
    assert_eq!(
        svc.metrics().jobs_completed.load(Ordering::Relaxed),
        sum(&|k| svc.shard_metrics(k).jobs_completed.load(Ordering::Relaxed)),
        "completed skewed"
    );
    assert_eq!(svc.metrics().jobs_failed.load(Ordering::Relaxed), 0);
    Arc::try_unwrap(svc).ok().expect("service still shared").shutdown();
}

#[test]
fn skewed_storm_triggers_migration_f64() {
    skewed_storm_migrates::<f64>();
}

#[test]
fn skewed_storm_triggers_migration_f32() {
    skewed_storm_migrates::<f32>();
}

// ---------------------------------------------------------------------
// Autoscaling pools
// ---------------------------------------------------------------------

#[test]
fn worker_pool_grows_under_backlog_and_shrinks_when_idle() {
    let m = 16;
    let svc = Arc::new(
        AnalysisService::<f64>::try_start_sharded(
            NatsaConfig::default().with_threads(1),
            ServiceConfig::default()
                .with_shards(1)
                .with_workers(1)
                .with_queue_depth(32)
                .with_elastic(ElasticConfig {
                    min_workers: 1,
                    max_workers: 3,
                    tick: Duration::from_millis(1),
                    grow_backlog: 2,
                    shrink_backlog: 0,
                    hysteresis_ticks: 2,
                    // One shard: the migration trigger can never arm
                    // (hot == cold), so only the pool actuator runs.
                    migrate_slack: u64::MAX / 2,
                    ..ElasticConfig::default()
                }),
        )
        .unwrap(),
    );
    assert_eq!(svc.metrics().pool_workers.load(Ordering::Relaxed), 1);

    // Storm one stream until the controller has grown the pool.
    let stream = svc.submit_stream(m, None).unwrap();
    let pk = packets::<f64>(20_000, 3);
    let storm = {
        let svc = svc.clone();
        let pk = pk.clone();
        std::thread::spawn(move || {
            let mut pending = VecDeque::new();
            for p in &pk {
                let (_, drained) = svc.append_stream_pipelined(stream, p, &mut pending).unwrap();
                for r in drained {
                    r.profile.unwrap();
                }
            }
            for id in pending {
                svc.wait(id).unwrap().profile.unwrap();
            }
        })
    };
    let deadline = Instant::now()
        .checked_add(Duration::from_secs(60))
        .expect("deadline representable");
    while svc.metrics().pool_workers.load(Ordering::Relaxed) < 2 {
        assert!(Instant::now() < deadline, "pool never grew under sustained backlog");
        std::thread::sleep(Duration::from_millis(1));
    }
    storm.join().unwrap();

    // Idle now: the controller lowers the target; workers leave at job
    // boundaries, so give them boundaries until the gauge is back at 1.
    let deadline = Instant::now()
        .checked_add(Duration::from_secs(60))
        .expect("deadline representable");
    while svc.metrics().pool_workers.load(Ordering::Relaxed) > 1 {
        assert!(Instant::now() < deadline, "pool never shrank back to min");
        let id = svc.append_stream(stream, &[0.5]).unwrap();
        svc.wait(id).unwrap().profile.unwrap();
        std::thread::sleep(Duration::from_millis(2));
    }

    // Growth never overshot the ceiling, and the gauges reconcile.
    assert!(svc.shard_metrics(0).pool_workers.load(Ordering::Relaxed) <= 3);
    assert_eq!(
        svc.metrics().pool_workers.load(Ordering::Relaxed),
        svc.shard_metrics(0).pool_workers.load(Ordering::Relaxed)
    );
    assert!(svc.close_stream(stream));
    assert_eq!(svc.metrics().in_flight(), 0);
    Arc::try_unwrap(svc).ok().expect("service still shared").shutdown();
}

// ---------------------------------------------------------------------
// AIMD admission
// ---------------------------------------------------------------------

#[test]
fn admission_window_rejects_overload_then_reopens() {
    let m = 16;
    let svc = AnalysisService::<f64>::start_sharded(
        NatsaConfig::default().with_threads(1),
        ServiceConfig::default()
            .with_shards(1)
            .with_workers(1)
            .with_queue_depth(64)
            .with_admission(AdmissionConfig {
                initial_cwnd: 2,
                min_cwnd: 1,
                max_cwnd: 64,
                latency_target: Duration::from_secs(10),
                decrease_pct: 50,
                cooldown_acks: 4,
            }),
    );
    assert_eq!(
        svc.metrics().cwnd_milli.load(Ordering::Relaxed),
        2000,
        "initial window gauge not published"
    );

    // Mature the stream so each append costs real work (keeps jobs in
    // flight long enough for the burst below to hit the window).
    let stream = svc.submit_stream(m, None).unwrap();
    let warm = generate::<f64>(Pattern::RandomWalk, 8000, 1);
    svc.wait(svc.append_stream(stream, &warm).unwrap()).unwrap().profile.unwrap();

    // Fire-and-forget burst: with cwnd = 2 jobs, a tight loop of 100
    // submissions must see rejections (the worker cannot drain 98
    // profile-sized appends inside one submission loop).
    let mut accepted = Vec::new();
    for k in 0..100 {
        if let Ok(id) = svc.append_stream(stream, &[k as f64 * 0.01]) {
            accepted.push(id);
        }
    }
    let rejected = svc.metrics().admission_rejected.load(Ordering::Relaxed);
    assert!(rejected > 0, "overload burst was never admission-limited");
    assert!(
        (accepted.len() as u64) < 100,
        "every submission was admitted past a 2-job window"
    );
    for id in accepted {
        svc.wait(id).unwrap().profile.unwrap();
    }

    // Recovery: every ack under the (generous) latency target grew the
    // window additively — the gauge must show it re-opening …
    assert!(
        svc.metrics().cwnd_milli.load(Ordering::Relaxed) > 2000,
        "window did not grow back on healthy traffic"
    );
    // … and fresh submissions are admitted again.
    let id = svc.append_stream(stream, &[0.5]).expect("recovered service rejected");
    svc.wait(id).unwrap().profile.unwrap();

    assert_eq!(svc.metrics().in_flight(), 0);
    assert_eq!(
        svc.metrics().admission_rejected.load(Ordering::Relaxed),
        svc.shard_metrics(0).admission_rejected.load(Ordering::Relaxed)
    );
    assert!(svc.close_stream(stream));
    svc.shutdown();
}
