//! Model-based test for the WAL writer/replay pair.
//!
//! Random interleavings of open/append/snapshot/close/rotate/crash are
//! driven against a [`WalWriter`] and, in parallel, against a trivial
//! in-memory reference model.  After every simulated crash (clean or
//! torn-tail) the directory is replayed and must agree with the model
//! exactly: same open streams, same per-stream appends (bit-for-bit),
//! same snapshot bytes, same next LSN.  Segment files must stay a
//! gap-free range ending at the writer's current segment.
//!
//! The writer code never sees the model; the model never sees a byte of
//! the on-disk format — any drift between the two is a real bug in one
//! of them.

use std::collections::{BTreeMap, BTreeSet};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use natsa::coordinator::wal::{replay, Replay, StreamMeta, WalOptions, WalWriter};
use natsa::mp::stampi::{SessionState, Stampi, StampiConfig};
use natsa::prop::Rng;

fn tempdir(tag: &str) -> PathBuf {
    static N: AtomicU64 = AtomicU64::new(0);
    let d = std::env::temp_dir().join(format!(
        "natsa-wal-model-{tag}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Any valid engine state works as a snapshot payload — the WAL treats
/// it as opaque bytes.  One donor per case keeps the model trivial; the
/// bytes still round-trip through encode → disk → decode → encode.
fn donor_state(rng: &mut Rng) -> SessionState<f64> {
    let mut s = Stampi::<f64>::new(StampiConfig::new(8)).unwrap();
    let xs: Vec<f64> = (0..64).map(|_| rng.gauss()).collect();
    s.extend(&xs);
    s.state()
}

#[derive(Debug)]
struct ModelStream {
    meta: StreamMeta,
    /// Placement epoch of this incarnation.
    epoch: u64,
    /// (next expected seq at snapshot time, encoded state bytes)
    snapshot: Option<(u64, Vec<u8>)>,
    /// appends since the snapshot (or since open): (seq, samples)
    appends: Vec<(u64, Vec<f64>)>,
    next_seq: u64,
}

#[derive(Debug, Default)]
struct Model {
    streams: BTreeMap<u64, ModelStream>,
    closed: BTreeSet<u64>,
    next_lsn: u64,
    /// Highest stream id ever opened (the id allocator's floor).
    max_id: u64,
    /// Epoch allocator (strictly increasing across opens/re-opens).
    next_epoch: u64,
}

fn encoded(state: &SessionState<f64>) -> Vec<u8> {
    let mut out = Vec::new();
    state.encode(&mut out);
    out
}

/// Replay vs model, field by field.
fn check_replay(rp: &Replay<f64>, model: &Model, ctx: &str) {
    let got_ids: Vec<u64> = rp.streams.iter().map(|s| s.id).collect();
    let want_ids: Vec<u64> = model.streams.keys().copied().collect();
    assert_eq!(got_ids, want_ids, "{ctx}: open stream set");
    if rp.records == 0 {
        // Compaction erased every record — possible only when no stream
        // is live (live streams pin their snapshot's segment).  An empty
        // log is indistinguishable from a fresh one, so LSNs restart.
        assert_eq!(rp.next_lsn, 0, "{ctx}: empty log must restart LSNs");
        assert!(model.streams.is_empty(), "{ctx}: streams lost with empty log");
    } else {
        assert_eq!(rp.next_lsn, model.next_lsn, "{ctx}: next LSN");
    }
    for rs in &rp.streams {
        let ms = &model.streams[&rs.id];
        // The Open's meta is the restore contract only until a snapshot
        // subsumes the stream: once compaction drops the Open, replay
        // synthesizes meta from the snapshot itself (which is what
        // restoration actually uses), so only snapshot-less streams
        // must carry the original meta verbatim.
        if rs.snapshot.is_none() {
            assert_eq!(rs.meta, ms.meta, "{ctx}: stream {} meta", rs.id);
        }
        // The incarnation's epoch survives whether the Open or only a
        // Snapshot was retained.
        assert_eq!(rs.epoch, ms.epoch, "{ctx}: stream {} epoch", rs.id);
        assert_eq!(rs.next_seq(), ms.next_seq, "{ctx}: stream {} next_seq", rs.id);
        match (&rs.snapshot, &ms.snapshot) {
            (None, None) => {}
            (Some((ns, state)), Some((want_ns, want_bytes))) => {
                assert_eq!(ns, want_ns, "{ctx}: stream {} snapshot seq", rs.id);
                assert_eq!(
                    &encoded(state),
                    want_bytes,
                    "{ctx}: stream {} snapshot bytes",
                    rs.id
                );
            }
            (got, want) => panic!(
                "{ctx}: stream {} snapshot presence: got {:?} want {:?}",
                rs.id,
                got.is_some(),
                want.is_some()
            ),
        }
        assert_eq!(
            rs.appends.len(),
            ms.appends.len(),
            "{ctx}: stream {} append count",
            rs.id
        );
        for ((gs, gx), (ws, wx)) in rs.appends.iter().zip(&ms.appends) {
            assert_eq!(gs, ws, "{ctx}: stream {} append seq", rs.id);
            let gb: Vec<u64> = gx.iter().map(|x| x.to_bits()).collect();
            let wb: Vec<u64> = wx.iter().map(|x| x.to_bits()).collect();
            assert_eq!(gb, wb, "{ctx}: stream {} append bits", rs.id);
        }
    }
    // the id high-water must survive compaction exactly (segment
    // headers carry it even after every record of a closed stream is
    // reclaimed) — otherwise a restarted allocator could reuse ids
    assert_eq!(rp.max_stream, model.max_id, "{ctx}: stream id high-water");
    // the epoch high-water must cover every LIVE incarnation (live
    // streams pin their Open/Snapshot, so their epochs are always
    // retained; closed streams' epochs may be compacted away, which is
    // safe — dedupe only ever compares live incarnations)
    for rs in &rp.streams {
        assert!(
            rp.max_epoch >= rs.epoch,
            "{ctx}: max_epoch {} below live epoch {}",
            rp.max_epoch,
            rs.epoch
        );
    }
    assert!(rp.max_epoch <= model.next_epoch, "{ctx}: phantom epoch");
    // closed ids in retained segments are a subset of what the model
    // closed (compaction may have dropped older Close records)...
    for id in &rp.closed {
        assert!(model.closed.contains(id), "{ctx}: phantom closed id {id}");
    }
    // ...and a closed stream must never come back as open
    for id in &model.closed {
        assert!(!model.streams.contains_key(id));
        assert!(
            !got_ids.contains(id),
            "{ctx}: closed stream {id} resurrected"
        );
    }
}

/// Retained segment files must be a contiguous id range ending at the
/// writer's current segment — compaction only ever trims the prefix.
fn check_segments(dir: &Path, current: u64, ctx: &str) {
    let mut ids: Vec<u64> = std::fs::read_dir(dir)
        .unwrap()
        .filter_map(|e| {
            let name = e.unwrap().file_name();
            let name = name.to_string_lossy().into_owned();
            name.strip_prefix("seg-")?
                .strip_suffix(".wal")?
                .parse::<u64>()
                .ok()
        })
        .collect();
    ids.sort_unstable();
    assert_eq!(*ids.last().unwrap(), current, "{ctx}: newest segment");
    for w in ids.windows(2) {
        assert_eq!(w[1], w[0] + 1, "{ctx}: segment id gap in {ids:?}");
    }
}

#[test]
fn random_interleavings_agree_with_reference_model() {
    for case in 0..6u64 {
        let mut rng = Rng::new(0xA11CE + case);
        let dir = tempdir(&format!("case{case}"));
        let opts = WalOptions {
            snapshot_every: 4,
            // tiny segments force frequent rotation + compaction
            segment_bytes: 700,
            sync: false,
        };
        let donor = donor_state(&mut rng);
        let donor_bytes = encoded(&donor);

        let empty = replay::<f64>(&dir).unwrap();
        let mut w = WalWriter::<f64>::resume(&dir, opts.clone(), &empty).unwrap();
        let mut model = Model::default();
        let mut next_id = 0u64;

        for step in 0..100 {
            let ctx = format!("case {case} step {step}");
            let open_ids: Vec<u64> = model.streams.keys().copied().collect();
            let pick = |rng: &mut Rng, ids: &[u64]| ids[rng.range(0, ids.len())];
            match rng.range(0, 100) {
                // open a stream
                0..=14 => {
                    let id = next_id;
                    next_id += 1;
                    model.next_epoch += 1;
                    let meta = StreamMeta {
                        m: rng.range(4, 64),
                        excl: (rng.range(0, 2) == 1).then(|| rng.range(1, 8)),
                        max_history: (rng.range(0, 2) == 1).then(|| rng.range(128, 512)),
                        epoch: model.next_epoch,
                    };
                    w.log_open(id, meta).unwrap();
                    model.next_lsn += 1;
                    model.max_id = model.max_id.max(id);
                    model.streams.insert(
                        id,
                        ModelStream {
                            meta,
                            epoch: meta.epoch,
                            snapshot: None,
                            appends: Vec::new(),
                            next_seq: 0,
                        },
                    );
                }
                // append a packet
                15..=59 if !open_ids.is_empty() => {
                    let id = pick(&mut rng, &open_ids);
                    let packet: Vec<f64> = (0..rng.range(1, 9)).map(|_| rng.gauss()).collect();
                    let ms = model.streams.get_mut(&id).unwrap();
                    w.log_append(id, ms.next_seq, &packet).unwrap();
                    model.next_lsn += 1;
                    ms.appends.push((ms.next_seq, packet));
                    ms.next_seq += 1;
                }
                // snapshot a stream (subsumes its appends)
                60..=69 if !open_ids.is_empty() => {
                    let id = pick(&mut rng, &open_ids);
                    let ms = model.streams.get_mut(&id).unwrap();
                    w.log_snapshot(id, ms.epoch, ms.next_seq, &donor).unwrap();
                    model.next_lsn += 1;
                    ms.snapshot = Some((ms.next_seq, donor_bytes.clone()));
                    ms.appends.clear();
                }
                // close a stream
                70..=75 if !open_ids.is_empty() => {
                    let id = pick(&mut rng, &open_ids);
                    w.log_close(id).unwrap();
                    model.next_lsn += 1;
                    model.streams.remove(&id);
                    model.closed.insert(id);
                }
                // re-open a closed id (migrate-away-and-back trace):
                // fresh incarnation with a strictly higher epoch
                76..=77 if !model.closed.is_empty() => {
                    let ids: Vec<u64> = model.closed.iter().copied().collect();
                    let id = pick(&mut rng, &ids);
                    model.next_epoch += 1;
                    let meta = StreamMeta {
                        m: rng.range(4, 64),
                        excl: None,
                        max_history: None,
                        epoch: model.next_epoch,
                    };
                    w.log_open(id, meta).unwrap();
                    model.next_lsn += 1;
                    model.closed.remove(&id);
                    model.streams.insert(
                        id,
                        ModelStream {
                            meta,
                            epoch: meta.epoch,
                            snapshot: None,
                            appends: Vec::new(),
                            next_seq: 0,
                        },
                    );
                }
                // explicit rotation (on top of size-triggered ones)
                78..=82 => {
                    w.rotate().unwrap();
                }
                // crash (clean or torn-tail), replay, verify, resume
                83..=92 => {
                    let torn = rng.range(0, 2) == 1;
                    let seg = w.segment();
                    drop(w);
                    if torn {
                        // a frame whose payload never finished hitting
                        // the disk: header promises 64 bytes, 8 arrive
                        let path = dir.join(format!("seg-{seg:012}.wal"));
                        let mut f = std::fs::OpenOptions::new()
                            .append(true)
                            .open(&path)
                            .unwrap();
                        f.write_all(&64u32.to_le_bytes()).unwrap();
                        f.write_all(&0u32.to_le_bytes()).unwrap();
                        f.write_all(&[0xAB; 8]).unwrap();
                    }
                    let rp = replay::<f64>(&dir).unwrap();
                    assert_eq!(rp.torn.is_some(), torn, "{ctx}: torn detection");
                    check_replay(&rp, &model, &ctx);
                    model.next_lsn = rp.next_lsn; // adopt a reset (empty log)
                    w = WalWriter::<f64>::resume(&dir, opts.clone(), &rp).unwrap();
                    // the recovery contract: re-snapshot every restored
                    // stream so pre-crash segments become reclaimable
                    let cps: Vec<(u64, u64, u64, SessionState<f64>)> = model
                        .streams
                        .iter()
                        .map(|(&id, ms)| (id, ms.epoch, ms.next_seq, donor.clone()))
                        .collect();
                    w.checkpoint(&cps).unwrap();
                    model.next_lsn += cps.len() as u64;
                    for ms in model.streams.values_mut() {
                        ms.snapshot = Some((ms.next_seq, donor_bytes.clone()));
                        ms.appends.clear();
                    }
                }
                // skipped guard (no open streams) or filler: append noop
                _ => {}
            }
            assert_eq!(w.next_lsn(), model.next_lsn, "{ctx}: writer LSN drift");
            check_segments(&dir, w.segment(), &ctx);
        }

        // final replay must still agree
        drop(w);
        let rp = replay::<f64>(&dir).unwrap();
        check_replay(&rp, &model, &format!("case {case} final"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
