//! End-to-end PJRT integration: the rust coordinator executing the AOT
//! Pallas kernels must agree with the native engines bit-tightly.
//!
//! These tests need `make artifacts`; when the artifact directory is
//! missing they SKIP (print + pass) so `cargo test` works on a fresh
//! clone, while `make test` (which builds artifacts first) runs them.

use std::path::PathBuf;

use natsa::coordinator::PjrtEngine;
use natsa::mp::{scrimp, MpConfig};
use natsa::natsa::{NatsaConfig, Order};
use natsa::runtime::Runtime;
use natsa::timeseries::generator::{generate, Pattern};
use natsa::timeseries::sliding_stats;

fn artifact_dir() -> Option<PathBuf> {
    let dir = natsa::runtime::default_artifact_dir();
    let dir = if dir.is_relative() {
        // tests run from the crate root
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(dir)
    } else {
        dir
    };
    if dir.join("manifest.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        None
    }
}

#[test]
fn runtime_loads_every_artifact() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    // compile everything once; any HLO-text or PJRT regression fails here
    let names: Vec<String> = rt
        .manifest()
        .artifacts
        .iter()
        .map(|a| a.name.clone())
        .collect();
    assert!(names.len() >= 16, "expected the full artifact grid");
    for name in names {
        rt.executable(&name).unwrap_or_else(|e| panic!("{name}: {e:#}"));
    }
}

#[test]
fn dot_init_matches_native() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let t = generate::<f64>(Pattern::RandomWalk, 600, 3);
    for m in [32usize, 64, 128, 256] {
        let q = rt.dot_init(m, &t[..m], &t[m..2 * m]).unwrap();
        let want: f64 = t[..m].iter().zip(&t[m..2 * m]).map(|(a, b)| a * b).sum();
        assert!((q - want).abs() < 1e-9, "m={m}: {q} vs {want}");
    }
}

#[test]
fn diag_chunk_matches_native_distances() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let m = 64;
    let v = rt
        .manifest()
        .find(natsa::runtime::ArtifactKind::DiagChunk, "f64", m)
        .unwrap()
        .v;
    let n = 2 * v + 3 * m;
    let t = generate::<f64>(Pattern::RandomWalk, n, 4);
    let st = sliding_stats(&t, m);
    let d = m; // diagonal offset
    let i0 = 1usize;
    let j0 = i0 + d;
    let q0: f64 = t[i0..i0 + m].iter().zip(&t[j0..j0 + m]).map(|(a, b)| a * b).sum();
    let out = rt
        .diag_chunk(
            m,
            Some(v),
            &t[i0 - 1..i0 - 1 + v + m],
            &t[j0 - 1..j0 - 1 + v + m],
            &st.mu[i0..i0 + v],
            &st.sig[i0..i0 + v],
            &st.mu[j0..j0 + v],
            &st.sig[j0..j0 + v],
            q0,
            v,
        )
        .unwrap();
    // reference distances straight from the definition
    for k in (0..v).step_by(37) {
        let (i, j) = (i0 + k, j0 + k);
        let q: f64 = t[i..i + m].iter().zip(&t[j..j + m]).map(|(a, b)| a * b).sum();
        let denom = m as f64 * st.sig[i] * st.sig[j];
        let corr = (q - m as f64 * st.mu[i] * st.mu[j]) / denom;
        let want = (2.0 * m as f64 * (1.0 - corr)).max(0.0).sqrt();
        assert!(
            (out.dists[k] - want).abs() < 1e-8,
            "k={k}: {} vs {want}",
            out.dists[k]
        );
    }
    // PUU pre-reduction is the argmin of the chunk
    let (min_k, min_v) = out
        .dists
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    assert_eq!(out.min_idx as usize, min_k);
    assert!((out.min_val - min_v).abs() < 1e-12);
}

#[test]
fn coordinator_agrees_with_scrimp_dp_and_sp() {
    let Some(dir) = artifact_dir() else { return };
    let n = 1500;
    let m = 32;
    let t64 = generate::<f64>(Pattern::PlantedMotif, n, 5);

    let engine = PjrtEngine::<f64>::new(NatsaConfig::default(), dir.clone()).with_workers(2);
    let out = engine.compute(&t64, m).unwrap();
    let want = scrimp::matrix_profile(&t64, MpConfig::new(m)).unwrap();
    // planted exact motif => cancellation residue ~2^-23 near d=0
    assert!(
        out.profile.max_abs_diff(&want) < 1e-6,
        "DP diff {}",
        out.profile.max_abs_diff(&want)
    );
    assert_eq!(out.work.cells, want_cells(n, m));

    let t32: Vec<f32> = t64.iter().map(|&x| x as f32).collect();
    let engine = PjrtEngine::<f32>::new(NatsaConfig::default(), dir).with_workers(2);
    let out32 = engine.compute(&t32, m).unwrap();
    let want32 = scrimp::matrix_profile(&t32, MpConfig::new(m)).unwrap();
    // f32 Eq. 2 chains accumulate ~1e-3 drift over 1.4K-cell diagonals,
    // with kernel-vs-native association differences on top; both stay
    // within the same few-ulp band of the f64 truth.
    assert!(
        out32.profile.max_abs_diff(&want32) < 0.02,
        "SP diff {}",
        out32.profile.max_abs_diff(&want32)
    );
    let truth = scrimp::matrix_profile(&t64, MpConfig::new(m)).unwrap();
    for k in 0..truth.len() {
        let diff = (out32.profile.p[k] as f64 - truth.p[k]).abs();
        assert!(diff < 0.05, "SP[{k}] far from f64 truth: {diff}");
    }
}

fn want_cells(n: usize, m: usize) -> u64 {
    natsa::mp::total_cells(n - m + 1, m / 4)
}

#[test]
fn coordinator_random_order_same_result() {
    let Some(dir) = artifact_dir() else { return };
    let t = generate::<f64>(Pattern::RandomWalk, 1200, 6);
    let m = 64;
    let seq = PjrtEngine::<f64>::new(NatsaConfig::default(), dir.clone())
        .with_workers(2)
        .compute(&t, m)
        .unwrap();
    let rnd = PjrtEngine::<f64>::new(
        NatsaConfig::default().with_order(Order::Random(9)),
        dir,
    )
    .with_workers(2)
    .compute(&t, m)
    .unwrap();
    assert!(seq.profile.max_abs_diff(&rnd.profile) < 1e-12);
}

#[test]
fn unsupported_window_lists_available() {
    let Some(dir) = artifact_dir() else { return };
    let t = generate::<f64>(Pattern::RandomWalk, 1000, 7);
    let engine = PjrtEngine::<f64>::new(NatsaConfig::default(), dir);
    let err = engine.compute(&t, 100).unwrap_err().to_string();
    assert!(err.contains("available m"), "{err}");
}

#[test]
fn mp_tile_artifact_agrees_with_scrimp() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let n = 1024;
    let m = 64; // the lowered tile parameters
    let t = generate::<f64>(Pattern::SineWithAnomaly, n, 8);
    let (p, i) = rt.mp_tile(&t).unwrap();
    let want = scrimp::matrix_profile(&t, MpConfig::new(m)).unwrap();
    let nw = n - m + 1;
    for k in 0..nw {
        let diff = (p[k] - want.p[k]).abs();
        assert!(diff < 1e-6, "P[{k}]: {} vs {}", p[k], want.p[k]);
    }
    // indices valid and outside the exclusion zone
    for (k, &j) in i[..nw].iter().enumerate() {
        assert!(j >= 0 && (j as usize) < nw);
        assert!((k as i64 - j as i64).unsigned_abs() as usize >= m / 4);
    }
}
