//! Snapshot fanout: one append, N registered subscribers — the profile
//! is computed once and delivered N times through bounded mailboxes.
//! Pins the edge cases: unsubscribe mid-stream, slow-subscriber
//! backpressure that never stalls the producer, and close/quarantine
//! teardown semantics (drain the queue, then `Closed`).

use std::sync::atomic::Ordering;
use std::sync::Arc;

use natsa::coordinator::service::{AnalysisService, ServiceConfig, SubRecv, SubmitError};
use natsa::natsa::NatsaConfig;
use natsa::prop::Rng;

fn service(result_cap: usize) -> AnalysisService<f64> {
    AnalysisService::start_sharded(
        NatsaConfig::default().with_threads(1),
        ServiceConfig::default()
            .with_shards(1)
            .with_workers(2)
            .with_queue_depth(32)
            .with_result_cap(result_cap),
    )
}

/// Open a stream and mature it past warm-up so every later single
/// append grows the profile by exactly one window.
fn warm_stream(svc: &AnalysisService<f64>, m: usize) -> u64 {
    let stream = svc.submit_stream(m, None).unwrap();
    let warm = Rng::new(stream ^ 0xfa11).gauss_vec(4 * m);
    let job = svc.append_stream(stream, &warm).unwrap();
    svc.wait(job).unwrap().profile.unwrap();
    stream
}

fn take_snapshot(
    svc: &AnalysisService<f64>,
    sub: u64,
) -> Arc<natsa::mp::MatrixProfile<f64>> {
    match svc.poll_subscription(sub) {
        SubRecv::Snapshot(p) => p,
        other => panic!("expected a snapshot, got {other:?}"),
    }
}

#[test]
fn fanout_computes_once_and_delivers_to_every_subscriber() {
    let svc = service(1024);
    let stream = warm_stream(&svc, 16);
    let subs: Vec<u64> = (0..5).map(|_| svc.subscribe_stream(stream).unwrap()).collect();

    let job = svc.append_stream_fanout(stream, &[0.7]).unwrap();
    let applied = svc.wait(job).unwrap().profile.unwrap();

    // one append job produced five deliveries — warm + fanout are the
    // only two jobs this service ever ran
    assert_eq!(svc.metrics().fanout_delivered.load(Ordering::Relaxed), 5);
    assert_eq!(svc.metrics().jobs_completed.load(Ordering::Relaxed), 2);

    // every subscriber polls the SAME allocation: computed once,
    // Arc-shared N ways, never recloned per subscriber
    let got: Vec<_> = subs.iter().map(|&s| take_snapshot(&svc, s)).collect();
    for p in &got[1..] {
        assert!(Arc::ptr_eq(&got[0], p), "snapshot was recomputed per subscriber");
    }
    assert_eq!(got[0].p, applied.p);
    assert_eq!(got[0].i, applied.i);
    for &s in &subs {
        assert!(matches!(svc.poll_subscription(s), SubRecv::Empty));
        assert_eq!(svc.subscription_lag(s), Some(0));
        assert!(svc.unsubscribe(s));
    }
    svc.shutdown();
}

#[test]
fn unsubscribe_mid_stream_skips_delivery_without_leaking() {
    let svc = service(1024);
    let stream = warm_stream(&svc, 16);
    let keep = svc.subscribe_stream(stream).unwrap();
    let gone = svc.subscribe_stream(stream).unwrap();

    let job = svc.append_stream_fanout(stream, &[0.1]).unwrap();
    svc.wait(job).unwrap().profile.unwrap();
    assert_eq!(svc.metrics().fanout_delivered.load(Ordering::Relaxed), 2);

    // the subscriber walks away between two appends
    assert!(svc.unsubscribe(gone));
    let job = svc.append_stream_fanout(stream, &[0.2]).unwrap();
    svc.wait(job).unwrap().profile.unwrap();
    assert_eq!(
        svc.metrics().fanout_delivered.load(Ordering::Relaxed),
        3,
        "delivery was not skipped for the unsubscribed mailbox"
    );

    // the departed mailbox is gone for good: no queue, no lag, and a
    // second unsubscribe finds nothing to free
    assert!(matches!(svc.poll_subscription(gone), SubRecv::Closed));
    assert_eq!(svc.subscription_lag(gone), None);
    assert!(!svc.unsubscribe(gone));

    // the remaining subscriber drains both snapshots, in append order
    let first = take_snapshot(&svc, keep);
    let second = take_snapshot(&svc, keep);
    assert_eq!(first.p.len() + 1, second.p.len());
    assert!(matches!(svc.poll_subscription(keep), SubRecv::Empty));
    assert!(svc.unsubscribe(keep));
    svc.shutdown();
}

#[test]
fn slow_subscriber_hits_bounded_mailbox_without_stalling_the_producer() {
    // result_cap doubles as the mailbox bound: a subscriber that never
    // polls loses the OLDEST snapshots while the producer keeps going
    let cap = 2usize;
    let svc = service(cap);
    let stream = warm_stream(&svc, 16);
    let lazy = svc.subscribe_stream(stream).unwrap();

    for k in 0..5 {
        let job = svc.append_stream_fanout(stream, &[k as f64 * 0.3]).unwrap();
        svc.wait(job).unwrap().profile.unwrap(); // producer never blocks
    }
    assert_eq!(svc.metrics().fanout_delivered.load(Ordering::Relaxed), 5);
    assert_eq!(svc.subscription_lag(lazy), Some(3), "evictions not accounted");

    // the two NEWEST survive; the last one is the live profile
    let older = take_snapshot(&svc, lazy);
    let newest = take_snapshot(&svc, lazy);
    assert!(matches!(svc.poll_subscription(lazy), SubRecv::Empty));
    assert_eq!(older.p.len() + 1, newest.p.len());
    let live = svc.snapshot_stream(stream).unwrap();
    assert_eq!(newest.p, live.p);
    assert_eq!(newest.i, live.i);
    assert!(svc.unsubscribe(lazy));
    svc.shutdown();
}

#[test]
fn closing_a_stream_closes_subscriptions_after_drain() {
    let svc = service(1024);
    let stream = warm_stream(&svc, 16);
    let sub = svc.subscribe_stream(stream).unwrap();

    let job = svc.append_stream_fanout(stream, &[1.0]).unwrap();
    svc.wait(job).unwrap().profile.unwrap();
    assert!(svc.close_stream(stream));

    // the stream is gone for producers and new subscribers...
    assert!(matches!(
        svc.append_stream_fanout(stream, &[2.0]),
        Err(SubmitError::UnknownStream)
    ));
    assert!(matches!(
        svc.subscribe_stream(stream),
        Err(SubmitError::UnknownStream)
    ));

    // ...but queued snapshots stay pollable: drain, then Closed
    let _last = take_snapshot(&svc, sub);
    assert!(matches!(svc.poll_subscription(sub), SubRecv::Closed));
    assert!(svc.unsubscribe(sub), "mailbox must stay claimable after close");
    svc.shutdown();
}
