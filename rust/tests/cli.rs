//! CLI integration tests: drive the `natsa` binary end to end.

use std::process::Command;

fn natsa(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_natsa"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn natsa");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn help_lists_commands() {
    let (ok, text) = natsa(&["help"]);
    assert!(ok);
    for cmd in ["generate", "profile", "anytime", "serve", "simulate", "repro", "artifacts"] {
        assert!(text.contains(cmd), "help missing {cmd}:\n{text}");
    }
}

#[test]
fn no_args_prints_usage() {
    let (ok, text) = natsa(&[]);
    assert!(ok && text.contains("usage:"));
}

#[test]
fn unknown_command_fails() {
    let (ok, text) = natsa(&["frobnicate"]);
    assert!(!ok && text.contains("unknown command"));
}

#[test]
fn profile_scrimp_finds_motif() {
    let (ok, text) = natsa(&[
        "profile", "--engine", "scrimp", "--pattern", "motif", "--n", "2048", "--m", "32",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("motif @"), "{text}");
    // planted motif => distance ~0
    assert!(text.contains("d=0.0000"), "{text}");
}

#[test]
fn profile_all_native_engines_run() {
    for engine in ["scrimp", "stomp", "brute", "parallel", "natsa"] {
        let (ok, text) = natsa(&[
            "profile", "--engine", engine, "--pattern", "ecg", "--n", "1024", "--m", "32",
        ]);
        assert!(ok, "{engine} failed:\n{text}");
        assert!(text.contains("discord @"), "{engine}:\n{text}");
    }
}

#[test]
fn profile_writes_csv() {
    let out = std::env::temp_dir().join("natsa-cli-profile.csv");
    let _ = std::fs::remove_file(&out);
    let (ok, _) = natsa(&[
        "profile", "--engine", "scrimp", "--pattern", "sine", "--n", "1024", "--m", "32",
        "--out", out.to_str().unwrap(),
    ]);
    assert!(ok);
    let text = std::fs::read_to_string(&out).unwrap();
    assert!(text.starts_with("index,distance,neighbor"));
    assert!(text.lines().count() > 900);
}

#[test]
fn generate_roundtrips_through_profile() {
    let f = std::env::temp_dir().join("natsa-cli-series.txt");
    let (ok, _) = natsa(&[
        "generate", "--pattern", "seismic", "--n", "1500", "--seed", "5",
        "--out", f.to_str().unwrap(),
    ]);
    assert!(ok);
    let (ok, text) = natsa(&[
        "profile", "--engine", "scrimp", "--input", f.to_str().unwrap(), "--m", "48",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("n=1500"));
}

#[test]
fn serve_drains_and_reconciles() {
    let (ok, text) = natsa(&[
        "serve", "--shards", "2", "--workers", "1", "--depth", "4", "--streams", "2",
        "--packets", "4", "--chunk", "256", "--jobs", "2", "--m", "32", "--pus", "8",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("shard 0:"), "{text}");
    assert!(text.contains("shard 1:"), "{text}");
    assert!(text.contains("aggregate:"), "{text}");
}

#[test]
fn simulate_all_platforms() {
    for platform in [
        "ddr4-ooo", "ddr4-inorder", "hbm-ooo", "hbm-inorder", "natsa", "natsa-ddr4",
    ] {
        let (ok, text) = natsa(&[
            "simulate", "--platform", platform, "--n", "524288", "--m", "256",
        ]);
        assert!(ok, "{platform}: {text}");
        assert!(text.contains("-bound"), "{platform}: {text}");
    }
}

#[test]
fn anytime_reports_progress() {
    let (ok, text) = natsa(&[
        "anytime", "--pattern", "motif", "--n", "4096", "--m", "64", "--fraction", "0.3",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("% of cells"), "{text}");
}

#[test]
fn repro_single_figure() {
    let (ok, text) = natsa(&["repro", "--id", "fig7"]);
    assert!(ok, "{text}");
    assert!(text.contains("speedup"), "{text}");
}

#[test]
fn repro_rejects_unknown_id() {
    let (ok, text) = natsa(&["repro", "--id", "fig99"]);
    assert!(!ok && text.contains("unknown experiment"));
}
