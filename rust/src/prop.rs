//! Minimal in-repo property-testing harness.
//!
//! The offline vendor set has no `proptest`/`quickcheck`, so this module
//! provides the two pieces the test suites actually need:
//!
//! * [`Rng`] — a tiny, fast, seedable xorshift64* generator (deterministic
//!   across platforms, no external deps), and
//! * [`check`] — a runner that executes a property over `cases` random
//!   seeds and, on failure, reports the failing seed so the case can be
//!   replayed with `Rng::new(seed)`.
//!
//! It is part of the public crate so integration tests, benches and
//! examples can share the same deterministic workload generation.

/// xorshift64* PRNG — 8 bytes of state, passes BigCrush for our purposes.
#[derive(Clone, Debug)]
pub struct Rng(u64);

impl Rng {
    /// Create a generator from a seed (0 is remapped internally).
    pub fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15).max(1))
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [lo, hi) — `hi > lo` required.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller (one value per call, simple > fast).
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of standard normals.
    pub fn gauss_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.gauss()).collect()
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }
}

/// Run `prop` over `cases` deterministic seeds; panic with the failing seed.
///
/// The property receives a fresh [`Rng`] per case.  Use the reported seed
/// with `Rng::new(seed)` to replay a failure under a debugger.
pub fn check(name: &str, cases: u64, mut prop: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = 0xA75A_0000 + case; // stable, per-property offset free
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            let v = r.range(3, 17);
            assert!((3..17).contains(&v));
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(3);
        let xs = r.gauss_vec(50_000);
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed at seed")]
    fn check_reports_seed() {
        check("always-fails", 3, |_| panic!("boom"));
    }
}
