//! General-purpose platform models — the ZSim-Ramulator substitute.
//!
//! Section 5.1 defines four simulated platforms (DDR4-OoO baseline,
//! DDR4-inOrder, HBM-OoO, HBM-inOrder) plus the real KNL testbed of
//! Figs. 3-4 and the real KNL/GPU/i7 reference points of Figs. 8-10.
//!
//! ## Model
//!
//! Per distance-matrix cell, a platform pays
//!
//! ```text
//! cell_ns = max( base + dram_lines × stall ,  dram_bytes / eff_bw )
//!            └──────── compute+latency ───┘   └──── bandwidth ────┘
//! ```
//!
//! * `base` — aggregate issue-limited cost of Alg. 1's ~13 flops + updates
//!   across all cores (OoO overlaps memory; in-order mostly does not, so
//!   its `base` already includes architectural stalls);
//! * `dram_lines × stall` — latency sensitivity: lines missing the cache
//!   hierarchy stall even an OoO window partially (this is why HBM-OoO
//!   gains only ~7%: bandwidth is not the binding resource, latency is);
//! * the bandwidth term uses the [`TrafficModel`] bytes/cell, which grows
//!   from `hot` to `cold` as the working set outgrows the LLC — this is
//!   what makes per-cell cost rise with `n` (Table 2's super-quadratic
//!   scaling) and why in-order DDR4 only wins for n > 1M (Fig. 11).
//!
//! Constants are calibrated against Table 2 anchors; the shape assertions
//! live in `rust/tests/paper_shape.rs`.

use crate::sim::cache::TrafficModel;
use crate::sim::dram::DramConfig;
use crate::sim::{Bound, Estimate, Precision, Workload};

/// Core microarchitecture class (Section 5.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoreKind {
    /// Four-wide out-of-order at 3.75 GHz (8 cores).
    OutOfOrder,
    /// Two-wide in-order at 2.5 GHz (64 cores).
    InOrder,
}

/// A simulated general-purpose platform.
#[derive(Clone, Debug)]
pub struct GpPlatform {
    pub name: &'static str,
    pub kind: CoreKind,
    pub cores: usize,
    pub freq_ghz: f64,
    pub dram: DramConfig,
    pub traffic: TrafficModel,
    /// Aggregate compute cost per cell (ns), per precision.
    pub base_cell_ns: [f64; 2], // [SP, DP]
    /// Stall per missing cache line (ns, aggregate), per precision.
    /// SP lines carry twice the elements, so per-line stall is higher
    /// (miss *events* per cell do not halve: the 8-byte index stream and
    /// per-stream advances are precision-independent).
    pub stall_ns_per_line: [f64; 2], // [SP, DP]
    /// Active dynamic power per core (W) — McPAT-style constant.
    pub core_dyn_w: f64,
}

impl GpPlatform {
    fn base_ns(&self, prec: Precision) -> f64 {
        match prec {
            Precision::Sp => self.base_cell_ns[0],
            Precision::Dp => self.base_cell_ns[1],
        }
    }

    /// Evaluate the model on a workload.
    pub fn estimate(&self, w: &Workload, prec: Precision) -> Estimate {
        let bytes_cell = self.traffic.bytes_per_cell(w.nw, prec);
        let lines = bytes_cell / 64.0;
        let stall = match prec {
            Precision::Sp => self.stall_ns_per_line[0],
            Precision::Dp => self.stall_ns_per_line[1],
        };
        let compute_ns = self.base_ns(prec) + lines * stall;
        let mem_ns = bytes_cell / self.dram.effective_bw_gbs();
        let cell_ns = compute_ns.max(mem_ns);
        let bound = if mem_ns > compute_ns {
            Bound::Memory
        } else {
            Bound::Compute
        };

        // First-dot overhead: one O(m) vectorized dot per diagonal; the
        // cache hierarchy serves it (both windows hot), so cost is issue
        // throughput only.  Matters when n/m is small (Section 6.5).
        let vec_lanes = match (self.kind, prec) {
            (CoreKind::OutOfOrder, Precision::Dp) => 4.0,
            (CoreKind::OutOfOrder, Precision::Sp) => 8.0,
            (CoreKind::InOrder, Precision::Dp) => 2.0,
            (CoreKind::InOrder, Precision::Sp) => 4.0,
        };
        let firstdot_ns = w.diagonals as f64 * w.m as f64
            / (vec_lanes * self.cores as f64 * self.freq_ghz);

        let time_s = (w.cells as f64 * cell_ns + firstdot_ns) * 1e-9;
        let bw_gbs = (w.cells as f64 * bytes_cell) / time_s / 1e9;
        let power_w =
            self.cores as f64 * self.core_dyn_w + self.dram.dynamic_power_w(bw_gbs);
        Estimate {
            platform: self.name.to_string(),
            precision: prec,
            time_s,
            bw_gbs,
            power_w,
            energy_j: power_w * time_s,
            bound,
        }
    }

    // ---- The four simulated platforms of Section 5.1 ----

    /// DDR4-OoO: the paper's baseline. 8 four-wide OoO cores @ 3.75 GHz,
    /// 32KB L1 + 256KB L2 private, 8MB shared L3, dual-channel DDR4-2400.
    pub fn ddr4_ooo() -> Self {
        GpPlatform {
            name: "DDR4-OoO",
            kind: CoreKind::OutOfOrder,
            cores: 8,
            freq_ghz: 3.75,
            dram: DramConfig::ddr4_2400_dual(),
            traffic: TrafficModel {
                llc_bytes: 8 << 20,
                hot_elems: 2.0,
                cold_elems: 10.0,
            },
            base_cell_ns: [0.45, 1.30],
            stall_ns_per_line: [4.0, 2.7],
            core_dyn_w: 3.4,
        }
    }

    /// HBM-OoO: same cores, HBM2 main memory. Latency barely improves,
    /// so SCRIMP gains only ~7% (Fig. 11 discussion).
    pub fn hbm_ooo() -> Self {
        GpPlatform {
            name: "HBM-OoO",
            dram: DramConfig::hbm2(),
            stall_ns_per_line: [3.7, 2.5],
            ..Self::ddr4_ooo()
        }
    }

    /// DDR4-inOrder: 64 two-wide in-order cores @ 2.5 GHz, 32KB L1 only.
    /// 64 miss streams on 2 channels thrash row buffers: efficiency drops.
    pub fn ddr4_inorder() -> Self {
        let mut dram = DramConfig::ddr4_2400_dual();
        dram.efficiency = 0.55;
        GpPlatform {
            name: "DDR4-inOrder",
            kind: CoreKind::InOrder,
            cores: 64,
            freq_ghz: 2.5,
            dram,
            traffic: TrafficModel {
                llc_bytes: 2 << 20, // 64 x 32KB private L1s
                hot_elems: 2.0,
                cold_elems: 11.0,
            },
            base_cell_ns: [0.62, 1.00],
            stall_ns_per_line: [1.2, 1.0],
            core_dyn_w: 0.27,
        }
    }

    /// HBM-inOrder: the general-purpose NDP platform (64 in-order cores on
    /// the HBM logic layer).
    pub fn hbm_inorder() -> Self {
        GpPlatform {
            name: "HBM-inOrder",
            dram: DramConfig::hbm2(),
            stall_ns_per_line: [0.9, 0.8],
            ..Self::ddr4_inorder()
        }
    }

    /// All four simulated platforms, baseline first (Fig. 11 order).
    pub fn all_simulated() -> Vec<GpPlatform> {
        vec![
            Self::ddr4_ooo(),
            Self::ddr4_inorder(),
            Self::hbm_ooo(),
            Self::hbm_inorder(),
        ]
    }
}

/// The Xeon Phi 7210 (KNL) testbed of Figs. 3-4: 64 cores / 256 threads,
/// AVX-512, with either DDR4 (6ch) or MCDRAM (HBM-class) behind them.
#[derive(Clone, Debug)]
pub struct KnlModel {
    pub dram: DramConfig,
    /// Sustainable cells/s of one hardware thread (AVX-512 SCRIMP).
    pub thread_cells_per_s: f64,
    /// DRAM bytes per cell for the Fig. 3 workload.
    pub bytes_per_cell: f64,
}

impl KnlModel {
    pub fn ddr4() -> Self {
        KnlModel {
            dram: DramConfig::knl_ddr4(),
            thread_cells_per_s: 68.6e6,
            bytes_per_cell: 41.0,
        }
    }

    pub fn mcdram() -> Self {
        KnlModel {
            dram: DramConfig::knl_mcdram(),
            thread_cells_per_s: 68.6e6,
            bytes_per_cell: 41.0,
        }
    }

    /// Fig. 3 point: (normalized performance vs 1 thread, bandwidth GB/s).
    pub fn scaling_point(&self, threads: usize) -> (f64, f64) {
        let compute = threads as f64 * self.thread_cells_per_s;
        let bw_cap = self.dram.effective_bw_gbs() * 1e9 / self.bytes_per_cell;
        let rate = compute.min(bw_cap);
        let norm = rate / self.thread_cells_per_s;
        let bw = rate * self.bytes_per_cell / 1e9;
        (norm, bw)
    }

    /// Thread count where bandwidth saturates (Fig. 3 knee).
    pub fn saturation_threads(&self) -> usize {
        let bw_cap = self.dram.effective_bw_gbs() * 1e9 / self.bytes_per_cell;
        (bw_cap / self.thread_cells_per_s).ceil() as usize
    }
}

/// A real hardware reference point (Figs. 8-10).  Power/energy/area come
/// from the paper's own measurements (PCM / NVVP) and public specs; they
/// are comparison rows, not simulations.
#[derive(Clone, Copy, Debug)]
pub struct RefPlatform {
    pub name: &'static str,
    pub tech_nm: u32,
    pub area_mm2: f64,
    /// Measured average dynamic power running matrix profile (W).
    pub dyn_power_w: f64,
    /// Measured execution time for rand_512K DP (s).
    pub time_512k_dp_s: f64,
}

impl RefPlatform {
    pub fn energy_512k_dp_j(&self) -> f64 {
        self.dyn_power_w * self.time_512k_dp_s
    }

    /// The paper's real comparison points (Figs. 8-10): Tesla K40c
    /// (STOMP-GPU), GTX 1050 (STOMP-GPU), Xeon Phi KNL (SCRIMP [27]),
    /// Core i7 (area row only — power column reuses SCRIMP 8-core).
    pub fn all() -> Vec<RefPlatform> {
        vec![
            RefPlatform {
                name: "Tesla K40c",
                tech_nm: 28,
                area_mm2: 614.0,
                dyn_power_w: 110.0,
                time_512k_dp_s: 8.5,
            },
            RefPlatform {
                name: "GTX 1050",
                tech_nm: 14,
                area_mm2: 140.0,
                dyn_power_w: 60.0,
                time_512k_dp_s: 37.6,
            },
            RefPlatform {
                name: "Xeon Phi KNL",
                tech_nm: 14,
                area_mm2: 746.0,
                dyn_power_w: 190.0,
                time_512k_dp_s: 31.8,
            },
            RefPlatform {
                name: "Core i7",
                tech_nm: 32,
                area_mm2: 233.0,
                dyn_power_w: 45.0,
                time_512k_dp_s: 520.0,
            },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2(n: usize) -> Workload {
        Workload::new(n, 256)
    }

    #[test]
    fn baseline_tracks_table2_anchors() {
        // Table 2 DDR4-OoO-DP: 14.72 / 414.55 / 9810.30 s.  The model must
        // land within 30% of each anchor (it is a calibrated analytic
        // model, not the authors' ZSim).
        let p = GpPlatform::ddr4_ooo();
        for (n, paper) in [(131_072, 14.72), (524_288, 414.55), (2_097_152, 9810.30)] {
            let e = p.estimate(&t2(n), Precision::Dp);
            let ratio = e.time_s / paper;
            assert!(
                (0.7..1.3).contains(&ratio),
                "n={n}: model {:.1}s vs paper {paper}s",
                e.time_s
            );
        }
    }

    #[test]
    fn hbm_inorder_tracks_table2_anchors() {
        let p = GpPlatform::hbm_inorder();
        for (n, paper) in [(131_072, 14.95), (524_288, 262.33), (2_097_152, 4347.38)] {
            let e = p.estimate(&t2(n), Precision::Dp);
            let ratio = e.time_s / paper;
            assert!(
                (0.7..1.3).contains(&ratio),
                "n={n}: model {:.1}s vs paper {paper}s",
                e.time_s
            );
        }
    }

    #[test]
    fn hbm_ooo_gains_are_marginal() {
        // Fig. 11: HBM-OoO improves over DDR4-OoO by only ~7%.
        let w = t2(2_097_152);
        let a = GpPlatform::ddr4_ooo().estimate(&w, Precision::Dp);
        let b = GpPlatform::hbm_ooo().estimate(&w, Precision::Dp);
        let gain = a.time_s / b.time_s;
        assert!((1.0..1.20).contains(&gain), "HBM-OoO gain {gain}");
    }

    #[test]
    fn inorder_crossover_above_1m() {
        // Fig. 11: DDR4-inOrder beats the baseline only for n > 1M.
        let ooo = GpPlatform::ddr4_ooo();
        let ino = GpPlatform::ddr4_inorder();
        let small = t2(131_072);
        let large = t2(2_097_152);
        assert!(
            ino.estimate(&small, Precision::Dp).time_s
                > ooo.estimate(&small, Precision::Dp).time_s,
            "in-order should lose at 128K"
        );
        assert!(
            ino.estimate(&large, Precision::Dp).time_s
                < ooo.estimate(&large, Precision::Dp).time_s,
            "in-order should win at 2M"
        );
    }

    #[test]
    fn hbm_inorder_uses_fraction_of_peak_bw() {
        // Fig. 11: ~17% of HBM peak with the largest dataset.
        let e = GpPlatform::hbm_inorder().estimate(&t2(2_097_152), Precision::Dp);
        let frac = e.bw_gbs / 256.0;
        assert!((0.10..0.30).contains(&frac), "bw fraction {frac}");
        assert_eq!(e.bound, Bound::Compute);
    }

    #[test]
    fn sp_faster_than_dp_everywhere() {
        for p in GpPlatform::all_simulated() {
            let w = t2(524_288);
            let dp = p.estimate(&w, Precision::Dp).time_s;
            let sp = p.estimate(&w, Precision::Sp).time_s;
            assert!(sp < dp, "{}: sp {sp} dp {dp}", p.name);
            assert!(dp / sp < 3.0, "{}: implausible SP gain {}", p.name, dp / sp);
        }
    }

    #[test]
    fn knl_fig3_saturation_knees() {
        // Fig. 3: DDR4 stops scaling ~32 threads; HBM scales to ~128.
        let ddr = KnlModel::ddr4().saturation_threads();
        let hbm = KnlModel::mcdram().saturation_threads();
        assert!(
            (24..=48).contains(&ddr),
            "DDR4 saturation at {ddr} threads"
        );
        assert!((96..=160).contains(&hbm), "HBM saturation at {hbm} threads");
        assert!(hbm > 3 * ddr);
    }

    #[test]
    fn knl_fig3_monotone_until_knee() {
        let knl = KnlModel::mcdram();
        let (p64, bw64) = knl.scaling_point(64);
        let (p128, bw128) = knl.scaling_point(128);
        let (p256, bw256) = knl.scaling_point(256);
        assert!(p128 > p64);
        assert!((p256 - p128).abs() / p128 < 0.12, "plateau after knee");
        assert!(bw128 > bw64);
        assert!(bw256 <= knl.dram.effective_bw_gbs() + 1e-9 && bw256 > 0.9 * bw128);
    }

    #[test]
    fn ref_platform_areas_match_fig10_ratios() {
        // Fig. 10: NATSA (77.76 mm²) is 9.6x / 7.9x / 3x / 1.8x smaller.
        let natsa = 77.76;
        let refs = RefPlatform::all();
        let find = |n: &str| refs.iter().find(|r| r.name == n).unwrap().area_mm2;
        assert!((find("Xeon Phi KNL") / natsa - 9.6).abs() < 0.3);
        assert!((find("Tesla K40c") / natsa - 7.9).abs() < 0.3);
        assert!((find("Core i7") / natsa - 3.0).abs() < 0.2);
        assert!((find("GTX 1050") / natsa - 1.8).abs() < 0.2);
    }
}
