//! Timing / power / energy / area models — the evaluation substrate.
//!
//! The paper evaluated NATSA with ZSim + Ramulator (general-purpose
//! platforms), gem5 + Aladdin (the accelerator), McPAT + the Micron power
//! calculator (power/energy), and real PCM/NVVP measurements (KNL / GPUs).
//! None of those run here, so this module implements the closest analytic
//! + discrete-event equivalents (DESIGN.md §2 substitution table):
//!
//! * [`dram`]     — DDR4 / HBM2 channel bandwidth + energy model (Ramulator
//!   + Micron power-calc substitute),
//! * [`cache`]    — working-set/LLC traffic model plus a real set-associative
//!   LRU simulator used to validate the analytic hit-rate assumptions,
//! * [`platform`] — general-purpose core models (OoO / in-order, the four
//!   simulated platforms of Section 5.1 + the KNL of Figs. 3-4) evaluated
//!   over a [`Workload`] (ZSim substitute),
//! * [`accel`]    — the NATSA accelerator timing model with a chunk-level
//!   discrete-event simulation of PU/channel contention (gem5-Aladdin
//!   substitute) and the design-space exploration of Section 6.3,
//! * [`des`]      — the small discrete-event engine behind [`accel`],
//! * [`power`]    — dynamic power / energy models (McPAT + Micron + Galal
//!   FPU energy substitute),
//! * [`area`]     — area accounting (Fig. 10),
//! * [`roofline`] — arithmetic-intensity + roofline analysis (Fig. 4).
//!
//! Model constants are calibrated against the paper's Table 2 / Figs. 8-11
//! anchor points; `rust/tests/paper_shape.rs` locks the claim *shapes*.
//! Absolute seconds are model outputs, not silicon measurements.

pub mod accel;
pub mod area;
pub mod cache;
pub mod dram;
pub mod des;
pub mod platform;
pub mod power;
pub mod roofline;

use crate::timeseries::{default_exclusion, num_windows};

/// Element precision of a run (the paper's DP/SP designs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Precision {
    Sp,
    Dp,
}

impl Precision {
    pub fn bytes(&self) -> usize {
        match self {
            Precision::Sp => 4,
            Precision::Dp => 8,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Precision::Sp => "SP",
            Precision::Dp => "DP",
        }
    }
}

/// Static description of one matrix profile job — everything the timing
/// models need, derived purely from `(n, m, excl)`.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub n: usize,
    pub m: usize,
    pub excl: usize,
    pub nw: usize,
    /// Admissible distance-matrix cells (upper triangle).
    pub cells: u64,
    /// Admissible diagonals (each costs one O(m) first dot product).
    pub diagonals: u64,
}

impl Workload {
    pub fn new(n: usize, m: usize) -> Self {
        Self::with_excl(n, m, default_exclusion(m))
    }

    pub fn with_excl(n: usize, m: usize, excl: usize) -> Self {
        let nw = num_windows(n, m);
        assert!(nw > excl, "degenerate workload: n={n} m={m} excl={excl}");
        Workload {
            n,
            m,
            excl,
            nw,
            cells: crate::mp::total_cells(nw, excl),
            diagonals: (nw - excl) as u64,
        }
    }

    /// The paper's Table 1 evaluation points with the default window used
    /// throughout the evaluation (m = 256).
    pub fn table1() -> Vec<(String, Workload)> {
        crate::timeseries::generator::TABLE1_SIZES
            .iter()
            .map(|(n, name)| (name.to_string(), Workload::new(*n, 256)))
            .collect()
    }

    /// Total FLOPs of the diagonal algorithm on this workload.
    pub fn flops(&self) -> u64 {
        self.cells * 13 + self.diagonals * 2 * self.m as u64
    }
}

/// A platform's evaluation of a workload — one row of Table 2 plus the
/// power/energy columns of Figs. 8-9.
#[derive(Clone, Debug)]
pub struct Estimate {
    pub platform: String,
    pub precision: Precision,
    /// Modeled end-to-end execution time (seconds).
    pub time_s: f64,
    /// Average DRAM bandwidth demand actually served (GB/s).
    pub bw_gbs: f64,
    /// Average dynamic power (W): compute + memory.
    pub power_w: f64,
    /// Energy = power × time (power-delay product, as the paper computes).
    pub energy_j: f64,
    /// Whether the model was compute- or memory-bound.
    pub bound: Bound,
}

/// Which resource limited the run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bound {
    Compute,
    Memory,
}

impl std::fmt::Display for Bound {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Bound::Compute => write!(f, "compute"),
            Bound::Memory => write!(f, "memory"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_cell_count() {
        let w = Workload::new(1000, 100);
        assert_eq!(w.nw, 901);
        assert_eq!(w.excl, 25);
        assert_eq!(w.cells, crate::mp::total_cells(901, 25));
        assert_eq!(w.diagonals, 876);
    }

    #[test]
    fn table1_matches_paper_sizes() {
        let t1 = Workload::table1();
        assert_eq!(t1.len(), 5);
        assert_eq!(t1[0].0, "rand_128K");
        assert_eq!(t1[0].1.n, 131_072);
        assert_eq!(t1[4].1.n, 2_097_152);
    }

    #[test]
    fn flops_scale_quadratically() {
        let small = Workload::new(10_000, 100);
        let big = Workload::new(20_000, 100);
        let ratio = big.flops() as f64 / small.flops() as f64;
        assert!((ratio - 4.0).abs() < 0.1, "{ratio}");
    }

    #[test]
    #[should_panic(expected = "degenerate workload")]
    fn degenerate_rejected() {
        Workload::new(100, 100);
    }
}
