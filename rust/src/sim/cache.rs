//! Cache modeling: the analytic traffic model used by the platform
//! estimates, plus a real set-associative LRU simulator that validates it.
//!
//! The diagonal algorithm touches six streams per cell — `t[i]`, `t[j]`,
//! the statistics at `i` and `j`, and the profile entries `P[i]`, `P[j]`.
//! The `i`-side streams advance by one element per cell (perfect spatial
//! locality); the `j`-side streams are offset by the diagonal index, so
//! their *reuse* across diagonals is what the LLC does or does not capture:
//!
//! * working set (all five vectors) fits in the LLC → only compulsory `t`
//!   traffic reaches DRAM (`hot` bytes/cell);
//! * working set ≫ LLC → every stream misses (`cold` bytes/cell);
//! * in between, the miss fraction grows as `1 - llc/ws` (stack-distance
//!   argument for cyclic reuse, validated by [`CacheSim`] in tests).

use crate::sim::Precision;

/// One cache level for the analytic model (only capacity matters at the
//  granularity we model; associativity is exercised by `CacheSim`).
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    pub capacity_bytes: usize,
    pub line_bytes: usize,
}

/// Analytic DRAM traffic model for the diagonal algorithm.
#[derive(Clone, Copy, Debug)]
pub struct TrafficModel {
    /// Last-level cache capacity shared by the cores (bytes).
    pub llc_bytes: usize,
    /// DRAM bytes per cell when the working set is cache-resident
    /// (compulsory `t` stream only), per element byte.
    pub hot_elems: f64,
    /// DRAM bytes per cell when nothing is reused, per element byte
    /// (six streams × line-granule waste).
    pub cold_elems: f64,
}

impl TrafficModel {
    /// Working set of the algorithm's reused vectors: t, mu, inv_msig,
    /// P (+ I at the same width) — five arrays of `nw` elements.
    pub fn working_set_bytes(nw: usize, prec: Precision) -> usize {
        5 * nw * prec.bytes()
    }

    /// Fraction of reuses that miss the LLC (0 = all hit, 1 = all miss).
    pub fn miss_fraction(&self, nw: usize, prec: Precision) -> f64 {
        let ws = Self::working_set_bytes(nw, prec) as f64;
        let llc = self.llc_bytes as f64;
        if ws <= llc {
            0.0
        } else {
            1.0 - llc / ws
        }
    }

    /// Modeled DRAM bytes per distance-matrix cell.
    pub fn bytes_per_cell(&self, nw: usize, prec: Precision) -> f64 {
        let e = prec.bytes() as f64;
        let f = self.miss_fraction(nw, prec);
        (self.hot_elems + f * (self.cold_elems - self.hot_elems)) * e
    }
}

/// A real set-associative LRU cache simulator (single level).  Used by
/// tests and the `ablate_cache` bench to ground the analytic model; too
/// slow for full-size workloads by design.
pub struct CacheSim {
    sets: Vec<Vec<u64>>, // per-set LRU stack of line tags, front = MRU
    ways: usize,
    line: usize,
    set_shift: u32,
    set_mask: u64,
    pub hits: u64,
    pub misses: u64,
}

impl CacheSim {
    pub fn new(capacity_bytes: usize, ways: usize, line_bytes: usize) -> Self {
        assert!(line_bytes.is_power_of_two());
        let lines = capacity_bytes / line_bytes;
        let nsets = (lines / ways).max(1);
        assert!(nsets.is_power_of_two(), "sets must be a power of two");
        CacheSim {
            sets: vec![Vec::with_capacity(ways); nsets],
            ways,
            line: line_bytes,
            set_shift: line_bytes.trailing_zeros(),
            set_mask: (nsets - 1) as u64,
            hits: 0,
            misses: 0,
        }
    }

    /// Access a byte address; returns true on hit.
    pub fn access(&mut self, addr: u64) -> bool {
        let line_addr = addr >> self.set_shift;
        let set = (line_addr & self.set_mask) as usize;
        let tag = line_addr >> self.set_mask.count_ones();
        let stack = &mut self.sets[set];
        if let Some(pos) = stack.iter().position(|&t| t == tag) {
            stack.remove(pos);
            stack.insert(0, tag);
            self.hits += 1;
            true
        } else {
            if stack.len() == self.ways {
                stack.pop();
            }
            stack.insert(0, tag);
            self.misses += 1;
            false
        }
    }

    pub fn miss_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.misses as f64 / total as f64
        }
    }

    /// DRAM bytes implied by the misses observed so far.
    pub fn dram_bytes(&self) -> u64 {
        self.misses * self.line as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> TrafficModel {
        TrafficModel {
            llc_bytes: 8 << 20,
            hot_elems: 2.0,
            cold_elems: 22.0,
        }
    }

    #[test]
    fn hot_when_ws_fits() {
        let m = model();
        // nw = 100k doubles: ws = 4 MB < 8 MB LLC
        assert_eq!(m.miss_fraction(100_000, Precision::Dp), 0.0);
        assert!((m.bytes_per_cell(100_000, Precision::Dp) - 16.0).abs() < 1e-12);
    }

    #[test]
    fn cold_fraction_grows_with_n() {
        let m = model();
        let f1 = m.miss_fraction(500_000, Precision::Dp); // 20 MB
        let f2 = m.miss_fraction(2_000_000, Precision::Dp); // 80 MB
        assert!(f1 > 0.0 && f2 > f1 && f2 < 1.0);
        let llc_mb = (8u64 << 20) as f64 / 20e6; // ws is 20 MB (decimal)
        assert!((f1 - (1.0 - llc_mb)).abs() < 0.01);
    }

    #[test]
    fn sp_halves_working_set() {
        let m = model();
        // 300k windows: DP ws = 12MB (misses), SP ws = 6MB (fits)
        assert!(m.miss_fraction(300_000, Precision::Dp) > 0.0);
        assert_eq!(m.miss_fraction(300_000, Precision::Sp), 0.0);
    }

    #[test]
    fn cachesim_sequential_stream_misses_once_per_line() {
        let mut c = CacheSim::new(32 << 10, 8, 64);
        for addr in 0..(16 << 10) {
            c.access(addr);
        }
        // 16 KiB touched byte-by-byte: one miss per 64 B line
        assert_eq!(c.misses, (16 << 10) / 64);
        assert!(c.miss_rate() < 0.02);
    }

    #[test]
    fn cachesim_cyclic_reuse_thrashes_when_too_big() {
        // Loop over 64 KiB through a 32 KiB cache: LRU on a cyclic pattern
        // evicts everything before reuse -> ~100% miss rate.
        let mut c = CacheSim::new(32 << 10, 8, 64);
        for _round in 0..4 {
            for line in 0..(64 << 10) / 64 {
                c.access((line * 64) as u64);
            }
        }
        assert!(c.miss_rate() > 0.95, "{}", c.miss_rate());
    }

    #[test]
    fn cachesim_cyclic_reuse_hits_when_fits() {
        let mut c = CacheSim::new(64 << 10, 8, 64);
        for _round in 0..4 {
            for line in 0..(32 << 10) / 64 {
                c.access((line * 64) as u64);
            }
        }
        // first round misses, later rounds hit
        assert!(c.miss_rate() < 0.30, "{}", c.miss_rate());
    }

    #[test]
    fn analytic_model_tracks_cachesim_on_diagonal_walk() {
        // Walk a few diagonals of a toy workload through CacheSim and
        // compare the measured DRAM bytes/cell against the analytic model.
        let nw = 40_000usize; // ws = 5*40k*8 = 1.6 MB
        let llc = 1 << 20; // 1 MB LLC -> partially cold
        let line = 64u64;
        let mut sim = CacheSim::new(llc, 16, 64);
        // address map: t at 0, mu at 1*GAP, inv at 2*GAP, P at 3*GAP, I at 4*GAP
        const GAP: u64 = 1 << 30;
        let mut cells = 0u64;
        for d in (1000..20_000).step_by(4000) {
            let len = nw - d;
            for i in 0..len {
                let j = i + d;
                for (base, idx) in [
                    (0u64, i as u64),
                    (0, j as u64),
                    (GAP, i as u64),
                    (GAP, j as u64),
                    (2 * GAP, i as u64),
                    (2 * GAP, j as u64),
                    (3 * GAP, i as u64),
                    (3 * GAP, j as u64),
                ] {
                    sim.access(base + idx * 8);
                }
                cells += 1;
            }
        }
        let measured = sim.dram_bytes() as f64 / cells as f64;
        let model = TrafficModel {
            llc_bytes: llc,
            hot_elems: 2.0,
            cold_elems: 16.0, // 8 stream touches x line-waste factor 2
        };
        let predicted = model.bytes_per_cell(nw, Precision::Dp);
        // same order of magnitude and same regime (partially cold)
        assert!(measured > 2.0 && predicted > 2.0);
        let ratio = measured / predicted;
        assert!(
            (0.2..5.0).contains(&ratio),
            "measured {measured:.1} vs predicted {predicted:.1}"
        );
    }
}
