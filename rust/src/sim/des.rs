//! Minimal discrete-event simulation engine.
//!
//! Drives the chunk-level NATSA accelerator simulation in [`crate::sim::
//! accel`]: processing units alternate compute phases with memory phases
//! served FCFS by their HBM channel.  The engine is a plain binary-heap
//! event queue over `u64` picosecond timestamps — deliberately tiny, fully
//! deterministic, no dependencies.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Simulation time in picoseconds (u64 keeps ordering exact).
pub type Time = u64;

/// An event: fires at `at`, carrying an opaque payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event<P> {
    pub at: Time,
    pub payload: P,
}

impl<P: Eq> Ord for Event<P> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.at.cmp(&other.at)
    }
}

impl<P: Eq> PartialOrd for Event<P> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// The event queue.  `P` must be `Eq` for deterministic tie handling;
/// ties fire in insertion order via a monotone sequence number.
pub struct EventQueue<P> {
    heap: BinaryHeap<Reverse<(Time, u64, P)>>,
    seq: u64,
    now: Time,
}

impl<P: Ord> EventQueue<P> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0,
        }
    }

    /// Schedule `payload` to fire at absolute time `at` (>= now).
    pub fn schedule(&mut self, at: Time, payload: P) {
        debug_assert!(at >= self.now, "scheduling into the past");
        self.heap.push(Reverse((at, self.seq, payload)));
        self.seq += 1;
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<Event<P>> {
        self.heap.pop().map(|Reverse((at, _, payload))| {
            self.now = at;
            Event { at, payload }
        })
    }

    pub fn now(&self) -> Time {
        self.now
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

impl<P: Ord> Default for EventQueue<P> {
    fn default() -> Self {
        Self::new()
    }
}

/// A shared resource serving requests FCFS at a fixed byte rate — models
/// one HBM channel.  `busy_until` tracks the head of line.
#[derive(Clone, Copy, Debug, Default)]
pub struct FcfsChannel {
    pub busy_until: Time,
    pub bytes_served: u64,
}

impl FcfsChannel {
    /// Enqueue a transfer of `bytes` arriving at `at`; returns completion
    /// time given `bw_bytes_per_ps`.
    pub fn serve(&mut self, at: Time, bytes: u64, bw_bytes_per_ps: f64) -> Time {
        let start = self.busy_until.max(at);
        let dur = (bytes as f64 / bw_bytes_per_ps).ceil() as Time;
        self.busy_until = start + dur.max(1);
        self.bytes_served += bytes;
        self.busy_until
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_fire_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(30, 3u32);
        q.schedule(10, 1);
        q.schedule(20, 2);
        let order: Vec<u32> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn ties_fire_in_insertion_order() {
        let mut q = EventQueue::new();
        q.schedule(5, 10u32);
        q.schedule(5, 20);
        q.schedule(5, 5); // payload smaller but inserted last
        let order: Vec<u32> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec![10, 20, 5]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(100, ());
        q.schedule(50, ());
        assert_eq!(q.pop().unwrap().at, 50);
        assert_eq!(q.now(), 50);
        assert_eq!(q.pop().unwrap().at, 100);
        assert_eq!(q.now(), 100);
        assert!(q.is_empty());
    }

    #[test]
    fn channel_serializes_requests() {
        let mut ch = FcfsChannel::default();
        // 1 byte per ps
        let t1 = ch.serve(0, 100, 1.0);
        let t2 = ch.serve(10, 100, 1.0); // arrives while busy
        let t3 = ch.serve(500, 100, 1.0); // arrives after idle gap
        assert_eq!(t1, 100);
        assert_eq!(t2, 200);
        assert_eq!(t3, 600);
        assert_eq!(ch.bytes_served, 300);
    }
}
