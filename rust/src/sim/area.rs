//! Area accounting (Table 3 per-component breakdown + Fig. 10 comparison).
//!
//! The per-PU totals come from the Aladdin-style component areas below
//! (45 nm): FP multiplier/adder macro areas after [29, 83], integer adders,
//! bitwise units, the register file and the 1 KB scratchpad.  They
//! reconstruct Table 3's 1.62 mm² (DP) / 1.51 mm² (SP) per-PU figures.

use crate::natsa::pu::PuDesign;
use crate::sim::Precision;

/// Component macro areas at 45 nm (mm²).
#[derive(Clone, Copy, Debug)]
pub struct ComponentAreas {
    pub fp_mult_mm2: f64,
    pub fp_add_mm2: f64,
    pub int_add_mm2: f64,
    pub bitwise_mm2: f64,
    pub register_mm2: f64,
    pub scratchpad_per_kb_mm2: f64,
    /// Control FSM + muxes + channel interface (fixed per PU).
    pub control_mm2: f64,
}

impl ComponentAreas {
    pub fn at_45nm(prec: Precision) -> Self {
        match prec {
            // DP macros are ~2x SP in area.
            Precision::Dp => ComponentAreas {
                fp_mult_mm2: 0.046,
                fp_add_mm2: 0.030,
                int_add_mm2: 0.004,
                bitwise_mm2: 0.002,
                register_mm2: 0.0016,
                scratchpad_per_kb_mm2: 0.035,
                control_mm2: 0.12,
            },
            Precision::Sp => ComponentAreas {
                fp_mult_mm2: 0.012,
                fp_add_mm2: 0.008,
                int_add_mm2: 0.0015,
                bitwise_mm2: 0.001,
                register_mm2: 0.0007,
                scratchpad_per_kb_mm2: 0.035,
                control_mm2: 0.12,
            },
        }
    }

    /// Bottom-up per-PU area from a design's component counts.
    pub fn pu_area_mm2(&self, d: &PuDesign) -> f64 {
        d.fp_mults as f64 * self.fp_mult_mm2
            + d.fp_adds as f64 * self.fp_add_mm2
            + d.int_adds as f64 * self.int_add_mm2
            + d.bitwise as f64 * self.bitwise_mm2
            + d.registers as f64 * self.register_mm2
            + d.scratchpad_bytes as f64 / 1024.0 * self.scratchpad_per_kb_mm2
            + self.control_mm2
    }
}

/// One bar of Fig. 10.
#[derive(Clone, Debug)]
pub struct AreaRow {
    pub platform: String,
    pub tech_nm: u32,
    pub area_mm2: f64,
    /// Ratio vs NATSA-DP's 77.76 mm².
    pub vs_natsa: f64,
}

/// Assemble the Fig. 10 comparison (NATSA + the real reference points).
pub fn fig10_rows() -> Vec<AreaRow> {
    let natsa = 48.0 * PuDesign::dp().area_mm2;
    let mut rows = vec![AreaRow {
        platform: "NATSA (48 PU)".into(),
        tech_nm: 45,
        area_mm2: natsa,
        vs_natsa: 1.0,
    }];
    for r in crate::sim::platform::RefPlatform::all() {
        rows.push(AreaRow {
            platform: r.name.into(),
            tech_nm: r.tech_nm,
            area_mm2: r.area_mm2,
            vs_natsa: r.area_mm2 / natsa,
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bottom_up_matches_table3_dp() {
        let a = ComponentAreas::at_45nm(Precision::Dp).pu_area_mm2(&PuDesign::dp());
        let table3 = 1.62;
        assert!(
            (a / table3 - 1.0).abs() < 0.15,
            "bottom-up {a:.2} vs Table 3 {table3}"
        );
    }

    #[test]
    fn bottom_up_matches_table3_sp() {
        let a = ComponentAreas::at_45nm(Precision::Sp).pu_area_mm2(&PuDesign::sp());
        let table3 = 1.51;
        assert!(
            (a / table3 - 1.0).abs() < 0.15,
            "bottom-up {a:.2} vs Table 3 {table3}"
        );
    }

    #[test]
    fn sp_pu_smaller_despite_more_units() {
        // Table 3: SP has 4x the multipliers yet slightly less area
        // (SP macros are much smaller).
        let dp = ComponentAreas::at_45nm(Precision::Dp).pu_area_mm2(&PuDesign::dp());
        let sp = ComponentAreas::at_45nm(Precision::Sp).pu_area_mm2(&PuDesign::sp());
        assert!(sp < dp);
    }

    #[test]
    fn fig10_natsa_is_smallest() {
        let rows = fig10_rows();
        let natsa = rows[0].area_mm2;
        for r in &rows[1..] {
            assert!(r.area_mm2 > natsa, "{} not larger than NATSA", r.platform);
            assert!(r.vs_natsa > 1.0);
        }
        // and NATSA uses the largest (oldest) node
        assert!(rows[1..].iter().all(|r| r.tech_nm <= rows[0].tech_nm));
    }
}
