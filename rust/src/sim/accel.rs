//! NATSA accelerator timing model — the gem5-Aladdin substitute.
//!
//! Two evaluators, cross-checked by tests:
//!
//! * [`NatsaDesign::estimate`] — closed-form: per-PU time is the max of
//!   its compute time (divider-limited PU pipeline) and its memory time
//!   (fair share of HBM channel bandwidth); the accelerator finishes when
//!   the most-loaded PU does.
//! * [`NatsaDesign::simulate`] — chunk-level discrete-event simulation:
//!   each PU alternates compute and memory phases per diagonal chunk, its
//!   HBM channel serving transfers FCFS ([`crate::sim::des`]).  Captures
//!   transient channel contention the closed form averages away.
//!
//! ## PU throughput
//!
//! The PU pipeline (Fig. 5) is limited by the shared DCU floating-point
//! divide + sqrt path: one cell needs one reciprocal-multiply and one
//! sqrt through the shared energy-efficient FPU [29], giving a steady
//! state of ~14 cycles/cell in DP and ~8 in SP at 1 GHz.  At 72 B (DP) /
//! 36 B (SP) of DRAM traffic per cell this demands ~5.1 GB/s (DP) and
//! ~4.5 GB/s (SP) per PU — exactly why the paper's DSE (Section 6.3)
//! balances at 48 PUs on a 256 GB/s HBM stack: 32 PUs leave bandwidth
//! stranded (compute-bound), 64 PUs starve (memory-bound).

use crate::natsa::pu::{ChunkWork, PuDesign};
use crate::natsa::scheduler;
use crate::sim::des::{EventQueue, FcfsChannel};
use crate::sim::dram::DramConfig;
use crate::sim::{Bound, Estimate, Precision, Workload};

/// Steady-state PU cycles per diagonal cell (divider-limited).
pub fn cycles_per_cell(prec: Precision) -> f64 {
    match prec {
        Precision::Dp => 14.0,
        Precision::Sp => 8.0,
    }
}

/// DRAM bytes per cell streamed by a PU (see `ChunkWork::traffic_bytes`).
pub fn bytes_per_cell(prec: Precision) -> f64 {
    9.0 * prec.bytes() as f64
}

/// A full NATSA configuration: PU fleet + memory stack.
#[derive(Clone, Debug)]
pub struct NatsaDesign {
    pub pus: usize,
    pub pu: PuDesign,
    pub dram: DramConfig,
    pub precision: Precision,
}

impl NatsaDesign {
    /// The paper's HBM design point: 48 PUs @ 1 GHz on HBM2.
    pub fn hbm(precision: Precision) -> Self {
        NatsaDesign {
            pus: 48,
            pu: match precision {
                Precision::Dp => PuDesign::dp(),
                Precision::Sp => PuDesign::sp(),
            },
            dram: DramConfig::hbm2(),
            precision,
        }
    }

    /// The DDR4 variant (footnote 2): 8 PUs saturate dual-channel DDR4.
    pub fn ddr4(precision: Precision) -> Self {
        NatsaDesign {
            pus: 8,
            dram: DramConfig::ddr4_2400_dual(),
            ..Self::hbm(precision)
        }
    }

    /// Same design with a different PU count (design space exploration).
    pub fn with_pus(mut self, pus: usize) -> Self {
        self.pus = pus;
        self
    }

    fn name(&self) -> String {
        format!("NATSA-{}x{}", self.dram.name, self.pus)
    }

    /// Per-PU HBM bandwidth share (GB/s) — channels divide evenly.
    pub fn bw_per_pu_gbs(&self) -> f64 {
        self.dram.effective_bw_gbs() / self.pus as f64
    }

    /// Per-PU compute demand on memory (GB/s) to keep the pipeline fed.
    pub fn demand_per_pu_gbs(&self) -> f64 {
        bytes_per_cell(self.precision)
            / (cycles_per_cell(self.precision) / self.pu.freq_ghz)
    }

    /// Closed-form evaluation (Table 2 / Fig. 7 path).
    pub fn estimate(&self, w: &Workload) -> Estimate {
        let sched = scheduler::schedule_banded(w.nw, w.excl, self.pus);
        let cyc = cycles_per_cell(self.precision);
        let bpc = bytes_per_cell(self.precision);
        let bw_pu = self.bw_per_pu_gbs() * 1e9;
        let freq = self.pu.freq_ghz * 1e9;
        let lanes = self.pu.lanes as f64;

        let mut t_max = 0.0f64;
        let mut compute_bound_pus = 0usize;
        let mut total_bytes = 0u64;
        for k in 0..self.pus {
            let cells = sched.load(k) as f64;
            let diags = sched.diagonals_assigned(k) as f64;
            // DPU startup per diagonal: m/lanes cycles.
            let compute_s = (cells * cyc + diags * w.m as f64 / lanes) / freq;
            let bytes = cells * bpc + diags * 2.0 * w.m as f64 * self.pu.elem_bytes as f64;
            let mem_s = bytes / bw_pu;
            total_bytes += bytes as u64;
            if compute_s >= mem_s {
                compute_bound_pus += 1;
            }
            t_max = t_max.max(compute_s.max(mem_s));
        }
        let bound = if compute_bound_pus * 2 >= self.pus {
            Bound::Compute
        } else {
            Bound::Memory
        };
        let bw_gbs = total_bytes as f64 / t_max / 1e9;
        let power_w = self.compute_power_w() + self.dram.dynamic_power_w(bw_gbs);
        Estimate {
            platform: self.name(),
            precision: self.precision,
            time_s: t_max,
            bw_gbs,
            power_w,
            energy_j: power_w * t_max,
            bound,
        }
    }

    /// PU-fleet dynamic power (W): peak per-PU power scaled by pipeline
    /// utilization (memory-bound PUs idle their FPUs part of the time).
    pub fn compute_power_w(&self) -> f64 {
        let util = (self.demand_per_pu_gbs() / self.bw_per_pu_gbs()).min(1.0);
        // util < 1 => compute-bound (FPUs busy); util > 1 clamped: memory
        // bound => FPUs busy a fraction 1/util of the time.
        let busy = if util >= 1.0 { 1.0 / util } else { 1.0 };
        self.pus as f64 * self.pu.peak_power_w * busy.max(0.3)
    }

    /// Chunk-level discrete-event simulation.  `sim_chunk` cells per
    /// event (defaults keep the event count ~1e5); returns an [`Estimate`]
    /// plus the number of events processed.
    pub fn simulate(&self, w: &Workload, sim_chunk: Option<u64>) -> (Estimate, u64) {
        let sched = scheduler::schedule_banded(w.nw, w.excl, self.pus);
        let chunk = sim_chunk
            .unwrap_or_else(|| (w.cells / self.pus as u64 / 2000).clamp(512, 1 << 22));
        let freq_hz = self.pu.freq_ghz * 1e9;
        let ps_per_cycle = 1e12 / freq_hz;
        let ch_bw_bytes_per_ps = self.dram.channel_bw_gbs() * 1e9 / 1e12;

        // Per-PU work: flatten its band tiles into chunk descriptors
        // (the tile's seed dots — one per diagonal — ride its first
        // chunk).
        let mut pu_chunks: Vec<std::vec::IntoIter<ChunkWork>> = sched
            .per_pu
            .iter()
            .map(|tiles| {
                let mut v = Vec::new();
                for tile in tiles {
                    let mut left = tile.cells(w.nw);
                    let mut dots = tile.width as u64;
                    while left > 0 {
                        let c = left.min(chunk);
                        v.push(ChunkWork { cells: c, first_dots: dots, m: w.m });
                        dots = 0;
                        left -= c;
                    }
                }
                v.into_iter()
            })
            .collect();

        let mut channels = vec![FcfsChannel::default(); self.dram.channels];
        let mut queue: EventQueue<usize> = EventQueue::new();
        let mut events = 0u64;
        let mut finish = vec![0u64; self.pus];

        // Kick off every PU at t=0.
        for pu in 0..self.pus {
            queue.schedule(0, pu);
        }
        while let Some(ev) = queue.pop() {
            let pu = ev.payload;
            if let Some(work) = pu_chunks[pu].next() {
                events += 1;
                // memory phase: the PU's channel streams the chunk while
                // the pipeline computes; completion = max(compute, mem)
                // from the channel's grant time (double-buffered).
                let ch = pu % self.dram.channels;
                let mem_done =
                    channels[ch].serve(ev.at, work.traffic_bytes(&self.pu), ch_bw_bytes_per_ps);
                let compute_ps = (work.cycles(&self.pu) as f64 * ps_per_cycle) as u64;
                let done = mem_done.max(ev.at + compute_ps);
                finish[pu] = done;
                queue.schedule(done, pu);
            }
        }
        let t_ps = *finish.iter().max().unwrap_or(&0);
        let time_s = t_ps as f64 * 1e-12;
        let total_bytes: u64 = channels.iter().map(|c| c.bytes_served).sum();
        let bw_gbs = total_bytes as f64 / time_s / 1e9;
        let power_w = self.compute_power_w() + self.dram.dynamic_power_w(bw_gbs);
        let est = Estimate {
            platform: format!("{}(des)", self.name()),
            precision: self.precision,
            time_s,
            bw_gbs,
            power_w,
            energy_j: power_w * time_s,
            bound: if self.demand_per_pu_gbs() > self.bw_per_pu_gbs() {
                Bound::Memory
            } else {
                Bound::Compute
            },
        };
        (est, events)
    }

    /// Total accelerator area (mm², 45 nm) — Table 3.
    pub fn area_mm2(&self) -> f64 {
        self.pus as f64 * self.pu.area_mm2
    }

    /// Total peak power (W) — Table 3.
    pub fn peak_power_w(&self) -> f64 {
        self.pus as f64 * self.pu.peak_power_w
    }
}

/// Design-space exploration row (Section 6.3): PU count sweep.
#[derive(Clone, Debug)]
pub struct DsePoint {
    pub pus: usize,
    pub time_s: f64,
    pub bound: Bound,
    pub bw_utilization: f64,
    pub area_mm2: f64,
    pub peak_power_w: f64,
}

/// Sweep PU counts on a workload (the Section 6.3 exploration).
pub fn design_space(
    precision: Precision,
    dram: DramConfig,
    pu_counts: &[usize],
    w: &Workload,
) -> Vec<DsePoint> {
    pu_counts
        .iter()
        .map(|&pus| {
            let mut d = NatsaDesign::hbm(precision);
            d.dram = dram.clone();
            d.pus = pus;
            let e = d.estimate(w);
            DsePoint {
                pus,
                time_s: e.time_s,
                bound: e.bound,
                bw_utilization: e.bw_gbs / d.dram.peak_bw_gbs,
                area_mm2: d.area_mm2(),
                peak_power_w: d.peak_power_w(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2(n: usize) -> Workload {
        Workload::new(n, 256)
    }

    #[test]
    fn tracks_table2_natsa_anchors() {
        // Table 2: NATSA-DP 2.47 / 42.45 / 690.65 s, NATSA-SP 1.41 / 393.45.
        for (prec, anchors) in [
            (
                Precision::Dp,
                vec![(131_072, 2.47), (524_288, 42.45), (2_097_152, 690.65)],
            ),
            (Precision::Sp, vec![(131_072, 1.41), (2_097_152, 393.45)]),
        ] {
            let d = NatsaDesign::hbm(prec);
            for (n, paper) in anchors {
                let e = d.estimate(&t2(n));
                let ratio = e.time_s / paper;
                assert!(
                    (0.7..1.3).contains(&ratio),
                    "{:?} n={n}: model {:.2}s vs paper {paper}s",
                    prec,
                    e.time_s
                );
            }
        }
    }

    #[test]
    fn table3_totals() {
        let dp = NatsaDesign::hbm(Precision::Dp);
        assert_eq!(dp.pus, 48);
        assert!((dp.area_mm2() - 77.76).abs() < 0.01);
        assert!((dp.peak_power_w() - 4.8).abs() < 0.01);
        let sp = NatsaDesign::hbm(Precision::Sp);
        assert!((sp.area_mm2() - 72.48).abs() < 0.01);
        assert!((sp.peak_power_w() - 3.84).abs() < 0.01);
    }

    #[test]
    fn dse_balance_at_48_pus() {
        // Section 6.3: 32 PUs compute-bound, 64 memory-bound, 48 balanced.
        let pts = design_space(
            Precision::Dp,
            DramConfig::hbm2(),
            &[32, 48, 64],
            &t2(524_288),
        );
        assert_eq!(pts[0].bound, Bound::Compute, "32 PUs");
        assert_eq!(pts[2].bound, Bound::Memory, "64 PUs");
        // 48 is the knee: adding PUs beyond it buys little
        let gain_32_48 = pts[0].time_s / pts[1].time_s;
        let gain_48_64 = pts[1].time_s / pts[2].time_s;
        assert!(gain_32_48 > 1.25, "{gain_32_48}");
        assert!(gain_48_64 < 1.12, "{gain_48_64}");
    }

    #[test]
    fn ddr4_variant_saturates_with_8_pus() {
        // Footnote 2: 8 PUs are enough for dual-channel DDR4.
        let d = NatsaDesign::ddr4(Precision::Dp);
        assert_eq!(d.pus, 8);
        let e = d.estimate(&t2(524_288));
        assert_eq!(e.bound, Bound::Memory);
        // adding more PUs gains <10%
        let e16 = NatsaDesign::ddr4(Precision::Dp)
            .with_pus(16)
            .estimate(&t2(524_288));
        assert!(e.time_s / e16.time_s < 1.10);
    }

    #[test]
    fn des_agrees_with_closed_form() {
        let d = NatsaDesign::hbm(Precision::Dp);
        let w = t2(131_072);
        let a = d.estimate(&w);
        let (b, events) = d.simulate(&w, None);
        let ratio = b.time_s / a.time_s;
        assert!(
            (0.9..1.15).contains(&ratio),
            "DES {:.3}s vs closed form {:.3}s",
            b.time_s,
            a.time_s
        );
        assert!(events > 1000, "expected a meaningful event count: {events}");
    }

    #[test]
    fn sp_speedup_over_dp_matches_paper_band() {
        // Table 2: NATSA-SP outperforms NATSA-DP by up to 1.75x.
        let w = t2(2_097_152);
        let dp = NatsaDesign::hbm(Precision::Dp).estimate(&w);
        let sp = NatsaDesign::hbm(Precision::Sp).estimate(&w);
        let s = dp.time_s / sp.time_s;
        assert!((1.4..2.0).contains(&s), "SP speedup {s}");
    }

    #[test]
    fn power_dominated_by_memory() {
        // Fig. 8: "most of its power is consumed by memory".
        let d = NatsaDesign::hbm(Precision::Dp);
        let e = d.estimate(&t2(524_288));
        let mem_w = d.dram.dynamic_power_w(e.bw_gbs);
        assert!(
            mem_w > e.power_w - mem_w,
            "memory {mem_w}W vs compute {}W",
            e.power_w - mem_w
        );
    }

    #[test]
    fn speedup_grows_with_series_length() {
        // Fig. 7: NATSA speedup over the baseline increases with n.
        let base = crate::sim::platform::GpPlatform::ddr4_ooo();
        let d = NatsaDesign::hbm(Precision::Dp);
        let mut last = 0.0;
        for n in [131_072, 524_288, 2_097_152] {
            let w = t2(n);
            let s = base.estimate(&w, Precision::Dp).time_s / d.estimate(&w).time_s;
            assert!(s > last, "speedup must grow: {s} after {last}");
            last = s;
        }
        assert!(last > 8.0, "2M speedup {last}");
    }
}
