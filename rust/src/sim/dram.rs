//! DRAM device models — the Ramulator + Micron-power-calculator substitute.
//!
//! Each [`DramConfig`] carries the timing/energy parameters the higher-level
//! models consume: peak bandwidth, channel organization, effective-bandwidth
//! derating (row-buffer conflicts and scheduling losses under many request
//! streams), loaded access latency, and access energy per byte.
//!
//! Energy constants follow public figures: DDR4 access energy in the tens
//! of pJ/bit once I/O + activation are included; HBM2 roughly 3.9 pJ/bit
//! thanks to TSV I/O (Lee+ ISSCC'14 [55], JEDEC [46]).  Background power
//! scales with capacity.

/// A DRAM subsystem (device + channel organization).
#[derive(Clone, Debug)]
pub struct DramConfig {
    pub name: &'static str,
    /// Independent channels (HBM2: 8; dual-channel DDR4: 2).
    pub channels: usize,
    /// Peak aggregate bandwidth (GB/s).
    pub peak_bw_gbs: f64,
    /// Fraction of peak sustainable by a many-stream diagonal workload
    /// (row-buffer locality is poor; HBM's channel count absorbs more).
    pub efficiency: f64,
    /// Loaded access latency (ns) — drives the in-order stall model.
    pub latency_ns: f64,
    /// Access energy (pJ per byte, read ≈ write for our purposes).
    pub energy_pj_per_byte: f64,
    /// Background + refresh power for the fitted capacity (W).
    pub background_w: f64,
    /// Capacity (GiB), for reporting.
    pub capacity_gib: usize,
}

impl DramConfig {
    /// Dual-channel DDR4-2400: 38.4 GB/s peak (paper Section 5.1).
    pub fn ddr4_2400_dual() -> Self {
        DramConfig {
            name: "DDR4-2400x2",
            channels: 2,
            peak_bw_gbs: 38.4,
            efficiency: 0.70,
            latency_ns: 75.0,
            energy_pj_per_byte: 62.0, // ~7.75 pJ/bit incl. I/O + ACT share
            background_w: 1.9,
            capacity_gib: 16,
        }
    }

    /// 4 GB HBM2 stack: 256 GB/s peak over 8 channels (paper Section 5.1).
    pub fn hbm2() -> Self {
        DramConfig {
            name: "HBM2",
            channels: 8,
            peak_bw_gbs: 256.0,
            efficiency: 0.90,
            latency_ns: 60.0,
            energy_pj_per_byte: 31.0, // ~3.9 pJ/bit (ISSCC'14)
            background_w: 1.2,
            capacity_gib: 4,
        }
    }

    /// KNL's 6-channel DDR4-2400 (Figs. 3-4 testbed): 115.2 GB/s peak,
    /// ~90 GB/s sustained.
    pub fn knl_ddr4() -> Self {
        DramConfig {
            name: "KNL-DDR4x6",
            channels: 6,
            peak_bw_gbs: 115.2,
            efficiency: 0.78,
            latency_ns: 85.0,
            energy_pj_per_byte: 62.0,
            background_w: 4.5,
            capacity_gib: 96,
        }
    }

    /// KNL's on-package MCDRAM (8 stacks, ~450 GB/s streaming).
    pub fn knl_mcdram() -> Self {
        DramConfig {
            name: "KNL-MCDRAM",
            channels: 8,
            peak_bw_gbs: 450.0,
            efficiency: 0.80,
            latency_ns: 95.0, // MCDRAM trades latency for bandwidth
            energy_pj_per_byte: 38.0,
            background_w: 3.0,
            capacity_gib: 16,
        }
    }

    /// Bandwidth actually sustainable for our access pattern (GB/s).
    pub fn effective_bw_gbs(&self) -> f64 {
        self.peak_bw_gbs * self.efficiency
    }

    /// Time (s) to move `bytes` at effective bandwidth.
    pub fn transfer_time_s(&self, bytes: u64) -> f64 {
        bytes as f64 / (self.effective_bw_gbs() * 1e9)
    }

    /// Dynamic memory power (W) when serving `bw_gbs` of traffic.
    pub fn dynamic_power_w(&self, bw_gbs: f64) -> f64 {
        self.background_w + bw_gbs * 1e9 * self.energy_pj_per_byte * 1e-12
    }

    /// Energy (J) for moving `bytes` over `time_s` seconds.
    pub fn energy_j(&self, bytes: u64, time_s: f64) -> f64 {
        self.background_w * time_s + bytes as f64 * self.energy_pj_per_byte * 1e-12
    }

    /// Per-channel effective bandwidth (GB/s).
    pub fn channel_bw_gbs(&self) -> f64 {
        self.effective_bw_gbs() / self.channels as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_peak_bandwidths() {
        assert!((DramConfig::ddr4_2400_dual().peak_bw_gbs - 38.4).abs() < 1e-9);
        assert!((DramConfig::hbm2().peak_bw_gbs - 256.0).abs() < 1e-9);
        assert_eq!(DramConfig::hbm2().channels, 8);
    }

    #[test]
    fn hbm_more_efficient_per_byte() {
        let ddr = DramConfig::ddr4_2400_dual();
        let hbm = DramConfig::hbm2();
        assert!(hbm.energy_pj_per_byte < ddr.energy_pj_per_byte / 1.5);
    }

    #[test]
    fn transfer_time_linear() {
        let hbm = DramConfig::hbm2();
        let t1 = hbm.transfer_time_s(1 << 30);
        let t2 = hbm.transfer_time_s(2 << 30);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
        // 230.4 GB/s effective: 1 GiB in ~4.7 ms
        assert!((t1 - (1u64 << 30) as f64 / 230.4e9).abs() < 1e-6);
    }

    #[test]
    fn power_scales_with_bandwidth() {
        let hbm = DramConfig::hbm2();
        let idle = hbm.dynamic_power_w(0.0);
        let busy = hbm.dynamic_power_w(230.0);
        assert!((idle - hbm.background_w).abs() < 1e-12);
        assert!(busy > idle + 6.0, "HBM at full tilt ~7W dynamic: {busy}");
    }

    #[test]
    fn energy_consistent_with_power() {
        let d = DramConfig::ddr4_2400_dual();
        let bytes = 26_880_000_000u64; // 26.88 GB/s for 1 s
        let e = d.energy_j(bytes, 1.0);
        let p = d.dynamic_power_w(26.88);
        assert!((e - p).abs() / p < 1e-6, "{e} vs {p}");
    }

    #[test]
    fn channel_bw_split() {
        let hbm = DramConfig::hbm2();
        assert!((hbm.channel_bw_gbs() * 8.0 - hbm.effective_bw_gbs()).abs() < 1e-9);
    }
}
