//! Power and energy models — McPAT + Micron + Galal-FPU substitute.
//!
//! Three granularities:
//!
//! * per-operation FPU energies ([`FpuEnergy`], 45 nm, after Galal &
//!   Horowitz [29] and Salehi [83]) — used for the NATSA PU's bottom-up
//!   energy estimate and the Fig. 9 decomposition,
//! * per-platform dynamic power (assembled in [`crate::sim::platform`] and
//!   [`crate::sim::accel`] from core/PU constants + the DRAM model),
//! * technology scaling ([`tech_scale`]), for the paper's closing remark
//!   that 15 nm would cut NATSA's energy ~4x and area ~3x [83].

use crate::sim::{Estimate, Precision};

/// Energy per floating-point operation at 45 nm (pJ) — energy-efficient
/// FPU design values [29].
#[derive(Clone, Copy, Debug)]
pub struct FpuEnergy {
    pub add_pj: f64,
    pub mul_pj: f64,
    pub div_sqrt_pj: f64,
    pub cmp_pj: f64,
    /// Register-file access (pJ per operand).
    pub reg_pj: f64,
}

impl FpuEnergy {
    pub fn at_45nm(prec: Precision) -> Self {
        match prec {
            Precision::Dp => FpuEnergy {
                add_pj: 18.0,
                mul_pj: 34.0,
                div_sqrt_pj: 85.0,
                cmp_pj: 4.0,
                reg_pj: 2.2,
            },
            Precision::Sp => FpuEnergy {
                add_pj: 8.0,
                mul_pj: 14.0,
                div_sqrt_pj: 38.0,
                cmp_pj: 2.0,
                reg_pj: 1.4,
            },
        }
    }

    /// Compute energy of one diagonal cell through the PU pipeline:
    /// DPUU (2 mul + 2 add) + DCU (3 mul + 2 add + div + sqrt) + PUU
    /// (2 cmp) + ~12 register operands.
    pub fn cell_pj(&self) -> f64 {
        2.0 * self.mul_pj
            + 2.0 * self.add_pj
            + 3.0 * self.mul_pj
            + 2.0 * self.add_pj
            + 2.0 * self.div_sqrt_pj
            + 2.0 * self.cmp_pj
            + 12.0 * self.reg_pj
    }
}

/// Multiplicative savings when moving to a smaller node.  Exponents are
/// fitted to the paper's Section 6.2 anchor (45 -> 15 nm: ~4x energy,
/// ~3x area, after [83]).
#[derive(Clone, Copy, Debug)]
pub struct TechScale {
    /// Divide energy by this.
    pub energy_factor: f64,
    /// Divide area by this.
    pub area_factor: f64,
}

impl TechScale {
    pub fn of(from_nm: f64, to_nm: f64) -> TechScale {
        let s = from_nm / to_nm;
        TechScale {
            energy_factor: s.powf(1.26),
            area_factor: s.powf(1.0), // ~3x from 45->15nm per [83]
        }
    }
}

/// Energy summary row for Fig. 9, decomposed into compute vs memory.
#[derive(Clone, Debug)]
pub struct EnergyRow {
    pub platform: String,
    pub total_j: f64,
    pub compute_j: f64,
    pub memory_j: f64,
}

impl EnergyRow {
    /// Split an [`Estimate`] using the platform's DRAM power at its
    /// served bandwidth.
    pub fn from_estimate(e: &Estimate, mem_power_w: f64) -> Self {
        let memory_j = mem_power_w * e.time_s;
        EnergyRow {
            platform: e.platform.clone(),
            total_j: e.energy_j,
            compute_j: (e.energy_j - memory_j).max(0.0),
            memory_j,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sp_ops_cheaper_than_dp() {
        let dp = FpuEnergy::at_45nm(Precision::Dp);
        let sp = FpuEnergy::at_45nm(Precision::Sp);
        assert!(sp.mul_pj < dp.mul_pj / 2.0 + 1.0);
        assert!(sp.cell_pj() < dp.cell_pj());
    }

    #[test]
    fn cell_energy_order_of_magnitude() {
        // a DP cell through the pipeline: a few hundred pJ at 45 nm
        let pj = FpuEnergy::at_45nm(Precision::Dp).cell_pj();
        assert!((200.0..700.0).contains(&pj), "{pj}");
    }

    #[test]
    fn bottom_up_pu_power_matches_table3() {
        // 48 DP PUs at the balanced point compute ~3.4e9 cells/s total;
        // bottom-up energy x rate should land near Table 3's 4.8 W peak.
        let pj = FpuEnergy::at_45nm(Precision::Dp).cell_pj();
        let cells_per_s = 48.0e9 / 14.0; // fleet rate at 1 GHz, 14 cyc/cell
        let watts = pj * 1e-12 * cells_per_s;
        assert!(
            (0.4..2.0).contains(&(watts / 4.8 * 4.0)),
            "bottom-up {watts:.2}W vs Table 3 4.8W peak"
        );
    }

    #[test]
    fn tech_scaling_matches_paper_claim() {
        // Section 6.2: 45 -> 15 nm gives ~4x energy and ~3x area.
        let ts = TechScale::of(45.0, 15.0);
        assert!((3.5..4.5).contains(&ts.energy_factor), "{}", ts.energy_factor);
        assert!((2.5..3.5).contains(&ts.area_factor), "{}", ts.area_factor);
    }

    #[test]
    fn energy_row_decomposition_sums() {
        let e = Estimate {
            platform: "X".into(),
            precision: Precision::Dp,
            time_s: 10.0,
            bw_gbs: 100.0,
            power_w: 20.0,
            energy_j: 200.0,
            bound: crate::sim::Bound::Memory,
        };
        let row = EnergyRow::from_estimate(&e, 8.0);
        assert!((row.memory_j - 80.0).abs() < 1e-9);
        assert!((row.compute_j - 120.0).abs() < 1e-9);
        assert!((row.total_j - (row.compute_j + row.memory_j)).abs() < 1e-9);
    }
}
