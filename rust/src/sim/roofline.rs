//! Roofline analysis (Fig. 4) — arithmetic intensity of SCRIMP on the KNL.
//!
//! The paper's Fig. 4 places SCRIMP far left of the ridge point on a Xeon
//! Phi 7210 roofline: the diagonal algorithm performs ~13 flops per cell
//! against tens of bytes of traffic, so attainable performance is the
//! bandwidth roof at every realistic cache behaviour.  This module
//! computes the same plot from the [`Workload`] descriptors and the
//! platform constants — no hand-entered results.

use crate::sim::cache::TrafficModel;
use crate::sim::dram::DramConfig;
use crate::sim::{Precision, Workload};

/// A machine roofline: peak compute and one or more bandwidth ceilings.
#[derive(Clone, Debug)]
pub struct Roofline {
    pub name: &'static str,
    /// Peak floating-point throughput (GFLOP/s).
    pub peak_gflops: f64,
    pub mems: Vec<DramConfig>,
}

impl Roofline {
    /// Xeon Phi 7210: 64 cores x 1.3 GHz x 32 DP flop/cycle ≈ 2662 GFLOP/s
    /// (double precision, AVX-512 FMA), DDR4 + MCDRAM ceilings.
    pub fn knl7210() -> Self {
        Roofline {
            name: "Xeon Phi 7210",
            peak_gflops: 2662.0,
            mems: vec![DramConfig::knl_ddr4(), DramConfig::knl_mcdram()],
        }
    }

    /// Attainable GFLOP/s at arithmetic intensity `ai` (flop/byte) for
    /// memory system `mem_idx`.
    pub fn attainable_gflops(&self, ai: f64, mem_idx: usize) -> f64 {
        (ai * self.mems[mem_idx].effective_bw_gbs()).min(self.peak_gflops)
    }

    /// Ridge point (flop/byte) where memory `mem_idx` stops binding.
    pub fn ridge(&self, mem_idx: usize) -> f64 {
        self.peak_gflops / self.mems[mem_idx].effective_bw_gbs()
    }
}

/// SCRIMP's arithmetic intensity on a workload under a traffic model.
pub fn scrimp_intensity(w: &Workload, traffic: &TrafficModel, prec: Precision) -> f64 {
    let bytes = w.cells as f64 * traffic.bytes_per_cell(w.nw, prec)
        + w.diagonals as f64 * 2.0 * w.m as f64 * prec.bytes() as f64;
    w.flops() as f64 / bytes
}

/// One point of Fig. 4: measured-equivalent (AI, achieved GFLOP/s).
#[derive(Clone, Copy, Debug)]
pub struct RooflinePoint {
    pub ai_flop_per_byte: f64,
    pub achieved_gflops: f64,
    pub attainable_gflops: f64,
    pub peak_fraction: f64,
}

/// Evaluate SCRIMP's position on the KNL roofline using the Fig. 3
/// scaling model at full thread count.
pub fn fig4_points(w: &Workload) -> Vec<(String, RooflinePoint)> {
    use crate::sim::platform::KnlModel;
    let roof = Roofline::knl7210();
    let traffic = TrafficModel {
        llc_bytes: 32 << 20, // 32 MB aggregate L2 on KNL
        hot_elems: 2.0,
        cold_elems: 10.0,
    };
    let ai = scrimp_intensity(w, &traffic, Precision::Dp);
    let mut out = Vec::new();
    for (idx, knl) in [KnlModel::ddr4(), KnlModel::mcdram()].iter().enumerate() {
        let (_, bw) = knl.scaling_point(256);
        let achieved = ai * bw; // flops delivered at the served bandwidth
        let attainable = roof.attainable_gflops(ai, idx);
        out.push((
            knl.dram.name.to_string(),
            RooflinePoint {
                ai_flop_per_byte: ai,
                achieved_gflops: achieved,
                attainable_gflops: attainable,
                peak_fraction: achieved / roof.peak_gflops,
            },
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scrimp_is_far_left_of_ridge() {
        // Fig. 4's message: AI is "significantly low" — well below the
        // ridge on both memories.
        let w = Workload::new(1_048_576, 256);
        let roof = Roofline::knl7210();
        let traffic = TrafficModel {
            llc_bytes: 32 << 20,
            hot_elems: 2.0,
            cold_elems: 10.0,
        };
        let ai = scrimp_intensity(&w, &traffic, Precision::Dp);
        assert!(ai < 1.0, "AI {ai} should be < 1 flop/byte");
        assert!(ai < roof.ridge(0) / 5.0, "AI {ai} vs ridge {}", roof.ridge(0));
        assert!(ai < roof.ridge(1) / 2.0);
    }

    #[test]
    fn attainable_is_bandwidth_bound() {
        let roof = Roofline::knl7210();
        let att = roof.attainable_gflops(0.3, 0);
        assert!(att < roof.peak_gflops / 10.0);
        assert!((att - 0.3 * DramConfig::knl_ddr4().effective_bw_gbs()).abs() < 1e-9);
    }

    #[test]
    fn peak_clamps_high_intensity() {
        let roof = Roofline::knl7210();
        assert_eq!(roof.attainable_gflops(1e6, 1), roof.peak_gflops);
    }

    #[test]
    fn fig4_cores_underutilized() {
        // "low arithmetic intensity ... leads processing cores to be
        // underutilized": achieved is a tiny fraction of peak.
        for (name, p) in fig4_points(&Workload::new(1_048_576, 256)) {
            assert!(
                p.peak_fraction < 0.10,
                "{name}: {:.1}% of peak",
                p.peak_fraction * 100.0
            );
            assert!(p.achieved_gflops <= p.attainable_gflops * 1.001);
        }
    }

    #[test]
    fn mcdram_achieves_more_than_ddr4() {
        let pts = fig4_points(&Workload::new(1_048_576, 256));
        assert!(pts[1].1.achieved_gflops > 2.0 * pts[0].1.achieved_gflops);
    }

    #[test]
    fn intensity_rises_with_window_reuse() {
        // larger m amortizes nothing per cell, but fewer windows shrink
        // the working set -> less traffic -> higher AI on small series.
        let traffic = TrafficModel {
            llc_bytes: 8 << 20,
            hot_elems: 2.0,
            cold_elems: 10.0,
        };
        let small = scrimp_intensity(&Workload::new(100_000, 256), &traffic, Precision::Dp);
        let large = scrimp_intensity(&Workload::new(2_000_000, 256), &traffic, Precision::Dp);
        assert!(small > large, "hot {small} vs cold {large}");
    }
}
