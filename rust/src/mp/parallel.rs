//! Multi-threaded SCRIMP — the software analogue of NATSA's PU fleet.
//!
//! Mirrors the paper's baseline setup (Section 2.2): diagonals are
//! partitioned across threads, each thread keeps a *private* profile
//! (`PP`/`II`, exactly like NATSA's per-PU replicated vectors — Section
//! 4.2 "Data mapping"), and a final reduction min-merges them.  No locks
//! or atomics on the hot path.
//!
//! Partitioning is pluggable so benches can contrast the naive contiguous
//! split (load-imbalanced: diagonal lengths vary) and per-diagonal work
//! lists against NATSA's balanced pair schemes from
//! [`crate::natsa::scheduler`].  The default is the band-granular scheme
//! ([`Partition::BandedPairs`]): each thread receives balanced pairs of
//! *adjacent-diagonal tiles* and executes them through the kernel's
//! multi-lane band path — same cells, same bits, ~2x fewer instructions
//! per cell than per-diagonal walking.

use crate::mp::kernel::compute_band_n;
use crate::mp::{MatrixProfile, MpConfig, WorkStats};
use crate::natsa::scheduler::BandTile;
use crate::timeseries::sliding_stats;
use crate::Real;

/// How diagonals are split across threads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partition {
    /// Contiguous blocks of diagonal indices (the naive split; threads
    /// holding short diagonals finish early).
    Contiguous,
    /// Round-robin by index (better but still unbalanced at the tail).
    Strided,
    /// NATSA's balanced diagonal-pair scheme (Section 4.2), one diagonal
    /// per work unit (the pre-band fleet baseline).
    BalancedPairs,
    /// The band-granular scheme: balanced pairs of adjacent-diagonal
    /// tiles, so every thread rides the kernel's multi-lane band path
    /// ([`crate::natsa::scheduler::schedule_banded`]).
    BandedPairs,
}

/// Parallel SCRIMP with `threads` workers (band-granular work lists).
pub fn matrix_profile<T: Real>(
    t: &[T],
    cfg: MpConfig,
    threads: usize,
) -> crate::Result<MatrixProfile<T>> {
    Ok(with_stats(t, cfg, threads, Partition::BandedPairs)?.0)
}

/// Parallel SCRIMP with explicit partitioning and aggregate work stats.
pub fn with_stats<T: Real>(
    t: &[T],
    cfg: MpConfig,
    threads: usize,
    partition: Partition,
) -> crate::Result<(MatrixProfile<T>, WorkStats)> {
    anyhow::ensure!(threads >= 1, "need at least one thread");
    let nw = cfg.validate(t.len())?;
    let excl = cfg.exclusion();
    let m = cfg.m;
    let st = sliding_stats(t, m);
    let assignments = assign_tiles(nw, excl, threads, partition);

    let results: Vec<(MatrixProfile<T>, WorkStats)> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for tiles in &assignments {
            let st = &st;
            handles.push(scope.spawn(move || {
                let mut local = MatrixProfile::new_inf(nw, m, excl);
                let mut work = WorkStats::default();
                for tile in tiles {
                    compute_band_n(t, st, tile.d0, tile.width, &mut local, &mut work);
                }
                (local, work)
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    // Host-side reduction (Alg. 2 line 6).
    let mut mp = MatrixProfile::new_inf(nw, m, excl);
    let mut work = WorkStats::default();
    for (local, w) in &results {
        mp.merge(local);
        work.add(w);
    }
    mp.sqrt_in_place(); // diagonals accumulate squared distances
    Ok((mp, work))
}

/// Split diagonals `excl..nw` into per-thread band-tile work lists.
/// Only [`Partition::BandedPairs`] produces multi-diagonal tiles; the
/// other schemes deal width-1 tiles (one diagonal per work unit), which
/// keeps them meaningful as per-diagonal baselines for the ablation
/// bench.
pub fn assign_tiles(
    nw: usize,
    excl: usize,
    threads: usize,
    partition: Partition,
) -> Vec<Vec<BandTile>> {
    if partition == Partition::BandedPairs {
        // Delegate to the NATSA scheduler so the software fleet and the
        // accelerator share one band-granular partitioning implementation.
        return crate::natsa::scheduler::schedule_banded(nw, excl, threads).per_pu;
    }
    let solo = |d: usize| BandTile { d0: d, width: 1 };
    let diags: Vec<usize> = (excl..nw).collect();
    let mut out = vec![Vec::new(); threads];
    match partition {
        Partition::Contiguous => {
            let per = diags.len().div_ceil(threads);
            for (k, chunk) in diags.chunks(per.max(1)).enumerate() {
                out[k.min(threads - 1)].extend(chunk.iter().map(|&d| solo(d)));
            }
        }
        Partition::Strided => {
            for (k, d) in diags.into_iter().enumerate() {
                out[k % threads].push(solo(d));
            }
        }
        Partition::BalancedPairs => {
            let sched = crate::natsa::scheduler::schedule(nw, excl, threads);
            for (k, pu) in sched.per_pu.into_iter().enumerate() {
                out[k] = pu.into_iter().map(solo).collect();
            }
        }
        Partition::BandedPairs => unreachable!("handled above"),
    }
    out
}

/// Split diagonals `excl..nw` into per-thread diagonal lists (the tile
/// assignment of [`assign_tiles`], expanded to individual diagonals —
/// load/coverage analysis and the ablation bench consume this view).
pub fn assign(nw: usize, excl: usize, threads: usize, partition: Partition) -> Vec<Vec<usize>> {
    assign_tiles(nw, excl, threads, partition)
        .into_iter()
        .map(|tiles| tiles.iter().flat_map(|t| t.diagonals()).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mp::scrimp;
    use crate::prop::{check, Rng};

    #[test]
    fn all_partitions_match_serial() {
        let mut rng = Rng::new(21);
        let t: Vec<f64> = rng.gauss_vec(600);
        let cfg = MpConfig::new(24);
        let want = scrimp::matrix_profile(&t, cfg).unwrap();
        for part in [
            Partition::Contiguous,
            Partition::Strided,
            Partition::BalancedPairs,
            Partition::BandedPairs,
        ] {
            let (got, _) = with_stats(&t, cfg, 4, part).unwrap();
            assert!(
                got.max_abs_diff(&want) < 1e-12,
                "{part:?}: {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn thread_counts_equivalent() {
        let mut rng = Rng::new(22);
        let t: Vec<f32> = rng.gauss_vec(500).iter().map(|&x| x as f32).collect();
        let cfg = MpConfig::new(16);
        let one = matrix_profile(&t, cfg, 1).unwrap();
        for threads in [2, 3, 7, 16] {
            let multi = matrix_profile(&t, cfg, threads).unwrap();
            assert!(one.max_abs_diff(&multi) < 1e-6, "threads={threads}");
        }
    }

    #[test]
    fn assignment_covers_every_diagonal_once() {
        check("partition-coverage", 15, |rng: &mut Rng| {
            let nw = rng.range(20, 500);
            let excl = rng.range(1, 8.min(nw / 2));
            let threads = rng.range(1, 17);
            for part in [
                Partition::Contiguous,
                Partition::Strided,
                Partition::BalancedPairs,
                Partition::BandedPairs,
            ] {
                let lists = assign(nw, excl, threads, part);
                assert_eq!(lists.len(), threads);
                let mut all: Vec<usize> = lists.concat();
                all.sort_unstable();
                let want: Vec<usize> = (excl..nw).collect();
                assert_eq!(all, want, "{part:?} nw={nw} excl={excl} thr={threads}");
            }
        });
    }

    #[test]
    fn balanced_pairs_has_lower_imbalance_than_contiguous() {
        // Work per thread = sum of diagonal lengths (nw - d).
        let nw = 4000;
        let excl = 4;
        let threads = 8;
        let load = |lists: &Vec<Vec<usize>>| -> (u64, u64) {
            let loads: Vec<u64> = lists
                .iter()
                .map(|l| l.iter().map(|&d| (nw - d) as u64).sum())
                .collect();
            (*loads.iter().max().unwrap(), *loads.iter().min().unwrap())
        };
        let (max_b, min_b) = load(&assign(nw, excl, threads, Partition::BalancedPairs));
        let (max_c, min_c) = load(&assign(nw, excl, threads, Partition::Contiguous));
        let imb_b = max_b as f64 / min_b.max(1) as f64;
        let imb_c = max_c as f64 / min_c.max(1) as f64;
        assert!(
            imb_b < 1.01,
            "balanced pairs imbalance {imb_b} (max {max_b}, min {min_b})"
        );
        assert!(imb_b < imb_c, "balanced {imb_b} vs contiguous {imb_c}");
        // the band-granular scheme must not give up the static balance
        // the per-diagonal pairing delivers
        let (max_t, min_t) = load(&assign(nw, excl, threads, Partition::BandedPairs));
        let imb_t = max_t as f64 / min_t.max(1) as f64;
        assert!(
            imb_t < 1.01,
            "banded pairs imbalance {imb_t} (max {max_t}, min {min_t})"
        );
    }

    #[test]
    fn work_stats_independent_of_threads() {
        let mut rng = Rng::new(23);
        let t: Vec<f64> = rng.gauss_vec(300);
        let cfg = MpConfig::new(12);
        let (_, w1) = with_stats(&t, cfg, 1, Partition::BalancedPairs).unwrap();
        let (_, w4) = with_stats(&t, cfg, 4, Partition::BalancedPairs).unwrap();
        assert_eq!(w1.cells, w4.cells);
        assert_eq!(w1.first_dots, w4.first_dots);
        // tiling must not change the closed-form accounting either
        let (_, wb) = with_stats(&t, cfg, 4, Partition::BandedPairs).unwrap();
        assert_eq!(w1, wb);
    }
}
