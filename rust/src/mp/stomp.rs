//! STOMP [44]: row-streaming exact matrix profile, O(n²) time, O(n) space.
//!
//! The GPU-oriented predecessor of SCRIMP.  Row `i`'s dot products are
//! derived from row `i-1`'s in O(1) per cell (the same Eq. 2 recurrence,
//! applied row-wise instead of diagonal-wise).  Included as the second
//! exact baseline the paper compares against (STOMP/GPU rows of Figs. 8-10)
//! and as another cross-check on SCRIMP.

use crate::mp::{znorm_sqdist, MatrixProfile, MpConfig, WorkStats};
use crate::timeseries::sliding_stats;
use crate::Real;

/// Compute the matrix profile with row-streaming STOMP.
pub fn matrix_profile<T: Real>(t: &[T], cfg: MpConfig) -> crate::Result<MatrixProfile<T>> {
    Ok(with_stats(t, cfg)?.0)
}

/// STOMP with work accounting for the timing models.
pub fn with_stats<T: Real>(
    t: &[T],
    cfg: MpConfig,
) -> crate::Result<(MatrixProfile<T>, WorkStats)> {
    let nw = cfg.validate(t.len())?;
    let m = cfg.m;
    let excl = cfg.exclusion();
    let st = sliding_stats(t, m);
    let mut mp = MatrixProfile::new_inf(nw, m, excl);
    let mut work = WorkStats::default();

    // Row 0: direct dot products for all admissible columns.
    let mut q_row: Vec<T> = vec![T::zero(); nw];
    for j in excl..nw {
        let q = (0..m).map(|k| t[k] * t[j + k]).sum::<T>();
        q_row[j] = q;
        let d = znorm_sqdist(q, m, st.mu[0], st.inv_msig[0], st.mu[j], st.inv_msig[j]);
        mp.update(0, j, d);
        work.cells += 1;
        work.updates += 2;
    }
    work.first_dots += (nw - excl) as u64;
    work.diagonals += 1; // row 0 counts once for accounting symmetry

    // Rows 1..: q[i][j] = q[i-1][j-1] - t[i-1] t[j-1] + t[i+m-1] t[j+m-1].
    // Only the upper triangle j >= i + excl is computed (symmetry handles
    // the rest through the two-sided update).
    for i in 1..nw {
        // walk j downward so q_row[j-1] is still row i-1's value
        let jlo = i + excl;
        if jlo >= nw {
            break;
        }
        for j in (jlo..nw).rev() {
            let q = if j == 0 {
                unreachable!()
            } else {
                q_row[j - 1] - t[i - 1] * t[j - 1] + t[i + m - 1] * t[j + m - 1]
            };
            q_row[j] = q;
            let d = znorm_sqdist(q, m, st.mu[i], st.inv_msig[i], st.mu[j], st.inv_msig[j]);
            mp.update(i, j, d);
            work.cells += 1;
            work.updates += 2;
        }
    }
    mp.sqrt_in_place(); // cells accumulate squared distances
    Ok((mp, work))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mp::brute;
    use crate::prop::{check, Rng};

    #[test]
    fn matches_brute_force() {
        let mut rng = Rng::new(5);
        let t: Vec<f64> = rng.gauss_vec(400);
        let cfg = MpConfig::new(16);
        let got = matrix_profile(&t, cfg).unwrap();
        let want = brute::matrix_profile(&t, cfg).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-8, "{}", got.max_abs_diff(&want));
    }

    #[test]
    fn prop_matches_brute_various_shapes() {
        check("stomp-vs-brute", 12, |rng: &mut Rng| {
            let n = rng.range(60, 250);
            let m = rng.range(4, 24);
            if n < 4 * m {
                return;
            }
            let t: Vec<f64> = rng.gauss_vec(n);
            let cfg = MpConfig::new(m);
            let got = matrix_profile(&t, cfg).unwrap();
            let want = brute::matrix_profile(&t, cfg).unwrap();
            assert!(
                got.max_abs_diff(&want) < 1e-7,
                "n={n} m={m} diff={}",
                got.max_abs_diff(&want)
            );
        });
    }

    #[test]
    fn f32_tracks_f64_loosely() {
        let mut rng = Rng::new(6);
        let tf64: Vec<f64> = rng.gauss_vec(300);
        let tf32: Vec<f32> = tf64.iter().map(|&x| x as f32).collect();
        let a = matrix_profile(&tf64, MpConfig::new(12)).unwrap();
        let b = matrix_profile(&tf32, MpConfig::new(12)).unwrap();
        for k in 0..a.len() {
            assert!(
                (a.p[k] - b.p[k] as f64).abs() < 1e-2,
                "k={k}: {} vs {}",
                a.p[k],
                b.p[k]
            );
        }
    }

    #[test]
    fn work_stats_count_upper_triangle() {
        let mut rng = Rng::new(7);
        let t: Vec<f64> = rng.gauss_vec(100);
        let cfg = MpConfig::new(8);
        let (_, work) = with_stats(&t, cfg).unwrap();
        let nw = 93;
        let excl = 2;
        assert_eq!(work.cells, crate::mp::total_cells(nw, excl));
    }
}
