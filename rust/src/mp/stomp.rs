//! STOMP [44]: the exact O(n²)-time, O(n)-space incremental-dot-product
//! profile, re-expressed over the unified diagonal kernel.
//!
//! STOMP's classic formulation streams *rows*: row `i`'s dot products are
//! derived from row `i-1`'s in O(1) per cell.  But each cell `(i, j)`
//! depends on `(i-1, j-1)` — the recurrence chains run **along
//! diagonals** either way, and the set of Eq. 2 updates a row walk
//! performs is cell-for-cell the set a diagonal walk performs.  This
//! engine therefore executes the same chains through
//! [`crate::mp::kernel::compute_diagonal`] (the per-cell row loop —
//! branchy two-sided updates, per-cell stats, and a dead `j == 0` guard
//! in its hot loop — is gone).
//!
//! Deliberately scheduled as *differently* from SCRIMP as the kernel
//! allows: the single-diagonal form (not the band path) in **descending**
//! diagonal order.  The kernel's core invariant says cell values are
//! bit-identical under any mix of entry points and visiting orders, so
//! the stomp↔scrimp equality tests pin that invariant against maximally
//! divergent schedules — a real cross-check, not a comparison of one
//! code path with itself.  (The pre-kernel row-walk's role as an
//! *algorithmically* independent oracle is carried by [`crate::mp::brute`],
//! which shares no Eq. 1/Eq. 2 code at all.)

use crate::mp::kernel::compute_diagonal;
use crate::mp::{MatrixProfile, MpConfig, WorkStats};
use crate::timeseries::sliding_stats;
use crate::Real;

/// Compute the matrix profile with STOMP (diagonal-order execution).
pub fn matrix_profile<T: Real>(t: &[T], cfg: MpConfig) -> crate::Result<MatrixProfile<T>> {
    Ok(with_stats(t, cfg)?.0)
}

/// STOMP with work accounting for the timing models.
pub fn with_stats<T: Real>(
    t: &[T],
    cfg: MpConfig,
) -> crate::Result<(MatrixProfile<T>, WorkStats)> {
    let nw = cfg.validate(t.len())?;
    let m = cfg.m;
    let excl = cfg.exclusion();
    let st = sliding_stats(t, m);
    let mut mp = MatrixProfile::new_inf(nw, m, excl);
    let mut work = WorkStats::default();
    for d in (excl..nw).rev() {
        compute_diagonal(t, &st, d, &mut mp, &mut work);
    }
    mp.sqrt_in_place(); // cells accumulate squared distances
    Ok((mp, work))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mp::{brute, scrimp};
    use crate::prop::{check, Rng};

    #[test]
    fn matches_brute_force() {
        let mut rng = Rng::new(5);
        let t: Vec<f64> = rng.gauss_vec(400);
        let cfg = MpConfig::new(16);
        let got = matrix_profile(&t, cfg).unwrap();
        let want = brute::matrix_profile(&t, cfg).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-8, "{}", got.max_abs_diff(&want));
    }

    #[test]
    fn prop_matches_brute_various_shapes() {
        check("stomp-vs-brute", 12, |rng: &mut Rng| {
            let n = rng.range(60, 250);
            let m = rng.range(4, 24);
            if n < 4 * m {
                return;
            }
            let t: Vec<f64> = rng.gauss_vec(n);
            let cfg = MpConfig::new(m);
            let got = matrix_profile(&t, cfg).unwrap();
            let want = brute::matrix_profile(&t, cfg).unwrap();
            assert!(
                got.max_abs_diff(&want) < 1e-7,
                "n={n} m={m} diff={}",
                got.max_abs_diff(&want)
            );
        });
    }

    #[test]
    fn f32_tracks_f64_loosely() {
        let mut rng = Rng::new(6);
        let tf64: Vec<f64> = rng.gauss_vec(300);
        let tf32: Vec<f32> = tf64.iter().map(|&x| x as f32).collect();
        let a = matrix_profile(&tf64, MpConfig::new(12)).unwrap();
        let b = matrix_profile(&tf32, MpConfig::new(12)).unwrap();
        for k in 0..a.len() {
            assert!(
                (a.p[k] - b.p[k] as f64).abs() < 1e-2,
                "k={k}: {} vs {}",
                a.p[k],
                b.p[k]
            );
        }
    }

    #[test]
    fn work_stats_count_upper_triangle() {
        let mut rng = Rng::new(7);
        let t: Vec<f64> = rng.gauss_vec(100);
        let cfg = MpConfig::new(8);
        let (_, work) = with_stats(&t, cfg).unwrap();
        let nw = 93;
        let excl = 2;
        assert_eq!(work.cells, crate::mp::total_cells(nw, excl));
    }

    #[test]
    fn work_stats_identical_to_scrimp() {
        // different tiling (descending single diagonals vs ascending band
        // tiles), same closed-form accounting — a real invariant, since
        // the two engines take different code paths through the kernel
        let mut rng = Rng::new(8);
        let t: Vec<f64> = rng.gauss_vec(300);
        let cfg = MpConfig::new(12);
        let (_, ws) = with_stats(&t, cfg).unwrap();
        let (_, wk) = scrimp::with_stats(&t, cfg, scrimp::DiagOrder::Sequential).unwrap();
        assert_eq!(ws, wk);
    }
}
