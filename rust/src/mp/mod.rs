//! Matrix profile: types, distance math, and the software baselines.
//!
//! Section 2.1 of the paper: for a series `T` of length `n` and window
//! length `m`, the profile `P[i]` is the minimum z-normalized Euclidean
//! distance (Eq. 1) from window `i` to any window outside its exclusion
//! zone, and `I[i]` is that neighbor's index.
//!
//! Implementations (all exact, all checked against each other):
//! * [`kernel`] — the unified tiled diagonal kernel: the single SIMD-
//!   friendly hot path every exact batch engine executes (tile →
//!   distance buffer → two branchless merge passes).
//! * [`brute`] — textbook O(n²·m) with explicit z-normalization; the
//!   independent oracle (deliberately does *not* use Eq. 1).
//! * [`stomp`]  — STOMP [44], its Eq. 2 row recurrence re-expressed as
//!   per-diagonal kernel walks in descending order (deliberately the
//!   opposite schedule from SCRIMP — see the module docs).
//! * [`scrimp`] — the paper's baseline: diagonal-order SCRIMP (Alg. 1)
//!   driving the kernel serially, with pluggable diagonal order.
//! * [`parallel`] — multi-threaded SCRIMP with per-thread private profiles,
//!   the software analogue of NATSA's PU fleet.
//! * [`prescrimp`] — the approximate SCRIMP++ preprocessing phase.
//! * [`stampi`] — exact *streaming* profile maintained under `append`
//!   (STAMPI row updates, O(n) per sample, optional bounded history),
//!   executing the kernel's row entry point (`kernel::compute_row_n`):
//!   width-1 tiles per append, blocked multi-row tiles per batch.
//! * [`topk`] — ranked motif/discord extraction with trivial-match
//!   suppression (the downstream-user API).

pub mod brute;
pub mod kernel;
pub mod parallel;
pub mod prescrimp;
pub mod scrimp;
pub mod stampi;
pub mod stomp;
pub mod topk;

use crate::timeseries::{default_exclusion, num_windows};
use crate::Real;

/// The result of a matrix profile computation.
#[derive(Clone, Debug)]
pub struct MatrixProfile<T> {
    /// `P`: minimum z-norm distance per window (+inf when nothing admissible).
    pub p: Vec<T>,
    /// `I`: index of the nearest neighbor (-1 when nothing admissible).
    pub i: Vec<i64>,
    /// Window length `m`.
    pub m: usize,
    /// Exclusion-zone radius actually used.
    pub excl: usize,
}

impl<T: Real> MatrixProfile<T> {
    /// Fresh all-infinite profile for `nw` windows.
    pub fn new_inf(nw: usize, m: usize, excl: usize) -> Self {
        MatrixProfile {
            p: vec![T::infinity(); nw],
            i: vec![-1; nw],
            m,
            excl,
        }
    }

    pub fn len(&self) -> usize {
        self.p.len()
    }

    pub fn is_empty(&self) -> bool {
        self.p.is_empty()
    }

    /// Record distance `d` between windows `a` and `b` (both directions) —
    /// the PUU update (Alg. 1 lines 9-10 / 21-22).
    #[inline]
    pub fn update(&mut self, a: usize, b: usize, d: T) {
        if d < self.p[a] {
            self.p[a] = d;
            self.i[a] = b as i64;
        }
        if d < self.p[b] {
            self.p[b] = d;
            self.i[b] = a as i64;
        }
    }

    /// Element-wise min-merge of another (partial) profile — Alg. 2 line 6.
    pub fn merge(&mut self, other: &MatrixProfile<T>) {
        assert_eq!(self.len(), other.len(), "profile length mismatch");
        for k in 0..self.p.len() {
            if other.p[k] < self.p[k] {
                self.p[k] = other.p[k];
                self.i[k] = other.i[k];
            }
        }
    }

    /// Strongest discord: the window with the *largest finite* profile
    /// value (most isolated subsequence — the anomaly detector).
    pub fn discord(&self) -> Option<(usize, T)> {
        self.p
            .iter()
            .enumerate()
            .filter(|(_, d)| d.is_finite())
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(k, d)| (k, *d))
    }

    /// Strongest motif: the window with the smallest profile value.
    pub fn motif(&self) -> Option<(usize, T)> {
        self.p
            .iter()
            .enumerate()
            .filter(|(_, d)| d.is_finite())
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(k, d)| (k, *d))
    }

    /// Replace every finite profile value with its square root — the
    /// deferred Eq. 1 finalization for engines that accumulate squared
    /// distances (see `kernel::compute_diagonal`'s PERF CONTRACT).
    pub fn sqrt_in_place(&mut self) {
        for v in self.p.iter_mut() {
            if v.is_finite() {
                *v = v.sqrt();
            }
        }
    }

    /// Maximum absolute profile difference vs another result (test helper).
    pub fn max_abs_diff(&self, other: &MatrixProfile<T>) -> f64 {
        assert_eq!(self.len(), other.len());
        self.p
            .iter()
            .zip(&other.p)
            .map(|(a, b)| {
                if a.is_infinite() && b.is_infinite() {
                    0.0
                } else {
                    (a.to_f64s() - b.to_f64s()).abs()
                }
            })
            .fold(0.0, f64::max)
    }
}

/// Configuration shared by all matrix profile implementations.
#[derive(Clone, Copy, Debug)]
pub struct MpConfig {
    /// Window (subsequence) length `m`.
    pub m: usize,
    /// Exclusion-zone radius; `None` = paper default `m/4`.
    pub excl: Option<usize>,
}

impl MpConfig {
    pub fn new(m: usize) -> Self {
        MpConfig { m, excl: None }
    }

    pub fn with_excl(m: usize, excl: usize) -> Self {
        MpConfig { m, excl: Some(excl) }
    }

    pub fn exclusion(&self) -> usize {
        self.excl.unwrap_or_else(|| default_exclusion(self.m))
    }

    /// Validate against a series length; returns the window count.
    pub fn validate(&self, n: usize) -> crate::Result<usize> {
        anyhow::ensure!(self.m >= 3, "window length m={} too small (min 3)", self.m);
        let nw = num_windows(n, self.m);
        anyhow::ensure!(
            nw > self.exclusion(),
            "series too short: n={n}, m={}, excl={} leaves no admissible pair",
            self.m,
            self.exclusion()
        );
        Ok(nw)
    }
}

/// Squared Eq. 1 distance (sqrt deferred — see `kernel::compute_diagonal`).
#[inline(always)]
pub fn znorm_sqdist<T: Real>(q: T, m: usize, mu_i: T, inv_i: T, mu_j: T, inv_j: T) -> T {
    let mf = T::of_f64(m as f64);
    let corr = (q - mf * mu_i * mu_j) * inv_i * inv_j * mf;
    let two_m = T::of_f64(2.0 * m as f64);
    (two_m * (T::one() - corr)).max(T::zero())
}

/// Eq. 1: z-normalized Euclidean distance from a raw dot product `q`.
///
/// `inv_msig_*` is the precomputed `1/(m*sigma)` (zero for constant
/// windows, which degenerate to correlation 0 ⇒ distance `sqrt(2m)`).
#[inline(always)]
pub fn znorm_dist<T: Real>(q: T, m: usize, mu_i: T, inv_i: T, mu_j: T, inv_j: T) -> T {
    let mf = T::of_f64(m as f64);
    let corr = (q - mf * mu_i * mu_j) * inv_i * inv_j * mf; // (q - m μi μj)/(m σi σj)
    let two_m = T::of_f64(2.0 * m as f64);
    (two_m * (T::one() - corr)).max(T::zero()).sqrt()
}

/// Work accounting emitted by the functional plane and consumed by the
/// timing/energy models in [`crate::sim`] (DESIGN.md §4).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WorkStats {
    /// Distance-matrix cells evaluated (excludes the exclusion zone).
    pub cells: u64,
    /// Diagonals walked.
    pub diagonals: u64,
    /// O(m) first-dot-products computed (one per diagonal or chunk seed).
    pub first_dots: u64,
    /// Profile update attempts (two per cell: row + column side).
    pub updates: u64,
}

impl WorkStats {
    pub fn add(&mut self, other: &WorkStats) {
        self.cells += other.cells;
        self.diagonals += other.diagonals;
        self.first_dots += other.first_dots;
        self.updates += other.updates;
    }

    /// Floating-point operations implied by this work, per Algorithm 1:
    /// Eq. 2 update (4 flops) + Eq. 1 distance (~7 flops) + 2 compares
    /// per cell, plus 2m flops per first dot product.
    pub fn flops(&self, m: usize) -> u64 {
        self.cells * 13 + self.first_dots * (2 * m as u64)
    }
}

/// Total admissible cells in the upper-triangular distance matrix —
/// the denominator for anytime progress and the DES workload size.
pub fn total_cells(nw: usize, excl: usize) -> u64 {
    // diagonals excl..nw-1; diagonal d has nw - d cells
    (excl..nw).map(|d| (nw - d) as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn update_both_directions() {
        let mut mp = MatrixProfile::<f64>::new_inf(4, 3, 1);
        mp.update(0, 2, 1.5);
        assert_eq!(mp.p[0], 1.5);
        assert_eq!(mp.i[0], 2);
        assert_eq!(mp.p[2], 1.5);
        assert_eq!(mp.i[2], 0);
        mp.update(0, 3, 2.0); // worse: no change on 0
        assert_eq!(mp.p[0], 1.5);
        assert_eq!(mp.p[3], 2.0);
    }

    #[test]
    fn merge_takes_elementwise_min() {
        let mut a = MatrixProfile::<f64>::new_inf(3, 3, 1);
        let mut b = MatrixProfile::<f64>::new_inf(3, 3, 1);
        a.update(0, 2, 1.0);
        b.update(1, 2, 0.5);
        a.merge(&b);
        assert_eq!(a.p[0], 1.0);
        assert_eq!(a.p[1], 0.5);
        assert_eq!(a.p[2], 0.5);
        assert_eq!(a.i[2], 1);
    }

    #[test]
    fn discord_and_motif() {
        let mp = MatrixProfile::<f64> {
            p: vec![1.0, 5.0, 0.25, f64::INFINITY],
            i: vec![2, 0, 0, -1],
            m: 4,
            excl: 1,
        };
        assert_eq!(mp.discord(), Some((1, 5.0)));
        assert_eq!(mp.motif(), Some((2, 0.25)));
    }

    #[test]
    fn config_validation() {
        assert!(MpConfig::new(2).validate(100).is_err());
        assert!(MpConfig::new(8).validate(9).is_err());
        assert_eq!(MpConfig::new(8).validate(100).unwrap(), 93);
        assert_eq!(MpConfig::new(8).exclusion(), 2);
        assert_eq!(MpConfig::with_excl(8, 5).exclusion(), 5);
    }

    #[test]
    fn znorm_dist_identical_windows_is_zero() {
        // identical windows: q = sum(x^2) over the window
        let w = [1.0f64, 2.0, 3.0, 4.0];
        let m = w.len();
        let mu = w.iter().sum::<f64>() / m as f64;
        let var = w.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / m as f64;
        let sig = var.sqrt();
        let q: f64 = w.iter().map(|x| x * x).sum();
        let inv = 1.0 / (m as f64 * sig);
        let d = znorm_dist(q, m, mu, inv, mu, inv);
        assert!(d.abs() < 1e-9, "{d}");
    }

    #[test]
    fn znorm_dist_constant_window_sqrt_2m() {
        let m = 8usize;
        let d = znorm_dist(64.0f64, m, 1.0, 0.0, 0.5, 1.0);
        assert!((d - (2.0 * m as f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn total_cells_matches_enumeration() {
        // nw=10, excl=2 -> diagonals 2..9, lengths 8..1
        assert_eq!(total_cells(10, 2), (1..=8).sum::<u64>());
        assert_eq!(total_cells(5, 1), 4 + 3 + 2 + 1);
        assert_eq!(total_cells(3, 3), 0);
    }

    #[test]
    fn merge_disjoint_updates_keeps_both_sides() {
        let mut a = MatrixProfile::<f64>::new_inf(6, 4, 1);
        let mut b = MatrixProfile::<f64>::new_inf(6, 4, 1);
        a.update(0, 2, 1.0); // touches 0 and 2
        b.update(3, 5, 0.5); // touches 3 and 5 — disjoint from a
        a.merge(&b);
        assert_eq!((a.p[0], a.i[0]), (1.0, 2));
        assert_eq!((a.p[2], a.i[2]), (1.0, 0));
        assert_eq!((a.p[3], a.i[3]), (0.5, 5));
        assert_eq!((a.p[5], a.i[5]), (0.5, 3));
        assert!(a.p[1].is_infinite() && a.i[1] == -1);
        assert!(a.p[4].is_infinite() && a.i[4] == -1);
    }

    #[test]
    fn merge_overlapping_updates_takes_min_with_its_index() {
        let mut a = MatrixProfile::<f64>::new_inf(4, 4, 1);
        let mut b = MatrixProfile::<f64>::new_inf(4, 4, 1);
        a.update(0, 2, 1.0);
        a.update(1, 3, 0.2);
        b.update(0, 3, 0.4); // better on 0, worse on 3
        b.update(1, 2, 0.9); // worse on 1, better on 2
        a.merge(&b);
        assert_eq!((a.p[0], a.i[0]), (0.4, 3)); // b won, index follows
        assert_eq!((a.p[1], a.i[1]), (0.2, 3)); // a kept
        assert_eq!((a.p[2], a.i[2]), (0.9, 1)); // b won
        assert_eq!((a.p[3], a.i[3]), (0.2, 1)); // a kept
        // merging is idempotent
        let snapshot = (a.p.clone(), a.i.clone());
        let b2 = b.clone();
        a.merge(&b2);
        assert_eq!((a.p, a.i), snapshot);
    }

    #[test]
    fn discord_and_motif_on_all_inf_profile_are_none() {
        let mp = MatrixProfile::<f64>::new_inf(8, 4, 1);
        assert_eq!(mp.discord(), None);
        assert_eq!(mp.motif(), None);
        // and sqrt finalization must leave the +inf entries untouched
        let mut mp = mp;
        mp.sqrt_in_place();
        assert!(mp.p.iter().all(|d| d.is_infinite()));
    }

    #[test]
    fn validate_rejects_series_shorter_than_window() {
        // n < m: zero windows
        assert!(MpConfig::new(8).validate(7).is_err());
        // n == m: one window, but exclusion >= 1 always bans the only pair
        assert!(MpConfig::new(8).validate(8).is_err());
    }

    #[test]
    fn validate_exclusion_boundary_is_exact() {
        // nw = n - m + 1 must strictly exceed the exclusion radius
        let cfg = MpConfig::with_excl(8, 5);
        assert!(cfg.validate(12).is_err()); // nw = 5 == excl
        assert_eq!(cfg.validate(13).unwrap(), 6); // nw = 6 > excl: minimal legal
        // minimum window length boundary
        assert!(MpConfig::new(2).validate(100).is_err());
        assert!(MpConfig::new(3).validate(100).is_ok());
    }

    #[test]
    fn workstats_flops() {
        let w = WorkStats {
            cells: 10,
            diagonals: 1,
            first_dots: 1,
            updates: 20,
        };
        assert_eq!(w.flops(16), 10 * 13 + 32);
    }
}
