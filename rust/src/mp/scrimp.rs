//! SCRIMP [112] — the paper's CPU baseline (Algorithm 1), diagonal order.
//!
//! The distance matrix is walked along diagonals; within a diagonal the
//! dot product is advanced incrementally (Eq. 2), and the inner loop is
//! *chunked* exactly like the paper's vectorized formulation: a batch of
//! `CHUNK` product deltas is computed element-wise (auto-vectorizable),
//! prefix-summed (the one serial step, Alg. 1 lines 16-17), and the batch
//! of distances + profile updates follows element-wise.
//!
//! Diagonal order is pluggable ([`DiagOrder`]): `Sequential` enables the
//! locality optimizations, `Random(seed)` preserves the anytime property
//! (Section 2.2) — interrupting a random-order run yields a uniform
//! partial exploration.

use crate::mp::{znorm_sqdist, MatrixProfile, MpConfig, WorkStats};
use crate::prop::Rng;
use crate::timeseries::{sliding_stats, WindowStats};
use crate::Real;

/// Inner-loop batch length — the software stand-in for the paper's AVX-512
/// `vectFact` (Alg. 1 line 2).  64 elements keeps the delta/dist scratch in
/// L1 while amortizing the serial prefix step.
pub const CHUNK: usize = 64;

/// Diagonal visiting order (Section 2.2 / 4.2 discussion).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiagOrder {
    /// Ascending diagonal index: best locality, no anytime property.
    Sequential,
    /// Seeded uniform shuffle: anytime property preserved.
    Random(u64),
}

/// Serial SCRIMP over the whole admissible triangle.
pub fn matrix_profile<T: Real>(t: &[T], cfg: MpConfig) -> crate::Result<MatrixProfile<T>> {
    Ok(with_stats(t, cfg, DiagOrder::Sequential)?.0)
}

/// Serial SCRIMP with explicit order and work accounting.
pub fn with_stats<T: Real>(
    t: &[T],
    cfg: MpConfig,
    order: DiagOrder,
) -> crate::Result<(MatrixProfile<T>, WorkStats)> {
    let nw = cfg.validate(t.len())?;
    let excl = cfg.exclusion();
    let st = sliding_stats(t, cfg.m);
    let mut mp = MatrixProfile::new_inf(nw, cfg.m, excl);
    let mut work = WorkStats::default();

    let mut diags: Vec<usize> = (excl..nw).collect();
    if let DiagOrder::Random(seed) = order {
        Rng::new(seed).shuffle(&mut diags);
    }
    for d in diags {
        compute_diagonal(t, &st, d, &mut mp, &mut work);
    }
    mp.sqrt_in_place();
    Ok((mp, work))
}

/// Walk one diagonal `d` (cells `(i, i+d)` for `i = 0..nw-d`), updating the
/// profile in place.  This is the unit of work NATSA assigns to a PU and
/// the paper's per-thread loop body (Alg. 1 lines 5-23).
///
/// PERF CONTRACT: the profile accumulates **squared** z-norm distances —
/// min is monotone under sqrt, so the per-cell `sqrt` of Eq. 1 is deferred
/// to one [`MatrixProfile::sqrt_in_place`] per window after all diagonals
/// merge (the same trick SCAMP [113] uses via correlations).  Every caller
/// must finalize; `with_stats` does it for the serial path.
pub fn compute_diagonal<T: Real>(
    t: &[T],
    st: &WindowStats<T>,
    d: usize,
    mp: &mut MatrixProfile<T>,
    work: &mut WorkStats,
) {
    let m = st.m;
    let nw = st.len();
    debug_assert!(d < nw, "diagonal {d} out of range (nw={nw})");
    let len = nw - d;

    // First cell: direct O(m) dot product (the DPU step, Alg. 1 line 7).
    let mut q = (0..m).map(|k| t[k] * t[d + k]).sum::<T>();
    let d0 = znorm_sqdist(q, m, st.mu[0], st.inv_msig[0], st.mu[d], st.inv_msig[d]);
    mp.update(0, d, d0);
    work.first_dots += 1;
    work.diagonals += 1;
    work.cells += 1;
    work.updates += 2;

    // Remaining cells in CHUNK batches (the vectorized loops of Alg. 1).
    // Constants are hoisted out of the loop: `Real::of_f64` conversions
    // per cell cost more than the FLOPs themselves (perf pass, see
    // EXPERIMENTS.md §Perf).
    let two_m = T::of_f64(2.0 * m as f64);
    let zero = T::zero();
    let mut deltas = [T::zero(); CHUNK];
    let mut dists = [T::zero(); CHUNK];
    let mut i = 1usize;
    while i < len {
        let c = CHUNK.min(len - i);
        let j = i + d;
        // 1) element-wise product deltas (lines 13-14) — slice views give
        //    the compiler provable bounds, so this loop auto-vectorizes.
        let lo_i = &t[i - 1..i - 1 + c];
        let lo_j = &t[j - 1..j - 1 + c];
        let hi_i = &t[i + m - 1..i + m - 1 + c];
        let hi_j = &t[j + m - 1..j + m - 1 + c];
        for k in 0..c {
            deltas[k] = hi_i[k] * hi_j[k] - lo_i[k] * lo_j[k];
        }
        // 2) propagate q (lines 15-18): a blocked prefix sum.  The naive
        //    chain serializes on FP-add latency (~4 cycles/cell); block
        //    partial sums first, then LANES independent chains.
        q = prefix_sum_blocked(&mut deltas[..c], q);
        // 3) distances (lines 19-20) — branch-free, vectorizable, using
        //    the folded factors from WindowStats: 3 mul + 2 add per cell.
        let za_i = &st.za[i..i + c];
        let za_j = &st.za[j..j + c];
        let zb_i = &st.zb[i..i + c];
        let zb_j = &st.zb[j..j + c];
        for k in 0..c {
            let d2 = two_m - deltas[k] * za_i[k] * za_j[k] + zb_i[k] * zb_j[k];
            dists[k] = d2.max(zero); // squared: sqrt deferred
        }
        // 4) profile updates (lines 21-22) — branchy but rarely taken.
        //    When the row and column ranges are disjoint (d >= c, true for
        //    any chunk once the exclusion zone >= CHUNK), split the profile
        //    into two slices so the compares run without bounds checks.
        if d >= c {
            let (pl, pr) = mp.p.split_at_mut(j);
            let (il, ir) = mp.i.split_at_mut(j);
            let prow = &mut pl[i..i + c];
            let irow = &mut il[i..i + c];
            let pcol = &mut pr[..c];
            let icol = &mut ir[..c];
            for k in 0..c {
                let dist = dists[k];
                if dist < prow[k] {
                    prow[k] = dist;
                    irow[k] = (j + k) as i64;
                }
                if dist < pcol[k] {
                    pcol[k] = dist;
                    icol[k] = (i + k) as i64;
                }
            }
        } else {
            for (k, &dist) in dists.iter().take(c).enumerate() {
                mp.update(i + k, j + k, dist);
            }
        }
        work.cells += c as u64;
        work.updates += 2 * c as u64;
        i += c;
    }
}

/// Blocked inclusive prefix sum: `xs[k] <- q0 + xs[0] + .. + xs[k]`;
/// returns the final running value.
///
/// Splitting the chunk into `LANES` blocks turns one latency-bound FP-add
/// chain of length `c` into (a) a vectorizable block-sum pass and (b)
/// `LANES` shorter chains with independent starting offsets, recovering
/// ~2-3x on the serial step of Algorithm 1 (lines 16-17).
#[inline]
fn prefix_sum_blocked<T: Real>(xs: &mut [T], q0: T) -> T {
    const LANES: usize = 4;
    let c = xs.len();
    let b = c / LANES;
    if b < 8 {
        // short tail: plain chain
        let mut q = q0;
        for x in xs.iter_mut() {
            q = q + *x;
            *x = q;
        }
        return q;
    }
    // (a) per-block totals, 4 sub-accumulators each so the reduction is
    //     not one long FP-add dependency chain
    let mut offs = [T::zero(); LANES];
    for l in 0..LANES {
        let blk = &xs[l * b..(l + 1) * b];
        let (mut a0, mut a1, mut a2, mut a3) = (T::zero(), T::zero(), T::zero(), T::zero());
        let mut k = 0;
        while k + 4 <= b {
            a0 = a0 + blk[k];
            a1 = a1 + blk[k + 1];
            a2 = a2 + blk[k + 2];
            a3 = a3 + blk[k + 3];
            k += 4;
        }
        let mut s = (a0 + a1) + (a2 + a3);
        while k < b {
            s = s + blk[k];
            k += 1;
        }
        offs[l] = s;
    }
    // (b) exclusive block offsets
    let mut run = q0;
    for off in offs.iter_mut() {
        let total = *off;
        *off = run;
        run = run + total;
    }
    // (c) LANES chains advanced in lock-step: 4 independent FP adds in
    //     flight per iteration instead of one
    let mut qs = offs;
    for k in 0..b {
        for (l, ql) in qs.iter_mut().enumerate() {
            let idx = l * b + k;
            *ql = *ql + xs[idx];
            xs[idx] = *ql;
        }
    }
    // tail (c % LANES cells) continues the last chain
    let mut q = xs[LANES * b - 1];
    for x in xs[LANES * b..].iter_mut() {
        q = q + *x;
        *x = q;
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mp::{brute, stomp, total_cells};
    use crate::prop::{check, Rng};
    use crate::timeseries::generator::{generate, generate_with_event, Pattern, PlantedEvent};

    #[test]
    fn matches_brute_force() {
        let mut rng = Rng::new(8);
        let t: Vec<f64> = rng.gauss_vec(500);
        let cfg = MpConfig::new(20);
        let got = matrix_profile(&t, cfg).unwrap();
        let want = brute::matrix_profile(&t, cfg).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-8, "{}", got.max_abs_diff(&want));
    }

    #[test]
    fn matches_stomp_exactly_in_structure() {
        let mut rng = Rng::new(9);
        let t: Vec<f64> = rng.gauss_vec(350);
        let cfg = MpConfig::new(14);
        let a = matrix_profile(&t, cfg).unwrap();
        let b = stomp::matrix_profile(&t, cfg).unwrap();
        assert!(a.max_abs_diff(&b) < 1e-9);
    }

    #[test]
    fn random_order_same_result() {
        let mut rng = Rng::new(10);
        let t: Vec<f64> = rng.gauss_vec(300);
        let cfg = MpConfig::new(12);
        let (seq, _) = with_stats(&t, cfg, DiagOrder::Sequential).unwrap();
        let (rnd, _) = with_stats(&t, cfg, DiagOrder::Random(123)).unwrap();
        assert!(seq.max_abs_diff(&rnd) < 1e-12);
        assert_eq!(seq.i, rnd.i);
    }

    #[test]
    fn work_stats_exact_cell_count() {
        let t = generate::<f64>(Pattern::RandomWalk, 400, 1);
        let cfg = MpConfig::new(16);
        let (_, work) = with_stats(&t, cfg, DiagOrder::Sequential).unwrap();
        let nw = 400 - 16 + 1;
        assert_eq!(work.cells, total_cells(nw, 4));
        assert_eq!(work.diagonals, (nw - 4) as u64);
        assert_eq!(work.first_dots, work.diagonals);
        assert_eq!(work.updates, 2 * work.cells);
    }

    #[test]
    fn finds_planted_anomaly_ecg() {
        let (t, ev) = generate_with_event::<f64>(Pattern::EcgLike, 4096, 2);
        let mp = matrix_profile(&t, MpConfig::new(64)).unwrap();
        let (disc, _) = mp.discord().unwrap();
        if let PlantedEvent::Anomaly { start, len } = ev {
            assert!(
                disc + 64 >= start && disc < start + len + 64,
                "discord at {disc}, planted [{start}, {})",
                start + len
            );
        }
    }

    #[test]
    fn prop_scrimp_vs_brute() {
        check("scrimp-vs-brute", 12, |rng: &mut Rng| {
            let n = rng.range(64, 300);
            let m = rng.range(4, 32);
            if n < 4 * m {
                return;
            }
            let t: Vec<f64> = rng.gauss_vec(n);
            let cfg = MpConfig::new(m);
            let got = matrix_profile(&t, cfg).unwrap();
            let want = brute::matrix_profile(&t, cfg).unwrap();
            assert!(
                got.max_abs_diff(&want) < 1e-7,
                "n={n} m={m} diff={}",
                got.max_abs_diff(&want)
            );
        });
    }

    #[test]
    fn prop_chunk_boundary_interior_equivalence() {
        // diagonal lengths straddling CHUNK multiples must all agree with
        // brute force (catches off-by-ones at batch edges)
        check("scrimp-chunk-edges", 6, |rng: &mut Rng| {
            let m = 8;
            for extra in [0usize, 1, CHUNK - 1, CHUNK, CHUNK + 1] {
                let n = 2 * m + CHUNK + extra + 16;
                let t: Vec<f64> = rng.gauss_vec(n);
                let cfg = MpConfig::new(m);
                let got = matrix_profile(&t, cfg).unwrap();
                let want = brute::matrix_profile(&t, cfg).unwrap();
                assert!(got.max_abs_diff(&want) < 1e-7, "extra={extra}");
            }
        });
    }

    #[test]
    fn custom_exclusion_respected() {
        let mut rng = Rng::new(11);
        let t: Vec<f64> = rng.gauss_vec(200);
        let mp = matrix_profile(&t, MpConfig::with_excl(10, 7)).unwrap();
        for (k, &j) in mp.i.iter().enumerate() {
            if j >= 0 {
                assert!((k as i64 - j).unsigned_abs() >= 7);
            }
        }
    }
}
