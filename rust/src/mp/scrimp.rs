//! SCRIMP [112] — the paper's CPU baseline (Algorithm 1), diagonal order.
//!
//! The distance matrix is walked along diagonals through the unified
//! kernel ([`crate::mp::kernel`]): sequential order rides the
//! [`crate::mp::kernel::compute_band`] SIMD path via
//! [`crate::mp::kernel::compute_triangle`]; random order interleaves
//! single diagonals through [`compute_diagonal`].  Both produce
//! bit-identical profile values (the kernel's core invariant), and the
//! same kernel executes inside STOMP, the parallel fleet, the NATSA PU
//! datapath, and anytime runs — one hot path everywhere.
//!
//! Diagonal order is pluggable ([`DiagOrder`]): `Sequential` enables the
//! locality optimizations, `Random(seed)` preserves the anytime property
//! (Section 2.2) — interrupting a random-order run yields a uniform
//! partial exploration.

use crate::mp::{MatrixProfile, MpConfig, WorkStats};
use crate::prop::Rng;
use crate::timeseries::sliding_stats;
use crate::Real;

/// The kernel's per-diagonal entry point, re-exported where the paper's
/// Algorithm 1 loop body historically lived.
pub use crate::mp::kernel::compute_diagonal;

/// Diagonal visiting order (Section 2.2 / 4.2 discussion).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiagOrder {
    /// Ascending diagonal index: best locality, no anytime property.
    Sequential,
    /// Seeded uniform shuffle: anytime property preserved.
    Random(u64),
}

/// Serial SCRIMP over the whole admissible triangle.
pub fn matrix_profile<T: Real>(t: &[T], cfg: MpConfig) -> crate::Result<MatrixProfile<T>> {
    Ok(with_stats(t, cfg, DiagOrder::Sequential)?.0)
}

/// Serial SCRIMP with explicit order and work accounting.
pub fn with_stats<T: Real>(
    t: &[T],
    cfg: MpConfig,
    order: DiagOrder,
) -> crate::Result<(MatrixProfile<T>, WorkStats)> {
    let nw = cfg.validate(t.len())?;
    let excl = cfg.exclusion();
    let st = sliding_stats(t, cfg.m);
    let mut mp = MatrixProfile::new_inf(nw, cfg.m, excl);
    let mut work = WorkStats::default();

    match order {
        DiagOrder::Sequential => {
            crate::mp::kernel::compute_triangle(t, &st, excl, &mut mp, &mut work);
        }
        DiagOrder::Random(seed) => {
            let mut diags: Vec<usize> = (excl..nw).collect();
            Rng::new(seed).shuffle(&mut diags);
            for d in diags {
                compute_diagonal(t, &st, d, &mut mp, &mut work);
            }
        }
    }
    mp.sqrt_in_place(); // diagonals accumulate squared distances
    Ok((mp, work))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mp::kernel::BAND;
    use crate::mp::{brute, stomp, total_cells};
    use crate::prop::{check, Rng};
    use crate::timeseries::generator::{generate, generate_with_event, Pattern, PlantedEvent};

    #[test]
    fn matches_brute_force() {
        let mut rng = Rng::new(8);
        let t: Vec<f64> = rng.gauss_vec(500);
        let cfg = MpConfig::new(20);
        let got = matrix_profile(&t, cfg).unwrap();
        let want = brute::matrix_profile(&t, cfg).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-8, "{}", got.max_abs_diff(&want));
    }

    #[test]
    fn matches_stomp_bit_for_bit() {
        // scrimp (ascending band tiles) and stomp (descending single
        // diagonals) schedule the kernel as differently as it allows;
        // the kernel invariant says the profiles must still be
        // identical to the bit, not merely close
        let mut rng = Rng::new(9);
        let t: Vec<f64> = rng.gauss_vec(350);
        let cfg = MpConfig::new(14);
        let a = matrix_profile(&t, cfg).unwrap();
        let b = stomp::matrix_profile(&t, cfg).unwrap();
        assert!(a.max_abs_diff(&b) == 0.0);
        assert_eq!(a.i, b.i);
    }

    #[test]
    fn random_order_same_result() {
        // sequential rides the band path, random the per-diagonal path;
        // the kernel guarantees bit-identical values between them
        let mut rng = Rng::new(10);
        let t: Vec<f64> = rng.gauss_vec(300);
        let cfg = MpConfig::new(12);
        let (seq, wseq) = with_stats(&t, cfg, DiagOrder::Sequential).unwrap();
        let (rnd, wrnd) = with_stats(&t, cfg, DiagOrder::Random(123)).unwrap();
        assert!(seq.max_abs_diff(&rnd) == 0.0);
        assert_eq!(seq.i, rnd.i);
        assert_eq!(wseq, wrnd);
    }

    #[test]
    fn work_stats_exact_cell_count() {
        let t = generate::<f64>(Pattern::RandomWalk, 400, 1);
        let cfg = MpConfig::new(16);
        let (_, work) = with_stats(&t, cfg, DiagOrder::Sequential).unwrap();
        let nw = 400 - 16 + 1;
        assert_eq!(work.cells, total_cells(nw, 4));
        assert_eq!(work.diagonals, (nw - 4) as u64);
        assert_eq!(work.first_dots, work.diagonals);
        assert_eq!(work.updates, 2 * work.cells);
    }

    #[test]
    fn finds_planted_anomaly_ecg() {
        let (t, ev) = generate_with_event::<f64>(Pattern::EcgLike, 4096, 2);
        let mp = matrix_profile(&t, MpConfig::new(64)).unwrap();
        let (disc, _) = mp.discord().unwrap();
        if let PlantedEvent::Anomaly { start, len } = ev {
            assert!(
                disc + 64 >= start && disc < start + len + 64,
                "discord at {disc}, planted [{start}, {})",
                start + len
            );
        }
    }

    #[test]
    fn prop_scrimp_vs_brute() {
        check("scrimp-vs-brute", 12, |rng: &mut Rng| {
            let n = rng.range(64, 300);
            let m = rng.range(4, 32);
            if n < 4 * m {
                return;
            }
            let t: Vec<f64> = rng.gauss_vec(n);
            let cfg = MpConfig::new(m);
            let got = matrix_profile(&t, cfg).unwrap();
            let want = brute::matrix_profile(&t, cfg).unwrap();
            assert!(
                got.max_abs_diff(&want) < 1e-7,
                "n={n} m={m} diff={}",
                got.max_abs_diff(&want)
            );
        });
    }

    #[test]
    fn prop_band_boundary_interior_equivalence() {
        // window counts straddling BAND multiples must all agree with
        // brute force (catches off-by-ones at band seams and the
        // partial-remainder driver fallback)
        check("scrimp-band-edges", 3, |rng: &mut Rng| {
            let m = 8;
            for extra in [0usize, 1, BAND - 1, BAND, BAND + 1] {
                let n = 2 * m + 8 * BAND + extra + 16;
                let t: Vec<f64> = rng.gauss_vec(n);
                let cfg = MpConfig::new(m);
                let got = matrix_profile(&t, cfg).unwrap();
                let want = brute::matrix_profile(&t, cfg).unwrap();
                assert!(got.max_abs_diff(&want) < 1e-7, "extra={extra}");
            }
        });
    }

    #[test]
    fn custom_exclusion_respected() {
        let mut rng = Rng::new(11);
        let t: Vec<f64> = rng.gauss_vec(200);
        let mp = matrix_profile(&t, MpConfig::with_excl(10, 7)).unwrap();
        for (k, &j) in mp.i.iter().enumerate() {
            if j >= 0 {
                assert!((k as i64 - j).unsigned_abs() >= 7);
            }
        }
    }
}
