//! PreSCRIMP — the approximate preprocessing phase of SCRIMP++ [112].
//!
//! The paper's related work positions SCRIMP++ (= PreSCRIMP + SCRIMP) as
//! the interactive-speed variant: PreSCRIMP samples the distance matrix on
//! a stride-`s` grid of anchor cells and *propagates* each sampled dot
//! product along its diagonal neighborhood (Eq. 2 both directions),
//! producing a high-quality approximate profile in O(n²/s) work.  Running
//! full SCRIMP afterwards converges to the exact answer with most of the
//! anytime benefit front-loaded.
//!
//! We include it as (a) the paper's "approximate algorithms are faster but
//! inexact" contrast point, and (b) a better-than-random anytime seed for
//! the NATSA engine.

use crate::mp::{znorm_sqdist, MatrixProfile, MpConfig, WorkStats};
use crate::prop::Rng;
use crate::timeseries::sliding_stats;
use crate::Real;

/// Default sampling stride: m/4 (the SCRIMP++ paper's choice).
pub fn default_stride(m: usize) -> usize {
    (m / 4).max(1)
}

/// Approximate matrix profile via anchor sampling + diagonal propagation.
///
/// `stride = None` uses the SCRIMP++ default m/4.  The result is an upper
/// bound of the exact profile (every recorded distance is a true pairwise
/// distance; some better neighbors may be missed).
pub fn matrix_profile<T: Real>(
    t: &[T],
    cfg: MpConfig,
    stride: Option<usize>,
    seed: u64,
) -> crate::Result<(MatrixProfile<T>, WorkStats)> {
    let nw = cfg.validate(t.len())?;
    let m = cfg.m;
    let excl = cfg.exclusion();
    let s = stride.unwrap_or_else(|| default_stride(m)).max(1);
    let st = sliding_stats(t, m);
    let mut mp = MatrixProfile::new_inf(nw, m, excl);
    let mut work = WorkStats::default();

    // Anchor rows in random order (preserves anytime behaviour).
    let mut anchors: Vec<usize> = (0..nw).step_by(s).collect();
    Rng::new(seed).shuffle(&mut anchors);

    for &i in &anchors {
        // Best admissible neighbor of window i by direct scan over the
        // stride grid of columns.
        let mut best_j = usize::MAX;
        let mut best_d2 = T::infinity();
        let mut j = 0usize;
        while j < nw {
            if j + excl > i && i + excl > j {
                j += s;
                continue; // inside exclusion zone
            }
            let q = (0..m).map(|k| t[i + k] * t[j + k]).sum::<T>();
            work.first_dots += 1;
            let d2 = znorm_sqdist(q, m, st.mu[i], st.inv_msig[i], st.mu[j], st.inv_msig[j]);
            mp.update(i, j, d2);
            work.cells += 1;
            work.updates += 2;
            if d2 < best_d2 {
                best_d2 = d2;
                best_j = j;
            }
            j += s;
        }
        if best_j == usize::MAX {
            continue;
        }

        // Propagate the best anchor pair along its diagonal, s cells in
        // each direction (Eq. 2 forward and backward).
        let (ii, jj) = (i, best_j);
        let q0 = (0..m).map(|k| t[ii + k] * t[jj + k]).sum::<T>();
        work.first_dots += 1;
        // forward
        let mut q = q0;
        for step in 1..s {
            let (a, b) = (ii + step, jj + step);
            if a >= nw || b >= nw {
                break;
            }
            q = q - t[a - 1] * t[b - 1] + t[a + m - 1] * t[b + m - 1];
            let d2 = znorm_sqdist(q, m, st.mu[a], st.inv_msig[a], st.mu[b], st.inv_msig[b]);
            mp.update(a, b, d2);
            work.cells += 1;
            work.updates += 2;
        }
        // backward
        let mut q = q0;
        for step in 1..s {
            if ii < step || jj < step {
                break;
            }
            let (a, b) = (ii - step, jj - step);
            q = q + t[a] * t[b] - t[a + m] * t[b + m];
            let d2 = znorm_sqdist(q, m, st.mu[a], st.inv_msig[a], st.mu[b], st.inv_msig[b]);
            mp.update(a, b, d2);
            work.cells += 1;
            work.updates += 2;
        }
        work.diagonals += 1;
    }
    mp.sqrt_in_place();
    Ok((mp, work))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mp::brute;
    use crate::prop::{check, Rng};
    use crate::timeseries::generator::{generate_with_event, Pattern, PlantedEvent};

    #[test]
    fn upper_bounds_exact_profile() {
        check("prescrimp-upper-bound", 8, |rng: &mut Rng| {
            let n = rng.range(200, 500);
            let m = rng.range(8, 32);
            if n < 5 * m {
                return;
            }
            let t: Vec<f64> = rng.gauss_vec(n);
            let cfg = MpConfig::new(m);
            let (approx, _) = matrix_profile(&t, cfg, None, 7).unwrap();
            let exact = brute::matrix_profile(&t, cfg).unwrap();
            for k in 0..exact.len() {
                assert!(
                    approx.p[k] >= exact.p[k] - 1e-9,
                    "approx P[{k}]={} below exact {}",
                    approx.p[k],
                    exact.p[k]
                );
            }
        });
    }

    #[test]
    fn finds_planted_motif_with_fraction_of_work() {
        let (t, ev) = generate_with_event::<f64>(Pattern::PlantedMotif, 4096, 3);
        let (a, b) = match ev {
            PlantedEvent::Motif { a, b, .. } => (a, b),
            _ => unreachable!(),
        };
        let m = 64;
        let cfg = MpConfig::new(m);
        let (approx, work) = matrix_profile(&t, cfg, None, 5).unwrap();
        // the planted pair is an exact repeat: PreSCRIMP's propagation
        // must find it (the anchor grid hits the motif diagonal)
        assert!(approx.p[a] < 0.5, "p[a]={}", approx.p[a]);
        assert_eq!(approx.i[a], b as i64);
        // and with far fewer cells than the full quadratic scan
        let full = crate::mp::total_cells(t.len() - m + 1, m / 4);
        assert!(
            work.cells * 4 < full,
            "PreSCRIMP did {} of {full} cells",
            work.cells
        );
    }

    #[test]
    fn respects_exclusion_zone() {
        let mut rng = Rng::new(9);
        let t: Vec<f64> = rng.gauss_vec(400);
        let (mp, _) = matrix_profile(&t, MpConfig::new(16), Some(8), 1).unwrap();
        for (k, &j) in mp.i.iter().enumerate() {
            if j >= 0 {
                assert!((k as i64 - j).unsigned_abs() as usize >= mp.excl);
            }
        }
    }

    #[test]
    fn stride_one_is_nearly_exact_on_grid_rows() {
        // with stride 1 every row is an anchor scanning every column:
        // the result IS the exact profile
        let mut rng = Rng::new(10);
        let t: Vec<f64> = rng.gauss_vec(200);
        let cfg = MpConfig::new(8);
        let (approx, _) = matrix_profile(&t, cfg, Some(1), 2).unwrap();
        let exact = brute::matrix_profile(&t, cfg).unwrap();
        assert!(approx.max_abs_diff(&exact) < 1e-7);
    }
}
