//! Top-k motif and discord extraction from a matrix profile.
//!
//! The profile gives the *1-nearest-neighbor* structure; applications
//! (the paper's §1 list: arrhythmia review, seismic catalogs, ...) want
//! the top-k ranked events with trivial-match suppression: once a window
//! is reported, its exclusion-zone neighborhood is masked so the next
//! pick is a genuinely distinct event, not the same one shifted by one
//! sample.

use crate::mp::MatrixProfile;
use crate::Real;

/// One ranked event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Event<T> {
    /// Window start index.
    pub index: usize,
    /// Its nearest-neighbor window (motifs: the matching occurrence).
    pub neighbor: i64,
    /// z-norm distance to that neighbor.
    pub distance: T,
}

fn extract<T: Real>(
    mp: &MatrixProfile<T>,
    k: usize,
    pick_max: bool,
    suppress: usize,
) -> Vec<Event<T>> {
    let mut masked = vec![false; mp.len()];
    let mut out = Vec::with_capacity(k);
    for _ in 0..k {
        let mut best: Option<(usize, T)> = None;
        for (idx, &d) in mp.p.iter().enumerate() {
            if masked[idx] || !d.is_finite() {
                continue;
            }
            let better = match best {
                None => true,
                Some((_, bd)) => {
                    if pick_max {
                        d > bd
                    } else {
                        d < bd
                    }
                }
            };
            if better {
                best = Some((idx, d));
            }
        }
        let Some((idx, d)) = best else { break };
        out.push(Event { index: idx, neighbor: mp.i[idx], distance: d });
        // trivial-match suppression around the pick (and, for motifs,
        // around its matching occurrence too)
        let lo = idx.saturating_sub(suppress);
        let hi = (idx + suppress + 1).min(mp.len());
        masked[lo..hi].iter_mut().for_each(|m| *m = true);
        if !pick_max && mp.i[idx] >= 0 {
            let nb = mp.i[idx] as usize;
            let lo = nb.saturating_sub(suppress);
            let hi = (nb + suppress + 1).min(mp.len());
            masked[lo..hi].iter_mut().for_each(|m| *m = true);
        }
    }
    out
}

/// Top-k motifs: the k smallest-profile windows, suppressing each pick's
/// neighborhood (radius = the profile's exclusion zone) *and* its match.
pub fn top_motifs<T: Real>(mp: &MatrixProfile<T>, k: usize) -> Vec<Event<T>> {
    extract(mp, k, false, mp.excl.max(mp.m / 2))
}

/// Top-k discords: the k largest finite-profile windows with the same
/// trivial-match suppression.
pub fn top_discords<T: Real>(mp: &MatrixProfile<T>, k: usize) -> Vec<Event<T>> {
    extract(mp, k, true, mp.excl.max(mp.m / 2))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mp::{scrimp, MpConfig};
    use crate::prop::Rng;
    use crate::timeseries::generator::{generate_with_event, Pattern, PlantedEvent};

    fn profile(n: usize, m: usize, seed: u64) -> (Vec<f64>, MatrixProfile<f64>) {
        let mut rng = Rng::new(seed);
        let t: Vec<f64> = rng.gauss_vec(n);
        let mp = scrimp::matrix_profile(&t, MpConfig::new(m)).unwrap();
        (t, mp)
    }

    #[test]
    fn motifs_sorted_ascending_discords_descending() {
        let (_, mp) = profile(800, 16, 1);
        let motifs = top_motifs(&mp, 5);
        let discords = top_discords(&mp, 5);
        assert!(motifs.windows(2).all(|w| w[0].distance <= w[1].distance));
        assert!(discords.windows(2).all(|w| w[0].distance >= w[1].distance));
        assert!(motifs[0].distance <= discords.last().unwrap().distance);
    }

    #[test]
    fn picks_are_separated_by_suppression_radius() {
        let (_, mp) = profile(1000, 20, 2);
        let radius = mp.excl.max(mp.m / 2);
        for events in [top_motifs(&mp, 6), top_discords(&mp, 6)] {
            for a in 0..events.len() {
                for b in (a + 1)..events.len() {
                    let gap = events[a].index.abs_diff(events[b].index);
                    assert!(gap > radius, "picks {a},{b} only {gap} apart");
                }
            }
        }
    }

    #[test]
    fn planted_motif_is_rank_one() {
        let (t, ev) = generate_with_event::<f64>(Pattern::PlantedMotif, 2048, 4);
        let mp = scrimp::matrix_profile(&t, MpConfig::new(32)).unwrap();
        let (a, b) = match ev {
            PlantedEvent::Motif { a, b, .. } => (a, b),
            _ => unreachable!(),
        };
        let motifs = top_motifs(&mp, 3);
        let top = &motifs[0];
        assert!(
            top.index.abs_diff(a) < 32 || top.index.abs_diff(b) < 32,
            "rank-1 motif at {} not near planted {a}/{b}",
            top.index
        );
        assert!(top.distance < 1e-4);
    }

    #[test]
    fn planted_anomaly_is_rank_one_discord() {
        let (t, ev) = generate_with_event::<f64>(Pattern::EcgLike, 4096, 5);
        let mp = scrimp::matrix_profile(&t, MpConfig::new(64)).unwrap();
        let (start, len) = match ev {
            PlantedEvent::Anomaly { start, len } => (start, len),
            _ => unreachable!(),
        };
        let discords = top_discords(&mp, 2);
        let top = discords[0].index;
        assert!(top + 64 >= start && top < start + len + 64);
    }

    #[test]
    fn exclusion_zone_deduplicates_trivial_matches() {
        // Hand-built profile: a "plateau" of near-identical minima around
        // index 10 (the same motif shifted by one sample — the trivial
        // matches §topk must suppress), plus one genuinely distinct motif
        // at index 40.  suppress radius = max(excl, m/2) = 8.
        let nw = 64;
        let m = 16;
        let mut p = vec![5.0f64; nw];
        let mut i = vec![-1i64; nw];
        for (off, d) in [(8usize, 0.11), (9, 0.10), (10, 0.09), (11, 0.10), (12, 0.12)] {
            p[off] = d;
            i[off] = (off + 30) as i64; // matches live around 38..42
        }
        p[40] = 0.2;
        i[40] = 9; // its match is inside the first plateau
        let mp = MatrixProfile { p, i, m, excl: 4 };
        let motifs = top_motifs(&mp, 5);
        // rank 1 is the plateau minimum; the rest of the plateau AND the
        // neighborhoods of both occurrences (10±8, 40±8) are masked, so
        // no second event from either zone may appear.
        assert_eq!(motifs[0].index, 10);
        let radius = mp.excl.max(mp.m / 2);
        for ev in &motifs[1..] {
            assert!(ev.index.abs_diff(10) > radius, "trivial match at {}", ev.index);
            assert!(ev.index.abs_diff(40) > radius, "match zone at {}", ev.index);
        }
        // every survivor has the background distance
        assert!(motifs[1..].iter().all(|e| e.distance == 5.0));
    }

    #[test]
    fn discords_on_all_inf_profile_are_empty() {
        let mp = MatrixProfile::<f64>::new_inf(32, 8, 2);
        assert!(top_discords(&mp, 3).is_empty());
        assert!(top_motifs(&mp, 3).is_empty());
    }

    #[test]
    fn k_larger_than_events_truncates() {
        let (_, mp) = profile(200, 16, 6);
        let motifs = top_motifs(&mp, 1000);
        assert!(motifs.len() < 1000);
        assert!(!motifs.is_empty());
    }
}
