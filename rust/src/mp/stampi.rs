//! STAMPI — exact *streaming* matrix profile under `append(sample)`.
//!
//! The batch engines ([`crate::mp::scrimp`], [`crate::mp::stomp`], …) walk
//! the whole distance matrix; the flagship applications the paper motivates
//! (arrhythmia review, seismic monitoring) instead see samples *arrive*.
//! Yeh's incremental formulation (STAMPI, arXiv 1811.03064 §STAMPI) keeps
//! the profile exact under appends at O(n) per sample: when sample `t[n-1]`
//! arrives it creates exactly one new window `k = n - m`, and the dot
//! products of `k` against every earlier window follow from the previous
//! append's row by the same Eq. 2 recurrence STOMP uses row-wise:
//!
//! ```text
//! q_new[j] = q_old[j-1] - t[j-1]·t[k-1] + t[j+m-1]·t[k+m-1]
//! ```
//!
//! with one direct O(m) dot product at the oldest retained window.
//!
//! ## On the unified kernel (the streaming hot path)
//!
//! The row update executes on the kernel family's row entry point,
//! [`crate::mp::kernel::compute_row_n`]: [`Stampi::append`] is a width-1
//! row tile over contiguous [`RingVec`] slice views (bounds checked once
//! per append, not once per cell), and [`Stampi::extend`] blocks up to
//! [`crate::mp::kernel::BAND`] buffered samples into one multi-row tile,
//! so batched appends amortize lane fill exactly like the batch fleet's
//! band tiles.  The cell math is the batch kernel's verbatim: delta-form
//! Eq. 2 chains (a row tile's lane pulls ARE the diagonal chains of the
//! batch sweep), folded Eq. 1 factors (`za = √2/σ`, `zb = √(2m)·μ/σ`),
//! and two branchless merge passes.
//!
//! PERF CONTRACT (same as every batch engine): the live profile stores
//! **squared** z-norm distances — min is monotone under sqrt, so the old
//! per-cell `sqrt` is deferred to ONE pass per [`Stampi::profile`]
//! snapshot.  Snapshots still expose true distances; only the internal
//! representation changed.
//!
//! One [`crate::mp::kernel::scalar_row`] evaluation per admissible pair
//! updates both `P[j]` (old window gained a new candidate neighbor) and
//! `P[k]` (new window scans all of history) — the profile after every
//! append is bit-equal in structure to a batch run over the prefix (the
//! differential property test in `rust/tests/cross_impl.rs` pins this at
//! < 1e-6 against the brute-force oracle at every step, and the kernel
//! property tests pin every tile width bit-identical to the retained
//! scalar row walk).
//!
//! ## Bounded history
//!
//! With [`StampiConfig::with_max_history`] the engine keeps only the last
//! `H` samples ([`crate::timeseries::stream::RingVec`] eviction) and the
//! profile entries of the windows still inside them — O(H) memory on an
//! unbounded stream.  Semantics follow streaming practice: a retained
//! window's profile value may still *record* a distance to an evicted
//! neighbor (computed while that neighbor was live; it remains a true
//! pairwise distance), but new windows can only match retained history, so
//! every bounded-profile value upper-bounds the unbounded one.  Snapshot
//! positions are relative to [`Stampi::first_window`] and neighbor indices
//! are rebased to match (an evicted neighbor reports `-1` — see
//! [`Stampi::profile`]).
//!
//! On the blocked [`Stampi::extend`] path, eviction runs at *tile*
//! granularity: every row in a tile sees the history bound as of the
//! tile's start, so later rows in a tile may evaluate up to `rows - 1`
//! extra just-past-the-bound candidates that per-sample appends would
//! have evicted first.  Those are true pairwise distances against real
//! history — the blocked profile is still exact for a valid (slightly
//! wider) history window, still upper-bounds the unbounded profile, and
//! lower-bounds the per-append bounded one.  With unbounded history the
//! blocked and per-append paths are **bit-identical** (pinned below).

use crate::mp::kernel::{self, RowTile, BAND};
use crate::mp::{MatrixProfile, WorkStats};
use crate::timeseries::default_exclusion;
use crate::timeseries::stream::RingVec;
use crate::Real;

/// Configuration of a streaming matrix profile session.
#[derive(Clone, Copy, Debug)]
pub struct StampiConfig {
    /// Window (subsequence) length `m`.
    pub m: usize,
    /// Exclusion-zone radius; `None` = paper default `m/4`.
    pub excl: Option<usize>,
    /// Retain only the last `max_history` samples (`None` = unbounded).
    pub max_history: Option<usize>,
}

impl StampiConfig {
    pub fn new(m: usize) -> Self {
        StampiConfig { m, excl: None, max_history: None }
    }

    pub fn with_excl(mut self, excl: usize) -> Self {
        self.excl = Some(excl);
        self
    }

    pub fn with_max_history(mut self, samples: usize) -> Self {
        self.max_history = Some(samples);
        self
    }

    pub fn exclusion(&self) -> usize {
        self.excl.unwrap_or_else(|| default_exclusion(self.m))
    }

    /// Validate the configuration (the streaming analogue of
    /// [`crate::mp::MpConfig::validate`]; there is no length to check up
    /// front — the profile simply stays empty until `m` samples arrived).
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.m >= 3, "window length m={} too small (min 3)", self.m);
        if let Some(h) = self.max_history {
            // m + excl samples hold windows 0..=excl, whose pair (0, excl)
            // is the first admissible one — same bound as the batch
            // `MpConfig::validate` (nw > excl).
            let need = self.m + self.exclusion();
            anyhow::ensure!(
                h >= need,
                "max_history={h} too small: m={} with excl={} needs at least {need} \
                 samples to ever hold one admissible pair",
                self.m,
                self.exclusion()
            );
        }
        Ok(())
    }
}

/// The canonical serializable state of a streaming session — everything
/// [`Stampi`] is, as plain data.
///
/// Yeh's streaming formulation makes this tiny relative to the stream:
/// the retained ring window, the folded Eq. 1 factors, the last row's
/// dot products, the squared-distance profile, and the rolling-sum
/// anchors.  Restoring via [`Stampi::from_state`] is **bit-identical**:
/// a restored session appends exactly the bits an uninterrupted one
/// would (pinned by the state round-trip test below and the service's
/// kill/restart differential).
///
/// This struct is deliberately the *shared* compact-state currency: the
/// per-shard WAL ([`crate::coordinator::wal`]) snapshots it, and the
/// planned hot-shard stream migration hands it off — one codec, two
/// consumers (ROADMAP).
///
/// [`Self::encode`]/[`Self::decode`] are the standalone binary codec:
/// every element is stored as the bit pattern of its `f64` widening
/// (exact for both `f32` and `f64`), so round-trips preserve bits for
/// either precision; a dtype tag prevents cross-precision decodes.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionState<T> {
    /// Window length `m`.
    pub m: usize,
    /// Exclusion-zone radius in effect.
    pub excl: usize,
    /// Retained-history bound (`None` = unbounded).
    pub max_history: Option<usize>,
    /// Absolute stream index of the oldest retained sample.
    pub first_sample: usize,
    /// Retained raw samples (ring window).
    pub t: Vec<T>,
    /// Absolute index of the oldest retained window.
    pub first_window: usize,
    /// Folded Eq. 1 factors of the retained windows.
    pub za: Vec<T>,
    pub zb: Vec<T>,
    /// Last row's dot products (`q[j]` = window j · latest window).
    pub q: Vec<T>,
    /// Live profile in the kernel's squared-distance representation.
    pub p: Vec<T>,
    /// Neighbor indices (absolute; `-1` = none/evicted).
    pub i: Vec<i64>,
    /// Rolling sums over the last `m` samples (f64 anchors).
    pub s: f64,
    pub s2: f64,
    /// Appends since the rolling sums were last recomputed exactly.
    pub since_anchor: u32,
    /// Aggregate functional work so far.
    pub work: WorkStats,
}

/// Codec magic + version ("NATSA session state v1").
const STATE_MAGIC: &[u8; 4] = b"NSS1";

/// Byte cursor for [`SessionState::decode`].
struct Cur<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> crate::Result<&'a [u8]> {
        anyhow::ensure!(
            self.at + n <= self.buf.len(),
            "session state truncated at byte {} (+{n} > {})",
            self.at,
            self.buf.len()
        );
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self) -> crate::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> crate::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> crate::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn usize(&mut self) -> crate::Result<usize> {
        Ok(usize::try_from(self.u64()?)?)
    }

    fn f64(&mut self) -> crate::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

impl<T: Real> SessionState<T> {
    /// Serialize to bytes (appends to `out`; framing/CRC is the WAL
    /// layer's job).  Bit-exact round-trip with [`Self::decode`].
    pub fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(STATE_MAGIC);
        out.push(T::BYTES as u8); // dtype tag
        put_u64(out, self.m as u64);
        put_u64(out, self.excl as u64);
        match self.max_history {
            Some(h) => {
                out.push(1);
                put_u64(out, h as u64);
            }
            None => {
                out.push(0);
                put_u64(out, 0);
            }
        }
        put_u64(out, self.first_sample as u64);
        put_u64(out, self.t.len() as u64);
        for &x in &self.t {
            put_u64(out, x.to_f64s().to_bits());
        }
        put_u64(out, self.first_window as u64);
        put_u64(out, self.p.len() as u64);
        for arr in [&self.za, &self.zb, &self.q, &self.p] {
            debug_assert_eq!(arr.len(), self.p.len());
            for &x in arr.iter() {
                put_u64(out, x.to_f64s().to_bits());
            }
        }
        for &j in &self.i {
            put_u64(out, j as u64);
        }
        put_u64(out, self.s.to_bits());
        put_u64(out, self.s2.to_bits());
        out.extend_from_slice(&self.since_anchor.to_le_bytes());
        put_u64(out, self.work.cells);
        put_u64(out, self.work.diagonals);
        put_u64(out, self.work.first_dots);
        put_u64(out, self.work.updates);
    }

    /// Deserialize from bytes; the whole buffer must be consumed.
    /// Structural integrity (magic, dtype, lengths) is verified here;
    /// semantic invariants are verified by [`Stampi::from_state`].
    pub fn decode(buf: &[u8]) -> crate::Result<Self> {
        let mut c = Cur { buf, at: 0 };
        anyhow::ensure!(c.take(4)? == STATE_MAGIC, "bad session state magic");
        let dtype = c.u8()?;
        anyhow::ensure!(
            dtype as usize == T::BYTES,
            "session state dtype mismatch: stored {dtype}-byte elements, expected {} ({})",
            T::BYTES,
            T::DTYPE
        );
        let m = c.usize()?;
        let excl = c.usize()?;
        let has_hist = c.u8()? != 0;
        let hist = c.usize()?;
        let max_history = has_hist.then_some(hist);
        let first_sample = c.usize()?;
        let tlen = c.usize()?;
        anyhow::ensure!(
            buf.len().saturating_sub(c.at) >= 8 * tlen,
            "session state sample array truncated"
        );
        let mut t = Vec::with_capacity(tlen);
        for _ in 0..tlen {
            t.push(T::of_f64(c.f64()?));
        }
        let first_window = c.usize()?;
        let wlen = c.usize()?;
        anyhow::ensure!(
            buf.len().saturating_sub(c.at) >= 8 * wlen * 5,
            "session state window arrays truncated"
        );
        let mut arrs: [Vec<T>; 4] = [Vec::new(), Vec::new(), Vec::new(), Vec::new()];
        for arr in arrs.iter_mut() {
            arr.reserve(wlen);
            for _ in 0..wlen {
                arr.push(T::of_f64(c.f64()?));
            }
        }
        let [za, zb, q, p] = arrs;
        let mut i = Vec::with_capacity(wlen);
        for _ in 0..wlen {
            i.push(c.u64()? as i64);
        }
        let s = c.f64()?;
        let s2 = c.f64()?;
        let since_anchor = c.u32()?;
        let work = WorkStats {
            cells: c.u64()?,
            diagonals: c.u64()?,
            first_dots: c.u64()?,
            updates: c.u64()?,
        };
        anyhow::ensure!(
            c.at == buf.len(),
            "session state has {} trailing bytes",
            buf.len() - c.at
        );
        Ok(SessionState {
            m,
            excl,
            max_history,
            first_sample,
            t,
            first_window,
            za,
            zb,
            q,
            p,
            i,
            s,
            s2,
            since_anchor,
            work,
        })
    }
}

/// What one [`Stampi::append`] did, when it completed a window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AppendOutcome {
    /// Absolute index of the window this sample completed.
    pub window: usize,
    /// First column of the incremental row (oldest retained window).
    pub row_start: usize,
    /// Admissible cells evaluated in this row (0 while the stream is
    /// shorter than one exclusion zone).
    pub row_cells: u64,
}

/// The streaming engine: an exact matrix profile maintained under appends.
#[derive(Clone, Debug)]
pub struct Stampi<T> {
    m: usize,
    excl: usize,
    max_history: Option<usize>,
    /// Raw samples (absolute sample indexing).
    t: RingVec<T>,
    /// Folded Eq. 1 factors, exactly the batch kernel's representation
    /// (see [`crate::timeseries::WindowStats`]): `za = sqrt(2)/sigma`,
    /// `zb = sqrt(2m)*mu/sigma`, both zero for constant windows (which
    /// degenerate to d² = 2m).
    za: RingVec<T>,
    zb: RingVec<T>,
    /// `q[j]` = dot product of window `j` with the latest window.
    q: RingVec<T>,
    /// The live profile in the kernel's **squared**-distance
    /// representation (PERF CONTRACT — one deferred sqrt per
    /// [`Stampi::profile`] snapshot), plus neighbor indices.
    p: RingVec<T>,
    i: RingVec<i64>,
    /// Rolling sums over the last `m` samples (f64 like the batch
    /// [`crate::timeseries::sliding_stats`], so f32 streams with large
    /// offsets keep their variance digits).  Unlike the batch path — which
    /// sums each window independently — these slide forever, and the
    /// `+x²/−old²` updates random-walk away from the true sums (on an
    /// offset-1e6 stream, `s2 ≈ m·1e12` has ulp ≈ 2e-3, so after ~1e6
    /// appends the drift *exceeds the O(1) signal variance* and the
    /// clamped `var = max(s2/m − mean², 0)` collapses windows to sd = 0).
    /// They are therefore re-anchored — recomputed exactly over the
    /// current window — at every ring compaction (every ~history appends
    /// on a bounded stream) and at least every
    /// [`REANCHOR_EVERY`] appends regardless.
    s: f64,
    s2: f64,
    /// Appends since the rolling sums were last recomputed exactly.
    since_anchor: u32,
    work: WorkStats,
}

/// Unconditional re-anchoring period for the rolling sums (appends).  The
/// drift between anchors is a random walk of O(ulp(s2)) steps, so 2^16
/// appends keep the relative sd error below ~3e-2 even at offset 1e6
/// (measured by the drift regression test below at its bounded — much
/// tighter — anchoring cadence); the amortized cost is O(m / 65536) per
/// append, i.e. nothing.
const REANCHOR_EVERY: u32 = 1 << 16;

impl<T: Real> Stampi<T> {
    pub fn new(cfg: StampiConfig) -> crate::Result<Self> {
        cfg.validate()?;
        Ok(Stampi {
            m: cfg.m,
            excl: cfg.exclusion(),
            max_history: cfg.max_history,
            t: RingVec::new(),
            za: RingVec::new(),
            zb: RingVec::new(),
            q: RingVec::new(),
            p: RingVec::new(),
            i: RingVec::new(),
            s: 0.0,
            s2: 0.0,
            since_anchor: 0,
            work: WorkStats::default(),
        })
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn exclusion(&self) -> usize {
        self.excl
    }

    /// Total samples appended so far (absolute stream length).
    pub fn len(&self) -> usize {
        self.t.next_index()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total windows completed so far (absolute count).
    pub fn num_windows(&self) -> usize {
        self.p.next_index()
    }

    /// Absolute index of the oldest retained window (0 when unbounded).
    pub fn first_window(&self) -> usize {
        self.p.first_index()
    }

    /// Retained window count (== [`Self::num_windows`] when unbounded).
    pub fn retained_windows(&self) -> usize {
        self.p.len()
    }

    /// Aggregate functional work — feeds the timing/energy models in
    /// [`crate::sim`] exactly like the batch engines' accounting: one
    /// seed dot + one diagonal-equivalent per row *with admissible
    /// cells*, so full-stream totals equal a batch run over the same
    /// series (appends that evaluate nothing charge nothing).
    pub fn work(&self) -> WorkStats {
        self.work
    }

    /// Extract the canonical serializable state (see [`SessionState`]).
    /// `from_state(state())` is the identity on every observable —
    /// profile bits, q chains, rolling sums, work accounting — so a
    /// restored session continues the stream bit-identically.
    pub fn state(&self) -> SessionState<T> {
        SessionState {
            m: self.m,
            excl: self.excl,
            max_history: self.max_history,
            first_sample: self.t.first_index(),
            t: self.t.retained().to_vec(),
            first_window: self.p.first_index(),
            za: self.za.retained().to_vec(),
            zb: self.zb.retained().to_vec(),
            q: self.q.retained().to_vec(),
            p: self.p.retained().to_vec(),
            i: self.i.retained().to_vec(),
            s: self.s,
            s2: self.s2,
            since_anchor: self.since_anchor,
            work: self.work,
        }
    }

    /// Rebuild a session from its canonical state, verifying the
    /// semantic invariants a live session maintains (window/sample
    /// alignment, array lengths, config bounds) — corrupt or
    /// inconsistent state is an error, never a silently-wrong engine.
    pub fn from_state(st: SessionState<T>) -> crate::Result<Self> {
        let cfg = StampiConfig {
            m: st.m,
            excl: Some(st.excl),
            max_history: st.max_history,
        };
        cfg.validate()?;
        let wlen = st.p.len();
        anyhow::ensure!(
            st.za.len() == wlen && st.zb.len() == wlen && st.q.len() == wlen && st.i.len() == wlen,
            "session state window arrays disagree: za {} zb {} q {} p {} i {}",
            st.za.len(),
            st.zb.len(),
            st.q.len(),
            wlen,
            st.i.len()
        );
        let n = st.first_sample + st.t.len();
        let num_windows = if n >= st.m { n - st.m + 1 } else { 0 };
        anyhow::ensure!(
            st.first_window + wlen == num_windows,
            "session state window range [{}, {}) inconsistent with {} samples (m={})",
            st.first_window,
            st.first_window + wlen,
            n,
            st.m
        );
        anyhow::ensure!(
            wlen == 0 || st.first_window == st.first_sample,
            "session state misaligned: first_window {} != first_sample {}",
            st.first_window,
            st.first_sample
        );
        anyhow::ensure!(
            st.s.is_finite() && st.s2.is_finite(),
            "session state rolling sums are not finite"
        );
        Ok(Stampi {
            m: st.m,
            excl: st.excl,
            max_history: st.max_history,
            t: RingVec::from_parts(st.first_sample, st.t),
            za: RingVec::from_parts(st.first_window, st.za),
            zb: RingVec::from_parts(st.first_window, st.zb),
            q: RingVec::from_parts(st.first_window, st.q),
            p: RingVec::from_parts(st.first_window, st.p),
            i: RingVec::from_parts(st.first_window, st.i),
            s: st.s,
            s2: st.s2,
            since_anchor: st.since_anchor,
            work: st.work,
        })
    }

    /// Push one sample; once it completes a window, push that window's
    /// statistics and fresh profile/q slots and return its absolute
    /// index.  The caller still has to advance the row state
    /// ([`Self::run_rows`]) and run [`Self::maintain`].
    fn admit(&mut self, x: T) -> Option<usize> {
        let m = self.m;
        self.t.push(x);
        let n = self.t.next_index();

        // Rolling statistics over the last m samples.
        let xf = x.to_f64s();
        self.s += xf;
        self.s2 += xf * xf;
        if n > m {
            let old = self.t.get(n - 1 - m).to_f64s();
            self.s -= old;
            self.s2 -= old * old;
        }
        if n < m {
            return None;
        }

        // Window k = n - m is now complete; push its statistics in the
        // kernel's folded representation.
        let k = n - m;
        let mf = m as f64;
        let mean = self.s / mf;
        let var = (self.s2 / mf - mean * mean).max(0.0);
        // One sqrt pair per *completed window* (statistics seeding), not
        // per profile cell — the deferred-sqrt contract bans sqrt on the
        // O(n)-per-append distance path, which stays squared.
        let sd = var.sqrt(); // natsa-lint: allow(hot_sqrt)
        if sd > 0.0 {
            self.za.push(T::of_f64(std::f64::consts::SQRT_2 / sd));
            // natsa-lint: allow(hot_sqrt) same once-per-window seeding pair
            self.zb.push(T::of_f64((2.0 * mf).sqrt() * mean / sd));
        } else {
            self.za.push(T::zero());
            self.zb.push(T::zero());
        }
        self.p.push(T::infinity());
        self.i.push(-1);
        self.q.push(T::zero()); // slot; the row tile writes every entry
        Some(k)
    }

    /// Advance the streaming state by a tile of `rows` freshly-admitted
    /// windows through the unified row kernel.  All hot-loop access goes
    /// through contiguous slice views acquired here — one retained-range
    /// check per ring per tile, zero per cell.  Returns the admissible
    /// cells evaluated.
    fn run_rows(&mut self, rows: usize) -> u64 {
        let m = self.m;
        let excl = self.excl;
        let n = self.t.next_index();
        let j0 = self.p.first_index();
        let wend = self.p.next_index();
        debug_assert_eq!(wend, n - m + 1);
        debug_assert_eq!(j0, self.t.first_index());
        let before = self.work.cells;
        let tile = RowTile {
            t: self.t.slice(j0, n),
            za: self.za.slice(j0, wend),
            zb: self.zb.slice(j0, wend),
            q: self.q.slice_mut(j0, wend),
            p: self.p.slice_mut(j0, wend),
            i: self.i.slice_mut(j0, wend),
            base: j0 as i64,
        };
        kernel::compute_row_n(tile, rows, m, excl, &mut self.work);
        self.work.cells - before
    }

    /// Post-tile bookkeeping: bounded-history eviction and rolling-sum
    /// re-anchoring, charged once per tile (`appends` samples).
    fn maintain(&mut self, newest_window: usize, appends: u32) {
        let n = self.t.next_index();
        let m = self.m;
        let mut compacted = false;
        if let Some(h) = self.max_history {
            if self.t.len() > h {
                let sample_base = n - h;
                compacted = self.t.evict_to(sample_base);
                let window_base = sample_base.min(newest_window);
                self.za.evict_to(window_base);
                self.zb.evict_to(window_base);
                self.q.evict_to(window_base);
                self.p.evict_to(window_base);
                self.i.evict_to(window_base);
            }
        }

        // Re-anchor the rolling sums (see the field docs): recompute them
        // exactly over the current last-m window on every ring compaction
        // and at least every REANCHOR_EVERY appends, so slide drift can
        // never accumulate past one anchoring period.
        self.since_anchor = self.since_anchor.saturating_add(appends);
        if compacted || self.since_anchor >= REANCHOR_EVERY {
            let mut s = 0.0;
            let mut s2 = 0.0;
            for &v in self.t.slice(n - m, n) {
                let vf = v.to_f64s();
                s += vf;
                s2 += vf * vf;
            }
            self.s = s;
            self.s2 = s2;
            self.since_anchor = 0;
        }
    }

    /// Append one sample.  Returns `Some` once the sample completes a
    /// window (i.e. from the `m`-th sample on).  The row update runs as
    /// a width-1 tile of the unified kernel.
    pub fn append(&mut self, x: T) -> Option<AppendOutcome> {
        let k = self.admit(x)?;
        let j0 = self.p.first_index();
        let row_cells = if k == 0 {
            // First window: seed q with its self-dot (feeds the lane-0
            // pull of the next row tile; no admissible pair yet and no
            // work charged — warm-up, like the zero-cell rows below).
            let m = self.m;
            let q0 = kernel::seed_dot(self.t.slice(0, m), 0, m);
            self.q.set(0, q0);
            0
        } else {
            self.run_rows(1)
        };
        self.maintain(k, 1);
        Some(AppendOutcome { window: k, row_start: j0, row_cells })
    }

    /// Append a batch of samples; returns how many windows were
    /// completed.
    ///
    /// This is the blocked fast path: once the stream has its first
    /// window, buffered samples are admitted in groups of up to
    /// `min(BAND, excl)` and advanced as ONE multi-row kernel tile, so
    /// a batch of appends amortizes lane fill exactly like the batch
    /// fleet's band tiles (each sample still updates the rolling
    /// statistics individually — the profile is identical to per-sample
    /// appends, bit-for-bit with unbounded history; see the module docs
    /// for the tile-granular eviction semantics under a history bound).
    pub fn extend(&mut self, xs: &[T]) -> usize {
        let mut completed = 0;
        let mut pos = 0;
        // Per-sample until the first window exists (the multi-row tile
        // needs a previous row's q state to pull from).
        while pos < xs.len() && self.num_windows() == 0 {
            if self.append(xs[pos]).is_some() {
                completed += 1;
            }
            pos += 1;
        }
        // Blocked path: every further sample completes exactly one
        // window.  Tile width is capped at the exclusion radius so the
        // kernel's merges stay order-free (bit-identical to per-sample
        // appends — see `compute_row_n`).
        let wmax = BAND.min(self.excl.max(1));
        while pos < xs.len() {
            // Never straddle the rolling-sum re-anchor boundary: cap the
            // tile so it ends exactly where the per-append schedule would
            // recompute s/s2 (`maintain` fires between tiles), otherwise
            // windows admitted mid-tile after the 2^16th append would see
            // drifted sums where per-sample appends see fresh ones, and
            // the bit-identity of the two paths would break there.
            let to_anchor = (REANCHOR_EVERY - self.since_anchor) as usize;
            let rows = wmax.min(xs.len() - pos).min(to_anchor.max(1));
            for &x in &xs[pos..pos + rows] {
                let admitted = self.admit(x);
                debug_assert!(admitted.is_some(), "post-first-window admit must complete");
            }
            self.run_rows(rows);
            let newest = self.num_windows() - 1;
            self.maintain(newest, rows as u32);
            completed += rows;
            pos += rows;
        }
        completed
    }

    /// Borrow this session's state as one lane of a cross-stream group
    /// tile (see [`kernel::compute_row_group`] and [`append_group`]):
    /// the same one-range-check-per-ring slice views [`Self::run_rows`]
    /// builds, bundled with this session's own work accumulator.  Only
    /// valid right after [`Self::admit`] returned `Some` (the newest
    /// window's slots exist, its row has not run yet).
    fn lane(&mut self) -> kernel::GroupLane<'_, T> {
        let n = self.t.next_index();
        let j0 = self.p.first_index();
        let wend = self.p.next_index();
        debug_assert_eq!(wend, n - self.m + 1);
        debug_assert_eq!(j0, self.t.first_index());
        kernel::GroupLane {
            tile: RowTile {
                t: self.t.slice(j0, n),
                za: self.za.slice(j0, wend),
                zb: self.zb.slice(j0, wend),
                q: self.q.slice_mut(j0, wend),
                p: self.p.slice_mut(j0, wend),
                i: self.i.slice_mut(j0, wend),
                base: j0 as i64,
            },
            work: &mut self.work,
        }
    }

    /// Snapshot the live profile.  Position `r` of the result is window
    /// `first_window() + r`, and neighbor indices are rebased to the same
    /// positions, so the snapshot is a self-consistent [`MatrixProfile`]
    /// that every downstream consumer ([`crate::mp::topk`], CSV dumps, …)
    /// can index directly.  A neighbor that has been *evicted* cannot be
    /// named in-snapshot: its entry keeps the (true, historical) distance
    /// but reports index `-1`.  With unbounded history the rebasing is the
    /// identity and `-1` only ever means "no admissible pair yet".
    ///
    /// The internal profile is squared (kernel PERF CONTRACT); this is
    /// the ONE place the deferred `sqrt` runs — once per snapshot, not
    /// once per cell.  Since sqrt is monotone and correctly rounded, the
    /// snapshot values equal what per-cell sqrt minimization produced.
    pub fn profile(&self) -> MatrixProfile<T> {
        let base = self.p.first_index() as i64;
        let i = self
            .i
            .to_vec()
            .iter()
            .map(|&j| if j >= base { j - base } else { -1 })
            .collect();
        let mut mp = MatrixProfile {
            p: self.p.to_vec(),
            i,
            m: self.m,
            excl: self.excl,
        };
        mp.sqrt_in_place();
        mp
    }
}

/// What one [`append_group`] pass did — the coalescing evidence the
/// service's metrics consume.  All three vectors are per-call; `windows`
/// and `cells` are indexed like the `members` slice.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct GroupAppendReport {
    /// Per member: `Some(window)` when its sample completed a window —
    /// the same contract as [`Stampi::append`]'s return.
    pub windows: Vec<Option<usize>>,
    /// Per member: admissible cells its row evaluated (0 for warm-up).
    pub cells: Vec<u64>,
    /// Kernel lane width of each sub-tile the group rode (chunks of up
    /// to [`BAND`] lanes; only rows past their stream's first window
    /// join a tile) — feeds the service's coalesce-width histogram.
    pub widths: Vec<usize>,
}

/// Advance several **independent** sessions by one sample each on shared
/// multi-lane kernel tiles ([`kernel::compute_row_group`]) — the
/// cross-stream analogue of [`Stampi::extend`]'s within-stream blocking,
/// and the engine half of the service's append-coalescing drain loop.
///
/// Every member must share the group key (`m`, `excl`); histories,
/// history bounds, and stream ages are free to differ per member.  Each
/// member's step is exactly [`Stampi::append`]'s: admit (rolling stats +
/// fresh slots — `None` pre-warm-up skips everything, a first window
/// seeds its q slot without a tile), one row through the kernel, then
/// [`Stampi::maintain`] at per-append granularity — so eviction
/// boundaries and rolling-sum re-anchoring land exactly where the
/// isolated path lands them.  Only the row itself is shared: admitted
/// rows of all members execute as one [`kernel::compute_row_group`]
/// call, whose lanes are bit-identical to per-lane scalar walks by
/// construction.
///
/// Net effect, pinned by the property test below and
/// `rust/tests/coalesce.rs`: every member ends **bit-identical** —
/// profile bits, neighbor indices, q chains, rolling sums, and work
/// accounting — to `member.append(x)` applied on its own.
pub fn append_group<T: Real>(members: &mut [(&mut Stampi<T>, T)]) -> GroupAppendReport {
    let mut report = GroupAppendReport::default();
    if members.is_empty() {
        return report;
    }
    let m = members[0].0.m;
    let excl = members[0].0.excl;
    for (s, _) in members.iter() {
        assert!(
            s.m == m && s.excl == excl,
            "append_group key mismatch: expected (m={m}, excl={excl}), got (m={}, excl={})",
            s.m,
            s.excl
        );
    }
    // Phase 1 — admit every sample.  A member's very first window takes
    // `append`'s seed-only path (q[0] = self dot, no tile, no work).
    let admitted: Vec<Option<usize>> = members.iter_mut().map(|(s, x)| s.admit(*x)).collect();
    for ((s, _), k) in members.iter_mut().zip(&admitted) {
        if *k == Some(0) {
            let q0 = kernel::seed_dot(s.t.slice(0, m), 0, m);
            s.q.set(0, q0);
        }
    }
    let before: Vec<u64> = members.iter().map(|(s, _)| s.work.cells).collect();
    // Phase 2 — every admitted non-first row joins ONE shared group
    // tile (chunked into <= BAND-lane sub-tiles by the kernel).
    {
        let mut lanes: Vec<kernel::GroupLane<'_, T>> = members
            .iter_mut()
            .zip(&admitted)
            .filter(|(_, k)| k.is_some_and(|k| k > 0))
            .map(|((s, _), _)| s.lane())
            .collect();
        let mut left = lanes.len();
        while left > 0 {
            let w = left.min(BAND);
            report.widths.push(w);
            left -= w;
        }
        kernel::compute_row_group(&mut lanes, m, excl);
    }
    // Phase 3 — per-member post-row bookkeeping, exactly `append`'s
    // maintain(k, 1) (bounded-history eviction + re-anchor cadence).
    for (w, (s, _)) in members.iter_mut().enumerate() {
        report.cells.push(s.work.cells - before[w]);
        if let Some(k) = admitted[w] {
            s.maintain(k, 1);
        }
    }
    report.windows = admitted;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mp::{brute, stomp, total_cells, MpConfig};
    use crate::prop::{check, Rng};
    use crate::timeseries::generator::{generate_with_event, Pattern, PlantedEvent};

    fn feed(t: &[f64], cfg: StampiConfig) -> Stampi<f64> {
        let mut eng = Stampi::new(cfg).unwrap();
        for &x in t {
            eng.append(x);
        }
        eng
    }

    #[test]
    fn matches_batch_on_full_series() {
        let mut rng = Rng::new(71);
        let t: Vec<f64> = rng.gauss_vec(500);
        let eng = feed(&t, StampiConfig::new(16));
        let want = stomp::matrix_profile(&t, MpConfig::new(16)).unwrap();
        let got = eng.profile();
        assert_eq!(got.len(), want.len());
        // same kernel cell math; only the f64 statistics accumulation
        // order differs between the rolling stream and the batch cumsum
        assert!(got.max_abs_diff(&want) < 1e-9, "{}", got.max_abs_diff(&want));
    }

    #[test]
    fn no_window_before_m_samples() {
        let mut eng = Stampi::<f64>::new(StampiConfig::new(8)).unwrap();
        for s in 0..7 {
            assert!(eng.append(s as f64).is_none(), "sample {s}");
        }
        let out = eng.append(7.0).unwrap();
        assert_eq!(out.window, 0);
        assert_eq!(eng.num_windows(), 1);
        assert!(eng.profile().p[0].is_infinite());
    }

    #[test]
    fn work_stats_count_each_pair_once() {
        let mut rng = Rng::new(72);
        let t: Vec<f64> = rng.gauss_vec(300);
        let eng = feed(&t, StampiConfig::new(12));
        let nw = 300 - 12 + 1;
        let excl = 3;
        assert_eq!(eng.work().cells, total_cells(nw, excl));
        assert_eq!(eng.work().updates, 2 * eng.work().cells);
        // one O(m) seed dot and one diagonal-equivalent per row WITH
        // admissible cells — exactly the batch engines' closed-form
        // totals over the same series (nw - excl diagonals)
        assert_eq!(eng.work().first_dots, (nw - excl) as u64);
        assert_eq!(eng.work().diagonals, (nw - excl) as u64);
    }

    #[test]
    fn zero_cell_appends_charge_no_work() {
        // Regression (accounting skew): appends whose row has no
        // admissible cell (k < excl + j0) used to charge a seed dot and
        // a diagonal anyway, inflating the sim timing/energy evidence
        // for short or heavily-excluded streams relative to batch runs.
        let m = 12;
        let excl = 3;
        let mut eng = Stampi::<f64>::new(StampiConfig::new(m)).unwrap();
        let mut rng = Rng::new(81);
        // window `excl` (the first with an admissible cell) completes at
        // sample index m - 1 + excl; everything before must cost nothing
        for (s, x) in rng.gauss_vec(m + excl - 1).into_iter().enumerate() {
            let out = eng.append(x);
            if let Some(o) = out {
                assert_eq!(o.row_cells, 0, "sample {s}");
            }
            assert_eq!(eng.work(), WorkStats::default(), "sample {s}");
        }
        let out = eng.append(rng.gauss()).unwrap();
        assert_eq!(out.window, excl);
        assert_eq!(out.row_cells, 1);
        let w = eng.work();
        assert_eq!((w.cells, w.diagonals, w.first_dots, w.updates), (1, 1, 1, 2));
        // batch accounting for the same prefix agrees
        assert_eq!(w.cells, total_cells(excl + 1, excl));
    }

    #[test]
    fn blocked_extend_bit_identical_to_appends_unbounded() {
        // the tentpole pin at engine level: feeding through the blocked
        // multi-row extend path leaves exactly the state per-sample
        // appends leave — profile bits, neighbor indices, q chains, and
        // work accounting — including ragged chunk boundaries
        check("stampi-extend-bits", 6, |rng: &mut Rng| {
            let m = rng.range(4, 40);
            let n = rng.range(4 * m, 600);
            let t: Vec<f64> = rng.gauss_vec(n);
            let mut a = Stampi::<f64>::new(StampiConfig::new(m)).unwrap();
            for &x in &t {
                a.append(x);
            }
            let mut b = Stampi::<f64>::new(StampiConfig::new(m)).unwrap();
            let mut pos = 0;
            while pos < n {
                let chunk = rng.range(1, 40).min(n - pos);
                b.extend(&t[pos..pos + chunk]);
                pos += chunk;
            }
            assert_eq!(a.num_windows(), b.num_windows());
            let bits = |e: &Stampi<f64>| -> (Vec<u64>, Vec<u64>, Vec<i64>) {
                (
                    e.p.to_vec().iter().map(|x| x.to_bits()).collect(),
                    e.q.to_vec().iter().map(|x| x.to_bits()).collect(),
                    e.i.to_vec(),
                )
            };
            assert_eq!(bits(&a), bits(&b), "m={m} n={n}");
            assert_eq!(a.work(), b.work(), "m={m} n={n}");
        });
    }

    #[test]
    fn prop_append_group_bit_identical_to_isolated_appends() {
        // The cross-stream tentpole pin at engine level: feeding N
        // independent sessions through shared group tiles — with members
        // joining mid-stream, bounded histories compacting at different
        // times, and warm-up members in the mix — leaves every session
        // exactly the state its own per-sample appends leave: profile
        // bits, neighbor indices, q chains, rolling sums, and work.
        check("stampi-group-bits", 6, |rng: &mut Rng| {
            let m = rng.range(4, 24);
            let n_streams = rng.range(2, 12);
            let steps = rng.range(3 * m, 300);
            let series: Vec<Vec<f64>> = (0..n_streams).map(|_| rng.gauss_vec(steps)).collect();
            let cfg = |rng: &mut Rng| {
                let mut c = StampiConfig::new(m);
                if rng.range(0, 2) == 1 {
                    c = c.with_max_history(rng.range(m + m / 4 + 1, 4 * m));
                }
                c
            };
            let cfgs: Vec<StampiConfig> = (0..n_streams).map(|_| cfg(rng)).collect();
            let mut grouped: Vec<Stampi<f64>> =
                cfgs.iter().map(|&c| Stampi::new(c).unwrap()).collect();
            let mut isolated: Vec<Stampi<f64>> =
                cfgs.iter().map(|&c| Stampi::new(c).unwrap()).collect();
            // members join the group at random offsets, so group widths
            // vary step to step and lanes sit at different stream ages
            let starts: Vec<usize> = (0..n_streams).map(|_| rng.range(0, 2 * m)).collect();
            for step in 0..steps {
                let mut members: Vec<(&mut Stampi<f64>, f64)> = grouped
                    .iter_mut()
                    .enumerate()
                    .filter(|(w, _)| starts[*w] <= step)
                    .map(|(w, s)| (s, series[w][step]))
                    .collect();
                append_group(&mut members);
                drop(members);
                for (w, s) in isolated.iter_mut().enumerate() {
                    if starts[w] <= step {
                        s.append(series[w][step]);
                    }
                }
            }
            let bits = |e: &Stampi<f64>| -> (Vec<u64>, Vec<u64>, Vec<i64>, u64, u64) {
                (
                    e.p.to_vec().iter().map(|x| x.to_bits()).collect(),
                    e.q.to_vec().iter().map(|x| x.to_bits()).collect(),
                    e.i.to_vec(),
                    e.s.to_bits(),
                    e.s2.to_bits(),
                )
            };
            for w in 0..n_streams {
                assert_eq!(bits(&grouped[w]), bits(&isolated[w]), "stream {w}, m={m}");
                assert_eq!(grouped[w].work(), isolated[w].work(), "stream {w} accounting");
                assert_eq!(grouped[w].first_window(), isolated[w].first_window());
            }
        });
    }

    #[test]
    fn append_group_rejects_mixed_keys_and_handles_empty() {
        let mut a = Stampi::<f64>::new(StampiConfig::new(8)).unwrap();
        let mut b = Stampi::<f64>::new(StampiConfig::new(8).with_excl(5)).unwrap();
        let r = append_group::<f64>(&mut []);
        assert!(r.windows.is_empty() && r.widths.is_empty());
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut members = vec![(&mut a, 1.0), (&mut b, 2.0)];
            append_group(&mut members);
        }));
        assert!(caught.is_err(), "mixed (m, excl) group must be rejected");
    }

    #[test]
    fn append_group_reports_sub_band_and_chunked_widths() {
        // 20 mature streams: one pass must ride 8+8+4 lane sub-tiles;
        // warm-up members must not occupy lanes
        let m = 8;
        let mut streams: Vec<Stampi<f64>> = (0..20)
            .map(|_| Stampi::new(StampiConfig::new(m)).unwrap())
            .collect();
        let mut rng = Rng::new(94);
        for s in streams.iter_mut().take(18) {
            s.extend(&rng.gauss_vec(4 * m)); // mature: every append completes a window
        }
        // streams 18, 19 stay empty (warm-up: admit returns None)
        let xs: Vec<f64> = (0..20).map(|_| rng.gauss()).collect();
        let mut members: Vec<(&mut Stampi<f64>, f64)> = streams
            .iter_mut()
            .zip(xs.iter().copied())
            .map(|(s, x)| (s, x))
            .collect();
        let report = append_group(&mut members);
        assert_eq!(report.widths, vec![8, 8, 2]);
        assert_eq!(report.windows.iter().filter(|w| w.is_some()).count(), 18);
        assert_eq!(report.windows[18], None);
        assert!(report.cells[18] == 0 && report.cells[19] == 0);
        assert!(report.cells[..18].iter().all(|&c| c > 0));
    }

    #[test]
    fn blocked_extend_bit_identical_across_reanchor_boundary() {
        // Regression: a tile straddling the REANCHOR_EVERY boundary would
        // admit its later windows with drifted rolling sums where the
        // per-append schedule has already recomputed them exactly —
        // extend() must cap the tile at the boundary.  The counter is the
        // only state the boundary depends on, so fast-forward it to a few
        // appends short (an offset-1e6 stream guarantees the recomputed
        // sums differ bitwise from the rolled ones).
        let m = 16;
        let mut rng = Rng::new(83);
        let t: Vec<f64> = (0..400).map(|_| 1.0e6 + rng.gauss()).collect();
        let mut a = Stampi::<f64>::new(StampiConfig::new(m)).unwrap();
        let mut b = Stampi::<f64>::new(StampiConfig::new(m)).unwrap();
        for &x in &t[..100] {
            a.append(x);
        }
        b.extend(&t[..100]);
        a.since_anchor = REANCHOR_EVERY - 5;
        b.since_anchor = REANCHOR_EVERY - 5;
        for &x in &t[100..] {
            a.append(x);
        }
        let mut pos = 100;
        while pos < t.len() {
            let chunk = rng.range(1, 3 * kernel::BAND).min(t.len() - pos);
            b.extend(&t[pos..pos + chunk]);
            pos += chunk;
        }
        // both re-anchored exactly once, at the same append
        assert_eq!(a.since_anchor, b.since_anchor);
        let bits = |e: &Stampi<f64>| -> (Vec<u64>, Vec<u64>, Vec<i64>) {
            (
                e.p.to_vec().iter().map(|x| x.to_bits()).collect(),
                e.q.to_vec().iter().map(|x| x.to_bits()).collect(),
                e.i.to_vec(),
            )
        };
        assert_eq!(bits(&a), bits(&b));
    }

    #[test]
    fn blocked_extend_on_bounded_history_brackets_the_append_path() {
        // Under a history bound, extend evicts at tile granularity: a
        // tile's later rows may see up to rows-1 extra just-evicted
        // candidates (true distances).  The blocked profile must
        // therefore sit between the unbounded profile and the
        // per-append bounded one, and all bounded invariants must hold
        // across the repeated compactions of the minimal legal bound.
        let m = 16;
        let excl = 4; // default m/4
        let h = m + excl; // minimal legal bound: compacts every ~h appends
        let mut rng = Rng::new(82);
        let t: Vec<f64> = rng.gauss_vec(700);
        let full = feed(&t, StampiConfig::new(m));
        let per_append = feed(&t, StampiConfig::new(m).with_max_history(h));
        let mut blocked = Stampi::<f64>::new(StampiConfig::new(m).with_max_history(h)).unwrap();
        let mut pos = 0;
        while pos < t.len() {
            let chunk = rng.range(1, 3 * kernel::BAND).min(t.len() - pos);
            blocked.extend(&t[pos..pos + chunk]);
            pos += chunk;
        }
        assert_eq!(blocked.num_windows(), per_append.num_windows());
        assert_eq!(blocked.first_window(), per_append.first_window());
        assert_eq!(blocked.retained_windows(), excl + 1);
        let fp = full.profile();
        let ap = per_append.profile();
        let bp = blocked.profile();
        let base = blocked.first_window();
        for r in 0..bp.len() {
            let w = base + r;
            // more candidates can only tighten, never loosen...
            assert!(bp.p[r] <= ap.p[r] + 1e-12, "window {w} vs per-append");
            // ...and bounded histories only ever miss pairs
            assert!(bp.p[r] >= fp.p[w] - 1e-9, "window {w} vs unbounded");
            // snapshot self-consistency (rebased, in-range neighbors)
            assert!(bp.i[r] < bp.len() as i64, "window {w} neighbor range");
        }
    }

    #[test]
    fn state_roundtrip_continues_bit_identically() {
        // THE durability pin at engine level: snapshot mid-stream, rebuild
        // from the (encoded) state, continue appending on both sessions —
        // every observable must stay bit-equal to the uninterrupted run,
        // across precisions, history bounds, and chunked extends.
        check("stampi-state-bits", 6, |rng: &mut Rng| {
            let m = rng.range(4, 32);
            let n = rng.range(6 * m, 700);
            let cut = rng.range(2 * m, n - m);
            let bounded = rng.range(0, 2) == 1;
            let mut cfg = StampiConfig::new(m);
            if bounded {
                cfg = cfg.with_max_history(rng.range(m + m / 4 + 1, 4 * m));
            }
            let t: Vec<f64> = rng.gauss_vec(n);
            let mut live = Stampi::<f64>::new(cfg).unwrap();
            live.extend(&t[..cut]);

            let mut bytes = Vec::new();
            live.state().encode(&mut bytes);
            let mut restored =
                Stampi::<f64>::from_state(SessionState::decode(&bytes).unwrap()).unwrap();

            let mut pos = cut;
            while pos < n {
                let chunk = rng.range(1, 50).min(n - pos);
                live.extend(&t[pos..pos + chunk]);
                restored.extend(&t[pos..pos + chunk]);
                pos += chunk;
            }
            let bits = |e: &Stampi<f64>| -> (Vec<u64>, Vec<u64>, Vec<i64>, u64, u64, u32) {
                (
                    e.p.to_vec().iter().map(|x| x.to_bits()).collect(),
                    e.q.to_vec().iter().map(|x| x.to_bits()).collect(),
                    e.i.to_vec(),
                    e.s.to_bits(),
                    e.s2.to_bits(),
                    e.since_anchor,
                )
            };
            assert_eq!(bits(&live), bits(&restored), "m={m} n={n} cut={cut}");
            assert_eq!(live.work(), restored.work());
            assert_eq!(live.first_window(), restored.first_window());
        });
    }

    #[test]
    fn f32_state_roundtrip_is_bit_exact() {
        // elements travel as f64 bit patterns; f32 -> f64 -> f32 is exact
        let mut rng = Rng::new(91);
        let t32: Vec<f32> = rng.gauss_vec(400).iter().map(|&x| x as f32).collect();
        let mut live = Stampi::<f32>::new(StampiConfig::new(16)).unwrap();
        live.extend(&t32[..250]);
        let mut bytes = Vec::new();
        live.state().encode(&mut bytes);
        let mut restored =
            Stampi::<f32>::from_state(SessionState::<f32>::decode(&bytes).unwrap()).unwrap();
        live.extend(&t32[250..]);
        restored.extend(&t32[250..]);
        let bits = |e: &Stampi<f32>| -> Vec<u32> {
            e.p.to_vec().iter().map(|x| x.to_bits()).collect()
        };
        assert_eq!(bits(&live), bits(&restored));
        assert_eq!(live.s.to_bits(), restored.s.to_bits());
    }

    #[test]
    fn state_codec_rejects_corruption() {
        let mut rng = Rng::new(92);
        let mut eng = Stampi::<f64>::new(StampiConfig::new(8)).unwrap();
        eng.extend(&rng.gauss_vec(100));
        let mut bytes = Vec::new();
        eng.state().encode(&mut bytes);
        // wrong precision: the dtype tag must refuse a cross decode
        assert!(SessionState::<f32>::decode(&bytes).is_err());
        // truncation and trailing garbage are structural errors
        assert!(SessionState::<f64>::decode(&bytes[..bytes.len() - 1]).is_err());
        assert!(SessionState::<f64>::decode(&bytes[..20]).is_err());
        let mut longer = bytes.clone();
        longer.push(0);
        assert!(SessionState::<f64>::decode(&longer).is_err());
        // semantic corruption: from_state refuses misaligned windows
        let mut st = eng.state();
        st.first_window += 1;
        assert!(Stampi::from_state(st).is_err());
        let mut st = eng.state();
        st.q.pop();
        assert!(Stampi::from_state(st).is_err());
        let mut st = eng.state();
        st.s = f64::NAN;
        assert!(Stampi::from_state(st).is_err());
        // the untouched state still restores
        assert!(Stampi::from_state(eng.state()).is_ok());
    }

    #[test]
    fn finds_planted_motif_incrementally() {
        let (t, ev) = generate_with_event::<f64>(Pattern::PlantedMotif, 2048, 13);
        let (a, b) = match ev {
            PlantedEvent::Motif { a, b, .. } => (a, b),
            _ => unreachable!(),
        };
        let eng = feed(&t, StampiConfig::new(32));
        let mp = eng.profile();
        assert!(mp.p[a] < 1e-6, "p[a] = {}", mp.p[a]);
        assert_eq!(mp.i[a], b as i64);
    }

    #[test]
    fn constant_stream_does_not_nan() {
        let eng = feed(&[5.0; 256], StampiConfig::new(16));
        let mp = eng.profile();
        let expect = (2.0 * 16.0f64).sqrt(); // Eq. 1 degeneracy convention
        for &d in &mp.p {
            assert!(d.is_finite());
            assert!((d - expect).abs() < 1e-9, "{d}");
        }
    }

    #[test]
    fn custom_exclusion_respected() {
        let mut rng = Rng::new(73);
        let t: Vec<f64> = rng.gauss_vec(240);
        let eng = feed(&t, StampiConfig::new(10).with_excl(7));
        let mp = eng.profile();
        for (r, &j) in mp.i.iter().enumerate() {
            if j >= 0 {
                assert!((r as i64 - j).unsigned_abs() >= 7);
            }
        }
    }

    #[test]
    fn bounded_history_is_upper_bound_with_true_distances() {
        let mut rng = Rng::new(74);
        let t: Vec<f64> = rng.gauss_vec(400);
        let m = 16;
        let bounded = feed(&t, StampiConfig::new(m).with_max_history(120));
        let full = feed(&t, StampiConfig::new(m));
        let fp = full.profile();
        let bp = bounded.profile();
        let base = bounded.first_window();
        assert!(base > 0, "history bound never kicked in");
        assert_eq!(base + bp.len(), full.num_windows());
        let mut named_neighbors = 0;
        for r in 0..bp.len() {
            let w = base + r;
            // (a) bounded can only miss pairs, never invent them
            assert!(bp.p[r] >= fp.p[w] - 1e-9, "window {w}");
            // (b) neighbor indices are snapshot positions; every named
            //     neighbor gives back a true pairwise distance on the
            //     full stream (evicted neighbors report -1 but keep
            //     their recorded distance)
            if bp.i[r] >= 0 && bp.p[r].is_finite() {
                let nb = base + bp.i[r] as usize;
                assert!((bp.i[r] as usize) < bp.len(), "neighbor not in snapshot");
                let d = brute_pair(&t, w, nb, m);
                assert!((bp.p[r] - d).abs() < 1e-9, "window {w} vs neighbor {nb}");
                named_neighbors += 1;
            }
        }
        assert!(named_neighbors > 0, "no in-snapshot neighbor survived");
    }

    #[test]
    fn bounded_snapshot_is_safe_for_downstream_consumers() {
        // regression: neighbor indices used to be absolute, which made
        // topk's exclusion-zone masking slice out of bounds on bounded
        // snapshots; rebased indices must keep every consumer in range
        let mut rng = Rng::new(79);
        let t: Vec<f64> = rng.gauss_vec(3000);
        let m = 16;
        let bounded = feed(&t, StampiConfig::new(m).with_max_history(400));
        let mp = bounded.profile();
        for (r, &j) in mp.i.iter().enumerate() {
            assert!(j < mp.len() as i64, "neighbor {j} out of snapshot at {r}");
        }
        let motifs = crate::mp::topk::top_motifs(&mp, 3);
        let discords = crate::mp::topk::top_discords(&mp, 3);
        assert!(!motifs.is_empty() && !discords.is_empty());
        for ev in motifs.iter().chain(&discords) {
            assert!(ev.index < mp.len());
        }
    }

    #[test]
    fn history_bound_larger_than_stream_is_exact() {
        let mut rng = Rng::new(75);
        let t: Vec<f64> = rng.gauss_vec(300);
        let a = feed(&t, StampiConfig::new(12).with_max_history(10_000));
        let b = feed(&t, StampiConfig::new(12));
        assert_eq!(a.first_window(), 0);
        assert!(a.profile().max_abs_diff(&b.profile()) < 1e-12);
        assert_eq!(a.profile().i, b.profile().i);
    }

    #[test]
    fn prop_bounded_memory_and_exactness_on_suffix_pairs() {
        check("stampi-bounded", 6, |rng: &mut Rng| {
            let m = rng.range(4, 12);
            let h = rng.range(3 * m, 6 * m);
            let n = rng.range(4 * h, 6 * h);
            let t: Vec<f64> = rng.gauss_vec(n);
            let mut eng = Stampi::new(StampiConfig::new(m).with_max_history(h)).unwrap();
            for &x in &t {
                eng.append(x);
                assert!(eng.retained_windows() <= h, "window state leaked");
            }
            assert_eq!(eng.num_windows(), n - m + 1);
            assert!(eng.first_window() >= n - h);
        });
    }

    #[test]
    fn config_rejections() {
        assert!(Stampi::<f64>::new(StampiConfig::new(2)).is_err());
        // m=16, excl=4: needs at least m + excl = 20 samples of history
        // (the same boundary batch MpConfig::validate accepts: nw > excl)
        assert!(Stampi::<f64>::new(StampiConfig::new(16).with_max_history(19)).is_err());
        assert!(Stampi::<f64>::new(StampiConfig::new(16).with_max_history(20)).is_ok());
    }

    #[test]
    fn minimal_history_survives_repeated_compactions_with_rebased_snapshots() {
        // The smallest legal bound, h == m + excl, keeps exactly
        // excl + 1 windows alive, so the ring compacts roughly every
        // `h` appends forever.  Across hundreds of compactions: appends
        // must never panic, every snapshot must rebase its positions to
        // first_window (self-consistent, in-range), and windows whose
        // recorded best neighbor has been evicted must report -1 while
        // keeping the (true, historical) distance.
        let m = 16;
        let excl = 4; // default m/4
        let h = m + excl;
        let mut eng = Stampi::<f64>::new(StampiConfig::new(m).with_max_history(h)).unwrap();
        let mut rng = Rng::new(80);
        let mut evicted_neighbor_seen = false;
        let mut in_snapshot_neighbor_seen = false;
        for (s, x) in rng.gauss_vec(600).into_iter().enumerate() {
            eng.append(x);
            if s + 1 < m {
                continue;
            }
            let mp = eng.profile();
            // snapshot indexing: position r == window first_window() + r
            assert_eq!(mp.len(), eng.retained_windows());
            assert_eq!(eng.first_window() + mp.len(), eng.num_windows());
            for (r, &j) in mp.i.iter().enumerate() {
                assert!(
                    (-1..mp.len() as i64).contains(&j),
                    "append {s}: neighbor {j} out of snapshot (len {})",
                    mp.len()
                );
                if j >= 0 {
                    // a named neighbor is in-snapshot and admissible
                    assert!((r as i64 - j).unsigned_abs() >= excl as u64);
                    in_snapshot_neighbor_seen = true;
                } else if mp.p[r].is_finite() {
                    evicted_neighbor_seen = true;
                }
            }
        }
        assert_eq!(eng.retained_windows(), excl + 1);
        assert!(eng.first_window() >= 600 - h, "compaction never engaged");
        // at h == m + excl only the (first, last) retained pair is
        // admissible, so most finite entries must have outlived their
        // neighbor — and some must still name one
        assert!(evicted_neighbor_seen, "no evicted neighbor ever reported -1");
        assert!(in_snapshot_neighbor_seen, "no in-snapshot neighbor survived");
    }

    #[test]
    fn minimal_history_bound_still_admits_pairs() {
        // at the exact minimum h = m + excl, the engine must keep finding
        // (finite) profile values rather than degenerating to all-inf
        let mut rng = Rng::new(78);
        let m = 16;
        let h = m + 4; // excl defaults to 4
        let mut eng = Stampi::<f64>::new(StampiConfig::new(m).with_max_history(h)).unwrap();
        for &x in rng.gauss_vec(200).iter() {
            eng.append(x);
        }
        let mp = eng.profile();
        assert!(mp.p.iter().any(|d| d.is_finite()), "no admissible pair survived");
    }

    #[test]
    fn rolling_sums_reanchored_against_drift_on_offset_stream() {
        // Regression for catastrophic cancellation: on a stream sitting at
        // offset 1e6, s2 ≈ m·1e12 has ulp ≈ 2e-3 while the window variance
        // is O(1).  The +x²/−old² slide random-walks s2 by ~ulp per append,
        // so after 1e6 appends the unanchored drift *swamps the variance*:
        // measured on this exact waveform, the stored sd reaches 100%
        // relative error (var clamps to 0, windows degrade to sd = 0, i.e.
        // the constant-window degeneracy) while re-anchoring at every ring
        // compaction holds it at ~1.4e-2.  The bounded history keeps each
        // append O(history), so the million-sample run stays fast.
        let m = 16;
        let h = 64; // compaction (and thus re-anchoring) every ~65 appends
        let n = 1_000_000usize;
        let mut eng = Stampi::<f64>::new(StampiConfig::new(m).with_max_history(h)).unwrap();
        for i in 0..n {
            let x = 1.0e6 + (i as f64 * 0.05).sin() + 0.3 * (i as f64 * 0.73).sin();
            eng.append(x);
        }
        assert!(eng.first_window() >= n - h, "history bound never engaged");
        let mut max_rel_sd_err = 0.0f64;
        let mut max_rel_zb_err = 0.0f64;
        let sqrt2 = std::f64::consts::SQRT_2;
        for w in eng.za.first_index()..eng.za.next_index() {
            let ws = eng.t.slice(w, w + m);
            let mu: f64 = ws.iter().sum::<f64>() / m as f64;
            let sd = (ws.iter().map(|&v| (v - mu) * (v - mu)).sum::<f64>() / m as f64)
                .max(0.0)
                .sqrt();
            assert!(sd > 0.0, "waveform window degenerated");
            // the retained state is the folded factors: za = sqrt(2)/sd
            // carries the same relative error as the old 1/(m·sd)
            // premultiplier did, and zb = sqrt(2m)·mu/sd additionally
            // pins the rolling-mean drift (mu ~ 1e6 here, so a mean
            // error of 1e-6 absolute is ~1e-12 relative on zb)
            let za_exact = sqrt2 / sd;
            let zb_exact = (2.0 * m as f64).sqrt() * mu / sd;
            max_rel_sd_err =
                max_rel_sd_err.max((eng.za.get(w) - za_exact).abs() / za_exact);
            max_rel_zb_err =
                max_rel_zb_err.max((eng.zb.get(w) - zb_exact).abs() / zb_exact.abs());
        }
        assert!(
            max_rel_sd_err < 0.05,
            "stored sqrt(2)/sd drifted {max_rel_sd_err:.3e} relative (unanchored \
             rolling sums reach 1.0 here)"
        );
        assert!(
            max_rel_zb_err < 0.05,
            "stored sqrt(2m)·mu/sd drifted {max_rel_zb_err:.3e} relative"
        );
    }

    #[test]
    fn f32_stream_tracks_f32_batch() {
        // single-precision streaming must agree with the single-precision
        // batch engine (both run the same folded kernel cell math in f32;
        // only the f64 stat accumulation order differs slightly)
        let mut rng = Rng::new(76);
        let t32: Vec<f32> = rng.gauss_vec(300).iter().map(|&x| x as f32).collect();
        let eng = {
            let mut e = Stampi::<f32>::new(StampiConfig::new(16)).unwrap();
            e.extend(&t32);
            e
        };
        let want = stomp::matrix_profile(&t32, MpConfig::new(16)).unwrap();
        assert!(eng.profile().max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn matches_brute_at_final_prefix() {
        let mut rng = Rng::new(77);
        let t: Vec<f64> = rng.gauss_vec(256);
        let eng = feed(&t, StampiConfig::new(8));
        let want = brute::matrix_profile(&t, MpConfig::new(8)).unwrap();
        assert!(eng.profile().max_abs_diff(&want) < 1e-7);
    }

    fn brute_pair(t: &[f64], a: usize, b: usize, m: usize) -> f64 {
        let z = |s: usize| -> Vec<f64> {
            let w = &t[s..s + m];
            let mu = w.iter().sum::<f64>() / m as f64;
            let sig = (w.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / m as f64).sqrt();
            if sig > 0.0 {
                w.iter().map(|x| (x - mu) / sig).collect()
            } else {
                vec![0.0; m]
            }
        };
        let (za, zb) = (z(a), z(b));
        za.iter()
            .zip(&zb)
            .map(|(x, y)| (x - y) * (x - y))
            .sum::<f64>()
            .sqrt()
    }
}
