//! STAMPI — exact *streaming* matrix profile under `append(sample)`.
//!
//! The batch engines ([`crate::mp::scrimp`], [`crate::mp::stomp`], …) walk
//! the whole distance matrix; the flagship applications the paper motivates
//! (arrhythmia review, seismic monitoring) instead see samples *arrive*.
//! Yeh's incremental formulation (STAMPI, arXiv 1811.03064 §STAMPI) keeps
//! the profile exact under appends at O(n) per sample: when sample `t[n-1]`
//! arrives it creates exactly one new window `k = n - m`, and the dot
//! products of `k` against every earlier window follow from the previous
//! append's row by the same Eq. 2 recurrence STOMP uses row-wise:
//!
//! ```text
//! q_new[j] = q_old[j-1] - t[j-1]·t[k-1] + t[j+m-1]·t[k+m-1]
//! ```
//!
//! with one direct O(m) dot product at the oldest retained window.  One
//! [`crate::mp::znorm_dist`] evaluation per admissible pair then updates
//! both `P[j]` (old window gained a new candidate neighbor) and `P[k]`
//! (new window scans all of history) — the profile after every append is
//! bit-equal in structure to a batch run over the prefix (the differential
//! property test in `rust/tests/cross_impl.rs` pins this at < 1e-6 against
//! the brute-force oracle at every step).
//!
//! ## Bounded history
//!
//! With [`StampiConfig::with_max_history`] the engine keeps only the last
//! `H` samples ([`crate::timeseries::stream::RingVec`] eviction) and the
//! profile entries of the windows still inside them — O(H) memory on an
//! unbounded stream.  Semantics follow streaming practice: a retained
//! window's profile value may still *record* a distance to an evicted
//! neighbor (computed while that neighbor was live; it remains a true
//! pairwise distance), but new windows can only match retained history, so
//! every bounded-profile value upper-bounds the unbounded one.  Snapshot
//! positions are relative to [`Stampi::first_window`] and neighbor indices
//! are rebased to match (an evicted neighbor reports `-1` — see
//! [`Stampi::profile`]).

use crate::mp::{znorm_dist, MatrixProfile, WorkStats};
use crate::timeseries::default_exclusion;
use crate::timeseries::stream::RingVec;
use crate::Real;

/// Configuration of a streaming matrix profile session.
#[derive(Clone, Copy, Debug)]
pub struct StampiConfig {
    /// Window (subsequence) length `m`.
    pub m: usize,
    /// Exclusion-zone radius; `None` = paper default `m/4`.
    pub excl: Option<usize>,
    /// Retain only the last `max_history` samples (`None` = unbounded).
    pub max_history: Option<usize>,
}

impl StampiConfig {
    pub fn new(m: usize) -> Self {
        StampiConfig { m, excl: None, max_history: None }
    }

    pub fn with_excl(mut self, excl: usize) -> Self {
        self.excl = Some(excl);
        self
    }

    pub fn with_max_history(mut self, samples: usize) -> Self {
        self.max_history = Some(samples);
        self
    }

    pub fn exclusion(&self) -> usize {
        self.excl.unwrap_or_else(|| default_exclusion(self.m))
    }

    /// Validate the configuration (the streaming analogue of
    /// [`crate::mp::MpConfig::validate`]; there is no length to check up
    /// front — the profile simply stays empty until `m` samples arrived).
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.m >= 3, "window length m={} too small (min 3)", self.m);
        if let Some(h) = self.max_history {
            // m + excl samples hold windows 0..=excl, whose pair (0, excl)
            // is the first admissible one — same bound as the batch
            // `MpConfig::validate` (nw > excl).
            let need = self.m + self.exclusion();
            anyhow::ensure!(
                h >= need,
                "max_history={h} too small: m={} with excl={} needs at least {need} \
                 samples to ever hold one admissible pair",
                self.m,
                self.exclusion()
            );
        }
        Ok(())
    }
}

/// What one [`Stampi::append`] did, when it completed a window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AppendOutcome {
    /// Absolute index of the window this sample completed.
    pub window: usize,
    /// First column of the incremental row (oldest retained window).
    pub row_start: usize,
    /// Admissible cells evaluated in this row (0 while the stream is
    /// shorter than one exclusion zone).
    pub row_cells: u64,
}

/// The streaming engine: an exact matrix profile maintained under appends.
#[derive(Clone, Debug)]
pub struct Stampi<T> {
    m: usize,
    excl: usize,
    max_history: Option<usize>,
    /// Raw samples (absolute sample indexing).
    t: RingVec<T>,
    /// Per-window statistics (absolute window indexing; the standard
    /// deviation itself is folded into `inv = 1/(m*sigma)` — the distance
    /// path never needs sigma alone).
    mu: RingVec<T>,
    inv: RingVec<T>,
    /// `q[j]` = dot product of window `j` with the latest window.
    q: RingVec<T>,
    /// The live profile (true distances, not squared) and neighbor indices.
    p: RingVec<T>,
    i: RingVec<i64>,
    /// Rolling sums over the last `m` samples (f64 like the batch
    /// [`crate::timeseries::sliding_stats`], so f32 streams with large
    /// offsets keep their variance digits).  Unlike the batch path — which
    /// sums each window independently — these slide forever, and the
    /// `+x²/−old²` updates random-walk away from the true sums (on an
    /// offset-1e6 stream, `s2 ≈ m·1e12` has ulp ≈ 2e-3, so after ~1e6
    /// appends the drift *exceeds the O(1) signal variance* and the
    /// clamped `var = max(s2/m − mean², 0)` collapses windows to sd = 0).
    /// They are therefore re-anchored — recomputed exactly over the
    /// current window — at every ring compaction (every ~history appends
    /// on a bounded stream) and at least every
    /// [`REANCHOR_EVERY`] appends regardless.
    s: f64,
    s2: f64,
    /// Appends since the rolling sums were last recomputed exactly.
    since_anchor: u32,
    work: WorkStats,
}

/// Unconditional re-anchoring period for the rolling sums (appends).  The
/// drift between anchors is a random walk of O(ulp(s2)) steps, so 2^16
/// appends keep the relative sd error below ~3e-2 even at offset 1e6
/// (measured by the drift regression test below at its bounded — much
/// tighter — anchoring cadence); the amortized cost is O(m / 65536) per
/// append, i.e. nothing.
const REANCHOR_EVERY: u32 = 1 << 16;

impl<T: Real> Stampi<T> {
    pub fn new(cfg: StampiConfig) -> crate::Result<Self> {
        cfg.validate()?;
        Ok(Stampi {
            m: cfg.m,
            excl: cfg.exclusion(),
            max_history: cfg.max_history,
            t: RingVec::new(),
            mu: RingVec::new(),
            inv: RingVec::new(),
            q: RingVec::new(),
            p: RingVec::new(),
            i: RingVec::new(),
            s: 0.0,
            s2: 0.0,
            since_anchor: 0,
            work: WorkStats::default(),
        })
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn exclusion(&self) -> usize {
        self.excl
    }

    /// Total samples appended so far (absolute stream length).
    pub fn len(&self) -> usize {
        self.t.next_index()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total windows completed so far (absolute count).
    pub fn num_windows(&self) -> usize {
        self.p.next_index()
    }

    /// Absolute index of the oldest retained window (0 when unbounded).
    pub fn first_window(&self) -> usize {
        self.p.first_index()
    }

    /// Retained window count (== [`Self::num_windows`] when unbounded).
    pub fn retained_windows(&self) -> usize {
        self.p.len()
    }

    /// Aggregate functional work — feeds the timing/energy models in
    /// [`crate::sim`] exactly like the batch engines' accounting.
    pub fn work(&self) -> WorkStats {
        self.work
    }

    /// Append one sample.  Returns `Some` once the sample completes a
    /// window (i.e. from the `m`-th sample on).
    pub fn append(&mut self, x: T) -> Option<AppendOutcome> {
        let m = self.m;
        self.t.push(x);
        let n = self.t.next_index();

        // Rolling statistics over the last m samples.
        let xf = x.to_f64s();
        self.s += xf;
        self.s2 += xf * xf;
        if n > m {
            let old = self.t.get(n - 1 - m).to_f64s();
            self.s -= old;
            self.s2 -= old * old;
        }
        if n < m {
            return None;
        }

        // Window k = n - m is now complete; push its statistics.
        let k = n - m;
        let mf = m as f64;
        let mean = self.s / mf;
        let var = (self.s2 / mf - mean * mean).max(0.0);
        let sd = var.sqrt();
        self.mu.push(T::of_f64(mean));
        self.inv.push(if sd > 0.0 { T::of_f64(1.0 / (mf * sd)) } else { T::zero() });
        self.p.push(T::infinity());
        self.i.push(-1);

        if k == 0 {
            // First window: seed q with its self-dot (feeds the recurrence
            // of the next append; no admissible pair yet).
            let w = self.t.slice(0, m);
            self.q.push(dot(w, w));
            self.work.first_dots += 1;
            return Some(AppendOutcome { window: 0, row_start: 0, row_cells: 0 });
        }

        // Advance q in place: entering this append, q[j] = dot(window j,
        // window k-1) for retained j; leaving it, q[j] = dot(window j,
        // window k).  Walking j downward keeps q[j-1] at its old value
        // until consumed (same trick as STOMP's row walk).
        let j0 = self.q.first_index();
        self.q.push(T::zero()); // slot for window k
        let tk1 = self.t.get(k - 1);
        let tkm1 = self.t.get(k + m - 1);
        for j in ((j0 + 1)..=k).rev() {
            let v = self.q.get(j - 1) - self.t.get(j - 1) * tk1 + self.t.get(j + m - 1) * tkm1;
            self.q.set(j, v);
        }
        let q0 = dot(self.t.slice(j0, j0 + m), self.t.slice(k, k + m));
        self.q.set(j0, q0);
        self.work.first_dots += 1;
        self.work.diagonals += 1;

        // Profile row: window k against every admissible retained window.
        let mut row_cells = 0u64;
        if k >= self.excl + j0 {
            let hi = k - self.excl; // inclusive
            let mu_k = self.mu.get(k);
            let inv_k = self.inv.get(k);
            let mut pk = self.p.get(k);
            let mut ik = self.i.get(k);
            for j in j0..=hi {
                let d = znorm_dist(self.q.get(j), m, self.mu.get(j), self.inv.get(j), mu_k, inv_k);
                if d < self.p.get(j) {
                    self.p.set(j, d);
                    self.i.set(j, k as i64);
                }
                if d < pk {
                    pk = d;
                    ik = j as i64;
                }
            }
            self.p.set(k, pk);
            self.i.set(k, ik);
            row_cells = (hi + 1 - j0) as u64;
            self.work.cells += row_cells;
            self.work.updates += 2 * row_cells;
        }

        // Bounded history: evict samples beyond the bound and the windows
        // no longer fully inside the retained samples.
        let mut compacted = false;
        if let Some(h) = self.max_history {
            if self.t.len() > h {
                let sample_base = n - h;
                compacted = self.t.evict_to(sample_base);
                let window_base = sample_base.min(k);
                self.mu.evict_to(window_base);
                self.inv.evict_to(window_base);
                self.q.evict_to(window_base);
                self.p.evict_to(window_base);
                self.i.evict_to(window_base);
            }
        }

        // Re-anchor the rolling sums (see the field docs): recompute them
        // exactly over the current last-m window on every ring compaction
        // and at least every REANCHOR_EVERY appends, so slide drift can
        // never accumulate past one anchoring period.
        self.since_anchor += 1;
        if compacted || self.since_anchor >= REANCHOR_EVERY {
            let mut s = 0.0;
            let mut s2 = 0.0;
            for &v in self.t.slice(n - m, n) {
                let vf = v.to_f64s();
                s += vf;
                s2 += vf * vf;
            }
            self.s = s;
            self.s2 = s2;
            self.since_anchor = 0;
        }

        Some(AppendOutcome { window: k, row_start: j0, row_cells })
    }

    /// Append a batch of samples; returns how many windows were completed.
    pub fn extend(&mut self, xs: &[T]) -> usize {
        xs.iter().filter(|&&x| self.append(x).is_some()).count()
    }

    /// Snapshot the live profile.  Position `r` of the result is window
    /// `first_window() + r`, and neighbor indices are rebased to the same
    /// positions, so the snapshot is a self-consistent [`MatrixProfile`]
    /// that every downstream consumer ([`crate::mp::topk`], CSV dumps, …)
    /// can index directly.  A neighbor that has been *evicted* cannot be
    /// named in-snapshot: its entry keeps the (true, historical) distance
    /// but reports index `-1`.  With unbounded history the rebasing is the
    /// identity and `-1` only ever means "no admissible pair yet".
    pub fn profile(&self) -> MatrixProfile<T> {
        let base = self.p.first_index() as i64;
        let i = self
            .i
            .to_vec()
            .iter()
            .map(|&j| if j >= base { j - base } else { -1 })
            .collect();
        MatrixProfile {
            p: self.p.to_vec(),
            i,
            m: self.m,
            excl: self.excl,
        }
    }
}

#[inline]
fn dot<T: Real>(a: &[T], b: &[T]) -> T {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mp::{brute, stomp, total_cells, MpConfig};
    use crate::prop::{check, Rng};
    use crate::timeseries::generator::{generate_with_event, Pattern, PlantedEvent};

    fn feed(t: &[f64], cfg: StampiConfig) -> Stampi<f64> {
        let mut eng = Stampi::new(cfg).unwrap();
        eng.extend(t);
        eng
    }

    #[test]
    fn matches_batch_on_full_series() {
        let mut rng = Rng::new(71);
        let t: Vec<f64> = rng.gauss_vec(500);
        let eng = feed(&t, StampiConfig::new(16));
        let want = stomp::matrix_profile(&t, MpConfig::new(16)).unwrap();
        let got = eng.profile();
        assert_eq!(got.len(), want.len());
        assert!(got.max_abs_diff(&want) < 1e-9, "{}", got.max_abs_diff(&want));
    }

    #[test]
    fn no_window_before_m_samples() {
        let mut eng = Stampi::<f64>::new(StampiConfig::new(8)).unwrap();
        for s in 0..7 {
            assert!(eng.append(s as f64).is_none(), "sample {s}");
        }
        let out = eng.append(7.0).unwrap();
        assert_eq!(out.window, 0);
        assert_eq!(eng.num_windows(), 1);
        assert!(eng.profile().p[0].is_infinite());
    }

    #[test]
    fn work_stats_count_each_pair_once() {
        let mut rng = Rng::new(72);
        let t: Vec<f64> = rng.gauss_vec(300);
        let eng = feed(&t, StampiConfig::new(12));
        let nw = 300 - 12 + 1;
        let excl = 3;
        assert_eq!(eng.work().cells, total_cells(nw, excl));
        assert_eq!(eng.work().updates, 2 * eng.work().cells);
        // one O(m) seed dot per completed window
        assert_eq!(eng.work().first_dots, nw as u64);
    }

    #[test]
    fn finds_planted_motif_incrementally() {
        let (t, ev) = generate_with_event::<f64>(Pattern::PlantedMotif, 2048, 13);
        let (a, b) = match ev {
            PlantedEvent::Motif { a, b, .. } => (a, b),
            _ => unreachable!(),
        };
        let eng = feed(&t, StampiConfig::new(32));
        let mp = eng.profile();
        assert!(mp.p[a] < 1e-6, "p[a] = {}", mp.p[a]);
        assert_eq!(mp.i[a], b as i64);
    }

    #[test]
    fn constant_stream_does_not_nan() {
        let eng = feed(&[5.0; 256], StampiConfig::new(16));
        let mp = eng.profile();
        let expect = (2.0 * 16.0f64).sqrt(); // Eq. 1 degeneracy convention
        for &d in &mp.p {
            assert!(d.is_finite());
            assert!((d - expect).abs() < 1e-9, "{d}");
        }
    }

    #[test]
    fn custom_exclusion_respected() {
        let mut rng = Rng::new(73);
        let t: Vec<f64> = rng.gauss_vec(240);
        let eng = feed(&t, StampiConfig::new(10).with_excl(7));
        let mp = eng.profile();
        for (r, &j) in mp.i.iter().enumerate() {
            if j >= 0 {
                assert!((r as i64 - j).unsigned_abs() >= 7);
            }
        }
    }

    #[test]
    fn bounded_history_is_upper_bound_with_true_distances() {
        let mut rng = Rng::new(74);
        let t: Vec<f64> = rng.gauss_vec(400);
        let m = 16;
        let bounded = feed(&t, StampiConfig::new(m).with_max_history(120));
        let full = feed(&t, StampiConfig::new(m));
        let fp = full.profile();
        let bp = bounded.profile();
        let base = bounded.first_window();
        assert!(base > 0, "history bound never kicked in");
        assert_eq!(base + bp.len(), full.num_windows());
        let mut named_neighbors = 0;
        for r in 0..bp.len() {
            let w = base + r;
            // (a) bounded can only miss pairs, never invent them
            assert!(bp.p[r] >= fp.p[w] - 1e-9, "window {w}");
            // (b) neighbor indices are snapshot positions; every named
            //     neighbor gives back a true pairwise distance on the
            //     full stream (evicted neighbors report -1 but keep
            //     their recorded distance)
            if bp.i[r] >= 0 && bp.p[r].is_finite() {
                let nb = base + bp.i[r] as usize;
                assert!((bp.i[r] as usize) < bp.len(), "neighbor not in snapshot");
                let d = brute_pair(&t, w, nb, m);
                assert!((bp.p[r] - d).abs() < 1e-9, "window {w} vs neighbor {nb}");
                named_neighbors += 1;
            }
        }
        assert!(named_neighbors > 0, "no in-snapshot neighbor survived");
    }

    #[test]
    fn bounded_snapshot_is_safe_for_downstream_consumers() {
        // regression: neighbor indices used to be absolute, which made
        // topk's exclusion-zone masking slice out of bounds on bounded
        // snapshots; rebased indices must keep every consumer in range
        let mut rng = Rng::new(79);
        let t: Vec<f64> = rng.gauss_vec(3000);
        let m = 16;
        let bounded = feed(&t, StampiConfig::new(m).with_max_history(400));
        let mp = bounded.profile();
        for (r, &j) in mp.i.iter().enumerate() {
            assert!(j < mp.len() as i64, "neighbor {j} out of snapshot at {r}");
        }
        let motifs = crate::mp::topk::top_motifs(&mp, 3);
        let discords = crate::mp::topk::top_discords(&mp, 3);
        assert!(!motifs.is_empty() && !discords.is_empty());
        for ev in motifs.iter().chain(&discords) {
            assert!(ev.index < mp.len());
        }
    }

    #[test]
    fn history_bound_larger_than_stream_is_exact() {
        let mut rng = Rng::new(75);
        let t: Vec<f64> = rng.gauss_vec(300);
        let a = feed(&t, StampiConfig::new(12).with_max_history(10_000));
        let b = feed(&t, StampiConfig::new(12));
        assert_eq!(a.first_window(), 0);
        assert!(a.profile().max_abs_diff(&b.profile()) < 1e-12);
        assert_eq!(a.profile().i, b.profile().i);
    }

    #[test]
    fn prop_bounded_memory_and_exactness_on_suffix_pairs() {
        check("stampi-bounded", 6, |rng: &mut Rng| {
            let m = rng.range(4, 12);
            let h = rng.range(3 * m, 6 * m);
            let n = rng.range(4 * h, 6 * h);
            let t: Vec<f64> = rng.gauss_vec(n);
            let mut eng = Stampi::new(StampiConfig::new(m).with_max_history(h)).unwrap();
            for &x in &t {
                eng.append(x);
                assert!(eng.retained_windows() <= h, "window state leaked");
            }
            assert_eq!(eng.num_windows(), n - m + 1);
            assert!(eng.first_window() >= n - h);
        });
    }

    #[test]
    fn config_rejections() {
        assert!(Stampi::<f64>::new(StampiConfig::new(2)).is_err());
        // m=16, excl=4: needs at least m + excl = 20 samples of history
        // (the same boundary batch MpConfig::validate accepts: nw > excl)
        assert!(Stampi::<f64>::new(StampiConfig::new(16).with_max_history(19)).is_err());
        assert!(Stampi::<f64>::new(StampiConfig::new(16).with_max_history(20)).is_ok());
    }

    #[test]
    fn minimal_history_survives_repeated_compactions_with_rebased_snapshots() {
        // The smallest legal bound, h == m + excl, keeps exactly
        // excl + 1 windows alive, so the ring compacts roughly every
        // `h` appends forever.  Across hundreds of compactions: appends
        // must never panic, every snapshot must rebase its positions to
        // first_window (self-consistent, in-range), and windows whose
        // recorded best neighbor has been evicted must report -1 while
        // keeping the (true, historical) distance.
        let m = 16;
        let excl = 4; // default m/4
        let h = m + excl;
        let mut eng = Stampi::<f64>::new(StampiConfig::new(m).with_max_history(h)).unwrap();
        let mut rng = Rng::new(80);
        let mut evicted_neighbor_seen = false;
        let mut in_snapshot_neighbor_seen = false;
        for (s, x) in rng.gauss_vec(600).into_iter().enumerate() {
            eng.append(x);
            if s + 1 < m {
                continue;
            }
            let mp = eng.profile();
            // snapshot indexing: position r == window first_window() + r
            assert_eq!(mp.len(), eng.retained_windows());
            assert_eq!(eng.first_window() + mp.len(), eng.num_windows());
            for (r, &j) in mp.i.iter().enumerate() {
                assert!(
                    (-1..mp.len() as i64).contains(&j),
                    "append {s}: neighbor {j} out of snapshot (len {})",
                    mp.len()
                );
                if j >= 0 {
                    // a named neighbor is in-snapshot and admissible
                    assert!((r as i64 - j).unsigned_abs() >= excl as u64);
                    in_snapshot_neighbor_seen = true;
                } else if mp.p[r].is_finite() {
                    evicted_neighbor_seen = true;
                }
            }
        }
        assert_eq!(eng.retained_windows(), excl + 1);
        assert!(eng.first_window() >= 600 - h, "compaction never engaged");
        // at h == m + excl only the (first, last) retained pair is
        // admissible, so most finite entries must have outlived their
        // neighbor — and some must still name one
        assert!(evicted_neighbor_seen, "no evicted neighbor ever reported -1");
        assert!(in_snapshot_neighbor_seen, "no in-snapshot neighbor survived");
    }

    #[test]
    fn minimal_history_bound_still_admits_pairs() {
        // at the exact minimum h = m + excl, the engine must keep finding
        // (finite) profile values rather than degenerating to all-inf
        let mut rng = Rng::new(78);
        let m = 16;
        let h = m + 4; // excl defaults to 4
        let mut eng = Stampi::<f64>::new(StampiConfig::new(m).with_max_history(h)).unwrap();
        for &x in rng.gauss_vec(200).iter() {
            eng.append(x);
        }
        let mp = eng.profile();
        assert!(mp.p.iter().any(|d| d.is_finite()), "no admissible pair survived");
    }

    #[test]
    fn rolling_sums_reanchored_against_drift_on_offset_stream() {
        // Regression for catastrophic cancellation: on a stream sitting at
        // offset 1e6, s2 ≈ m·1e12 has ulp ≈ 2e-3 while the window variance
        // is O(1).  The +x²/−old² slide random-walks s2 by ~ulp per append,
        // so after 1e6 appends the unanchored drift *swamps the variance*:
        // measured on this exact waveform, the stored sd reaches 100%
        // relative error (var clamps to 0, windows degrade to sd = 0, i.e.
        // the constant-window degeneracy) while re-anchoring at every ring
        // compaction holds it at ~1.4e-2.  The bounded history keeps each
        // append O(history), so the million-sample run stays fast.
        let m = 16;
        let h = 64; // compaction (and thus re-anchoring) every ~65 appends
        let n = 1_000_000usize;
        let mut eng = Stampi::<f64>::new(StampiConfig::new(m).with_max_history(h)).unwrap();
        for i in 0..n {
            let x = 1.0e6 + (i as f64 * 0.05).sin() + 0.3 * (i as f64 * 0.73).sin();
            eng.append(x);
        }
        assert!(eng.first_window() >= n - h, "history bound never engaged");
        let mut max_mu_err = 0.0f64;
        let mut max_rel_sd_err = 0.0f64;
        for w in eng.mu.first_index()..eng.mu.next_index() {
            let ws = eng.t.slice(w, w + m);
            let mu: f64 = ws.iter().sum::<f64>() / m as f64;
            let sd = (ws.iter().map(|&v| (v - mu) * (v - mu)).sum::<f64>() / m as f64)
                .max(0.0)
                .sqrt();
            assert!(sd > 0.0, "waveform window degenerated");
            let inv_exact = 1.0 / (m as f64 * sd);
            max_mu_err = max_mu_err.max((eng.mu.get(w) - mu).abs());
            max_rel_sd_err =
                max_rel_sd_err.max((eng.inv.get(w) - inv_exact).abs() / inv_exact);
        }
        assert!(
            max_rel_sd_err < 0.05,
            "stored 1/(m·sd) drifted {max_rel_sd_err:.3e} relative (unanchored \
             rolling sums reach 1.0 here)"
        );
        assert!(max_mu_err < 1e-7, "stored mean drifted {max_mu_err:.3e}");
    }

    #[test]
    fn f32_stream_tracks_f32_batch() {
        // single-precision streaming must agree with the single-precision
        // batch engine (both run the same Eq. 2 diagonal chains in f32;
        // only the f64 stat accumulation order differs slightly)
        let mut rng = Rng::new(76);
        let t32: Vec<f32> = rng.gauss_vec(300).iter().map(|&x| x as f32).collect();
        let eng = {
            let mut e = Stampi::<f32>::new(StampiConfig::new(16)).unwrap();
            e.extend(&t32);
            e
        };
        let want = stomp::matrix_profile(&t32, MpConfig::new(16)).unwrap();
        assert!(eng.profile().max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn matches_brute_at_final_prefix() {
        let mut rng = Rng::new(77);
        let t: Vec<f64> = rng.gauss_vec(256);
        let eng = feed(&t, StampiConfig::new(8));
        let want = brute::matrix_profile(&t, MpConfig::new(8)).unwrap();
        assert!(eng.profile().max_abs_diff(&want) < 1e-7);
    }

    fn brute_pair(t: &[f64], a: usize, b: usize, m: usize) -> f64 {
        let z = |s: usize| -> Vec<f64> {
            let w = &t[s..s + m];
            let mu = w.iter().sum::<f64>() / m as f64;
            let sig = (w.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / m as f64).sqrt();
            if sig > 0.0 {
                w.iter().map(|x| (x - mu) / sig).collect()
            } else {
                vec![0.0; m]
            }
        };
        let (za, zb) = (z(a), z(b));
        za.iter()
            .zip(&zb)
            .map(|(x, y)| (x - y) * (x - y))
            .sum::<f64>()
            .sqrt()
    }
}
