//! Brute-force exact matrix profile — the independent oracle.
//!
//! Deliberately formulated *differently* from the production algorithms:
//! each window is explicitly z-normalized and the plain Euclidean distance
//! between the normalized windows is taken (no Eq. 1, no Eq. 2, no shared
//! statistics code).  O(n²·m) — small inputs only, used by tests to pin
//! down every other implementation.

use crate::mp::{MatrixProfile, MpConfig};
use crate::Real;

/// Compute the exact matrix profile by explicit z-normalization.
pub fn matrix_profile<T: Real>(t: &[T], cfg: MpConfig) -> crate::Result<MatrixProfile<T>> {
    let nw = cfg.validate(t.len())?;
    let m = cfg.m;
    let excl = cfg.exclusion();

    // Pre-normalize every window (f64 internally for oracle quality).
    let mut znorm: Vec<Vec<f64>> = Vec::with_capacity(nw);
    for i in 0..nw {
        let w: Vec<f64> = t[i..i + m].iter().map(|x| x.to_f64s()).collect();
        let mu = w.iter().sum::<f64>() / m as f64;
        let var = w.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / m as f64;
        let sig = var.sqrt();
        znorm.push(if sig > 0.0 {
            w.iter().map(|x| (x - mu) / sig).collect()
        } else {
            vec![0.0; m]
        });
    }

    let mut mp = MatrixProfile::new_inf(nw, m, excl);
    for i in 0..nw {
        for j in (i + excl)..nw {
            let d2: f64 = znorm[i]
                .iter()
                .zip(&znorm[j])
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            mp.update(i, j, T::of_f64(d2.sqrt()));
        }
    }
    Ok(mp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check, Rng};
    use crate::timeseries::generator::{generate_with_event, Pattern, PlantedEvent};

    #[test]
    fn planted_motif_found() {
        let (t, ev) = generate_with_event::<f64>(Pattern::PlantedMotif, 512, 11);
        let mp = matrix_profile(&t, MpConfig::new(24)).unwrap();
        if let PlantedEvent::Motif { a, b, .. } = ev {
            assert!(mp.p[a] < 1e-6, "p[{a}] = {}", mp.p[a]);
            assert!(mp.p[b] < 1e-6);
            assert_eq!(mp.i[a], b as i64);
            assert_eq!(mp.i[b], a as i64);
        } else {
            unreachable!()
        }
    }

    #[test]
    fn trivial_match_banned_by_exclusion() {
        let mut rng = Rng::new(2);
        let t: Vec<f64> = rng.gauss_vec(200);
        let mp = matrix_profile(&t, MpConfig::new(16)).unwrap();
        for (k, &j) in mp.i.iter().enumerate() {
            assert!(j >= 0);
            assert!((k as i64 - j).unsigned_abs() as usize >= mp.excl);
        }
    }

    #[test]
    fn profile_bounded_by_2_sqrt_m() {
        // z-norm distance is bounded: d^2 = 2m(1-corr) <= 4m
        let mut rng = Rng::new(3);
        let t: Vec<f64> = rng.gauss_vec(300);
        let m = 12;
        let mp = matrix_profile(&t, MpConfig::new(m)).unwrap();
        let bound = 2.0 * (m as f64).sqrt() + 1e-9;
        for &d in &mp.p {
            assert!(d <= bound, "{d} > {bound}");
        }
    }

    #[test]
    fn symmetric_distances_give_consistent_index_pairs() {
        check("brute-index-consistency", 10, |rng: &mut Rng| {
            let n = rng.range(80, 200);
            let t: Vec<f64> = rng.gauss_vec(n);
            let mp = matrix_profile(&t, MpConfig::new(8)).unwrap();
            // For every i, the distance to I[i] must equal P[i] when
            // recomputed from scratch.
            for (i, &j) in mp.i.iter().enumerate() {
                let j = j as usize;
                let d = znorm_pair(&t, i, j, 8);
                assert!(
                    (d - mp.p[i]).abs() < 1e-9,
                    "P[{i}]={} but d(i,I[i])={d}",
                    mp.p[i]
                );
            }
        });
    }

    fn znorm_pair(t: &[f64], i: usize, j: usize, m: usize) -> f64 {
        let z = |s: usize| -> Vec<f64> {
            let w = &t[s..s + m];
            let mu = w.iter().sum::<f64>() / m as f64;
            let sig = (w.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / m as f64).sqrt();
            w.iter().map(|x| (x - mu) / sig).collect()
        };
        let (a, b) = (z(i), z(j));
        a.iter()
            .zip(&b)
            .map(|(x, y)| (x - y) * (x - y))
            .sum::<f64>()
            .sqrt()
    }
}
