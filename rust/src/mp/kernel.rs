//! The unified diagonal kernel — the single hot path shared by every
//! exact engine (SCRIMP, STOMP, the parallel fleet, the NATSA PU
//! datapath, and anytime execution).
//!
//! # Performance architecture (the paper's vectFact pipeline in software)
//!
//! NATSA's speedup story (Figs. 7–9) rests on a dense, vectorized
//! Eq. 2 / Eq. 1 diagonal pipeline.  This module is that pipeline as one
//! reusable software kernel with two entry points that compute
//! **bit-identical** cell values:
//!
//! * [`compute_band`] — the SIMD autobahn.  A tile of [`BAND`] adjacent
//!   diagonals advances row by row: the Eq. 2 product deltas are applied
//!   element-wise across the lanes (each lane runs its own serial
//!   dot-product accumulation — the one unavoidable serial step of
//!   Alg. 1, here amortized across [`BAND`] independent chains), the
//!   z-normalized *squared* distances land in a flat lane buffer via the
//!   folded Eq. 1 factors (`d² = 2m − q·za_i·za_j + zb_i·zb_j`, 3 mul +
//!   2 add, branch-free; see [`crate::timeseries::WindowStats`]), and the
//!   buffer is merged into the profile in two *separate* branchless
//!   min/argmin passes: the column direction is a conditional-move vector
//!   merge into the contiguous slice `P[j0..j0+BAND]`, and the row
//!   direction collapses into a min-tree reduction with one update of
//!   `P[i]` per row (the argmin lane scan runs only on the rare
//!   improvement).  No interleaved two-sided `update`, no per-cell
//!   branches on the hot path.
//! * [`compute_band_n`] — the same pipeline at any width `1..=BAND`.
//!   The band-granular scheduler ([`crate::natsa::scheduler`]) deals
//!   *tiles* of adjacent diagonals to PUs, and remainder tiles / short
//!   schedule tails are narrower than [`BAND`]; this entry point keeps
//!   them on the multi-lane path instead of degrading to per-diagonal
//!   walking.
//! * [`compute_diagonal`] — the same cell math for a *single* diagonal
//!   (== [`compute_band_n`] at width 1), the finest work unit the NATSA
//!   scheduler deals and the anytime / random-order engines interleave.
//!   Sequential sweeps should prefer [`compute_triangle`], which rides
//!   the band path.
//! * [`compute_row_n`] — the *streaming* member of the family: the
//!   STAMPI row update ([`crate::mp::stampi`]) as a tile of `1..=BAND`
//!   freshly-completed windows ("rows") advanced together across the
//!   retained history.  Lane `w` carries `q(j, k0+w)` and pulls from
//!   lane `w-1` at the previous column, which turns Yeh's row
//!   recurrence into the exact same delta-form Eq. 2 chain the batch
//!   paths run; the folded Eq. 1 buffer and the two branchless merge
//!   passes are shared verbatim (min-tree + rare argmin scan toward the
//!   column side, register-resident running minima toward the row
//!   side).  Any width is bit-identical to [`scalar_row`] applied once
//!   per row, by construction (see the function docs for the
//!   `rows <= excl` condition that makes the merges order-free).
//!
//! Both paths evaluate every cell with the exact same expressions in the
//! exact same association order (the delta-form recurrence
//! `q += t[i+m-1]·t[j+m-1] − t[i-1]·t[j-1]`, then the folded Eq. 1), so
//! any mix of engines, thread counts, schedules, and visiting orders
//! yields bit-identical profile *values*; neighbor *indices* can differ
//! only on exact distance ties (e.g. all-constant input).  The
//! conformance suite in `tests/cross_impl.rs` pins this down.
//!
//! [`WorkStats`] are charged in closed form per diagonal or per band —
//! never per cell.
//!
//! PERF CONTRACT: the profile accumulates **squared** z-norm distances —
//! min is monotone under sqrt, so the per-cell `sqrt` of Eq. 1 is
//! deferred to one [`MatrixProfile::sqrt_in_place`] per window after all
//! diagonals merge (the same trick SCAMP uses via correlations).  Every
//! caller must finalize.
//!
//! [`scalar_diagonal`] retains the pre-kernel per-cell hot loop (one
//! `znorm_sqdist` + branchy two-sided `update` + per-cell stats per
//! cell — the shape the old STOMP row walk and PU datapath ran) as the
//! differential-test oracle and the baseline `benches/hotpath.rs`
//! measures speedup against.  The third pre-kernel loop, SCRIMP's
//! chunked buffer pipeline, was deleted outright: its three extra
//! buffer passes cost more than the blocked prefix saved, and the
//! delta-form chain of [`compute_diagonal`] outruns it on the same
//! scattered work units.

use crate::mp::{znorm_sqdist, MatrixProfile, WorkStats};
use crate::timeseries::WindowStats;
use crate::Real;

/// Lanes per band: adjacent diagonals advanced together by
/// [`compute_band`].  8 f64 lanes fill an AVX-512 register (two AVX2
/// registers) while the lane state (`q`, `d²`) stays register-resident.
pub const BAND: usize = 8;

/// O(m) seed dot product of a diagonal: `sum_k t[k] * t[d+k]` (the DPU
/// step, Alg. 1 line 7).  Four sub-accumulators keep the reduction off
/// the FP-add latency chain.
#[inline]
pub fn seed_dot<T: Real>(t: &[T], d: usize, m: usize) -> T {
    let a = &t[..m];
    let b = &t[d..d + m];
    let (mut s0, mut s1, mut s2, mut s3) = (T::zero(), T::zero(), T::zero(), T::zero());
    let mut k = 0;
    while k + 4 <= m {
        s0 = s0 + a[k] * b[k];
        s1 = s1 + a[k + 1] * b[k + 1];
        s2 = s2 + a[k + 2] * b[k + 2];
        s3 = s3 + a[k + 3] * b[k + 3];
        k += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    while k < m {
        s = s + a[k] * b[k];
        k += 1;
    }
    s
}

/// Walk the whole admissible triangle `excl..nw` in ascending diagonal
/// order: whole [`BAND`]-wide tiles through [`compute_band`], the final
/// remainder as one narrower tile through [`compute_band_n`] — no path
/// falls back to single-diagonal walking.  This is the driver sequential
/// engines (SCRIMP sequential order, STOMP) share.
pub fn compute_triangle<T: Real>(
    t: &[T],
    st: &WindowStats<T>,
    excl: usize,
    mp: &mut MatrixProfile<T>,
    work: &mut WorkStats,
) {
    let nw = st.len();
    let mut d = excl;
    while d + BAND <= nw {
        compute_band(t, st, d, mp, work);
        d += BAND;
    }
    if d < nw {
        compute_band_n(t, st, d, nw - d, mp, work);
    }
}

/// Advance the band of diagonals `d0..d0+BAND` (requires
/// `d0 + BAND <= nw`) row by row, updating the profile in place.
///
/// See the module docs for the pipeline; see [`compute_diagonal`] for the
/// identical-value single-diagonal form and [`compute_band_n`] for
/// narrower tiles.  PERF CONTRACT: squared distances (callers finalize
/// with [`MatrixProfile::sqrt_in_place`]).
pub fn compute_band<T: Real>(
    t: &[T],
    st: &WindowStats<T>,
    d0: usize,
    mp: &mut MatrixProfile<T>,
    work: &mut WorkStats,
) {
    band_w::<T, BAND>(t, st, d0, mp, work);
}

/// Advance a tile of `width` adjacent diagonals `d0..d0+width`
/// (`1 <= width <= BAND`, `d0 + width <= nw`), updating the profile in
/// place.  The band-granular scheduler deals tiles of any admissible
/// width, so remainder tiles and short tails ride the same multi-lane
/// pipeline as full [`BAND`]-wide tiles instead of degrading to
/// one-diagonal-at-a-time execution.  Width 1 is exactly
/// [`compute_diagonal`]; every width computes bit-identical cell values
/// (same association order — see the module docs).  PERF CONTRACT:
/// squared distances (callers finalize with
/// [`MatrixProfile::sqrt_in_place`]).
pub fn compute_band_n<T: Real>(
    t: &[T],
    st: &WindowStats<T>,
    d0: usize,
    width: usize,
    mp: &mut MatrixProfile<T>,
    work: &mut WorkStats,
) {
    // Monomorphized per width: the lane state must stay a fixed-size
    // array for the compiler to keep it register-resident.
    match width {
        1 => compute_diagonal(t, st, d0, mp, work),
        2 => band_w::<T, 2>(t, st, d0, mp, work),
        3 => band_w::<T, 3>(t, st, d0, mp, work),
        4 => band_w::<T, 4>(t, st, d0, mp, work),
        5 => band_w::<T, 5>(t, st, d0, mp, work),
        6 => band_w::<T, 6>(t, st, d0, mp, work),
        7 => band_w::<T, 7>(t, st, d0, mp, work),
        8 => band_w::<T, 8>(t, st, d0, mp, work),
        _ => panic!("band width {width} out of range 1..={BAND}"),
    }
}

/// The width-generic band pipeline behind [`compute_band`] /
/// [`compute_band_n`] (see the module docs for the stages).
fn band_w<T: Real, const W: usize>(
    t: &[T],
    st: &WindowStats<T>,
    d0: usize,
    mp: &mut MatrixProfile<T>,
    work: &mut WorkStats,
) {
    let m = st.m;
    let nw = st.len();
    assert!(d0 + W <= nw, "band {d0}..{} out of range (nw={nw})", d0 + W);

    // Closed-form accounting: one charge per band, never per cell.
    let band_cells: u64 = (0..W).map(|dd| (nw - d0 - dd) as u64).sum();
    work.cells += band_cells;
    work.updates += 2 * band_cells;
    work.diagonals += W as u64;
    work.first_dots += W as u64;

    // Per-lane seed dot products (the DPU step, once per diagonal).
    let mut q = [T::zero(); W];
    for (dd, qd) in q.iter_mut().enumerate() {
        *qd = seed_dot(t, d0 + dd, m);
    }

    let two_m = T::of_f64(2.0 * m as f64);
    let zero = T::zero();
    let mut d2 = [T::zero(); W];
    // Rows where all W lanes are active (the shortest lane's length).
    let len_short = nw - (d0 + W - 1);
    for i in 0..len_short {
        let j0 = i + d0;
        // Eq. 2 delta, element-wise across the lanes; each lane is its
        // own serial accumulation chain (row 0 uses the seeds directly).
        if i > 0 {
            let hi = t[i + m - 1];
            let lo = t[i - 1];
            let tj_hi: &[T; W] = (&t[j0 + m - 1..j0 + m - 1 + W]).try_into().unwrap();
            let tj_lo: &[T; W] = (&t[j0 - 1..j0 - 1 + W]).try_into().unwrap();
            for dd in 0..W {
                q[dd] = q[dd] + (hi * tj_hi[dd] - lo * tj_lo[dd]);
            }
        }
        // Folded Eq. 1 into the lane buffer + column-direction branchless
        // merge (conditional moves into the contiguous profile slice).
        let za_i = st.za[i];
        let zb_i = st.zb[i];
        let za_j: &[T; W] = (&st.za[j0..j0 + W]).try_into().unwrap();
        let zb_j: &[T; W] = (&st.zb[j0..j0 + W]).try_into().unwrap();
        {
            let pc: &mut [T; W] = (&mut mp.p[j0..j0 + W]).try_into().unwrap();
            let ic: &mut [i64; W] = (&mut mp.i[j0..j0 + W]).try_into().unwrap();
            for dd in 0..W {
                let v = (two_m - q[dd] * za_i * za_j[dd] + zb_i * zb_j[dd]).max(zero);
                d2[dd] = v;
                let take = v < pc[dd];
                pc[dd] = if take { v } else { pc[dd] };
                ic[dd] = if take { i as i64 } else { ic[dd] };
            }
        }
        // Row-direction merge: branchless min tree, then one profile
        // update per row; the argmin lane scan runs only on the rare
        // improvement (first-equal lane = lowest diagonal = the same
        // tie order as ascending per-diagonal processing).
        let mut best = d2[0];
        for &v in d2.iter().skip(1) {
            best = if v < best { v } else { best };
        }
        if best < mp.p[i] {
            let mut bdd = 0;
            while d2[bdd] != best {
                bdd += 1;
            }
            mp.p[i] = best;
            mp.i[i] = (j0 + bdd) as i64;
        }
    }
    // Ragged tail: lanes 0..W-1 outlive the shortest lane; finish each
    // with the identical-value single-diagonal recurrence.
    for dd in 0..W.saturating_sub(1) {
        let d = d0 + dd;
        let mut q_d = q[dd];
        for i in len_short..nw - d {
            let j = i + d;
            q_d = q_d + (t[i + m - 1] * t[j + m - 1] - t[i - 1] * t[j - 1]);
            let v = (two_m - q_d * st.za[i] * st.za[j] + st.zb[i] * st.zb[j]).max(zero);
            mp.update(i, j, v);
        }
    }
}

/// Walk one diagonal `d` (cells `(i, i+d)` for `i = 0..nw-d`), updating
/// the profile in place — the unit of work NATSA assigns to a PU and the
/// loop body of scheduled, random-order, and anytime execution.
///
/// Cell values are bit-identical to [`compute_band`]'s: the same
/// delta-form Eq. 2 chain (`q += hi·hj − lo·lj`, one dependent add per
/// cell — half the chain latency of the classic `q − lo·lj + hi·hj`
/// form) and the same folded Eq. 1 expression.  PERF CONTRACT: squared
/// distances (callers finalize with [`MatrixProfile::sqrt_in_place`]).
pub fn compute_diagonal<T: Real>(
    t: &[T],
    st: &WindowStats<T>,
    d: usize,
    mp: &mut MatrixProfile<T>,
    work: &mut WorkStats,
) {
    let m = st.m;
    let nw = st.len();
    debug_assert!(d < nw, "diagonal {d} out of range (nw={nw})");
    let len = nw - d;

    // Closed-form accounting: one charge per diagonal, never per cell.
    work.cells += len as u64;
    work.updates += 2 * len as u64;
    work.diagonals += 1;
    work.first_dots += 1;

    let two_m = T::of_f64(2.0 * m as f64);
    let zero = T::zero();
    let mut q = seed_dot(t, d, m);
    let v0 = (two_m - q * st.za[0] * st.za[d] + st.zb[0] * st.zb[d]).max(zero);
    mp.update(0, d, v0);
    for i in 1..len {
        let j = i + d;
        q = q + (t[i + m - 1] * t[j + m - 1] - t[i - 1] * t[j - 1]);
        let v = (two_m - q * st.za[i] * st.za[j] + st.zb[i] * st.zb[j]).max(zero);
        mp.update(i, j, v);
    }
}

/// Borrowed views over a streaming engine's retained state — the operand
/// bundle of [`compute_row_n`] / [`scalar_row`].
///
/// Everything is **local window indexing**: window `w` of the tile reads
/// samples `t[w..w + m]`, and `za`/`zb`/`q`/`p`/`i` line up with it, so
/// the caller ([`crate::mp::stampi`]) acquires each slice from its ring
/// buffers with ONE range check and the kernel's inner loops index plain
/// slices (no per-element retained-range asserts — the bounds drag the
/// old per-cell row walk paid on every access).  `base` is the absolute
/// window index of local position 0: neighbor indices written into `i`
/// are `base + local`, so profile entries stay stable across ring
/// compactions.
pub struct RowTile<'a, T> {
    /// Samples: at least `za.len() + m - 1` of them.
    pub t: &'a [T],
    /// Folded Eq. 1 factor `sqrt(2)/sigma` per window (0 for constant).
    pub za: &'a [T],
    /// Folded Eq. 1 factor `sqrt(2m)*mu/sigma` per window (0 for constant).
    pub zb: &'a [T],
    /// Streaming dot-product state: on entry `q[j] = dot(window j,
    /// window k0-1)` for the windows that existed before this tile
    /// (`k0 = za.len() - rows`); on exit `q[j] = dot(window j, last
    /// window)` for every `j` — ready for the next tile.
    pub q: &'a mut [T],
    /// The live profile (**squared** distances — kernel PERF CONTRACT).
    pub p: &'a mut [T],
    /// Neighbor indices (absolute: `base + local`).
    pub i: &'a mut [i64],
    /// Absolute window index of local position 0.
    pub base: i64,
}

/// Advance the streaming profile by a tile of `rows` freshly-completed
/// windows (`1 <= rows <= BAND`) — the STAMPI row update on the unified
/// kernel pipeline.
///
/// The last `rows` entries of the tile are the new windows
/// `k0..k0+rows` (`k0 = za.len() - rows`); every admissible cell
/// `(j, k)` with `k - j >= excl` among them is evaluated with the exact
/// batch-kernel expressions (delta-form Eq. 2 chains, folded Eq. 1),
/// updating `p[j]` (an old window gained a candidate neighbor) and
/// `p[k]` (a new window scans all of retained history).  One O(m) seed
/// dot is computed per row at column 0, exactly like the per-append
/// scalar walk.
///
/// `rows > 1` requires `rows <= excl`: then no evaluated column is
/// itself a new row, the column- and row-direction merges touch
/// disjoint profile entries, and the tile is **bit-identical** (values,
/// indices, q state, and [`WorkStats`]) to `rows` successive
/// [`scalar_row`] calls — the property test below pins every width.
/// With `rows == 1` there is no such constraint (a single row cannot
/// race itself).
///
/// [`WorkStats`] are charged in closed form per row, and only for rows
/// with at least one admissible cell — zero-cell warm-up rows (young or
/// heavily-excluded streams) cost nothing, matching the batch engines'
/// accounting which starts at the first admissible diagonal.
///
/// PERF CONTRACT: `p` accumulates **squared** distances; the streaming
/// engine defers the sqrt to one pass per profile snapshot.
pub fn compute_row_n<T: Real>(
    tile: RowTile<'_, T>,
    rows: usize,
    m: usize,
    excl: usize,
    work: &mut WorkStats,
) {
    // Monomorphized per width, like `compute_band_n`: the lane state
    // (q chain values, d², row minima) must be fixed-size arrays for the
    // compiler to keep it register-resident.
    match rows {
        1 => row_w::<T, 1>(tile, m, excl, work),
        2 => row_w::<T, 2>(tile, m, excl, work),
        3 => row_w::<T, 3>(tile, m, excl, work),
        4 => row_w::<T, 4>(tile, m, excl, work),
        5 => row_w::<T, 5>(tile, m, excl, work),
        6 => row_w::<T, 6>(tile, m, excl, work),
        7 => row_w::<T, 7>(tile, m, excl, work),
        8 => row_w::<T, 8>(tile, m, excl, work),
        _ => panic!("row tile of {rows} rows out of range 1..={BAND}"),
    }
}

/// The width-generic row pipeline behind [`compute_row_n`].
///
/// Lane `w` walks row `k0 + w`: at column `j` it holds
/// `q(j, k0+w) = dot(window j, window k0+w)`, obtained from lane `w-1`'s
/// value at column `j-1` by one delta-form Eq. 2 step (`+ (hi·hiₖ −
/// lo·loₖ)`, the row factors `hiₖ = t[k+m-1]`, `loₖ = t[k-1]` hoisted
/// into registers).  Lane 0 pulls from the stored `q[j-1]` of the
/// previous tile.  Lane `W-1`'s value IS the next tile's stored state,
/// written back in place as the walk passes each column.
fn row_w<T: Real, const W: usize>(
    tile: RowTile<'_, T>,
    m: usize,
    excl: usize,
    work: &mut WorkStats,
) {
    let RowTile { t, za, zb, q, p, i: idx, base } = tile;
    let nw = za.len();
    assert!(W >= 1 && W <= nw, "row tile of {W} rows on {nw} windows");
    assert!(
        W == 1 || W <= excl,
        "row tile of {W} rows needs excl >= {W} (order-free merges); got excl={excl}"
    );
    assert_eq!(zb.len(), nw, "zb length");
    assert_eq!(q.len(), nw, "q length");
    assert_eq!(p.len(), nw, "p length");
    assert_eq!(idx.len(), nw, "i length");
    assert!(t.len() >= nw + m - 1, "t too short: {} < {}", t.len(), nw + m - 1);
    let k0 = nw - W;

    // Closed-form accounting: one charge per row with admissible cells,
    // never per cell; a streaming row is the accounting twin of one
    // batch diagonal, so full-stream totals equal the batch engines'.
    for w in 0..W {
        let k = k0 + w;
        if k >= excl {
            let c = (k - excl + 1) as u64;
            work.cells += c;
            work.updates += 2 * c;
            work.diagonals += 1;
            work.first_dots += 1;
        }
    }

    let two_m = T::of_f64(2.0 * m as f64);
    let zero = T::zero();

    // Hoisted per-row constants: Eq. 2 factors and folded Eq. 1 stats of
    // the W new windows stay register-resident for the whole walk.
    let mut hi_k = [zero; W];
    let mut lo_k = [zero; W];
    let mut za_k = [zero; W];
    let mut zb_k = [zero; W];
    for w in 0..W {
        let k = k0 + w;
        hi_k[w] = t[k + m - 1];
        // k == 0 only for the very first window, whose lane never
        // advances past its seed; zero keeps the hoist in range.
        lo_k[w] = if k > 0 { t[k - 1] } else { zero };
        za_k[w] = za[k];
        zb_k[w] = zb[k];
    }

    // Row-direction running minima, seeded from the rows' current
    // entries so the final write-back is unconditional — exactly the
    // scalar walk's `pk = p[k]; ...; p[k] = pk` shape (ties between a
    // row's own minimum and a later column update resolve identically).
    let mut rb = [zero; W];
    let mut ri = [0i64; W];
    for w in 0..W {
        rb[w] = p[k0 + w];
        ri[w] = idx[k0 + w];
    }

    // Column 0: one O(m) fresh seed dot per row (the DPU step in row
    // form — dot of the oldest retained window with each new window).
    let mut v = [zero; W];
    for (w, vw) in v.iter_mut().enumerate() {
        *vw = seed_dot(t, k0 + w, m);
    }
    // Lane 0's pull at column 1 needs the stored q[0] — save it before
    // the in-place write of lane W-1's value.
    let mut q_prev = if k0 > 0 { q[0] } else { zero };
    q[0] = v[W - 1];
    {
        // Evaluate column 0: lanes with k0 + w >= excl (all of them on a
        // mature stream; a shrinking prefix while the stream is young).
        let elo = excl.saturating_sub(k0);
        if elo < W {
            let za_j = za[0];
            let zb_j = zb[0];
            let mut d2 = [T::infinity(); W];
            for w in elo..W {
                d2[w] = (two_m - v[w] * za_j * za_k[w] + zb_j * zb_k[w]).max(zero);
            }
            merge_col::<T, W>(&d2, elo, 0, p, idx, k0, base);
            merge_rows::<T, W>(&d2, elo, 0, &mut rb, &mut ri, base);
        }
    }

    // Full-width region: every lane alive, every lane admissible — the
    // branchless hot path (this is where O(retained) of the work lives).
    let jf = k0.saturating_sub(excl).min(nw - 1);
    for j in 1..=jf {
        let hi = t[j + m - 1];
        let lo = t[j - 1];
        // Lane shift + Eq. 2 delta, descending so each lane consumes its
        // predecessor's previous-column value before it is overwritten.
        for w in (1..W).rev() {
            v[w] = v[w - 1] + (hi * hi_k[w] - lo * lo_k[w]);
        }
        v[0] = q_prev + (hi * hi_k[0] - lo * lo_k[0]);
        q_prev = q[j];
        q[j] = v[W - 1];
        // Folded Eq. 1 into the lane buffer.
        let za_j = za[j];
        let zb_j = zb[j];
        let mut d2 = [zero; W];
        for w in 0..W {
            d2[w] = (two_m - v[w] * za_j * za_k[w] + zb_j * zb_k[w]).max(zero);
        }
        merge_col::<T, W>(&d2, 0, j, p, idx, k0, base);
        merge_rows::<T, W>(&d2, 0, j, &mut rb, &mut ri, base);
    }

    // Ragged tail: columns where lanes stop being admissible (within
    // `excl` of a new row) and then stop existing (columns that are new
    // rows themselves) — at most `excl + W` columns, off the hot path.
    for j in (jf + 1).max(1)..nw {
        let wlo = j.saturating_sub(k0); // lanes w >= wlo still alive
        let hi = t[j + m - 1];
        let lo = t[j - 1];
        for w in (wlo.max(1)..W).rev() {
            v[w] = v[w - 1] + (hi * hi_k[w] - lo * lo_k[w]);
        }
        if wlo == 0 {
            v[0] = q_prev + (hi * hi_k[0] - lo * lo_k[0]);
            q_prev = q[j];
        }
        q[j] = v[W - 1];
        let elo = wlo.max((j + excl).saturating_sub(k0));
        if elo < W {
            let za_j = za[j];
            let zb_j = zb[j];
            let mut d2 = [T::infinity(); W];
            for w in elo..W {
                d2[w] = (two_m - v[w] * za_j * za_k[w] + zb_j * zb_k[w]).max(zero);
            }
            merge_col::<T, W>(&d2, elo, j, p, idx, k0, base);
            merge_rows::<T, W>(&d2, elo, j, &mut rb, &mut ri, base);
        }
    }

    // Row-direction write-back (unconditional, mirroring the scalar
    // walk's final `p[k] = pk`): untouched rows write their seeds back.
    for w in 0..W {
        p[k0 + w] = rb[w];
        idx[k0 + w] = ri[w];
    }
}

/// Column-direction merge of one lane buffer into `p[j]`: branchless
/// min-tree over the admissible lanes, argmin lane scan only on the rare
/// improvement (first-equal lane = lowest row = the same tie order as
/// processing the rows one append at a time).
#[inline(always)]
fn merge_col<T: Real, const W: usize>(
    d2: &[T; W],
    elo: usize,
    j: usize,
    p: &mut [T],
    idx: &mut [i64],
    k0: usize,
    base: i64,
) {
    let mut best = d2[elo];
    for &x in d2.iter().skip(elo + 1) {
        best = if x < best { x } else { best };
    }
    if best < p[j] {
        let mut bw = elo;
        while d2[bw] != best {
            bw += 1;
        }
        p[j] = best;
        idx[j] = base + (k0 + bw) as i64;
    }
}

/// Row-direction merge of one lane buffer into the register-resident
/// running minima: conditional moves, strict `<` so the first (lowest-j)
/// occurrence of a row's minimum keeps the argmin — the scalar walk's
/// tie order.
#[inline(always)]
fn merge_rows<T: Real, const W: usize>(
    d2: &[T; W],
    elo: usize,
    j: usize,
    rb: &mut [T; W],
    ri: &mut [i64; W],
    base: i64,
) {
    for w in elo..W {
        let take = d2[w] < rb[w];
        rb[w] = if take { d2[w] } else { rb[w] };
        ri[w] = if take { base + j as i64 } else { ri[w] };
    }
}

/// The pre-kernel streaming row walk, retained as the differential
/// oracle and the perf baseline for `benches/streaming.rs` — one row
/// (the single newest window) advanced with per-cell evaluation and the
/// branchy two-sided update, exactly the shape `Stampi::append` ran
/// before the row kernel (minus its per-element ring asserts and eager
/// per-cell sqrt, which died with the old loop; the oracle obeys the
/// squared-distance PERF CONTRACT so it stays bit-comparable).
///
/// [`compute_row_n`] at any width is bit-identical to successive calls
/// of this function — the streaming analogue of [`scalar_diagonal`].
pub fn scalar_row<T: Real>(tile: RowTile<'_, T>, m: usize, excl: usize, work: &mut WorkStats) {
    let RowTile { t, za, zb, q, p, i: idx, base } = tile;
    let nw = za.len();
    assert!(nw >= 1 && q.len() == nw && p.len() == nw && idx.len() == nw && zb.len() == nw);
    assert!(t.len() >= nw + m - 1);
    let k = nw - 1;

    // Advance q in place: walking j downward keeps q[j-1] at its old
    // value until consumed (the classic STOMP row trick), with the same
    // delta-form association as the kernel chains.
    if k > 0 {
        let hi_k = t[k + m - 1];
        let lo_k = t[k - 1];
        for j in (1..=k).rev() {
            q[j] = q[j - 1] + (t[j + m - 1] * hi_k - t[j - 1] * lo_k);
        }
    }
    q[0] = seed_dot(t, k, m);

    if k < excl {
        return; // zero admissible cells: no work charged (warm-up row)
    }
    let hi = k - excl; // inclusive last admissible column
    let two_m = T::of_f64(2.0 * m as f64);
    let zero = T::zero();
    let za_k = za[k];
    let zb_k = zb[k];
    let mut pk = p[k];
    let mut ik = idx[k];
    for j in 0..=hi {
        let d = (two_m - q[j] * za[j] * za_k + zb[j] * zb_k).max(zero);
        if d < p[j] {
            p[j] = d;
            idx[j] = base + k as i64;
        }
        if d < pk {
            pk = d;
            ik = base + j as i64;
        }
        work.cells += 1;
        work.updates += 2;
    }
    p[k] = pk;
    idx[k] = ik;
    work.diagonals += 1;
    work.first_dots += 1;
}

/// One stream's lane of a cross-stream group tile (see
/// [`compute_row_group`]): the stream's single freshly-admitted row as a
/// [`RowTile`], plus that stream's own [`WorkStats`] accumulator — lanes
/// belong to *different* sessions, so work cannot be pooled the way
/// [`compute_row_n`]'s single accumulator pools rows of one stream.
pub struct GroupLane<'a, T> {
    pub tile: RowTile<'a, T>,
    pub work: &'a mut WorkStats,
}

/// Advance several **independent streams** by one freshly-admitted row
/// each, as shared multi-lane tiles — the cross-stream member of the
/// kernel family (the service's append-coalescing hot path).
///
/// [`compute_row_n`] widens a tile with *consecutive rows of one
/// stream*: lane `w` pulls its Eq. 2 chain from lane `w-1`, which is
/// what forces `rows <= excl` for order-free merges.  Here every lane is
/// a *different* stream's newest row over that stream's own retained
/// history, so the lanes share no state at all: each lane replicates
/// [`scalar_row`]'s exact operation order (the in-place descending q
/// advance, the seed dot at column 0, the ascending evaluate-and-merge
/// walk with strict-`<` ties), merely interleaved column-lockstep across
/// lanes so `W` independent delta chains and running-minimum chains
/// amortize each other's FP latency — the same lane-fill economics as
/// the batch band tiles, with **no** width constraint from `excl` and no
/// dtype/m/excl mixing (the caller groups compatible streams; `m` and
/// `excl` here are the group's shared values).
///
/// Per lane, the result (profile bits, neighbor indices, q chain,
/// [`WorkStats`]) is **bit-identical** to a [`scalar_row`] call on that
/// lane alone, by construction — pinned for every group width by the
/// property test below.  Lanes wider than [`BAND`] are chunked into
/// `<= BAND` sub-tiles (monomorphized like every other entry point);
/// warm-up lanes (`k < excl`, including a stream's very first window)
/// are legal and charge nothing, exactly like the scalar walk.
pub fn compute_row_group<T: Real>(lanes: &mut [GroupLane<'_, T>], m: usize, excl: usize) {
    let mut rest = lanes;
    while !rest.is_empty() {
        let w = rest.len().min(BAND);
        let (chunk, tail) = rest.split_at_mut(w);
        match w {
            1 => group_w::<T, 1>(chunk, m, excl),
            2 => group_w::<T, 2>(chunk, m, excl),
            3 => group_w::<T, 3>(chunk, m, excl),
            4 => group_w::<T, 4>(chunk, m, excl),
            5 => group_w::<T, 5>(chunk, m, excl),
            6 => group_w::<T, 6>(chunk, m, excl),
            7 => group_w::<T, 7>(chunk, m, excl),
            8 => group_w::<T, 8>(chunk, m, excl),
            _ => unreachable!("chunk width {w} out of 1..={BAND}"),
        }
        rest = tail;
    }
}

/// The width-generic pipeline behind [`compute_row_group`]: `W`
/// independent [`scalar_row`] walks interleaved column-lockstep.  The
/// per-lane hoisted constants (`hi_k`, `lo_k`, folded Eq. 1 stats,
/// running row minima) live in fixed-size arrays so they stay
/// register-resident like [`row_w`]'s lane state.
fn group_w<T: Real, const W: usize>(lanes: &mut [GroupLane<'_, T>], m: usize, excl: usize) {
    debug_assert_eq!(lanes.len(), W);
    let zero = T::zero();
    let two_m = T::of_f64(2.0 * m as f64);
    let mut k_l = [0usize; W];
    let mut hi_k = [zero; W];
    let mut lo_k = [zero; W];
    let mut za_k = [zero; W];
    let mut zb_k = [zero; W];
    for (w, lane) in lanes.iter().enumerate() {
        let tile = &lane.tile;
        let nw = tile.za.len();
        assert!(
            nw >= 1
                && tile.zb.len() == nw
                && tile.q.len() == nw
                && tile.p.len() == nw
                && tile.i.len() == nw,
            "group lane {w}: window arrays disagree"
        );
        assert!(
            tile.t.len() >= nw + m - 1,
            "group lane {w}: t too short: {} < {}",
            tile.t.len(),
            nw + m - 1
        );
        let k = nw - 1;
        k_l[w] = k;
        hi_k[w] = tile.t[k + m - 1];
        lo_k[w] = if k > 0 { tile.t[k - 1] } else { zero };
        za_k[w] = tile.za[k];
        zb_k[w] = tile.zb[k];
    }
    let k_max = k_l.iter().copied().max().unwrap_or(0);

    // Phase A — every lane's in-place q advance, lockstep by
    // distance-from-top so each lane still walks ITS columns descending
    // (reading the old q[j-1] before any write lands on it — exactly
    // scalar_row's STOMP row trick, delta association included).
    for s in 0..k_max {
        for (w, lane) in lanes.iter_mut().enumerate() {
            let k = k_l[w];
            if s < k {
                let j = k - s;
                let t = lane.tile.t;
                lane.tile.q[j] =
                    lane.tile.q[j - 1] + (t[j + m - 1] * hi_k[w] - t[j - 1] * lo_k[w]);
            }
        }
    }
    for (w, lane) in lanes.iter_mut().enumerate() {
        lane.tile.q[0] = seed_dot(lane.tile.t, k_l[w], m);
    }

    // Closed-form accounting per lane — scalar_row's charges, into each
    // stream's own accumulator; warm-up lanes (k < excl) cost nothing.
    for (w, lane) in lanes.iter_mut().enumerate() {
        let k = k_l[w];
        if k >= excl {
            let c = (k - excl + 1) as u64;
            lane.work.cells += c;
            lane.work.updates += 2 * c;
            lane.work.diagonals += 1;
            lane.work.first_dots += 1;
        }
    }

    // Phase B — evaluate + merge, lockstep ascending j: W independent
    // running-minimum chains interleave where a single lane's chain
    // would serialize on its own compare latency.  Strict-`<` on both
    // sides keeps scalar_row's tie order per lane.
    let mut pk = [zero; W];
    let mut ik = [0i64; W];
    let mut hi_j = [0usize; W];
    let mut live = [false; W];
    let mut j_max = 0usize;
    let mut any = false;
    for (w, lane) in lanes.iter().enumerate() {
        if k_l[w] >= excl {
            live[w] = true;
            hi_j[w] = k_l[w] - excl;
            j_max = j_max.max(hi_j[w]);
            pk[w] = lane.tile.p[k_l[w]];
            ik[w] = lane.tile.i[k_l[w]];
            any = true;
        }
    }
    if !any {
        return;
    }
    for j in 0..=j_max {
        for (w, lane) in lanes.iter_mut().enumerate() {
            if live[w] && j <= hi_j[w] {
                let tile = &mut lane.tile;
                let d = (two_m - tile.q[j] * tile.za[j] * za_k[w] + tile.zb[j] * zb_k[w])
                    .max(zero);
                if d < tile.p[j] {
                    tile.p[j] = d;
                    tile.i[j] = tile.base + k_l[w] as i64;
                }
                if d < pk[w] {
                    pk[w] = d;
                    ik[w] = tile.base + j as i64;
                }
            }
        }
    }
    for (w, lane) in lanes.iter_mut().enumerate() {
        if live[w] {
            lane.tile.p[k_l[w]] = pk[w];
            lane.tile.i[k_l[w]] = ik[w];
        }
    }
}

/// The pre-kernel per-cell hot loop, retained as the differential oracle
/// and the perf baseline: one `znorm_sqdist` + branchy two-sided
/// [`MatrixProfile::update`] + per-cell [`WorkStats`] charges, with the
/// classic two-dependent-add dot-product chain.  Same PERF CONTRACT
/// (squared distances) as [`compute_diagonal`].
pub fn scalar_diagonal<T: Real>(
    t: &[T],
    st: &WindowStats<T>,
    d: usize,
    mp: &mut MatrixProfile<T>,
    work: &mut WorkStats,
) {
    let m = st.m;
    let nw = st.len();
    debug_assert!(d < nw);
    let len = nw - d;
    let mut q = (0..m).map(|k| t[k] * t[d + k]).sum::<T>();
    let d0 = znorm_sqdist(q, m, st.mu[0], st.inv_msig[0], st.mu[d], st.inv_msig[d]);
    mp.update(0, d, d0);
    work.first_dots += 1;
    work.diagonals += 1;
    work.cells += 1;
    work.updates += 2;
    for i in 1..len {
        let j = i + d;
        q = q - t[i - 1] * t[j - 1] + t[i + m - 1] * t[j + m - 1];
        let dist = znorm_sqdist(q, m, st.mu[i], st.inv_msig[i], st.mu[j], st.inv_msig[j]);
        mp.update(i, j, dist);
        work.cells += 1;
        work.updates += 2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mp::{brute, MpConfig};
    use crate::prop::{check, Rng};
    use crate::timeseries::sliding_stats;

    /// Full profile through the banded sequential driver.
    fn banded_profile<T: Real>(t: &[T], cfg: MpConfig) -> (MatrixProfile<T>, WorkStats) {
        let nw = cfg.validate(t.len()).unwrap();
        let excl = cfg.exclusion();
        let st = sliding_stats(t, cfg.m);
        let mut mp = MatrixProfile::new_inf(nw, cfg.m, excl);
        let mut work = WorkStats::default();
        compute_triangle(t, &st, excl, &mut mp, &mut work);
        mp.sqrt_in_place();
        (mp, work)
    }

    type DiagFn<T> = fn(&[T], &WindowStats<T>, usize, &mut MatrixProfile<T>, &mut WorkStats);

    /// Full profile through a per-diagonal function (kernel or scalar).
    fn diag_profile<T: Real>(
        t: &[T],
        cfg: MpConfig,
        f: DiagFn<T>,
    ) -> (MatrixProfile<T>, WorkStats) {
        let nw = cfg.validate(t.len()).unwrap();
        let excl = cfg.exclusion();
        let st = sliding_stats(t, cfg.m);
        let mut mp = MatrixProfile::new_inf(nw, cfg.m, excl);
        let mut work = WorkStats::default();
        for d in excl..nw {
            f(t, &st, d, &mut mp, &mut work);
        }
        mp.sqrt_in_place();
        (mp, work)
    }

    #[test]
    fn prop_band_and_diagonal_bit_identical_f64() {
        // the tentpole invariant: the SIMD band path and the scheduled
        // per-diagonal path compute the same cells to the bit
        check("band-vs-diag-bits", 10, |rng: &mut Rng| {
            let n = rng.range(60, 2000);
            let m = rng.range(4, 65);
            if n < 5 * m {
                return;
            }
            let t: Vec<f64> = rng.gauss_vec(n);
            let cfg = MpConfig::new(m);
            let (band, wb) = banded_profile(&t, cfg);
            let (diag, wd) = diag_profile(&t, cfg, compute_diagonal);
            assert_eq!(
                band.p.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                diag.p.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "n={n} m={m}"
            );
            assert_eq!(band.i, diag.i, "n={n} m={m}");
            assert_eq!(wb, wd, "closed-form accounting must not depend on tiling");
        });
    }

    #[test]
    fn prop_kernel_vs_brute_and_scalar_f64() {
        // The satellite differential property: kernel vs the brute oracle
        // AND vs the retained scalar reference, m in {4, 16, 64}, n to 2k.
        check("kernel-vs-brute-scalar-f64", 6, |rng: &mut Rng| {
            for m in [4usize, 16, 64] {
                let n = rng.range(5 * m.max(16), 2000);
                let t: Vec<f64> = rng.gauss_vec(n);
                let cfg = MpConfig::new(m);
                let (got, wk) = banded_profile(&t, cfg);
                let want = brute::matrix_profile(&t, cfg).unwrap();
                assert!(
                    got.max_abs_diff(&want) < 1e-8,
                    "m={m} n={n} vs brute: {}",
                    got.max_abs_diff(&want)
                );
                let (sca, ws) = diag_profile(&t, cfg, scalar_diagonal);
                assert!(
                    got.max_abs_diff(&sca) < 1e-8,
                    "m={m} n={n} vs scalar: {}",
                    got.max_abs_diff(&sca)
                );
                // closed-form accounting must equal the per-cell counts
                assert_eq!(wk, ws, "m={m} n={n}");
            }
        });
    }

    #[test]
    fn prop_kernel_vs_brute_and_scalar_f32() {
        check("kernel-vs-brute-scalar-f32", 4, |rng: &mut Rng| {
            for m in [4usize, 16, 64] {
                let n = rng.range(5 * m.max(16), 2000);
                let t: Vec<f32> = rng.gauss_vec(n).iter().map(|&x| x as f32).collect();
                let cfg = MpConfig::new(m);
                let (got, _) = banded_profile(&t, cfg);
                let want = brute::matrix_profile(&t, cfg).unwrap();
                assert!(
                    got.max_abs_diff(&want) < 2e-2,
                    "m={m} n={n} vs brute: {}",
                    got.max_abs_diff(&want)
                );
                let (sca, _) = diag_profile(&t, cfg, scalar_diagonal);
                assert!(
                    got.max_abs_diff(&sca) < 2e-2,
                    "m={m} n={n} vs scalar: {}",
                    got.max_abs_diff(&sca)
                );
            }
        });
    }

    #[test]
    fn prop_every_band_width_bit_identical_to_diagonal() {
        // the tentpole generalization: a tile of ANY width 1..=BAND
        // computes the same cells to the bit as per-diagonal walking, so
        // the band-granular scheduler may deal tiles of arbitrary width
        check("band-width-bits", 8, |rng: &mut Rng| {
            let n = rng.range(80, 900);
            let m = rng.range(4, 33);
            if n < 5 * m {
                return;
            }
            let t: Vec<f64> = rng.gauss_vec(n);
            let cfg = MpConfig::new(m);
            let nw = cfg.validate(t.len()).unwrap();
            let excl = cfg.exclusion();
            let st = sliding_stats(&t, m);
            let (diag, wd) = diag_profile(&t, cfg, compute_diagonal);
            for width in 1..=BAND {
                let mut mp = MatrixProfile::new_inf(nw, m, excl);
                let mut work = WorkStats::default();
                // tile the admissible range at this width (ragged tail
                // becomes a narrower tile)
                let mut d = excl;
                while d < nw {
                    let w = width.min(nw - d);
                    compute_band_n(&t, &st, d, w, &mut mp, &mut work);
                    d += w;
                }
                mp.sqrt_in_place();
                assert_eq!(
                    mp.p.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    diag.p.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "width={width} n={n} m={m}"
                );
                assert_eq!(mp.i, diag.i, "width={width} n={n} m={m}");
                assert_eq!(work, wd, "width={width}: accounting must not depend on tiling");
            }
        });
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn band_tile_overhanging_nw_panics() {
        // legal width, but the tile hangs past the last diagonal
        let t: Vec<f64> = Rng::new(60).gauss_vec(64);
        let st = sliding_stats(&t, 8);
        let nw = st.len();
        let mut mp = MatrixProfile::new_inf(nw, 8, 2);
        let mut w = WorkStats::default();
        compute_band_n(&t, &st, nw - 2, 3, &mut mp, &mut w);
    }

    #[test]
    #[should_panic(expected = "band width")]
    fn band_width_above_band_panics() {
        // the width-dispatch guard itself: widths beyond BAND have no
        // monomorphization and must be rejected
        let t: Vec<f64> = Rng::new(60).gauss_vec(64);
        let st = sliding_stats(&t, 8);
        let nw = st.len();
        let mut mp = MatrixProfile::new_inf(nw, 8, 2);
        let mut w = WorkStats::default();
        compute_band_n(&t, &st, 2, BAND + 1, &mut mp, &mut w);
    }

    #[test]
    fn band_seam_lengths_agree_with_brute() {
        // window counts straddling BAND multiples exercise every driver
        // fallback (whole bands, partial remainder, no band at all)
        let mut rng = Rng::new(61);
        let m = 8;
        for n in (12..46).chain([
            2 * m + 8 * BAND,
            2 * m + 8 * BAND + 1,
            2 * m + 8 * BAND + BAND - 1,
        ]) {
            let t: Vec<f64> = rng.gauss_vec(n);
            let cfg = MpConfig::with_excl(m, 2);
            let (got, _) = banded_profile(&t, cfg);
            let (diag, _) = diag_profile(&t, cfg, compute_diagonal);
            assert!(got.max_abs_diff(&diag) == 0.0, "n={n}");
            assert_eq!(got.i, diag.i, "n={n}");
            let want = brute::matrix_profile(&t, cfg).unwrap();
            assert!(got.max_abs_diff(&want) < 1e-7, "n={n}");
        }
    }

    #[test]
    fn all_constant_series_degenerates_to_sqrt_2m() {
        // every window constant: za = zb = 0, so every distance must be
        // exactly sqrt(2m) by the degeneracy convention (inv_msig edge);
        // indices may differ between paths (every cell ties) but values
        // must not
        for m in [4usize, 16, 64] {
            let t = vec![3.25f64; 6 * m + 8 * BAND];
            let cfg = MpConfig::new(m);
            let (got, _) = banded_profile(&t, cfg);
            let expect = (2.0 * m as f64).sqrt();
            assert!(got.p.iter().all(|&d| (d - expect).abs() < 1e-12), "m={m}");
            let (diag, _) = diag_profile(&t, cfg, compute_diagonal);
            assert!(got.max_abs_diff(&diag) == 0.0, "m={m}");
            let (sca, _) = diag_profile(&t, cfg, scalar_diagonal);
            assert!(got.max_abs_diff(&sca) < 1e-12, "m={m}");
        }
    }

    #[test]
    fn constant_window_inside_noise_matches_scalar() {
        // a flat plateau long enough to make some (not all) windows
        // constant: the za = zb = 0 rows must mix correctly with live
        // ones.  NOTE: the brute oracle z-normalizes constant windows to
        // zeros — a different degeneracy convention from the engines'
        // corr = 0 => d² = 2m — so plateau inputs are only comparable
        // within the engine family.
        let mut rng = Rng::new(62);
        let m = 16;
        let mut t: Vec<f64> = rng.gauss_vec(700);
        for x in t[200..200 + 3 * m].iter_mut() {
            *x = 1.5;
        }
        let cfg = MpConfig::new(m);
        let (got, _) = banded_profile(&t, cfg);
        let (diag, _) = diag_profile(&t, cfg, compute_diagonal);
        assert!(got.max_abs_diff(&diag) == 0.0);
        let (sca, _) = diag_profile(&t, cfg, scalar_diagonal);
        assert!(got.max_abs_diff(&sca) < 1e-9, "{}", got.max_abs_diff(&sca));
        assert!(got.p.iter().all(|d| d.is_finite()));
    }

    /// Streaming driver for the row-kernel tests: advance a stream over
    /// plain vectors one tile at a time through `f`, which receives the
    /// tile view and the tile width.  Stats come from the shared batch
    /// precompute so row results are comparable to the batch paths.
    struct RowState<T> {
        q: Vec<T>,
        p: Vec<T>,
        i: Vec<i64>,
        work: WorkStats,
    }

    impl<T: Real> RowState<T> {
        fn new() -> Self {
            RowState { q: vec![], p: vec![], i: vec![], work: WorkStats::default() }
        }

        /// Grow by `rows` windows and run one tile over the whole state.
        fn tile(&mut self, t: &[T], st: &WindowStats<T>, excl: usize, rows: usize) {
            for _ in 0..rows {
                self.q.push(T::zero());
                self.p.push(T::infinity());
                self.i.push(-1);
            }
            let nw = self.p.len();
            let tile = RowTile {
                t: &t[..nw + st.m - 1],
                za: &st.za[..nw],
                zb: &st.zb[..nw],
                q: &mut self.q,
                p: &mut self.p,
                i: &mut self.i,
                base: 0,
            };
            compute_row_n(tile, rows, st.m, excl, &mut self.work);
        }

        /// Grow by one window and run the scalar oracle row.
        fn oracle_row(&mut self, t: &[T], st: &WindowStats<T>, excl: usize) {
            self.q.push(T::zero());
            self.p.push(T::infinity());
            self.i.push(-1);
            let nw = self.p.len();
            let tile = RowTile {
                t: &t[..nw + st.m - 1],
                za: &st.za[..nw],
                zb: &st.zb[..nw],
                q: &mut self.q,
                p: &mut self.p,
                i: &mut self.i,
                base: 0,
            };
            scalar_row(tile, st.m, excl, &mut self.work);
        }

        fn bits(&self) -> (Vec<u64>, Vec<u64>, Vec<i64>) {
            (
                self.q.iter().map(|x| x.to_f64s().to_bits()).collect(),
                self.p.iter().map(|x| x.to_f64s().to_bits()).collect(),
                self.i.clone(),
            )
        }
    }

    #[test]
    fn prop_row_tile_every_width_bit_identical_to_scalar_row() {
        // The streaming tentpole invariant: a multi-row tile of ANY
        // width 1..=min(BAND, excl) leaves exactly the state (profile
        // values, neighbor indices, q chains, WorkStats) that the
        // retained scalar row walk leaves after the same appends —
        // checked after EVERY tile, so young-stream edges (zero-cell
        // warm-up rows, partially admissible columns) are pinned too.
        check("row-tile-width-bits", 6, |rng: &mut Rng| {
            let m = rng.range(4, 40);
            let excl = rng.range(1, 2 * BAND + 1).min(m); // spans < and > BAND
            let n = rng.range(3 * m + 4 * BAND, 500.max(3 * m + 4 * BAND + 1));
            let t: Vec<f64> = rng.gauss_vec(n);
            let st = sliding_stats(&t, m);
            let nw = st.len();
            let wmax = BAND.min(excl);
            for width in 1..=wmax {
                let mut orc = RowState::<f64>::new();
                let mut sub = RowState::<f64>::new();
                let mut done = 0usize;
                while done < nw {
                    let rows = width.min(nw - done);
                    sub.tile(&t, &st, excl, rows);
                    for _ in 0..rows {
                        orc.oracle_row(&t, &st, excl);
                    }
                    done += rows;
                    assert_eq!(sub.bits(), orc.bits(), "width={width} after {done} rows");
                    assert_eq!(sub.work, orc.work, "width={width} accounting after {done}");
                }
            }
        });
    }

    #[test]
    fn row_tile_width_sweep_bit_identical_f32() {
        // single-precision spot check of the same invariant
        let t: Vec<f32> = Rng::new(66).gauss_vec(400).iter().map(|&x| x as f32).collect();
        let m = 16;
        let excl = 8;
        let st = sliding_stats(&t, m);
        let nw = st.len();
        for width in 1..=BAND.min(excl) {
            let mut orc = RowState::<f32>::new();
            let mut sub = RowState::<f32>::new();
            let mut done = 0usize;
            while done < nw {
                let rows = width.min(nw - done);
                sub.tile(&t, &st, excl, rows);
                for _ in 0..rows {
                    orc.oracle_row(&t, &st, excl);
                }
                done += rows;
            }
            assert_eq!(sub.bits(), orc.bits(), "width={width}");
            assert_eq!(sub.work, orc.work, "width={width}");
        }
    }

    #[test]
    fn row_tiles_on_constant_plateau_keep_scalar_tie_order() {
        // exact distance ties (flat plateau => equal d² = 2m cells) are
        // where merge order could diverge; indices must still match the
        // scalar walk bit-for-bit at every width
        let mut rng = Rng::new(67);
        let m = 8;
        let excl = 4;
        let mut t: Vec<f64> = rng.gauss_vec(300);
        for x in t[100..100 + 4 * m].iter_mut() {
            *x = -0.75;
        }
        let st = sliding_stats(&t, m);
        let nw = st.len();
        for width in 1..=BAND.min(excl) {
            let mut orc = RowState::<f64>::new();
            let mut sub = RowState::<f64>::new();
            let mut done = 0usize;
            while done < nw {
                let rows = width.min(nw - done);
                sub.tile(&t, &st, excl, rows);
                for _ in 0..rows {
                    orc.oracle_row(&t, &st, excl);
                }
                done += rows;
            }
            assert_eq!(sub.bits(), orc.bits(), "width={width}");
        }
    }

    #[test]
    fn streaming_rows_reproduce_batch_kernel_to_the_bit() {
        // The conformance keystone: a full stream driven through row
        // tiles computes the exact same chains (seed_dot at column 0 =
        // the batch diagonal seed; lane pulls = the delta-form Eq. 2
        // steps) and the exact same folded Eq. 1 cells as the batch band
        // sweep, so with shared statistics the profiles must agree to
        // the BIT — values and neighbor indices.
        let mut rng = Rng::new(68);
        let t: Vec<f64> = rng.gauss_vec(1100);
        let m = 24;
        let cfg = MpConfig::new(m);
        let excl = cfg.exclusion(); // 6 — admits widths up to 6
        let (batch, wb) = banded_profile(&t, cfg);
        let st = sliding_stats(&t, m);
        let nw = st.len();
        for width in [1usize, 3, BAND.min(excl)] {
            let mut sub = RowState::<f64>::new();
            let mut done = 0usize;
            while done < nw {
                let rows = width.min(nw - done);
                sub.tile(&t, &st, excl, rows);
                done += rows;
            }
            let mut p = sub.p.clone();
            for v in p.iter_mut() {
                if v.is_finite() {
                    *v = v.sqrt();
                }
            }
            assert_eq!(
                p.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                batch.p.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "width={width}"
            );
            assert_eq!(sub.i, batch.i, "width={width}");
            assert_eq!(sub.work, wb, "width={width}: accounting must match batch");
        }
    }

    #[test]
    fn row_tile_base_offsets_neighbor_indices() {
        // compaction story: `base` rebases every written index, nothing
        // else — the same tile at base 0 and base 1000 differs exactly
        // by the shift
        let t: Vec<f64> = Rng::new(69).gauss_vec(200);
        let m = 8;
        let excl = 2;
        let st = sliding_stats(&t, m);
        let nw = st.len();
        let run = |base: i64| -> (Vec<u64>, Vec<i64>) {
            let mut s = RowState::<f64>::new();
            let mut done = 0usize;
            while done < nw {
                let rows = 2.min(nw - done);
                for _ in 0..rows {
                    s.q.push(0.0);
                    s.p.push(f64::INFINITY);
                    s.i.push(-1);
                }
                let len = s.p.len();
                let tile = RowTile {
                    t: &t[..len + m - 1],
                    za: &st.za[..len],
                    zb: &st.zb[..len],
                    q: &mut s.q,
                    p: &mut s.p,
                    i: &mut s.i,
                    base,
                };
                compute_row_n(tile, rows, m, excl, &mut s.work);
                done += rows;
            }
            (s.p.iter().map(|x| x.to_bits()).collect(), s.i)
        };
        let (p0, i0) = run(0);
        let (p1, i1) = run(1000);
        assert_eq!(p0, p1);
        for (a, b) in i0.iter().zip(&i1) {
            if *a >= 0 {
                assert_eq!(*a + 1000, *b);
            } else {
                assert_eq!(*a, *b);
            }
        }
    }

    #[test]
    #[should_panic(expected = "order-free merges")]
    fn row_tile_wider_than_exclusion_panics() {
        // rows > excl would let column updates race row write-backs on
        // ties; the guard must reject it
        let t: Vec<f64> = Rng::new(60).gauss_vec(64);
        let st = sliding_stats(&t, 8);
        let nw = st.len();
        let mut q = vec![0.0; nw];
        let mut p = vec![f64::INFINITY; nw];
        let mut i = vec![-1i64; nw];
        let mut w = WorkStats::default();
        let tile = RowTile { t: &t, za: &st.za, zb: &st.zb, q: &mut q, p: &mut p, i: &mut i, base: 0 };
        compute_row_n(tile, 4, 8, 2, &mut w);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn row_tile_wider_than_band_panics() {
        let t: Vec<f64> = Rng::new(60).gauss_vec(64);
        let st = sliding_stats(&t, 8);
        let nw = st.len();
        let mut q = vec![0.0; nw];
        let mut p = vec![f64::INFINITY; nw];
        let mut i = vec![-1i64; nw];
        let mut w = WorkStats::default();
        let tile = RowTile { t: &t, za: &st.za, zb: &st.zb, q: &mut q, p: &mut p, i: &mut i, base: 0 };
        compute_row_n(tile, BAND + 1, 8, 16, &mut w);
    }

    #[test]
    fn seed_dot_matches_naive() {
        let mut rng = Rng::new(63);
        let t: Vec<f64> = rng.gauss_vec(200);
        for (d, m) in [(5usize, 7usize), (9, 16), (50, 33), (1, 4)] {
            let naive = (0..m).map(|k| t[k] * t[d + k]).sum::<f64>();
            assert!((seed_dot(&t, d, m) - naive).abs() < 1e-10, "d={d} m={m}");
        }
    }

    #[test]
    fn small_exclusion_overlapping_directions_match_scalar() {
        // excl << BAND: row and column targets interleave densely; the
        // two-pass merges must still produce the exact two-sided min
        let mut rng = Rng::new(64);
        let t: Vec<f64> = rng.gauss_vec(900);
        let cfg = MpConfig::with_excl(8, 2);
        let (got, _) = banded_profile(&t, cfg);
        let (sca, _) = diag_profile(&t, cfg, scalar_diagonal);
        assert!(got.max_abs_diff(&sca) < 1e-9);
        for (k, &j) in got.i.iter().enumerate() {
            assert!(j >= 0 && (k as i64 - j).unsigned_abs() >= 2);
        }
    }

    #[test]
    fn shuffled_diagonal_order_is_bit_stable() {
        // scheduled execution visits diagonals in arbitrary order; values
        // must not depend on it
        let mut rng = Rng::new(65);
        let t: Vec<f64> = rng.gauss_vec(600);
        let cfg = MpConfig::new(12);
        let nw = cfg.validate(t.len()).unwrap();
        let excl = cfg.exclusion();
        let st = sliding_stats(&t, 12);
        let mut fwd = MatrixProfile::new_inf(nw, 12, excl);
        let mut rev = MatrixProfile::new_inf(nw, 12, excl);
        let mut w = WorkStats::default();
        for d in excl..nw {
            compute_diagonal(&t, &st, d, &mut fwd, &mut w);
        }
        for d in (excl..nw).rev() {
            compute_diagonal(&t, &st, d, &mut rev, &mut w);
        }
        fwd.sqrt_in_place();
        rev.sqrt_in_place();
        assert!(fwd.max_abs_diff(&rev) == 0.0);
        assert_eq!(fwd.i, rev.i);
    }

    /// Grow every still-short lane by one window and run ONE group tile
    /// over the active lanes (the cross-stream driver the service's
    /// coalescing loop mirrors).  Returns how many lanes participated.
    fn group_step<T: Real>(
        series: &[Vec<T>],
        sts: &[WindowStats<T>],
        states: &mut [RowState<T>],
        m: usize,
        excl: usize,
    ) -> usize {
        let grew: Vec<bool> = states
            .iter_mut()
            .zip(sts)
            .map(|(s, st)| {
                if s.p.len() < st.len() {
                    s.q.push(T::zero());
                    s.p.push(T::infinity());
                    s.i.push(-1);
                    true
                } else {
                    false
                }
            })
            .collect();
        let mut lanes: Vec<GroupLane<'_, T>> = states
            .iter_mut()
            .enumerate()
            .filter(|(w, _)| grew[*w])
            .map(|(w, s)| {
                let nw = s.p.len();
                let RowState { q, p, i, work } = s;
                GroupLane {
                    tile: RowTile {
                        t: &series[w][..nw + m - 1],
                        za: &sts[w].za[..nw],
                        zb: &sts[w].zb[..nw],
                        q,
                        p,
                        i,
                        base: 0,
                    },
                    work,
                }
            })
            .collect();
        let n = lanes.len();
        compute_row_group(&mut lanes, m, excl);
        n
    }

    #[test]
    fn prop_group_tile_bit_identical_to_per_stream_scalar_rows() {
        // The cross-stream tentpole invariant: a group tile over N
        // INDEPENDENT streams leaves each stream exactly the state its
        // own scalar row walk leaves — profile bits, neighbor indices,
        // q chains, and per-stream WorkStats — across group widths both
        // below and above BAND (exercising the chunked dispatch),
        // heterogeneous stream lengths (lanes drop out at different
        // steps), and warm-up lanes with zero admissible cells.
        check("group-tile-bits", 6, |rng: &mut Rng| {
            let m = rng.range(4, 24);
            let excl = rng.range(1, BAND + 3).min(m);
            let lanes = rng.range(2, 2 * BAND + 3); // spans > BAND
            let series: Vec<Vec<f64>> = (0..lanes)
                .map(|_| {
                    let n = rng.range(m + 1, 160);
                    rng.gauss_vec(n)
                })
                .collect();
            let sts: Vec<WindowStats<f64>> =
                series.iter().map(|t| sliding_stats(t, m)).collect();
            let mut grp: Vec<RowState<f64>> = (0..lanes).map(|_| RowState::new()).collect();
            let mut orc: Vec<RowState<f64>> = (0..lanes).map(|_| RowState::new()).collect();
            while group_step(&series, &sts, &mut grp, m, excl) > 0 {}
            for (w, st) in sts.iter().enumerate() {
                for _ in 0..st.len() {
                    orc[w].oracle_row(&series[w], st, excl);
                }
            }
            for w in 0..lanes {
                assert_eq!(grp[w].bits(), orc[w].bits(), "lane {w} of {lanes}, m={m} excl={excl}");
                assert_eq!(grp[w].work, orc[w].work, "lane {w} accounting");
            }
        });
    }

    #[test]
    fn group_tile_on_constant_plateau_keeps_scalar_tie_order() {
        // all-constant streams make every admissible cell an exact tie
        // (d² = 2m degeneracy); each lane's argmin choices must still
        // match its own scalar walk bit-for-bit
        let m = 8;
        let excl = 3;
        let series: Vec<Vec<f64>> = (0..5).map(|w| vec![w as f64 + 1.0; 60]).collect();
        let sts: Vec<WindowStats<f64>> = series.iter().map(|t| sliding_stats(t, m)).collect();
        let mut grp: Vec<RowState<f64>> = (0..5).map(|_| RowState::new()).collect();
        let mut orc: Vec<RowState<f64>> = (0..5).map(|_| RowState::new()).collect();
        while group_step(&series, &sts, &mut grp, m, excl) > 0 {}
        for (w, st) in sts.iter().enumerate() {
            for _ in 0..st.len() {
                orc[w].oracle_row(&series[w], st, excl);
            }
        }
        for w in 0..5 {
            assert_eq!(grp[w].bits(), orc[w].bits(), "lane {w}");
        }
    }

    #[test]
    fn group_tile_bit_identical_f32() {
        // single-precision spot check of the cross-stream invariant
        let mut rng = Rng::new(59);
        let m = 12;
        let excl = 3;
        let series: Vec<Vec<f32>> = (0..9)
            .map(|_| rng.gauss_vec(140).iter().map(|&x| x as f32).collect())
            .collect();
        let sts: Vec<WindowStats<f32>> = series.iter().map(|t| sliding_stats(t, m)).collect();
        let mut grp: Vec<RowState<f32>> = (0..9).map(|_| RowState::new()).collect();
        let mut orc: Vec<RowState<f32>> = (0..9).map(|_| RowState::new()).collect();
        while group_step(&series, &sts, &mut grp, m, excl) > 0 {}
        for (w, st) in sts.iter().enumerate() {
            for _ in 0..st.len() {
                orc[w].oracle_row(&series[w], st, excl);
            }
        }
        for w in 0..9 {
            assert_eq!(grp[w].bits(), orc[w].bits(), "lane {w}");
            assert_eq!(grp[w].work, orc[w].work, "lane {w}");
        }
    }
}
