//! Synchronization facade: every concurrent module imports its
//! primitives from here instead of `std::sync`, so the whole crate can
//! be compiled against [loom](https://docs.rs/loom)'s model-checked
//! replacements with `RUSTFLAGS="--cfg loom"` (see
//! `docs/CONCURRENCY.md` and `rust/tests/loom_service.rs`) while normal
//! builds keep the zero-cost `std` types.
//!
//! Two things live here besides the re-exports:
//!
//! * the crate's **poison policy** ([`lock_ok`] / [`wait_ok`] /
//!   [`try_lock_ok`] / [`wait_timeout_ok`]): a worker panic is contained
//!   by the quarantine protocol (failed job + quarantined stream), so
//!   guarded state is still consistent — blocking every later
//!   `wait`/`poll`/`append_stream` behind a `PoisonError` would turn one
//!   bad job into a dead shard.  The repo lint (`tools/lint`) rejects
//!   naked `.lock().unwrap()` / Condvar-wait unwraps outside this
//!   module, so the policy cannot silently regress;
//! * a `cfg(loom)` [`mpsc`] shim: loom has no channel types, so under
//!   the model checker the std channel API is emulated on loom's own
//!   `Mutex`/`Condvar` (same blocking semantics, fully modeled).
//!
//! ## Lock hierarchy
//!
//! The coordinator's documented lock order (enforced by `tools/lint`,
//! modeled by the loom tests, prose in `docs/CONCURRENCY.md`):
//!
//! ```text
//! shard.streams (map)  →  entry.submit_seq  →  entry.state  →  sub-box state
//! ```
//!
//! plus two leaf locks that never take others while held: the WAL
//! writer cell (taken under `entry.state`) and the slot store / slot
//! state pair (`shard.slots` → `slot.state`, disjoint from the stream
//! chain).  `try_lock` acquisitions (the coalescing group pass) are
//! exempt: they cannot deadlock by definition and bail out instead of
//! blocking.

#[cfg(not(loom))]
pub use std::sync::{atomic, mpsc, Arc, Condvar, Mutex, MutexGuard, OnceLock};
#[cfg(not(loom))]
pub use std::thread;

#[cfg(loom)]
pub use loom::sync::{atomic, Arc, Condvar, Mutex, MutexGuard};
#[cfg(loom)]
pub use loom::thread;
// loom has no OnceLock replacement; the std one stays in loom builds.
// Its only consumer is the PJRT engine's lazy worker pool, which no
// loom model constructs — pool init is engine-internal, not part of
// the coordinator protocols under test.
#[cfg(loom)]
pub use std::sync::OnceLock;

use std::time::Duration;

/// Lock that shrugs off poisoning (see the module docs for why the
/// coordinator treats a poisoned mutex as recoverable).
#[cfg(not(loom))]
pub fn lock_ok<'a, U>(m: &'a Mutex<U>) -> MutexGuard<'a, U> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Loom build: loom mutexes mirror the std API but never poison.
#[cfg(loom)]
pub fn lock_ok<'a, U>(m: &'a Mutex<U>) -> MutexGuard<'a, U> {
    m.lock().expect("loom mutexes do not poison")
}

/// Condvar wait with the same poison policy as [`lock_ok`].
#[cfg(not(loom))]
pub fn wait_ok<'a, U>(cv: &Condvar, g: MutexGuard<'a, U>) -> MutexGuard<'a, U> {
    cv.wait(g).unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(loom)]
pub fn wait_ok<'a, U>(cv: &Condvar, g: MutexGuard<'a, U>) -> MutexGuard<'a, U> {
    cv.wait(g).expect("loom mutexes do not poison")
}

/// Condvar wait with a timeout and [`lock_ok`]'s poison policy; the
/// bool is `true` when the wait timed out (the caller re-checks its
/// predicate either way — timeouts and wakeups race by nature).
#[cfg(not(loom))]
pub fn wait_timeout_ok<'a, U>(
    cv: &Condvar,
    g: MutexGuard<'a, U>,
    dur: Duration,
) -> (MutexGuard<'a, U>, bool) {
    let (g, res) = cv
        .wait_timeout(g, dur)
        .unwrap_or_else(std::sync::PoisonError::into_inner);
    (g, res.timed_out())
}

#[cfg(loom)]
pub fn wait_timeout_ok<'a, U>(
    cv: &Condvar,
    g: MutexGuard<'a, U>,
    dur: Duration,
) -> (MutexGuard<'a, U>, bool) {
    let (g, res) = cv
        .wait_timeout(g, dur)
        .expect("loom mutexes do not poison");
    (g, res.timed_out())
}

/// `try_lock` with [`lock_ok`]'s poison policy; `None` only when the
/// lock is actually held elsewhere.
#[cfg(not(loom))]
pub fn try_lock_ok<U>(m: &Mutex<U>) -> Option<MutexGuard<'_, U>> {
    match m.try_lock() {
        Ok(g) => Some(g),
        Err(std::sync::TryLockError::Poisoned(e)) => Some(e.into_inner()),
        Err(std::sync::TryLockError::WouldBlock) => None,
    }
}

#[cfg(loom)]
pub fn try_lock_ok<U>(m: &Mutex<U>) -> Option<MutexGuard<'_, U>> {
    m.try_lock().ok()
}

/// Minimal `std::sync::mpsc` stand-in for loom builds, implemented on
/// loom's own `Mutex`/`Condvar` so channel waits are part of the
/// explored interleavings.  Only the surface this crate uses:
/// `channel`/`sync_channel`, blocking `recv`, `try_recv`, `send`,
/// `try_send`, sender cloning, and disconnect-on-drop semantics.
#[cfg(loom)]
pub mod mpsc {
    use super::{lock_ok, wait_ok, Arc, Condvar, Mutex};
    use std::collections::VecDeque;

    /// Identical shape to `std::sync::mpsc::TrySendError`.
    #[derive(Debug)]
    pub enum TrySendError<T> {
        Full(T),
        Disconnected(T),
    }

    /// Identical shape to `std::sync::mpsc::SendError`.
    #[derive(Debug)]
    pub struct SendError<T>(pub T);

    /// Identical shape to `std::sync::mpsc::RecvError`.
    #[derive(Debug)]
    pub struct RecvError;

    /// Identical shape to `std::sync::mpsc::TryRecvError`.
    #[derive(Debug)]
    pub enum TryRecvError {
        Empty,
        Disconnected,
    }

    struct Inner<T> {
        queue: VecDeque<T>,
        senders: usize,
        receiver_alive: bool,
    }

    struct Chan<T> {
        inner: Mutex<Inner<T>>,
        cv: Condvar,
        cap: Option<usize>,
    }

    pub struct Sender<T>(Arc<Chan<T>>);
    pub struct SyncSender<T>(Arc<Chan<T>>);
    pub struct Receiver<T>(Arc<Chan<T>>);

    fn new_chan<T>(cap: Option<usize>) -> Arc<Chan<T>> {
        Arc::new(Chan {
            inner: Mutex::new(Inner {
                queue: VecDeque::new(),
                senders: 1,
                receiver_alive: true,
            }),
            cv: Condvar::new(),
            cap,
        })
    }

    pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
        let ch = new_chan(None);
        (Sender(ch.clone()), Receiver(ch))
    }

    pub fn sync_channel<T>(cap: usize) -> (SyncSender<T>, Receiver<T>) {
        let ch = new_chan(Some(cap));
        (SyncSender(ch.clone()), Receiver(ch))
    }

    fn clone_half<T>(ch: &Arc<Chan<T>>) -> Arc<Chan<T>> {
        lock_ok(&ch.inner).senders += 1;
        ch.clone()
    }

    fn drop_sender<T>(ch: &Chan<T>) {
        let mut g = lock_ok(&ch.inner);
        g.senders -= 1;
        if g.senders == 0 {
            ch.cv.notify_all();
        }
    }

    impl<T> Clone for Sender<T> {
        fn clone(&self) -> Self {
            Sender(clone_half(&self.0))
        }
    }

    impl<T> Clone for SyncSender<T> {
        fn clone(&self) -> Self {
            SyncSender(clone_half(&self.0))
        }
    }

    impl<T> Drop for Sender<T> {
        fn drop(&mut self) {
            drop_sender(&self.0);
        }
    }

    impl<T> Drop for SyncSender<T> {
        fn drop(&mut self) {
            drop_sender(&self.0);
        }
    }

    impl<T> Drop for Receiver<T> {
        fn drop(&mut self) {
            let mut g = lock_ok(&self.0.inner);
            g.receiver_alive = false;
            drop(g);
            self.0.cv.notify_all();
        }
    }

    impl<T> Sender<T> {
        pub fn send(&self, t: T) -> Result<(), SendError<T>> {
            let mut g = lock_ok(&self.0.inner);
            if !g.receiver_alive {
                return Err(SendError(t));
            }
            g.queue.push_back(t);
            drop(g);
            self.0.cv.notify_all();
            Ok(())
        }
    }

    impl<T> SyncSender<T> {
        pub fn try_send(&self, t: T) -> Result<(), TrySendError<T>> {
            let cap = self.0.cap.unwrap_or(usize::MAX).max(1);
            let mut g = lock_ok(&self.0.inner);
            if !g.receiver_alive {
                return Err(TrySendError::Disconnected(t));
            }
            if g.queue.len() >= cap {
                return Err(TrySendError::Full(t));
            }
            g.queue.push_back(t);
            drop(g);
            self.0.cv.notify_all();
            Ok(())
        }
    }

    impl<T> Receiver<T> {
        pub fn recv(&self) -> Result<T, RecvError> {
            let mut g = lock_ok(&self.0.inner);
            loop {
                if let Some(t) = g.queue.pop_front() {
                    return Ok(t);
                }
                if g.senders == 0 {
                    return Err(RecvError);
                }
                g = wait_ok(&self.0.cv, g);
            }
        }

        pub fn try_recv(&self) -> Result<T, TryRecvError> {
            let mut g = lock_ok(&self.0.inner);
            match g.queue.pop_front() {
                Some(t) => Ok(t),
                None if g.senders == 0 => Err(TryRecvError::Disconnected),
                None => Err(TryRecvError::Empty),
            }
        }
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn lock_ok_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        assert_eq!(*lock_ok(&m), 7);
        assert_eq!(*try_lock_ok(&m).expect("free lock"), 7);
    }

    #[test]
    fn try_lock_ok_is_none_only_when_held() {
        let m = Mutex::new(1u32);
        let g = lock_ok(&m);
        assert!(try_lock_ok(&m).is_none());
        drop(g);
        assert!(try_lock_ok(&m).is_some());
    }

    #[test]
    fn wait_timeout_ok_reports_timeout() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = lock_ok(&m);
        let (_g, timed_out) = wait_timeout_ok(&cv, g, Duration::from_millis(1));
        assert!(timed_out);
    }
}
