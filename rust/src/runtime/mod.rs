//! Request-path runtime: load and execute the AOT-compiled kernels.
//!
//! `make artifacts` (python, build-time) lowers the Layer-1/Layer-2 Pallas
//! + JAX graphs to **HLO text** under `artifacts/` plus a `manifest.tsv`
//! describing each variant.  This module is everything the rust binary
//! needs at run time:
//!
//! * [`Manifest`] — parse the TSV, resolve `(kind, dtype, m)` to a file;
//! * [`Runtime`]  — executes the artifact set.
//!
//! Two interchangeable backends sit behind the same [`Runtime`] API:
//!
//! * **`xla-pjrt` feature** — the real thing: a PJRT CPU client that
//!   compiles each HLO module once (lazily, cached) and executes it with
//!   `xla::Literal` inputs.  Interchange is HLO *text*, never serialized
//!   protos: jax >= 0.5 emits 64-bit instruction ids that xla_extension
//!   0.5.1 rejects; the text parser reassigns ids (see
//!   /opt/xla-example/README.md and `python/compile/aot.py`).  The xla
//!   wrapper types hold raw pointers and are not `Send`; the coordinator
//!   therefore gives each worker thread its own [`Runtime`].  Enabling the
//!   feature requires the `xla` bindings crate, which is not in the
//!   offline vendor set — add it to `[dependencies]` by hand.
//! * **default (native interpreter)** — a dependency-free evaluator with
//!   the *same kernel semantics* (Eq. 2 dot-product chaining, Eq. 1
//!   distances, masked-lane +inf, PUU argmin pre-reduction), validated by
//!   the same `rust/tests/e2e_pjrt.rs` suite.  It still requires the
//!   artifact manifest so variant selection, error paths, and window
//!   support discovery behave identically to the PJRT backend.

use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::Real;

/// Kinds of AOT artifacts (matches `python/compile/aot.py`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// The PU pipeline over one diagonal chunk (hot path).
    DiagChunk,
    /// The DPU first dot product.
    DotInit,
    /// Sliding mean/std precompute.
    Stats,
    /// Self-contained small matrix profile (MXU-tile formulation).
    MpTile,
}

impl ArtifactKind {
    fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "diag_chunk" => ArtifactKind::DiagChunk,
            "dot_init" => ArtifactKind::DotInit,
            "stats" => ArtifactKind::Stats,
            "mp_tile" => ArtifactKind::MpTile,
            _ => return None,
        })
    }
}

/// One artifact entry from `manifest.tsv`.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub path: PathBuf,
    pub kind: ArtifactKind,
    pub dtype: String,
    pub m: usize,
    /// Chunk length V (diag_chunk only).
    pub v: usize,
    /// Fixed series length (stats / mp_tile only).
    pub n: usize,
}

/// The parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<Artifact>,
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: &Path) -> crate::Result<Manifest> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts` first)", path.display()))?;
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            anyhow::ensure!(f.len() >= 7, "manifest line {}: bad field count", lineno + 1);
            let kind = ArtifactKind::parse(f[2])
                .with_context(|| format!("manifest line {}: unknown kind {}", lineno + 1, f[2]))?;
            artifacts.push(Artifact {
                name: f[0].to_string(),
                path: dir.join(f[1]),
                kind,
                dtype: f[3].to_string(),
                m: f[4].parse().context("m")?,
                v: f[5].parse().context("v")?,
                n: f[6].parse().context("n")?,
            });
        }
        anyhow::ensure!(!artifacts.is_empty(), "empty manifest {}", path.display());
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// Find an artifact by kind/dtype and exact window length.  For the
    /// hot-path chunk kernel the *largest* available V is preferred:
    /// fewer kernel invocations per diagonal (perf pass, EXPERIMENTS.md).
    pub fn find(&self, kind: ArtifactKind, dtype: &str, m: usize) -> Option<&Artifact> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == kind && a.dtype == dtype && a.m == m)
            .max_by_key(|a| a.v)
    }

    /// All diag_chunk variants for (dtype, m), sorted by ascending V.
    pub fn chunk_variants(&self, dtype: &str, m: usize) -> Vec<&Artifact> {
        let mut v: Vec<&Artifact> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::DiagChunk && a.dtype == dtype && a.m == m)
            .collect();
        v.sort_by_key(|a| a.v);
        v
    }

    /// Window lengths available for the hot-path chunk kernel.
    pub fn chunk_windows(&self, dtype: &str) -> Vec<usize> {
        let mut ms: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::DiagChunk && a.dtype == dtype)
            .map(|a| a.m)
            .collect();
        ms.sort_unstable();
        ms
    }
}

/// Outputs of one `diag_chunk` kernel invocation.
#[derive(Clone, Debug)]
pub struct DiagChunkOut<T> {
    /// Distances for the chunk's cells (+inf on masked lanes).
    pub dists: Vec<T>,
    /// Dot product at the last valid cell (chains into the next chunk).
    pub q_last: T,
    /// PUU pre-reduction over the chunk.
    pub min_val: T,
    pub min_idx: i32,
}

/// Default artifact directory: `$NATSA_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("NATSA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

// ---------------------------------------------------------------------------
// PJRT backend (feature `xla-pjrt`): compile + execute the HLO artifacts.
// ---------------------------------------------------------------------------
#[cfg(feature = "xla-pjrt")]
mod backend {
    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::path::Path;
    use std::rc::Rc;

    use anyhow::Context;

    use super::{ArtifactKind, DiagChunkOut, Manifest};
    use crate::Real;

    /// Element types the runtime can feed to PJRT.
    pub trait XlaReal: Real + xla::NativeType + xla::ArrayElement {}
    impl XlaReal for f32 {}
    impl XlaReal for f64 {}

    /// A PJRT CPU runtime over one artifact directory.
    pub struct Runtime {
        client: xla::PjRtClient,
        manifest: Manifest,
        cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    }

    impl Runtime {
        /// Create a runtime for `artifacts/` (compiles lazily on first use).
        pub fn new(artifact_dir: &Path) -> crate::Result<Runtime> {
            let manifest = Manifest::load(artifact_dir)?;
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            Ok(Runtime {
                client,
                manifest,
                cache: RefCell::new(HashMap::new()),
            })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// Compile (or fetch from cache) an executable by artifact name.
        pub fn executable(&self, name: &str) -> crate::Result<Rc<xla::PjRtLoadedExecutable>> {
            if let Some(exe) = self.cache.borrow().get(name) {
                return Ok(exe.clone());
            }
            let art = self
                .manifest
                .artifacts
                .iter()
                .find(|a| a.name == name)
                .with_context(|| format!("unknown artifact '{name}'"))?;
            let proto = xla::HloModuleProto::from_text_file(&art.path)
                .with_context(|| format!("parse HLO text {}", art.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = Rc::new(
                self.client
                    .compile(&comp)
                    .with_context(|| format!("PJRT compile {name}"))?,
            );
            self.cache.borrow_mut().insert(name.to_string(), exe.clone());
            Ok(exe)
        }

        fn run(&self, name: &str, inputs: &[xla::Literal]) -> crate::Result<Vec<xla::Literal>> {
            let exe = self.executable(name)?;
            let result = exe
                .execute::<xla::Literal>(inputs)
                .with_context(|| format!("execute {name}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .with_context(|| format!("fetch result of {name}"))?;
            // aot.py lowers with return_tuple=True: always a tuple.
            Ok(lit.to_tuple()?)
        }

        /// Execute the DPU first-dot-product kernel.
        pub fn dot_init<T: XlaReal>(&self, m: usize, ta: &[T], tb: &[T]) -> crate::Result<T> {
            anyhow::ensure!(ta.len() == m && tb.len() == m, "dot_init wants length-m slices");
            let art = self
                .manifest
                .find(ArtifactKind::DotInit, T::DTYPE, m)
                .with_context(|| format!("no dot_init artifact for {} m={m}", T::DTYPE))?;
            let name = art.name.clone();
            let out = self.run(&name, &[xla::Literal::vec1(ta), xla::Literal::vec1(tb)])?;
            Ok(out[0].to_vec::<T>()?[0])
        }

        /// Execute the PU pipeline over one diagonal chunk.
        #[allow(clippy::too_many_arguments)]
        pub fn diag_chunk<T: XlaReal>(
            &self,
            m: usize,
            v_want: Option<usize>,
            ta: &[T],
            tb: &[T],
            mu_a: &[T],
            sig_a: &[T],
            mu_b: &[T],
            sig_b: &[T],
            q0: T,
            nvalid: usize,
        ) -> crate::Result<DiagChunkOut<T>> {
            let art = super::resolve_chunk_artifact(&self.manifest, T::DTYPE, m, v_want)?;
            let v = art.v;
            super::check_chunk_inputs(v, m, ta, tb, mu_a, sig_a, mu_b, sig_b, nvalid)?;
            let name = art.name.clone();
            let out = self.run(
                &name,
                &[
                    xla::Literal::vec1(ta),
                    xla::Literal::vec1(tb),
                    xla::Literal::vec1(mu_a),
                    xla::Literal::vec1(sig_a),
                    xla::Literal::vec1(mu_b),
                    xla::Literal::vec1(sig_b),
                    xla::Literal::vec1(&[q0]),
                    xla::Literal::vec1(&[nvalid as i32]),
                ],
            )?;
            Ok(DiagChunkOut {
                dists: out[0].to_vec::<T>()?,
                q_last: out[1].to_vec::<T>()?[0],
                min_val: out[2].to_vec::<T>()?[0],
                min_idx: out[3].to_vec::<i32>()?[0],
            })
        }

        /// Execute the offloaded stats precompute (fixed demo length).
        pub fn stats<T: XlaReal>(&self, t: &[T]) -> crate::Result<(Vec<T>, Vec<T>)> {
            let art = super::resolve_fixed_artifact(&self.manifest, ArtifactKind::Stats, T::DTYPE, t.len())?;
            let name = art.name.clone();
            let out = self.run(&name, &[xla::Literal::vec1(t)])?;
            Ok((out[0].to_vec::<T>()?, out[1].to_vec::<T>()?))
        }

        /// Execute the self-contained MXU-tile matrix profile (fixed n).
        pub fn mp_tile<T: XlaReal>(&self, t: &[T]) -> crate::Result<(Vec<T>, Vec<i32>)> {
            let art = super::resolve_fixed_artifact(&self.manifest, ArtifactKind::MpTile, T::DTYPE, t.len())?;
            let name = art.name.clone();
            let out = self.run(&name, &[xla::Literal::vec1(t)])?;
            Ok((out[0].to_vec::<T>()?, out[1].to_vec::<i32>()?))
        }
    }
}

// ---------------------------------------------------------------------------
// Native backend (default): dependency-free evaluator with identical
// semantics — what the lowered kernels compute, computed directly.
// ---------------------------------------------------------------------------
#[cfg(not(feature = "xla-pjrt"))]
mod backend {
    use std::path::Path;

    use anyhow::Context;

    use super::{ArtifactKind, DiagChunkOut, Manifest};
    use crate::mp::znorm_dist;
    use crate::Real;

    /// Element types the runtime can execute (no extra bounds natively).
    pub trait XlaReal: Real {}
    impl XlaReal for f32 {}
    impl XlaReal for f64 {}

    /// A native runtime over one artifact directory.  The manifest is
    /// still mandatory — variant selection and the error surface must
    /// match the PJRT backend exactly.
    pub struct Runtime {
        manifest: Manifest,
    }

    impl Runtime {
        pub fn new(artifact_dir: &Path) -> crate::Result<Runtime> {
            Ok(Runtime { manifest: Manifest::load(artifact_dir)? })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// "Compile" an artifact by name: resolve it and verify its HLO
        /// text is present and readable (the native stand-in for a PJRT
        /// compile, so missing/broken artifact files still fail loudly).
        pub fn executable(&self, name: &str) -> crate::Result<()> {
            let art = self
                .manifest
                .artifacts
                .iter()
                .find(|a| a.name == name)
                .with_context(|| format!("unknown artifact '{name}'"))?;
            std::fs::metadata(&art.path)
                .with_context(|| format!("parse HLO text {}", art.path.display()))?;
            Ok(())
        }

        /// The DPU first dot product.
        pub fn dot_init<T: XlaReal>(&self, m: usize, ta: &[T], tb: &[T]) -> crate::Result<T> {
            anyhow::ensure!(ta.len() == m && tb.len() == m, "dot_init wants length-m slices");
            self.manifest
                .find(ArtifactKind::DotInit, T::DTYPE, m)
                .with_context(|| format!("no dot_init artifact for {} m={m}", T::DTYPE))?;
            Ok(ta.iter().zip(tb).map(|(&a, &b)| a * b).sum())
        }

        /// The PU pipeline over one diagonal chunk: Eq. 2 chains the dot
        /// product across the chunk, Eq. 1 turns each into a distance,
        /// masked lanes are +inf, and the PUU pre-reduces to the argmin.
        ///
        /// Input layout (same as the lowered kernel): `ta[x] = t[i0-1+x]`
        /// where `i0` is the chunk's first row — `ta[0]` is a dummy when
        /// `i0 == 0` and is never read (cell 0 uses `q0` directly).
        #[allow(clippy::too_many_arguments)]
        pub fn diag_chunk<T: XlaReal>(
            &self,
            m: usize,
            v_want: Option<usize>,
            ta: &[T],
            tb: &[T],
            mu_a: &[T],
            sig_a: &[T],
            mu_b: &[T],
            sig_b: &[T],
            q0: T,
            nvalid: usize,
        ) -> crate::Result<DiagChunkOut<T>> {
            let art = super::resolve_chunk_artifact(&self.manifest, T::DTYPE, m, v_want)?;
            let v = art.v;
            super::check_chunk_inputs(v, m, ta, tb, mu_a, sig_a, mu_b, sig_b, nvalid)?;

            let mf = m as f64;
            let inv = |sig: T| {
                if sig > T::zero() {
                    T::of_f64(1.0 / (mf * sig.to_f64s()))
                } else {
                    T::zero()
                }
            };
            let mut dists = vec![T::infinity(); v];
            let mut q = q0;
            let mut q_last = q0;
            for k in 0..nvalid {
                if k > 0 {
                    // Eq. 2: advance (i, j) -> (i+1, j+1) via the shifted
                    // views (t[i-1] = ta[k], t[i+m-1] = ta[k+m]).
                    q = q - ta[k] * tb[k] + ta[k + m] * tb[k + m];
                }
                dists[k] = znorm_dist(q, m, mu_a[k], inv(sig_a[k]), mu_b[k], inv(sig_b[k]));
                q_last = q;
            }
            let (min_idx, min_val) = dists
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(k, &d)| (k as i32, d))
                .unwrap_or((0, T::infinity()));
            Ok(DiagChunkOut { dists, q_last, min_val, min_idx })
        }

        /// The offloaded stats precompute (fixed demo length).
        pub fn stats<T: XlaReal>(&self, t: &[T]) -> crate::Result<(Vec<T>, Vec<T>)> {
            let art = super::resolve_fixed_artifact(&self.manifest, ArtifactKind::Stats, T::DTYPE, t.len())?;
            let st = crate::timeseries::sliding_stats(t, art.m);
            Ok((st.mu, st.sig))
        }

        /// The self-contained MXU-tile matrix profile (fixed n).
        pub fn mp_tile<T: XlaReal>(&self, t: &[T]) -> crate::Result<(Vec<T>, Vec<i32>)> {
            let art = super::resolve_fixed_artifact(&self.manifest, ArtifactKind::MpTile, T::DTYPE, t.len())?;
            let mp = crate::mp::stomp::matrix_profile(t, crate::mp::MpConfig::new(art.m))?;
            let i: Vec<i32> = mp.i.iter().map(|&j| j as i32).collect();
            Ok((mp.p, i))
        }
    }
}

pub use backend::{Runtime, XlaReal};

/// Resolve the diag_chunk artifact for `(dtype, m)`, honoring an exact-V
/// request when given (shared by both backends so errors are identical).
fn resolve_chunk_artifact<'a>(
    manifest: &'a Manifest,
    dtype: &str,
    m: usize,
    v_want: Option<usize>,
) -> crate::Result<&'a Artifact> {
    match v_want {
        Some(vw) => manifest
            .artifacts
            .iter()
            .find(|a| a.kind == ArtifactKind::DiagChunk && a.dtype == dtype && a.m == m && a.v == vw)
            .with_context(|| format!("no diag_chunk for {dtype} m={m} v={vw}")),
        None => manifest
            .find(ArtifactKind::DiagChunk, dtype, m)
            .with_context(|| format!("no diag_chunk artifact for {dtype} m={m}")),
    }
}

/// Resolve a fixed-length artifact (stats / mp_tile) and check the length.
fn resolve_fixed_artifact<'a>(
    manifest: &'a Manifest,
    kind: ArtifactKind,
    dtype: &str,
    n: usize,
) -> crate::Result<&'a Artifact> {
    let label = match kind {
        ArtifactKind::Stats => "stats",
        ArtifactKind::MpTile => "mp_tile",
        _ => "artifact",
    };
    let art = manifest
        .artifacts
        .iter()
        .find(|a| a.kind == kind && a.dtype == dtype)
        .with_context(|| format!("no {label} artifact for {dtype}"))?;
    anyhow::ensure!(
        n == art.n,
        "{label} artifact is fixed at n={}, got {n}",
        art.n
    );
    Ok(art)
}

/// Validate the diag_chunk input slice lengths against variant V.
#[allow(clippy::too_many_arguments)]
fn check_chunk_inputs<T>(
    v: usize,
    m: usize,
    ta: &[T],
    tb: &[T],
    mu_a: &[T],
    sig_a: &[T],
    mu_b: &[T],
    sig_b: &[T],
    nvalid: usize,
) -> crate::Result<()> {
    anyhow::ensure!(ta.len() == v + m && tb.len() == v + m, "ta/tb must be V+m");
    anyhow::ensure!(
        mu_a.len() == v && sig_a.len() == v && mu_b.len() == v && sig_b.len() == v,
        "stats slices must be V"
    );
    anyhow::ensure!(nvalid >= 1 && nvalid <= v, "nvalid out of range");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.tsv"), body).unwrap();
    }

    #[test]
    fn manifest_parses_and_finds() {
        let dir = std::env::temp_dir().join("natsa-manifest-test");
        write_manifest(
            &dir,
            "# name\tfile\tkind\tdtype\tm\tv\tn\tinputs\n\
             diag_chunk_f32_m64\tdiag_chunk_f32_m64.hlo.txt\tdiag_chunk\tf32\t64\t512\t0\tx\n\
             dot_init_f64_m32\tdot_init_f64_m32.hlo.txt\tdot_init\tf64\t32\t0\t0\tx\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.find(ArtifactKind::DiagChunk, "f32", 64).unwrap();
        assert_eq!(a.v, 512);
        assert!(m.find(ArtifactKind::DiagChunk, "f64", 64).is_none());
        assert_eq!(m.chunk_windows("f32"), vec![64]);
    }

    #[test]
    fn manifest_missing_dir_errors_helpfully() {
        let err = Manifest::load(Path::new("/nonexistent-natsa"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn manifest_rejects_bad_kind() {
        let dir = std::env::temp_dir().join("natsa-manifest-badkind");
        write_manifest(&dir, "x\tx.hlo.txt\tnope\tf32\t1\t2\t3\tx\n");
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn manifest_rejects_empty() {
        let dir = std::env::temp_dir().join("natsa-manifest-empty");
        write_manifest(&dir, "# header only\n");
        assert!(Manifest::load(&dir).is_err());
    }

    // ---- native-backend semantics (cheap enough to run everywhere; the
    // PJRT backend is pinned by rust/tests/e2e_pjrt.rs against real
    // artifacts, which exercise these exact same contracts) ----
    #[cfg(not(feature = "xla-pjrt"))]
    mod native {
        use super::*;
        use crate::prop::Rng;
        use crate::timeseries::sliding_stats;

        fn runtime(tag: &str, body: &str) -> Runtime {
            let dir = std::env::temp_dir().join(format!("natsa-native-rt-{tag}"));
            write_manifest(&dir, body);
            Runtime::new(&dir).unwrap()
        }

        #[test]
        fn dot_init_native() {
            let rt = runtime(
                "dot",
                "dot_init_f64_m8\tdot.hlo.txt\tdot_init\tf64\t8\t0\t0\tx\n",
            );
            let a: Vec<f64> = (0..8).map(|k| k as f64).collect();
            let b: Vec<f64> = (0..8).map(|k| (k * 2) as f64).collect();
            let want: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert_eq!(rt.dot_init(8, &a, &b).unwrap(), want);
            // missing variant errors like the PJRT backend
            let err = rt.dot_init::<f32>(8, &[0.0; 8], &[0.0; 8]).unwrap_err();
            assert!(err.to_string().contains("no dot_init artifact"), "{err}");
        }

        #[test]
        fn diag_chunk_native_matches_definition() {
            let m = 16;
            let v = 32;
            let rt = runtime(
                "chunk",
                "diag_chunk_f64_m16_v32\tc.hlo.txt\tdiag_chunk\tf64\t16\t32\t0\tx\n",
            );
            let mut rng = Rng::new(5);
            let t: Vec<f64> = rng.gauss_vec(2 * v + 3 * m);
            let st = sliding_stats(&t, m);
            let d = m; // diagonal offset
            let i0 = 1usize;
            let j0 = i0 + d;
            let q0: f64 = t[i0..i0 + m].iter().zip(&t[j0..j0 + m]).map(|(a, b)| a * b).sum();
            let out = rt
                .diag_chunk(
                    m,
                    Some(v),
                    &t[i0 - 1..i0 - 1 + v + m],
                    &t[j0 - 1..j0 - 1 + v + m],
                    &st.mu[i0..i0 + v],
                    &st.sig[i0..i0 + v],
                    &st.mu[j0..j0 + v],
                    &st.sig[j0..j0 + v],
                    q0,
                    v,
                )
                .unwrap();
            for k in 0..v {
                let (i, j) = (i0 + k, j0 + k);
                let q: f64 = t[i..i + m].iter().zip(&t[j..j + m]).map(|(a, b)| a * b).sum();
                let corr = (q - m as f64 * st.mu[i] * st.mu[j]) / (m as f64 * st.sig[i] * st.sig[j]);
                let want = (2.0 * m as f64 * (1.0 - corr)).max(0.0).sqrt();
                assert!(
                    (out.dists[k] - want).abs() < 1e-8,
                    "k={k}: {} vs {want}",
                    out.dists[k]
                );
            }
            // PUU pre-reduction is the argmin of the chunk
            let (min_k, min_v) = out
                .dists
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            assert_eq!(out.min_idx as usize, min_k);
            assert_eq!(out.min_val, *min_v);
            // q_last chains: it is the dot product AT the last valid cell
            let i_last = i0 + v - 1;
            let j_last = j0 + v - 1;
            let q_want: f64 = t[i_last..i_last + m]
                .iter()
                .zip(&t[j_last..j_last + m])
                .map(|(a, b)| a * b)
                .sum();
            assert!((out.q_last - q_want).abs() < 1e-7, "{} vs {q_want}", out.q_last);
        }

        #[test]
        fn diag_chunk_masks_invalid_lanes() {
            let m = 8;
            let rt = runtime(
                "mask",
                "diag_chunk_f64_m8_v16\tc.hlo.txt\tdiag_chunk\tf64\t8\t16\t0\tx\n",
            );
            let v = 16;
            let mut rng = Rng::new(6);
            let t: Vec<f64> = rng.gauss_vec(v + 3 * m);
            let st = sliding_stats(&t, m);
            let nvalid = 5;
            let q0: f64 = t[1..1 + m].iter().zip(&t[m..2 * m]).map(|(a, b)| a * b).sum();
            let out = rt
                .diag_chunk(
                    m,
                    None,
                    &t[0..v + m],
                    &t[m - 1..m - 1 + v + m],
                    &st.mu[1..1 + v],
                    &st.sig[1..1 + v],
                    &st.mu[m..m + v],
                    &st.sig[m..m + v],
                    q0,
                    nvalid,
                )
                .unwrap();
            assert!(out.dists[..nvalid].iter().all(|d| d.is_finite()));
            assert!(out.dists[nvalid..].iter().all(|d| d.is_infinite()));
            assert!((out.min_idx as usize) < nvalid);
        }

        #[test]
        fn executable_requires_artifact_file() {
            let dir = std::env::temp_dir().join("natsa-native-rt-exe");
            write_manifest(&dir, "k1\tmissing.hlo.txt\tdot_init\tf64\t8\t0\t0\tx\n");
            std::fs::write(dir.join("present.hlo.txt"), "HloModule x").unwrap();
            write_manifest(
                &dir,
                "k1\tmissing.hlo.txt\tdot_init\tf64\t8\t0\t0\tx\n\
                 k2\tpresent.hlo.txt\tdot_init\tf64\t16\t0\t0\tx\n",
            );
            let rt = Runtime::new(&dir).unwrap();
            assert!(rt.executable("k2").is_ok());
            assert!(rt.executable("k1").is_err());
            assert!(rt.executable("nope").is_err());
        }

        #[test]
        fn mp_tile_native_matches_scrimp() {
            let n = 256;
            let m = 16;
            let rt = runtime(
                "tile",
                "mp_tile_f64\ttile.hlo.txt\tmp_tile\tf64\t16\t0\t256\tx\n",
            );
            let mut rng = Rng::new(7);
            let t: Vec<f64> = rng.gauss_vec(n);
            let (p, i) = rt.mp_tile(&t).unwrap();
            let want = crate::mp::scrimp::matrix_profile(&t, crate::mp::MpConfig::new(m)).unwrap();
            for k in 0..want.len() {
                assert!((p[k] - want.p[k]).abs() < 1e-8);
                assert!(i[k] >= 0);
            }
            // wrong length is rejected with the fixed-n message
            let err = rt.mp_tile(&t[..100]).unwrap_err().to_string();
            assert!(err.contains("fixed at n=256"), "{err}");
        }

        #[test]
        fn stats_native_matches_host_precompute() {
            let rt = runtime(
                "stats",
                "stats_f64\tstats.hlo.txt\tstats\tf64\t32\t0\t512\tx\n",
            );
            let mut rng = Rng::new(8);
            let t: Vec<f64> = rng.gauss_vec(512);
            let (mu, sig) = rt.stats(&t).unwrap();
            let st = sliding_stats(&t, 32);
            assert_eq!(mu.len(), st.mu.len());
            for k in 0..mu.len() {
                assert!((mu[k] - st.mu[k]).abs() < 1e-12);
                assert!((sig[k] - st.sig[k]).abs() < 1e-12);
            }
        }
    }
}
