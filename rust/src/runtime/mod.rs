//! Request-path runtime: load and execute the AOT-compiled kernels.
//!
//! `make artifacts` (python, build-time) lowers the Layer-1/Layer-2 Pallas
//! + JAX graphs to **HLO text** under `artifacts/` plus a `manifest.tsv`
//! describing each variant.  This module is everything the rust binary
//! needs at run time:
//!
//! * [`Manifest`] — parse the TSV, resolve `(kind, dtype, m)` to a file;
//! * [`Runtime`]  — a PJRT CPU client that compiles each HLO module once
//!   (lazily, cached) and executes it with [`xla::Literal`] inputs.
//!
//! Interchange is HLO *text*, never serialized protos: jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and
//! `python/compile/aot.py`).
//!
//! The xla wrapper types hold raw pointers and are not `Send`; the
//! coordinator therefore gives each worker thread its own [`Runtime`]
//! (PJRT CPU executions are cheap to duplicate; compilation is per-worker
//! but amortized over the whole run).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::Context;

use crate::Real;

/// Kinds of AOT artifacts (matches `python/compile/aot.py`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// The PU pipeline over one diagonal chunk (hot path).
    DiagChunk,
    /// The DPU first dot product.
    DotInit,
    /// Sliding mean/std precompute.
    Stats,
    /// Self-contained small matrix profile (MXU-tile formulation).
    MpTile,
}

impl ArtifactKind {
    fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "diag_chunk" => ArtifactKind::DiagChunk,
            "dot_init" => ArtifactKind::DotInit,
            "stats" => ArtifactKind::Stats,
            "mp_tile" => ArtifactKind::MpTile,
            _ => return None,
        })
    }
}

/// One artifact entry from `manifest.tsv`.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub path: PathBuf,
    pub kind: ArtifactKind,
    pub dtype: String,
    pub m: usize,
    /// Chunk length V (diag_chunk only).
    pub v: usize,
    /// Fixed series length (stats / mp_tile only).
    pub n: usize,
}

/// The parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<Artifact>,
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: &Path) -> crate::Result<Manifest> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts` first)", path.display()))?;
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            anyhow::ensure!(f.len() >= 7, "manifest line {}: bad field count", lineno + 1);
            let kind = ArtifactKind::parse(f[2])
                .with_context(|| format!("manifest line {}: unknown kind {}", lineno + 1, f[2]))?;
            artifacts.push(Artifact {
                name: f[0].to_string(),
                path: dir.join(f[1]),
                kind,
                dtype: f[3].to_string(),
                m: f[4].parse().context("m")?,
                v: f[5].parse().context("v")?,
                n: f[6].parse().context("n")?,
            });
        }
        anyhow::ensure!(!artifacts.is_empty(), "empty manifest {}", path.display());
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// Find an artifact by kind/dtype and exact window length.  For the
    /// hot-path chunk kernel the *largest* available V is preferred:
    /// fewer PJRT invocations per diagonal (perf pass, EXPERIMENTS.md).
    pub fn find(&self, kind: ArtifactKind, dtype: &str, m: usize) -> Option<&Artifact> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == kind && a.dtype == dtype && a.m == m)
            .max_by_key(|a| a.v)
    }

    /// All diag_chunk variants for (dtype, m), sorted by ascending V.
    pub fn chunk_variants(&self, dtype: &str, m: usize) -> Vec<&Artifact> {
        let mut v: Vec<&Artifact> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::DiagChunk && a.dtype == dtype && a.m == m)
            .collect();
        v.sort_by_key(|a| a.v);
        v
    }

    /// Window lengths available for the hot-path chunk kernel.
    pub fn chunk_windows(&self, dtype: &str) -> Vec<usize> {
        let mut ms: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::DiagChunk && a.dtype == dtype)
            .map(|a| a.m)
            .collect();
        ms.sort_unstable();
        ms
    }
}

/// Outputs of one `diag_chunk` kernel invocation.
#[derive(Clone, Debug)]
pub struct DiagChunkOut<T> {
    /// Distances for the chunk's cells (+inf on masked lanes).
    pub dists: Vec<T>,
    /// Dot product at the last valid cell (chains into the next chunk).
    pub q_last: T,
    /// PUU pre-reduction over the chunk.
    pub min_val: T,
    pub min_idx: i32,
}

/// Element types the runtime can feed to PJRT.
pub trait XlaReal: Real + xla::NativeType + xla::ArrayElement {}
impl XlaReal for f32 {}
impl XlaReal for f64 {}

/// A PJRT CPU runtime over one artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a runtime for `artifacts/` (compiles lazily on first use).
    pub fn new(artifact_dir: &Path) -> crate::Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) an executable by artifact name.
    pub fn executable(&self, name: &str) -> crate::Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let art = self
            .manifest
            .artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("unknown artifact '{name}'"))?;
        let proto = xla::HloModuleProto::from_text_file(&art.path)
            .with_context(|| format!("parse HLO text {}", art.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("PJRT compile {name}"))?,
        );
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    fn run(&self, name: &str, inputs: &[xla::Literal]) -> crate::Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("execute {name}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetch result of {name}"))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        Ok(lit.to_tuple()?)
    }

    /// Execute the DPU first-dot-product kernel.
    pub fn dot_init<T: XlaReal>(&self, m: usize, ta: &[T], tb: &[T]) -> crate::Result<T> {
        anyhow::ensure!(ta.len() == m && tb.len() == m, "dot_init wants length-m slices");
        let art = self
            .manifest
            .find(ArtifactKind::DotInit, T::DTYPE, m)
            .with_context(|| format!("no dot_init artifact for {} m={m}", T::DTYPE))?;
        let name = art.name.clone();
        let out = self.run(&name, &[xla::Literal::vec1(ta), xla::Literal::vec1(tb)])?;
        Ok(out[0].to_vec::<T>()?[0])
    }

    /// Execute the PU pipeline over one diagonal chunk.
    #[allow(clippy::too_many_arguments)]
    pub fn diag_chunk<T: XlaReal>(
        &self,
        m: usize,
        v_want: Option<usize>,
        ta: &[T],
        tb: &[T],
        mu_a: &[T],
        sig_a: &[T],
        mu_b: &[T],
        sig_b: &[T],
        q0: T,
        nvalid: usize,
    ) -> crate::Result<DiagChunkOut<T>> {
        let art = match v_want {
            Some(vw) => self
                .manifest
                .artifacts
                .iter()
                .find(|a| {
                    a.kind == ArtifactKind::DiagChunk && a.dtype == T::DTYPE && a.m == m && a.v == vw
                })
                .with_context(|| format!("no diag_chunk for {} m={m} v={vw}", T::DTYPE))?,
            None => self
                .manifest
                .find(ArtifactKind::DiagChunk, T::DTYPE, m)
                .with_context(|| format!("no diag_chunk artifact for {} m={m}", T::DTYPE))?,
        };
        let v = art.v;
        anyhow::ensure!(ta.len() == v + m && tb.len() == v + m, "ta/tb must be V+m");
        anyhow::ensure!(
            mu_a.len() == v && sig_a.len() == v && mu_b.len() == v && sig_b.len() == v,
            "stats slices must be V"
        );
        anyhow::ensure!(nvalid >= 1 && nvalid <= v, "nvalid out of range");
        let name = art.name.clone();
        let out = self.run(
            &name,
            &[
                xla::Literal::vec1(ta),
                xla::Literal::vec1(tb),
                xla::Literal::vec1(mu_a),
                xla::Literal::vec1(sig_a),
                xla::Literal::vec1(mu_b),
                xla::Literal::vec1(sig_b),
                xla::Literal::vec1(&[q0]),
                xla::Literal::vec1(&[nvalid as i32]),
            ],
        )?;
        Ok(DiagChunkOut {
            dists: out[0].to_vec::<T>()?,
            q_last: out[1].to_vec::<T>()?[0],
            min_val: out[2].to_vec::<T>()?[0],
            min_idx: out[3].to_vec::<i32>()?[0],
        })
    }

    /// Execute the offloaded stats precompute (fixed demo length).
    pub fn stats<T: XlaReal>(&self, t: &[T]) -> crate::Result<(Vec<T>, Vec<T>)> {
        let art = self
            .manifest
            .artifacts
            .iter()
            .find(|a| a.kind == ArtifactKind::Stats && a.dtype == T::DTYPE)
            .with_context(|| format!("no stats artifact for {}", T::DTYPE))?;
        anyhow::ensure!(
            t.len() == art.n,
            "stats artifact is fixed at n={}, got {}",
            art.n,
            t.len()
        );
        let name = art.name.clone();
        let out = self.run(&name, &[xla::Literal::vec1(t)])?;
        Ok((out[0].to_vec::<T>()?, out[1].to_vec::<T>()?))
    }

    /// Execute the self-contained MXU-tile matrix profile (fixed n).
    pub fn mp_tile<T: XlaReal>(&self, t: &[T]) -> crate::Result<(Vec<T>, Vec<i32>)> {
        let art = self
            .manifest
            .artifacts
            .iter()
            .find(|a| a.kind == ArtifactKind::MpTile && a.dtype == T::DTYPE)
            .with_context(|| format!("no mp_tile artifact for {}", T::DTYPE))?;
        anyhow::ensure!(
            t.len() == art.n,
            "mp_tile artifact is fixed at n={}, got {}",
            art.n,
            t.len()
        );
        let name = art.name.clone();
        let out = self.run(&name, &[xla::Literal::vec1(t)])?;
        Ok((out[0].to_vec::<T>()?, out[1].to_vec::<i32>()?))
    }
}

/// Default artifact directory: `$NATSA_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("NATSA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.tsv"), body).unwrap();
    }

    #[test]
    fn manifest_parses_and_finds() {
        let dir = std::env::temp_dir().join("natsa-manifest-test");
        write_manifest(
            &dir,
            "# name\tfile\tkind\tdtype\tm\tv\tn\tinputs\n\
             diag_chunk_f32_m64\tdiag_chunk_f32_m64.hlo.txt\tdiag_chunk\tf32\t64\t512\t0\tx\n\
             dot_init_f64_m32\tdot_init_f64_m32.hlo.txt\tdot_init\tf64\t32\t0\t0\tx\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.find(ArtifactKind::DiagChunk, "f32", 64).unwrap();
        assert_eq!(a.v, 512);
        assert!(m.find(ArtifactKind::DiagChunk, "f64", 64).is_none());
        assert_eq!(m.chunk_windows("f32"), vec![64]);
    }

    #[test]
    fn manifest_missing_dir_errors_helpfully() {
        let err = Manifest::load(Path::new("/nonexistent-natsa"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn manifest_rejects_bad_kind() {
        let dir = std::env::temp_dir().join("natsa-manifest-badkind");
        write_manifest(&dir, "x\tx.hlo.txt\tnope\tf32\t1\t2\t3\tx\n");
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn manifest_rejects_empty() {
        let dir = std::env::temp_dir().join("natsa-manifest-empty");
        write_manifest(&dir, "# header only\n");
        assert!(Manifest::load(&dir).is_err());
    }
}
