//! Report harness: regenerate every table and figure of the paper.
//!
//! Each `fig*` / `table*` function returns the rows the paper reports as
//! plain text (series for figures, aligned columns for tables), computed
//! from the live models and — for the accuracy figures — from real
//! functional runs.  The CLI (`natsa repro <id>`) and the benches print
//! these; EXPERIMENTS.md records paper-vs-model side by side.

use crate::mp::{scrimp, MpConfig};
use crate::natsa::pu::PuDesign;
use crate::sim::accel::{design_space, NatsaDesign};
use crate::sim::area::fig10_rows;
use crate::sim::dram::DramConfig;
use crate::sim::platform::{GpPlatform, KnlModel, RefPlatform};
use crate::sim::power::EnergyRow;
use crate::sim::roofline::fig4_points;
use crate::sim::{Precision, Workload};
use crate::timeseries::generator::{generate_with_event, Pattern, PlantedEvent};

/// All experiment ids, in paper order.
pub const ALL: [&str; 12] = [
    "fig1", "fig3", "fig4", "fig7", "table2", "fig8", "fig9", "fig10", "table3", "fig11",
    "fig12", "sens-m",
];

/// Dispatch by experiment id.
pub fn run(id: &str) -> crate::Result<String> {
    Ok(match id {
        "fig1" => fig1(),
        "fig3" => fig3(),
        "fig4" => fig4(),
        "fig7" => fig7(),
        "table2" => table2(),
        "fig8" => fig8(),
        "fig9" => fig9(),
        "fig10" => fig10(),
        "table3" => table3(),
        "fig11" => fig11(),
        "fig12" => fig12(),
        "sens-m" => sens_m(),
        other => anyhow::bail!("unknown experiment '{other}'; known: {ALL:?}"),
    })
}

fn hr(title: &str) -> String {
    format!("== {title} ==\n")
}

/// Fig. 1: a time series with an anomaly and its matrix profile — the
/// profile must peak inside the planted anomaly window.
pub fn fig1() -> String {
    let n = 2048;
    let m = 64;
    let (t, ev) = generate_with_event::<f64>(Pattern::SineWithAnomaly, n, 7);
    let mp = scrimp::matrix_profile(&t, MpConfig::new(m)).unwrap();
    let (peak, dist) = mp.profile_discord();
    let mut s = hr("Fig. 1: time series with anomaly + matrix profile");
    if let PlantedEvent::Anomaly { start, len } = ev {
        s += &format!("planted anomaly: [{start}, {})\n", start + len);
        s += &format!("profile peak:    index {peak} (distance {dist:.3})\n");
        let hit = peak + m >= start && peak < start + len + m;
        s += &format!("detected: {}\n", if hit { "YES" } else { "NO" });
    }
    // coarse ASCII profile (32 buckets)
    let buckets = 32;
    let per = mp.len() / buckets;
    s += "profile (bucket max, normalized):\n";
    let maxv = dist.max(1e-9);
    for b in 0..buckets {
        let lo = b * per;
        let hi = ((b + 1) * per).min(mp.len());
        let v = mp.p[lo..hi].iter().cloned().fold(0.0f64, f64::max);
        let bars = ((v / maxv) * 40.0) as usize;
        s += &format!("{:5} |{}\n", lo, "#".repeat(bars));
    }
    s
}

impl<T: crate::Real> crate::mp::MatrixProfile<T> {
    fn profile_discord(&self) -> (usize, f64) {
        let (i, d) = self.discord().expect("non-empty profile");
        (i, d.to_f64s())
    }
}

/// Fig. 3: SCRIMP thread scaling + bandwidth on KNL (DDR4 vs MCDRAM/HBM).
pub fn fig3() -> String {
    let mut s = hr("Fig. 3: SCRIMP scaling on Xeon Phi KNL (model)");
    s += "threads |  DDR4 norm-perf  DDR4 GB/s |  HBM norm-perf  HBM GB/s\n";
    let ddr = KnlModel::ddr4();
    let hbm = KnlModel::mcdram();
    for threads in [1usize, 2, 4, 8, 16, 32, 64, 128, 256] {
        let (pd, bd) = ddr.scaling_point(threads);
        let (ph, bh) = hbm.scaling_point(threads);
        s += &format!("{threads:7} | {pd:15.1} {bd:10.1} | {ph:14.1} {bh:9.1}\n");
    }
    s += &format!(
        "saturation: DDR4 at ~{} threads, HBM at ~{} threads\n",
        ddr.saturation_threads(),
        hbm.saturation_threads()
    );
    s
}

/// Fig. 4: roofline of SCRIMP on KNL.
pub fn fig4() -> String {
    let w = Workload::new(1_048_576, 256);
    let mut s = hr("Fig. 4: roofline, SCRIMP on Xeon Phi 7210 (model)");
    s += "memory  |  AI (flop/B)  achieved GF/s  attainable GF/s  % of peak\n";
    for (name, p) in fig4_points(&w) {
        s += &format!(
            "{name:11} | {:10.3} {:14.1} {:16.1} {:9.2}%\n",
            p.ai_flop_per_byte,
            p.achieved_gflops,
            p.attainable_gflops,
            p.peak_fraction * 100.0
        );
    }
    s += "=> arithmetic intensity is far left of the ridge: memory-bound.\n";
    s
}

/// Fig. 7: NATSA-DP speedup over the DDR4-OoO baseline.
pub fn fig7() -> String {
    let mut s = hr("Fig. 7: NATSA-DP speedup vs DDR4-OoO (DP)");
    s += "dataset    |  baseline(s)  HBM-inOrder(s)  NATSA-DP(s)  speedup  vs-NDP\n";
    let base = GpPlatform::ddr4_ooo();
    let ndp = GpPlatform::hbm_inorder();
    let natsa = NatsaDesign::hbm(Precision::Dp);
    let mut speedups = Vec::new();
    for (name, w) in Workload::table1() {
        let b = base.estimate(&w, Precision::Dp).time_s;
        let g = ndp.estimate(&w, Precision::Dp).time_s;
        let a = natsa.estimate(&w).time_s;
        speedups.push(b / a);
        s += &format!(
            "{name:10} | {b:12.2} {g:15.2} {a:12.2} {:8.1}x {:6.1}x\n",
            b / a,
            g / a
        );
    }
    let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
    let max = speedups.iter().cloned().fold(0.0, f64::max);
    s += &format!("average speedup {avg:.1}x, max {max:.1}x  (paper: 9.9x avg, 14.2x max)\n");
    s
}

/// Table 2: execution time for SP and DP across configs and sizes.
pub fn table2() -> String {
    let mut s = hr("Table 2: execution time (s), model vs paper");
    let paper: &[(&str, [f64; 5])] = &[
        ("DDR4-OoO-DP", [14.72, 77.55, 414.55, 2089.05, 9810.30]),
        ("DDR4-OoO-SP", [6.46, 44.47, 207.85, 1106.36, 5206.75]),
        ("HBM-inOrder-DP", [14.95, 64.20, 262.33, 1071.03, 4347.38]),
        ("HBM-inOrder-SP", [8.16, 35.68, 130.23, 625.27, 2466.69]),
        ("NATSA-DP", [2.47, 10.37, 42.45, 171.72, 690.65]),
        ("NATSA-SP", [1.41, 5.91, 24.19, 97.84, 393.45]),
    ];
    let sizes = Workload::table1();
    s += &format!(
        "{:16} {:>10} {:>10} {:>10} {:>10} {:>10}\n",
        "config", "rand_128K", "rand_256K", "rand_512K", "rand_1M", "rand_2M"
    );
    for (cfg, paper_row) in paper {
        let mut model_row = Vec::new();
        for (_, w) in &sizes {
            let t = match *cfg {
                "DDR4-OoO-DP" => GpPlatform::ddr4_ooo().estimate(w, Precision::Dp).time_s,
                "DDR4-OoO-SP" => GpPlatform::ddr4_ooo().estimate(w, Precision::Sp).time_s,
                "HBM-inOrder-DP" => GpPlatform::hbm_inorder().estimate(w, Precision::Dp).time_s,
                "HBM-inOrder-SP" => GpPlatform::hbm_inorder().estimate(w, Precision::Sp).time_s,
                "NATSA-DP" => NatsaDesign::hbm(Precision::Dp).estimate(w).time_s,
                "NATSA-SP" => NatsaDesign::hbm(Precision::Sp).estimate(w).time_s,
                _ => unreachable!(),
            };
            model_row.push(t);
        }
        s += &format!(
            "{:16} {:>10.2} {:>10.2} {:>10.2} {:>10.2} {:>10.2}   <- model\n",
            cfg, model_row[0], model_row[1], model_row[2], model_row[3], model_row[4]
        );
        s += &format!(
            "{:16} {:>10.2} {:>10.2} {:>10.2} {:>10.2} {:>10.2}   <- paper\n",
            "", paper_row[0], paper_row[1], paper_row[2], paper_row[3], paper_row[4]
        );
    }
    s
}

fn all_estimates_512k() -> Vec<(String, crate::sim::Estimate, f64)> {
    // (name, estimate, memory power W) for the rand_512K DP comparison
    let w = Workload::new(524_288, 256);
    let mut rows = Vec::new();
    for p in GpPlatform::all_simulated() {
        let e = p.estimate(&w, Precision::Dp);
        let mem_w = p.dram.dynamic_power_w(e.bw_gbs);
        rows.push((p.name.to_string(), e, mem_w));
    }
    let natsa = NatsaDesign::hbm(Precision::Dp);
    let e = natsa.estimate(&w);
    let mem_w = natsa.dram.dynamic_power_w(e.bw_gbs);
    rows.push(("NATSA-DP".to_string(), e, mem_w));
    rows
}

/// Fig. 8: dynamic power per platform (simulated + real references).
pub fn fig8() -> String {
    let mut s = hr("Fig. 8: dynamic power (W), rand_512K DP");
    for (name, e, mem_w) in all_estimates_512k() {
        s += &format!(
            "{name:14} {:8.1} W  (compute {:6.1}, memory {:5.1})\n",
            e.power_w,
            e.power_w - mem_w,
            mem_w
        );
    }
    for r in RefPlatform::all() {
        s += &format!("{:14} {:8.1} W  (measured, real hw)\n", r.name, r.dyn_power_w);
    }
    s += "=> NATSA has the lowest power; most of it is memory.\n";
    s
}

/// Fig. 9: energy per platform for rand_512K DP.
pub fn fig9() -> String {
    let mut s = hr("Fig. 9: energy (J), rand_512K DP");
    let rows = all_estimates_512k();
    let natsa_j = rows.last().unwrap().1.energy_j;
    for (name, e, mem_w) in &rows {
        let er = EnergyRow::from_estimate(e, *mem_w);
        s += &format!(
            "{name:14} {:10.0} J  (compute {:8.0}, memory {:8.0})  {:5.1}x NATSA\n",
            er.total_j,
            er.compute_j,
            er.memory_j,
            er.total_j / natsa_j
        );
    }
    for r in RefPlatform::all() {
        s += &format!(
            "{:14} {:10.0} J  (measured)  {:5.1}x NATSA\n",
            r.name,
            r.energy_512k_dp_j(),
            r.energy_512k_dp_j() / natsa_j
        );
    }
    s += "paper: 27.2x max / 19.4x avg vs baseline; 10.2x vs HBM-inOrder;\n";
    s += "       1.7x K40c, 4.1x GTX1050, 11.0x KNL\n";
    s
}

/// Fig. 10: area comparison.
pub fn fig10() -> String {
    let mut s = hr("Fig. 10: area (mm^2)");
    for r in fig10_rows() {
        s += &format!(
            "{:16} {:7.1} mm^2 @ {:2} nm   {:4.1}x NATSA\n",
            r.platform, r.area_mm2, r.tech_nm, r.vs_natsa
        );
    }
    s
}

/// Table 3: NATSA design components + the PU-count DSE behind them.
pub fn table3() -> String {
    let mut s = hr("Table 3: NATSA design (48 PUs) + Section 6.3 DSE");
    for (label, d) in [("DP", PuDesign::dp()), ("SP", PuDesign::sp())] {
        s += &format!(
            "PU-{label}: {} GB/s, {:.2} W, {:.2} mm^2, mults/adds {}/{}, int {}, bitwise {}, regs {}\n",
            d.mem_bw_gbs,
            d.peak_power_w,
            d.area_mm2,
            d.fp_mults,
            d.fp_adds,
            d.int_adds,
            d.bitwise,
            d.registers
        );
        s += &format!(
            "NATSA-{label} (48 PUs): {:.0} GB/s, {:.2} W, {:.2} mm^2\n",
            48.0 * d.mem_bw_gbs,
            48.0 * d.peak_power_w,
            48.0 * d.area_mm2
        );
    }
    let w = Workload::new(524_288, 256);
    s += "\nDSE (HBM, DP, rand_512K):\n  PUs   time(s)   bound     BW-util\n";
    for p in design_space(Precision::Dp, DramConfig::hbm2(), &[16, 32, 48, 64, 96], &w) {
        s += &format!(
            "{:5} {:9.2}   {:8} {:8.0}%\n",
            p.pus,
            p.time_s,
            p.bound.to_string(),
            p.bw_utilization * 100.0
        );
    }
    s += "DDR4 variant (footnote 2):\n";
    for p in design_space(Precision::Dp, DramConfig::ddr4_2400_dual(), &[4, 8, 16], &w) {
        s += &format!(
            "{:5} {:9.2}   {:8} {:8.0}%\n",
            p.pus,
            p.time_s,
            p.bound.to_string(),
            p.bw_utilization * 100.0
        );
    }
    s
}

/// Fig. 11: general-purpose platform speedups + bandwidth usage.
pub fn fig11() -> String {
    let mut s = hr("Fig. 11: GP platforms vs baseline (DP): speedup | GB/s");
    let platforms = GpPlatform::all_simulated();
    let base = GpPlatform::ddr4_ooo();
    s += &format!("{:10}", "dataset");
    for p in &platforms {
        s += &format!(" | {:>20}", p.name);
    }
    s += "\n";
    for (name, w) in Workload::table1() {
        let tb = base.estimate(&w, Precision::Dp).time_s;
        s += &format!("{name:10}");
        for p in &platforms {
            let e = p.estimate(&w, Precision::Dp);
            s += &format!(" | {:>9.2}x {:>7.1}GB/s", tb / e.time_s, e.bw_gbs);
        }
        s += "\n";
    }
    s += "paper: HBM-inOrder up to 2.25x; HBM-OoO only ~7% over baseline.\n";
    s
}

/// Fig. 12: SP vs DP accuracy on ECG-like and seismic-like data (real
/// functional runs, not models).
pub fn fig12() -> String {
    let mut s = hr("Fig. 12: SP vs DP event detection (functional run)");
    for (pat, m) in [(Pattern::EcgLike, 64), (Pattern::SeismicLike, 64)] {
        let (t64, ev) = generate_with_event::<f64>(pat, 6144, 5);
        let t32: Vec<f32> = t64.iter().map(|&x| x as f32).collect();
        let dp = scrimp::matrix_profile(&t64, MpConfig::new(m)).unwrap();
        let sp = scrimp::matrix_profile(&t32, MpConfig::new(m)).unwrap();
        let (pk_dp, d_dp) = dp.discord().unwrap();
        let (pk_sp, d_sp) = sp.discord().unwrap();
        let (start, len) = match ev {
            PlantedEvent::Anomaly { start, len } => (start, len),
            _ => unreachable!(),
        };
        let near = |pk: usize| pk + m >= start && pk < start + len + m;
        s += &format!(
            "{:8}: planted [{start},{}) | DP peak {pk_dp} ({d_dp:.3}) {} | SP peak {pk_sp} ({d_sp:.3}) {}\n",
            pat.name(),
            start + len,
            if near(pk_dp) { "HIT" } else { "MISS" },
            if near(pk_sp as usize) { "HIT" } else { "MISS" },
        );
        // profile agreement between precisions
        let mut max_rel = 0.0f64;
        for k in 0..dp.len() {
            let a = dp.p[k];
            let b = sp.p[k] as f64;
            if a.is_finite() {
                max_rel = max_rel.max((a - b).abs() / a.max(1e-9));
            }
        }
        s += &format!("          max relative SP-vs-DP profile deviation: {max_rel:.2e}\n");
    }
    s += "=> events remain detectable in single precision (paper Fig. 12).\n";
    s
}

/// Section 6.5: sensitivity to the window length m.
pub fn sens_m() -> String {
    let mut s = hr("Sect. 6.5: sensitivity to window length m (model, DDR4-OoO DP)");
    let base = GpPlatform::ddr4_ooo();
    for n in [131_072usize, 2_097_152] {
        let t1k = base.estimate(&Workload::new(n, 1024), Precision::Dp).time_s;
        s += &format!("n = {n}:\n");
        for m in [1024usize, 2048, 4096, 8192, 16384] {
            let t = base.estimate(&Workload::new(n, m), Precision::Dp).time_s;
            s += &format!(
                "  m={m:6}: {t:10.2}s  ({:+5.1}% vs m=1024)\n",
                (t / t1k - 1.0) * 100.0
            );
        }
    }
    s += "paper: 41% reduction at n=128K, 13% at n=2M when m: 1K -> 16K.\n";
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_experiment_runs() {
        for id in ALL {
            let out = run(id).unwrap();
            assert!(out.len() > 100, "{id} output too short:\n{out}");
        }
    }

    #[test]
    fn unknown_experiment_errors() {
        assert!(run("fig99").is_err());
    }

    #[test]
    fn fig1_detects_anomaly() {
        assert!(fig1().contains("detected: YES"));
    }

    #[test]
    fn fig12_hits_in_both_precisions() {
        let out = fig12();
        assert_eq!(out.matches("HIT").count(), 4, "{out}");
    }

    #[test]
    fn fig7_speedup_band() {
        let out = fig7();
        // the model's average speedup printed in the last line should be
        // in the paper's neighborhood; parse it loosely.
        assert!(out.contains("average speedup"), "{out}");
    }

    #[test]
    fn sens_m_reduces_time() {
        // larger m => fewer windows/diagonals => faster (as in the paper)
        let out = sens_m();
        assert!(out.contains("-"), "{out}");
    }
}
