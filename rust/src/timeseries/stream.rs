//! Streaming substrate: an absolute-indexed growable buffer with optional
//! head eviction — the ring/window abstraction under [`crate::mp::stampi`].
//!
//! A live stream only ever *appends*; what changes over its lifetime is how
//! much history is retained.  [`RingVec`] therefore addresses elements by
//! their **absolute stream index** (the index the element had when it was
//! appended, stable forever), while [`RingVec::evict_to`] drops the oldest
//! retained elements in O(1) amortized time.  Contiguous slices over the
//! retained region are always available (the buffer compacts itself when
//! the evicted prefix grows past half the allocation), which is what the
//! O(m) dot products and the row-kernel tiles of the STAMPI update need.
//!
//! ## Assert policy (hot vs cold paths)
//!
//! The scalar accessors [`RingVec::get`] / [`RingVec::set`] check the
//! retained range with a **hard assert in every build profile**: an
//! evicted absolute index must fail deterministically, never return
//! stale data.  That makes them *cold-path* accessors — bookkeeping,
//! snapshots, tests.  Hot loops (the O(retained) streaming row update in
//! [`crate::mp::kernel::compute_row_n`]) must instead acquire a view
//! once via [`RingVec::slice`] / [`RingVec::slice_mut`] — the retained
//! range is checked a single time at acquisition and the loop body runs
//! on a plain `&[T]` / `&mut [T]`, where the compiler can hoist or
//! elide the remaining slice bounds checks.  Internal buffer invariants
//! (`head <= buf.len()`) are `debug_assert`s: they guard implementation
//! bugs, not caller errors, and cost nothing in release builds.

/// Growable, absolute-indexed vector with amortized-O(1) head eviction.
///
/// Invariant: live elements are `buf[head..]`; `buf[i]` holds absolute
/// index `off + i`; the first retained absolute index is `off + head`.
#[derive(Clone, Debug)]
pub struct RingVec<T> {
    buf: Vec<T>,
    off: usize,
    head: usize,
}

impl<T: Copy> RingVec<T> {
    pub fn new() -> Self {
        RingVec { buf: Vec::new(), off: 0, head: 0 }
    }

    /// Rebuild a ring from its serialized view: `items` are the retained
    /// elements, the first of which has absolute index `first_index`.
    /// Together with [`Self::retained`] + [`Self::first_index`] this is
    /// the round-trip the durability codec ([`crate::mp::stampi`]'s
    /// `SessionState`) uses: the reconstructed ring is observationally
    /// identical to the original — same absolute indices, same retained
    /// contents — even though the evicted prefix (already unreachable)
    /// is not resurrected.
    pub fn from_parts(first_index: usize, items: Vec<T>) -> Self {
        RingVec { buf: items, off: first_index, head: 0 }
    }

    /// Borrow the whole retained region (absolute indices
    /// `[first_index, next_index)`) without cloning — the read side of
    /// the serialization view (see [`Self::from_parts`]).
    pub fn retained(&self) -> &[T] {
        &self.buf[self.head..]
    }

    /// Append one element; it receives absolute index [`Self::next_index`].
    pub fn push(&mut self, x: T) {
        self.buf.push(x);
    }

    /// Absolute index of the oldest retained element.
    pub fn first_index(&self) -> usize {
        self.off + self.head
    }

    /// Absolute index the next [`Self::push`] will receive.
    pub fn next_index(&self) -> usize {
        self.off + self.buf.len()
    }

    /// Number of retained elements.
    pub fn len(&self) -> usize {
        self.buf.len() - self.head
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Read the element at absolute index `abs`.  Panics (in every build
    /// profile) when `abs` falls outside the retained range: an evicted
    /// index must fail deterministically, never return stale data.
    #[inline]
    pub fn get(&self, abs: usize) -> T {
        assert!(
            abs >= self.first_index() && abs < self.next_index(),
            "index {abs} outside retained range [{}, {})",
            self.first_index(),
            self.next_index()
        );
        self.buf[abs - self.off]
    }

    /// Overwrite the element at absolute index `abs` (must be retained;
    /// panics otherwise, like [`Self::get`]).
    #[inline]
    pub fn set(&mut self, abs: usize, x: T) {
        assert!(
            abs >= self.first_index() && abs < self.next_index(),
            "index {abs} outside retained range [{}, {})",
            self.first_index(),
            self.next_index()
        );
        self.buf[abs - self.off] = x;
    }

    /// Contiguous retained slice covering absolute indices `[lo, hi)`.
    /// The range is checked once here; iterate the returned slice
    /// instead of calling [`Self::get`] per element on hot paths.
    pub fn slice(&self, lo: usize, hi: usize) -> &[T] {
        assert!(
            lo >= self.first_index() && hi <= self.next_index() && lo <= hi,
            "slice [{lo}, {hi}) outside retained range [{}, {})",
            self.first_index(),
            self.next_index()
        );
        debug_assert!(self.head <= self.buf.len());
        &self.buf[lo - self.off..hi - self.off]
    }

    /// Contiguous **mutable** retained slice covering absolute indices
    /// `[lo, hi)` — the write-side twin of [`Self::slice`], added for
    /// the streaming row kernel: the q-advance and profile merges of
    /// [`crate::mp::kernel::compute_row_n`] run over plain `&mut [T]`
    /// with this one range check hoisted out of the whole tile, where
    /// the old per-element [`Self::get`]/[`Self::set`] walk re-checked
    /// the retained range on every cell.
    pub fn slice_mut(&mut self, lo: usize, hi: usize) -> &mut [T] {
        assert!(
            lo >= self.first_index() && hi <= self.next_index() && lo <= hi,
            "slice_mut [{lo}, {hi}) outside retained range [{}, {})",
            self.first_index(),
            self.next_index()
        );
        debug_assert!(self.head <= self.buf.len());
        let off = self.off;
        &mut self.buf[lo - off..hi - off]
    }

    /// Clone the whole retained region into a plain `Vec`.
    pub fn to_vec(&self) -> Vec<T> {
        self.buf[self.head..].to_vec()
    }

    /// Drop every element with absolute index below `new_first`.  No-op if
    /// the boundary is at or before the current head; the boundary may not
    /// exceed [`Self::next_index`].  Storage is reclaimed lazily: once the
    /// evicted prefix outgrows the live region it is compacted away, so a
    /// bounded stream uses O(retained) memory.
    ///
    /// Returns whether this call physically compacted the storage — a
    /// natural (amortized, every ~len) hook for periodic O(retained)
    /// maintenance in callers (e.g. [`crate::mp::stampi`] re-anchors its
    /// rolling sums on compaction to cancel float drift).
    pub fn evict_to(&mut self, new_first: usize) -> bool {
        assert!(
            new_first <= self.next_index(),
            "cannot evict past the end ({new_first} > {})",
            self.next_index()
        );
        if new_first <= self.first_index() {
            return false;
        }
        self.head = new_first - self.off;
        if self.head >= 64 && self.head > self.buf.len() - self.head {
            self.buf.drain(..self.head);
            self.off += self.head;
            self.head = 0;
            return true;
        }
        false
    }
}

impl<T: Copy> Default for RingVec<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absolute_indices_survive_eviction() {
        let mut r = RingVec::new();
        for v in 0..200u32 {
            r.push(v);
        }
        assert_eq!(r.first_index(), 0);
        assert_eq!(r.next_index(), 200);
        r.evict_to(150);
        assert_eq!(r.first_index(), 150);
        assert_eq!(r.len(), 50);
        // absolute addressing is unchanged by eviction/compaction
        for abs in 150..200 {
            assert_eq!(r.get(abs), abs as u32);
        }
        for v in 200..400u32 {
            r.push(v);
        }
        r.evict_to(380);
        assert_eq!(r.get(399), 399);
        assert_eq!(r.slice(390, 395), &[390, 391, 392, 393, 394]);
    }

    #[test]
    fn eviction_is_monotone_and_idempotent() {
        let mut r = RingVec::new();
        for v in 0..100u64 {
            r.push(v);
        }
        r.evict_to(40);
        r.evict_to(10); // backwards: no-op
        assert_eq!(r.first_index(), 40);
        r.evict_to(40); // same boundary: no-op
        assert_eq!(r.len(), 60);
        r.evict_to(100); // evict everything retained
        assert!(r.is_empty());
        assert_eq!(r.next_index(), 100);
        r.push(7);
        assert_eq!(r.get(100), 7);
    }

    #[test]
    fn bounded_stream_memory_stays_bounded() {
        let mut r = RingVec::new();
        let bound = 256usize;
        for v in 0..100_000usize {
            r.push(v);
            let n = r.next_index();
            if n > bound {
                r.evict_to(n - bound);
            }
            // the backing allocation never holds more than ~2x the bound
            assert!(r.buf.len() <= 2 * bound + 64, "buf grew to {}", r.buf.len());
        }
        assert_eq!(r.len(), bound);
        assert_eq!(r.get(99_999), 99_999);
    }

    #[test]
    fn evict_reports_compaction() {
        let mut r = RingVec::new();
        for v in 0..300u32 {
            r.push(v);
        }
        assert!(!r.evict_to(10)); // small prefix: storage untouched
        assert!(r.evict_to(200)); // prefix outgrew live region: compacted
        assert_eq!(r.first_index(), 200);
        assert_eq!(r.get(299), 299);
        assert!(!r.evict_to(200)); // no-op boundary
    }

    #[test]
    fn set_and_to_vec() {
        let mut r = RingVec::new();
        for v in 0..10i64 {
            r.push(v);
        }
        r.evict_to(5);
        r.set(7, -1);
        assert_eq!(r.to_vec(), vec![5, 6, -1, 8, 9]);
    }

    #[test]
    fn slice_mut_writes_through_absolute_indices() {
        let mut r = RingVec::new();
        for v in 0..300u32 {
            r.push(v);
        }
        r.evict_to(200); // compacts (off != 0): local != absolute
        {
            let s = r.slice_mut(250, 260);
            assert_eq!(s.len(), 10);
            for (k, x) in s.iter_mut().enumerate() {
                *x = 1000 + k as u32;
            }
        }
        for abs in 250..260 {
            assert_eq!(r.get(abs), 1000 + (abs - 250) as u32);
        }
        assert_eq!(r.get(249), 249);
        assert_eq!(r.get(260), 260);
        // full retained range is a valid (and the largest) view
        let first = r.first_index();
        let next = r.next_index();
        assert_eq!(r.slice_mut(first, next).len(), 100);
    }

    #[test]
    #[should_panic(expected = "outside retained range")]
    fn slice_mut_below_head_panics() {
        let mut r = RingVec::new();
        for v in 0..10u32 {
            r.push(v);
        }
        r.evict_to(5);
        let _ = r.slice_mut(4, 8);
    }

    #[test]
    #[should_panic(expected = "outside retained range")]
    fn slice_below_head_panics() {
        let mut r = RingVec::new();
        for v in 0..10u32 {
            r.push(v);
        }
        r.evict_to(5);
        let _ = r.slice(3, 6);
    }

    #[test]
    fn from_parts_round_trips_the_retained_view() {
        let mut r = RingVec::new();
        for v in 0..300u32 {
            r.push(v);
        }
        r.evict_to(180); // compacts: off != 0
        let rebuilt = RingVec::from_parts(r.first_index(), r.retained().to_vec());
        assert_eq!(rebuilt.first_index(), r.first_index());
        assert_eq!(rebuilt.next_index(), r.next_index());
        assert_eq!(rebuilt.retained(), r.retained());
        // the rebuilt ring keeps behaving like the original
        let mut rebuilt = rebuilt;
        rebuilt.push(300);
        assert_eq!(rebuilt.get(300), 300);
        assert_eq!(rebuilt.get(180), 180);
        rebuilt.evict_to(290);
        assert_eq!(rebuilt.first_index(), 290);
        // empty view round-trips too (a stream evicted to the tip)
        let empty = RingVec::<u32>::from_parts(42, Vec::new());
        assert!(empty.is_empty());
        assert_eq!(empty.first_index(), 42);
        assert_eq!(empty.next_index(), 42);
    }

    #[test]
    #[should_panic(expected = "cannot evict past the end")]
    fn evict_past_end_panics() {
        let mut r = RingVec::<u32>::new();
        r.push(1);
        r.evict_to(5);
    }
}
