//! Series preprocessing — what users run before matrix profile.
//!
//! Matrix profile assumes a reasonably clean real-valued series; the
//! domains the paper motivates (ECG, seismology, economics) all need the
//! same small toolkit first: gap repair, detrending, global scaling, and
//! downsampling.  Everything is allocation-explicit and generic over the
//! crate's [`Real`] types.

use crate::Real;

/// Replace non-finite samples by linear interpolation between the nearest
/// finite neighbors (edges: nearest finite value).  Errors if no finite
/// sample exists.
pub fn repair_gaps<T: Real>(t: &[T]) -> crate::Result<Vec<T>> {
    anyhow::ensure!(
        t.iter().any(|x| x.is_finite()),
        "series has no finite samples"
    );
    let mut out = t.to_vec();
    let n = t.len();
    let mut i = 0usize;
    while i < n {
        if out[i].is_finite() {
            i += 1;
            continue;
        }
        // find gap [i, j)
        let mut j = i;
        while j < n && !out[j].is_finite() {
            j += 1;
        }
        let left = if i > 0 { Some(out[i - 1]) } else { None };
        let right = if j < n { Some(out[j]) } else { None };
        match (left, right) {
            (Some(l), Some(r)) => {
                let span = (j - i + 1) as f64;
                for (k, slot) in out[i..j].iter_mut().enumerate() {
                    let w = (k + 1) as f64 / span;
                    *slot = T::of_f64(l.to_f64s() * (1.0 - w) + r.to_f64s() * w);
                }
            }
            (Some(l), None) => out[i..j].fill(l),
            (None, Some(r)) => out[i..j].fill(r),
            (None, None) => unreachable!("checked above"),
        }
        i = j;
    }
    Ok(out)
}

/// Remove the least-squares linear trend (in place).
pub fn detrend<T: Real>(t: &mut [T]) {
    let n = t.len();
    if n < 2 {
        return;
    }
    let nf = n as f64;
    let mean_x = (nf - 1.0) / 2.0;
    let mean_y = t.iter().map(|v| v.to_f64s()).sum::<f64>() / nf;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for (i, v) in t.iter().enumerate() {
        let dx = i as f64 - mean_x;
        sxy += dx * (v.to_f64s() - mean_y);
        sxx += dx * dx;
    }
    let slope = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    for (i, v) in t.iter_mut().enumerate() {
        let fit = mean_y + slope * (i as f64 - mean_x);
        *v = T::of_f64(v.to_f64s() - fit);
    }
}

/// Scale to zero mean / unit variance globally (no-op on constant series).
pub fn standardize<T: Real>(t: &mut [T]) {
    let n = t.len() as f64;
    if n == 0.0 {
        return;
    }
    let mean = t.iter().map(|v| v.to_f64s()).sum::<f64>() / n;
    let var = t
        .iter()
        .map(|v| {
            let d = v.to_f64s() - mean;
            d * d
        })
        .sum::<f64>()
        / n;
    let sd = var.sqrt();
    if sd == 0.0 {
        return;
    }
    for v in t.iter_mut() {
        *v = T::of_f64((v.to_f64s() - mean) / sd);
    }
}

/// Downsample by integer factor using block means (anti-aliasing-lite);
/// the window length should be divided by the same factor.
pub fn downsample<T: Real>(t: &[T], factor: usize) -> Vec<T> {
    assert!(factor >= 1, "factor must be >= 1");
    if factor == 1 {
        return t.to_vec();
    }
    t.chunks(factor)
        .map(|blk| {
            let s = blk.iter().map(|v| v.to_f64s()).sum::<f64>();
            T::of_f64(s / blk.len() as f64)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check, Rng};

    #[test]
    fn repair_interpolates_interior_gap() {
        let t = vec![1.0f64, f64::NAN, f64::NAN, 4.0];
        let r = repair_gaps(&t).unwrap();
        assert_eq!(r, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn repair_extends_edges() {
        let t = vec![f64::NAN, 2.0, 3.0, f64::INFINITY];
        let r = repair_gaps(&t).unwrap();
        assert_eq!(r, vec![2.0, 2.0, 3.0, 3.0]);
    }

    #[test]
    fn repair_all_nan_errors() {
        assert!(repair_gaps(&[f64::NAN, f64::NAN]).is_err());
    }

    #[test]
    fn detrend_removes_exact_line() {
        let mut t: Vec<f64> = (0..100).map(|i| 3.0 + 0.5 * i as f64).collect();
        detrend(&mut t);
        assert!(t.iter().all(|v| v.abs() < 1e-9), "max {:?}", t.iter().cloned().fold(0.0, f64::max));
    }

    #[test]
    fn prop_detrend_kills_added_trend() {
        check("detrend-invariance", 8, |rng: &mut Rng| {
            let n = rng.range(50, 400);
            let base: Vec<f64> = rng.gauss_vec(n);
            let slope = rng.gauss();
            let mut with_trend: Vec<f64> = base
                .iter()
                .enumerate()
                .map(|(i, v)| v + slope * i as f64)
                .collect();
            let mut plain = base.clone();
            detrend(&mut with_trend);
            detrend(&mut plain);
            for k in 0..n {
                assert!(
                    (with_trend[k] - plain[k]).abs() < 1e-6,
                    "k={k}: {} vs {}",
                    with_trend[k],
                    plain[k]
                );
            }
        });
    }

    #[test]
    fn standardize_moments() {
        let mut rng = Rng::new(3);
        let mut t: Vec<f64> = rng.gauss_vec(500).iter().map(|x| 10.0 + 5.0 * x).collect();
        standardize(&mut t);
        let mean = t.iter().sum::<f64>() / 500.0;
        let var = t.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / 500.0;
        assert!(mean.abs() < 1e-12);
        assert!((var - 1.0).abs() < 1e-12);
    }

    #[test]
    fn standardize_constant_is_noop() {
        let mut t = vec![2.0f32; 10];
        standardize(&mut t);
        assert_eq!(t, vec![2.0f32; 10]);
    }

    #[test]
    fn downsample_block_means() {
        let t = vec![1.0f64, 3.0, 5.0, 7.0, 9.0];
        assert_eq!(downsample(&t, 2), vec![2.0, 6.0, 9.0]);
        assert_eq!(downsample(&t, 1), t);
    }
}
