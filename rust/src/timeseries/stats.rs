//! Sliding window statistics — Algorithm 1 line 1 / Algorithm 2 line 2.
//!
//! The paper precomputes the per-window mean and population standard
//! deviation on the host CPU in O(n) [81] before starting the accelerator;
//! this module is that precompute.  Two formulations are provided:
//!
//! * [`sliding_stats`] — cumulative-sum based, one pass, the fast path;
//! * [`sliding_stats_exact`] — direct per-window summation, numerically
//!   robust oracle used by tests to bound the cumsum error.
//!
//! The cumsum variant accumulates in `f64` regardless of the element type:
//! for the SP design the paper's host would do the same (the statistics are
//! tiny compared to the O(n²) profile work) and it keeps f32 series with
//! large offsets from losing all variance digits.

use crate::timeseries::num_windows;
use crate::Real;

/// Per-window statistics: `mu[i]`, `sig[i]` for window `T[i, m]`.
#[derive(Clone, Debug)]
pub struct WindowStats<T> {
    pub mu: Vec<T>,
    pub sig: Vec<T>,
    /// 1/(m*sig) premultiplier used by the hot distance loop; zero where
    /// the window is constant (sig == 0).
    pub inv_msig: Vec<T>,
    /// Folded Eq. 1 factors (perf pass): with za = sqrt(2)/sig and
    /// zb = sqrt(2m)*mu/sig, the squared distance collapses to
    /// `d2 = 2m - q*za_i*za_j + zb_i*zb_j` (3 mul + 2 add per cell).
    /// Zero for constant windows, making d2 degenerate to 2m.
    pub za: Vec<T>,
    pub zb: Vec<T>,
    pub m: usize,
}

impl<T: Real> WindowStats<T> {
    /// Number of windows covered.
    pub fn len(&self) -> usize {
        self.mu.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mu.is_empty()
    }
}

/// O(n) cumulative-sum sliding mean/std (population, ddof = 0).
///
/// Panics if `m == 0` or the series is shorter than `m`.
pub fn sliding_stats<T: Real>(t: &[T], m: usize) -> WindowStats<T> {
    assert!(m > 0, "window length must be positive");
    let nw = num_windows(t.len(), m);
    assert!(nw > 0, "series shorter than window ({} < {m})", t.len());

    let mf = m as f64;
    let mut mu = Vec::with_capacity(nw);
    let mut sig = Vec::with_capacity(nw);
    let mut inv_msig = Vec::with_capacity(nw);
    let mut za = Vec::with_capacity(nw);
    let mut zb = Vec::with_capacity(nw);
    let sqrt2 = 2.0f64.sqrt(); // za = sqrt(2)/sigma
    let sqrt_2m = (2.0 * mf).sqrt(); // zb = sqrt(2m)*mu/sigma

    // Rolling f64 accumulators; re-anchored subtraction keeps drift bounded
    // for the lengths we target (<= 2^21 paper sizes).
    let mut s = 0.0f64;
    let mut s2 = 0.0f64;
    for &x in &t[..m] {
        let x = x.to_f64s();
        s += x;
        s2 += x * x;
    }
    for i in 0..nw {
        let mean = s / mf;
        let var = (s2 / mf - mean * mean).max(0.0);
        let sd = var.sqrt();
        mu.push(T::of_f64(mean));
        sig.push(T::of_f64(sd));
        if sd > 0.0 {
            inv_msig.push(T::of_f64(1.0 / (mf * sd)));
            za.push(T::of_f64(sqrt2 / sd));
            zb.push(T::of_f64(sqrt_2m * mean / sd));
        } else {
            inv_msig.push(T::zero());
            za.push(T::zero());
            zb.push(T::zero());
        }
        if i + 1 < nw {
            let out = t[i].to_f64s();
            let inc = t[i + m].to_f64s();
            s += inc - out;
            s2 += inc * inc - out * out;
        }
    }
    WindowStats { mu, sig, inv_msig, za, zb, m }
}

/// Direct per-window two-pass mean/std — the numerically robust oracle.
pub fn sliding_stats_exact<T: Real>(t: &[T], m: usize) -> WindowStats<T> {
    assert!(m > 0);
    let nw = num_windows(t.len(), m);
    assert!(nw > 0);
    let mf = m as f64;
    let mut mu = Vec::with_capacity(nw);
    let mut sig = Vec::with_capacity(nw);
    let mut inv_msig = Vec::with_capacity(nw);
    let mut za = Vec::with_capacity(nw);
    let mut zb = Vec::with_capacity(nw);
    let sqrt2 = 2.0f64.sqrt();
    let sqrt_2m = (2.0 * mf).sqrt();
    for i in 0..nw {
        let w = &t[i..i + m];
        let mean = w.iter().map(|x| x.to_f64s()).sum::<f64>() / mf;
        let var = w
            .iter()
            .map(|x| {
                let d = x.to_f64s() - mean;
                d * d
            })
            .sum::<f64>()
            / mf;
        let sd = var.sqrt();
        mu.push(T::of_f64(mean));
        sig.push(T::of_f64(sd));
        if sd > 0.0 {
            inv_msig.push(T::of_f64(1.0 / (mf * sd)));
            za.push(T::of_f64(sqrt2 / sd));
            zb.push(T::of_f64(sqrt_2m * mean / sd));
        } else {
            inv_msig.push(T::zero());
            za.push(T::zero());
            zb.push(T::zero());
        }
    }
    WindowStats { mu, sig, inv_msig, za, zb, m }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check, Rng};

    #[test]
    fn matches_exact_small() {
        let t: Vec<f64> = vec![1.0, 2.0, 4.0, 7.0, 11.0, 16.0];
        let a = sliding_stats(&t, 3);
        let b = sliding_stats_exact(&t, 3);
        for i in 0..a.len() {
            assert!((a.mu[i] - b.mu[i]).abs() < 1e-12);
            assert!((a.sig[i] - b.sig[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn single_window_equals_whole_series() {
        let t = vec![2.0f64, 4.0, 6.0, 8.0];
        let st = sliding_stats(&t, 4);
        assert_eq!(st.len(), 1);
        assert!((st.mu[0] - 5.0).abs() < 1e-12);
        assert!((st.sig[0] - 5.0f64.sqrt()).abs() < 1e-12); // var = 5
    }

    #[test]
    fn constant_window_has_zero_sig_and_inv() {
        let t = vec![3.0f32; 10];
        let st = sliding_stats(&t, 4);
        for i in 0..st.len() {
            assert_eq!(st.sig[i], 0.0);
            assert_eq!(st.inv_msig[i], 0.0);
        }
    }

    #[test]
    #[should_panic(expected = "shorter than window")]
    fn too_short_panics() {
        sliding_stats(&[1.0f64, 2.0], 5);
    }

    #[test]
    fn prop_cumsum_matches_exact() {
        check("stats-cumsum-vs-exact", 25, |rng: &mut Rng| {
            let n = rng.range(16, 400);
            let m = rng.range(2, (n / 2).max(3));
            let offset = rng.gauss() * 100.0; // stress cancellation
            let t: Vec<f64> = rng.gauss_vec(n).iter().map(|x| x + offset).collect();
            let a = sliding_stats(&t, m);
            let b = sliding_stats_exact(&t, m);
            for i in 0..a.len() {
                assert!(
                    (a.mu[i] - b.mu[i]).abs() < 1e-8,
                    "mu[{i}] {} vs {}",
                    a.mu[i],
                    b.mu[i]
                );
                assert!(
                    (a.sig[i] - b.sig[i]).abs() < 1e-6,
                    "sig[{i}] {} vs {}",
                    a.sig[i],
                    b.sig[i]
                );
            }
        });
    }

    #[test]
    fn prop_f32_accumulates_in_f64() {
        // A large constant offset obliterates f32 accumulation; our f64
        // internal accumulators must keep the std-dev accurate.
        check("stats-f32-offset", 10, |rng: &mut Rng| {
            let n = rng.range(64, 256);
            let m = 16;
            let t: Vec<f32> = rng
                .gauss_vec(n)
                .iter()
                .map(|x| (*x + 1.0e4) as f32)
                .collect();
            let st = sliding_stats(&t, m);
            let exact = sliding_stats_exact(&t, m);
            for i in 0..st.len() {
                assert!(
                    (st.sig[i] - exact.sig[i]).abs() < 2e-2,
                    "sig[{i}] {} vs {}",
                    st.sig[i],
                    exact.sig[i]
                );
            }
        });
    }
}
