//! Deterministic synthetic workload generators.
//!
//! The paper evaluates on five MATLAB-generated random series
//! (`rand_128K` … `rand_2M`, Table 1) plus two real recordings (an ECG from
//! the European ST-T database and a seismograph trace).  The real datasets
//! are not redistributable, so this module generates *synthetic equivalents
//! that plant the same event classes* (DESIGN.md §2 substitutions):
//!
//! * [`Pattern::RandomWalk`] — the Table 1 performance workloads,
//! * [`Pattern::SineWithAnomaly`] — the paper's Fig. 1 demo signal,
//! * [`Pattern::EcgLike`] — periodic PQRST-ish beats with one arrhythmic
//!   (premature, misshapen) beat: the profile must spike there (Fig. 12
//!   left),
//! * [`Pattern::SeismicLike`] — background microseism noise with a planted
//!   quake burst: profile spike at onset (Fig. 12 right),
//! * [`Pattern::PlantedMotif`] — a pair of near-identical windows for
//!   motif-discovery tests (profile dip to ~0 at both sites).
//!
//! All generators are pure functions of `(pattern, n, seed)`.

use crate::prop::Rng;
use crate::Real;

/// Synthetic workload families.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pattern {
    /// Integrated white noise — the paper's `rand_*` series.
    RandomWalk,
    /// Sinusoid with a flattened anomaly, as in the paper's Fig. 1.
    SineWithAnomaly,
    /// ECG-like periodic beats, one arrhythmic beat planted mid-series.
    EcgLike,
    /// Low-amplitude noise with one high-energy quake burst.
    SeismicLike,
    /// Gaussian noise with one exact repeated window pair (a motif).
    PlantedMotif,
}

impl Pattern {
    /// All patterns, for sweep-style tests.
    pub const ALL: [Pattern; 5] = [
        Pattern::RandomWalk,
        Pattern::SineWithAnomaly,
        Pattern::EcgLike,
        Pattern::SeismicLike,
        Pattern::PlantedMotif,
    ];

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Pattern> {
        Some(match s {
            "random-walk" | "rand" => Pattern::RandomWalk,
            "sine-anomaly" | "sine" => Pattern::SineWithAnomaly,
            "ecg" => Pattern::EcgLike,
            "seismic" => Pattern::SeismicLike,
            "motif" => Pattern::PlantedMotif,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Pattern::RandomWalk => "random-walk",
            Pattern::SineWithAnomaly => "sine-anomaly",
            Pattern::EcgLike => "ecg",
            Pattern::SeismicLike => "seismic",
            Pattern::PlantedMotif => "motif",
        }
    }
}

/// Where a generator planted its event, if any.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlantedEvent {
    None,
    /// Anomaly (discord) covering `[start, start+len)`.
    Anomaly { start: usize, len: usize },
    /// Motif pair at the two window start positions.
    Motif { a: usize, b: usize, len: usize },
}

/// Generate a series and report the planted event location.
pub fn generate_with_event<T: Real>(p: Pattern, n: usize, seed: u64) -> (Vec<T>, PlantedEvent) {
    assert!(n >= 64, "generators assume n >= 64");
    let mut rng = Rng::new(seed ^ 0xDA7A_5E7);
    match p {
        Pattern::RandomWalk => {
            let mut acc = 0.0f64;
            let t = (0..n)
                .map(|_| {
                    acc += rng.gauss();
                    T::of_f64(acc)
                })
                .collect();
            (t, PlantedEvent::None)
        }
        Pattern::SineWithAnomaly => {
            // Fig. 1: periodic signal, anomaly ~ values [n/2, n/2 + n/25).
            let period = 64.0;
            let start = n / 2;
            let len = (n / 25).max(8);
            let t = (0..n)
                .map(|i| {
                    let base = (2.0 * std::f64::consts::PI * i as f64 / period).sin();
                    let v = if (start..start + len).contains(&i) {
                        0.15 * base + 0.05 * rng.gauss() // flattened segment
                    } else {
                        base + 0.02 * rng.gauss()
                    };
                    T::of_f64(v)
                })
                .collect();
            (t, PlantedEvent::Anomaly { start, len })
        }
        Pattern::EcgLike => {
            // Beats every `beat` samples: sharp R spike + smaller T hump.
            // One premature, inverted beat in the middle = arrhythmia.
            let beat = 96usize;
            let anomaly_beat = (n / beat) / 2;
            let start = anomaly_beat * beat;
            let mut t = vec![0.0f64; n];
            let mut k = 0usize;
            let mut idx = 0usize;
            while idx + beat <= n {
                let is_anom = k == anomaly_beat;
                // premature beat: shifted onset, inverted R, no T wave
                let shift = if is_anom { beat / 3 } else { 0 };
                let r_at = idx + 20 - shift.min(15);
                let sgn = if is_anom { -0.9 } else { 1.0 };
                for (off, amp) in [(0isize, 1.4), (-2, 0.35), (2, 0.4)] {
                    let p = r_at as isize + off;
                    if (0..n as isize).contains(&p) {
                        t[p as usize] += sgn * amp;
                    }
                }
                if !is_anom {
                    for j in 0..16 {
                        let p = idx + 50 + j;
                        if p < n {
                            t[p] += 0.25 * (std::f64::consts::PI * j as f64 / 16.0).sin();
                        }
                    }
                }
                idx += beat;
                k += 1;
            }
            for v in t.iter_mut() {
                *v += 0.03 * rng.gauss();
            }
            let t = t.into_iter().map(T::of_f64).collect();
            (t, PlantedEvent::Anomaly { start, len: beat })
        }
        Pattern::SeismicLike => {
            // Periodic microseism background + decaying *chirp* burst.
            // The burst must be aperiodic: under z-normalization a
            // fixed-frequency burst is self-similar (its windows match
            // each other at one period of lag), which makes it a motif,
            // not a discord.  A frequency sweep keeps every burst window
            // unique, so the profile spikes at the onset.
            let start = 2 * n / 3;
            let len = (n / 20).max(64);
            let t = (0..n)
                .map(|i| {
                    let bg = 0.1 * (2.0 * std::f64::consts::PI * i as f64 / 173.0).sin()
                        + 0.02 * rng.gauss();
                    let v = if (start..start + len).contains(&i) {
                        let k = (i - start) as f64;
                        let lf = len as f64;
                        // instantaneous frequency sweeps 1/40 -> 1/6
                        let phase = 2.0
                            * std::f64::consts::PI
                            * (k / 40.0 + (k * k) / (2.0 * lf) * (1.0 / 6.0 - 1.0 / 40.0));
                        bg + 2.0 * (-k / (lf / 2.0)).exp() * phase.sin()
                    } else {
                        bg
                    };
                    T::of_f64(v)
                })
                .collect();
            (t, PlantedEvent::Anomaly { start, len })
        }
        Pattern::PlantedMotif => {
            let len = (n / 16).clamp(16, 256);
            let a = n / 8;
            let b = 5 * n / 8;
            let mut t: Vec<f64> = rng.gauss_vec(n);
            let motif: Vec<f64> = t[a..a + len].to_vec();
            t[b..b + len].copy_from_slice(&motif);
            let t = t.into_iter().map(T::of_f64).collect();
            (t, PlantedEvent::Motif { a, b, len })
        }
    }
}

/// Generate a series, discarding the event metadata.
pub fn generate<T: Real>(p: Pattern, n: usize, seed: u64) -> Vec<T> {
    generate_with_event(p, n, seed).0
}

/// The paper's Table 1 synthetic sizes: 128K, 256K, 512K, 1M, 2M points.
pub const TABLE1_SIZES: [(usize, &str); 5] = [
    (131_072, "rand_128K"),
    (262_144, "rand_256K"),
    (524_288, "rand_512K"),
    (1_048_576, "rand_1M"),
    (2_097_152, "rand_2M"),
];

/// Generate a Table 1 workload by name (`rand_128K` …).
pub fn table1_series<T: Real>(name: &str, seed: u64) -> Option<Vec<T>> {
    TABLE1_SIZES
        .iter()
        .find(|(_, nm)| *nm == name)
        .map(|(n, _)| generate(Pattern::RandomWalk, *n, seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        for p in Pattern::ALL {
            let a = generate::<f64>(p, 512, 42);
            let b = generate::<f64>(p, 512, 42);
            let c = generate::<f64>(p, 512, 43);
            assert_eq!(a, b, "{p:?} not deterministic");
            assert_ne!(a, c, "{p:?} ignores seed");
        }
    }

    #[test]
    fn lengths_match() {
        for p in Pattern::ALL {
            assert_eq!(generate::<f32>(p, 300, 1).len(), 300);
        }
    }

    #[test]
    fn motif_is_exact_pair() {
        let (t, ev) = generate_with_event::<f64>(Pattern::PlantedMotif, 2048, 9);
        if let PlantedEvent::Motif { a, b, len } = ev {
            assert_eq!(&t[a..a + len], &t[b..b + len]);
        } else {
            panic!("expected motif event");
        }
    }

    #[test]
    fn anomaly_inside_series() {
        for p in [Pattern::SineWithAnomaly, Pattern::EcgLike, Pattern::SeismicLike] {
            let (t, ev) = generate_with_event::<f64>(p, 4096, 3);
            if let PlantedEvent::Anomaly { start, len } = ev {
                assert!(start + len <= t.len(), "{p:?} event out of range");
                assert!(start > 0);
            } else {
                panic!("{p:?}: expected anomaly event");
            }
        }
    }

    #[test]
    fn random_walk_is_nonstationary() {
        let t = generate::<f64>(Pattern::RandomWalk, 10_000, 5);
        let first = t[..100].iter().sum::<f64>() / 100.0;
        let last = t[9_900..].iter().sum::<f64>() / 100.0;
        // a walk drifts; identical means would indicate white noise
        assert!((first - last).abs() > 1e-3);
    }

    #[test]
    fn table1_names_resolve() {
        assert_eq!(table1_series::<f32>("rand_128K", 1).unwrap().len(), 131_072);
        assert!(table1_series::<f32>("rand_3M", 1).is_none());
    }

    #[test]
    fn pattern_parse_roundtrip() {
        for p in Pattern::ALL {
            assert_eq!(Pattern::parse(p.name()), Some(p));
        }
        assert_eq!(Pattern::parse("nope"), None);
    }
}
