//! Time series substrate: generation, window statistics, and I/O.
//!
//! A time series is a plain `Vec<T>`/`&[T]` throughout the crate — the
//! paper's `T` of `n` data points (Section 2.1).  This module provides:
//!
//! * [`stats`] — the O(n) sliding mean/std precompute of Algorithm 1 line 1
//!   (host-side `precalculateMeansDevs`),
//! * [`generator`] — deterministic synthetic workloads: the paper's
//!   `rand_128K..rand_2M` MATLAB series plus ECG-like / seismic-like /
//!   sinusoid-with-anomaly signals substituting for the real datasets
//!   (DESIGN.md §2, substitution table),
//! * [`io`] — newline/CSV loaders so users can feed real recordings,
//! * [`stream`] — the absolute-indexed ring buffer with bounded-history
//!   eviction that backs the streaming engine ([`crate::mp::stampi`]).

pub mod generator;
pub mod io;
pub mod stats;
pub mod stream;
pub mod transform;

pub use stats::{sliding_stats, WindowStats};

use crate::Real;

/// Number of length-`m` windows in a series of length `n`: `n - m + 1`.
///
/// Returns 0 when the series is shorter than the window.
pub fn num_windows(n: usize, m: usize) -> usize {
    (n + 1).saturating_sub(m)
}

/// Paper-default exclusion zone: `m / 4`, at least 1 (Section 2.1; the
/// main diagonal is always excluded).
pub fn default_exclusion(m: usize) -> usize {
    (m / 4).max(1)
}

/// z-normalize a window in place (test/visualization helper).
pub fn znormalize<T: Real>(w: &mut [T]) {
    let n = T::of_f64(w.len() as f64);
    let mu = w.iter().copied().sum::<T>() / n;
    let var = w.iter().map(|&x| (x - mu) * (x - mu)).sum::<T>() / n;
    let sig = var.sqrt();
    if sig > T::zero() {
        for x in w.iter_mut() {
            *x = (*x - mu) / sig;
        }
    } else {
        for x in w.iter_mut() {
            *x = T::zero();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_count() {
        assert_eq!(num_windows(10, 4), 7);
        assert_eq!(num_windows(4, 4), 1);
        assert_eq!(num_windows(3, 4), 0);
    }

    #[test]
    fn exclusion_default() {
        assert_eq!(default_exclusion(4), 1);
        assert_eq!(default_exclusion(16), 4);
        assert_eq!(default_exclusion(2), 1);
    }

    #[test]
    fn znormalize_zero_mean_unit_var() {
        let mut w = vec![1.0f64, 2.0, 3.0, 4.0, 5.0];
        znormalize(&mut w);
        let mean: f64 = w.iter().sum::<f64>() / 5.0;
        let var: f64 = w.iter().map(|x| x * x).sum::<f64>() / 5.0;
        assert!(mean.abs() < 1e-12);
        assert!((var - 1.0).abs() < 1e-12);
    }

    #[test]
    fn znormalize_constant_window() {
        let mut w = vec![3.0f32; 8];
        znormalize(&mut w);
        assert!(w.iter().all(|&x| x == 0.0));
    }
}
