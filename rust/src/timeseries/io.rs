//! Plain-text time series I/O.
//!
//! Formats supported (auto-detected on load):
//! * one value per line (comments with `#`, blank lines ignored),
//! * single-line or multi-line comma/whitespace separated values,
//! * an optional `value` CSV header (first non-numeric token line skipped).
//!
//! Kept dependency-free on purpose: the offline vendor set has no serde,
//! and a profile dump is just numbers.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::Context;

use crate::Real;

/// Load a series from a text/CSV file.
pub fn load_series<T: Real>(path: &Path) -> crate::Result<Vec<T>> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let reader = std::io::BufReader::new(f);
    let mut out = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        for tok in trimmed.split(|c: char| c == ',' || c.is_whitespace()) {
            if tok.is_empty() {
                continue;
            }
            match tok.parse::<f64>() {
                Ok(v) => out.push(T::of_f64(v)),
                Err(_) if lineno == 0 => continue, // header tokens
                Err(e) => {
                    anyhow::bail!("{}:{}: bad value '{tok}': {e}", path.display(), lineno + 1)
                }
            }
        }
    }
    anyhow::ensure!(!out.is_empty(), "{}: no data points", path.display());
    Ok(out)
}

/// Write a series, one value per line.
pub fn save_series<T: Real>(path: &Path, t: &[T]) -> crate::Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# series n={}", t.len())?;
    for v in t {
        writeln!(w, "{v}")?;
    }
    Ok(())
}

/// Write a matrix profile as `index,distance,neighbor` CSV.
pub fn save_profile<T: Real>(path: &Path, p: &[T], i: &[i64]) -> crate::Result<()> {
    anyhow::ensure!(p.len() == i.len(), "profile/index length mismatch");
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "index,distance,neighbor")?;
    for (k, (d, j)) in p.iter().zip(i).enumerate() {
        writeln!(w, "{k},{d},{j}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("natsa-io-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_lines() {
        let path = tmp("roundtrip.txt");
        let t = vec![1.5f64, -2.25, 3.0, 0.0];
        save_series(&path, &t).unwrap();
        let got: Vec<f64> = load_series(&path).unwrap();
        assert_eq!(got, t);
    }

    #[test]
    fn loads_csv_with_header() {
        let path = tmp("hdr.csv");
        std::fs::write(&path, "value\n1.0\n2.0\n3.5\n").unwrap();
        let got: Vec<f32> = load_series(&path).unwrap();
        assert_eq!(got, vec![1.0, 2.0, 3.5]);
    }

    #[test]
    fn loads_comma_separated_single_line() {
        let path = tmp("flat.csv");
        std::fs::write(&path, "1,2,3,4\n").unwrap();
        let got: Vec<f64> = load_series(&path).unwrap();
        assert_eq!(got, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn skips_comments_and_blanks() {
        let path = tmp("comments.txt");
        std::fs::write(&path, "# hello\n\n1.0\n# mid\n2.0\n").unwrap();
        let got: Vec<f64> = load_series(&path).unwrap();
        assert_eq!(got, vec![1.0, 2.0]);
    }

    #[test]
    fn bad_value_errors_with_location() {
        let path = tmp("bad.txt");
        std::fs::write(&path, "1.0\nnope\n").unwrap();
        let err = load_series::<f64>(&path).unwrap_err().to_string();
        assert!(err.contains(":2:"), "{err}");
    }

    #[test]
    fn empty_file_errors() {
        let path = tmp("empty.txt");
        std::fs::write(&path, "# nothing\n").unwrap();
        assert!(load_series::<f64>(&path).is_err());
    }

    #[test]
    fn profile_csv_shape() {
        let path = tmp("profile.csv");
        save_profile(&path, &[1.0f64, 2.0], &[5, 0]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("index,distance,neighbor\n0,1,5\n1,2,0\n"));
    }
}
