//! Per-shard segment write-ahead log for STAMPI streaming sessions.
//!
//! NATSA's premise is analyzing time series where the data resides; this
//! module makes the *sessions* reside somewhere too.  Every mutation of a
//! shard's stream table is logged before it is applied, so a crash or
//! restart replays the shard back to a state **bit-identical** to an
//! uninterrupted run (pinned by `tests/wal_recovery.rs`).
//!
//! ## Format
//!
//! A WAL directory holds numbered segment files `seg-NNNNNNNNNNNN.wal`.
//! Each segment starts with a 14-byte header (`b"NWG1"`, format
//! version, dtype tag, and the highest stream id the writer had seen
//! when the segment was created — so the id high-water survives even
//! after every record mentioning a closed stream is compacted away)
//! and then a sequence of CRC-framed records:
//!
//! ```text
//! [len: u32 LE] [crc32: u32 LE] [payload: len bytes]
//! payload = [kind: u8] [lsn: u64 LE] [stream: u64 LE] [body...]
//! ```
//!
//! Record kinds:
//!
//! * `Open` — a stream was created; body carries its configuration
//!   (`m`, exclusion override, history bound).
//! * `Append` — one append packet; body carries the service-level
//!   sequence number and the raw samples (each as the bit pattern of its
//!   `f64` widening — exact for `f32` and `f64`, same convention as
//!   [`SessionState`]'s codec).
//! * `Snapshot` — a full serialized [`SessionState`] plus the next
//!   expected append sequence; **subsumes** every earlier record of that
//!   stream.
//! * `Close` — the stream was closed; replay never resurrects it.
//!
//! LSNs are contiguous and monotone across the whole directory (they
//! survive rotation, compaction and restart); replay verifies this, and
//! the model test (`tests/wal_model.rs`) drives random interleavings of
//! append/snapshot/rotate/crash against a reference model to hold the
//! invariant.
//!
//! ## Rotation and compaction
//!
//! The writer rotates to a fresh segment once the current one exceeds
//! `segment_bytes`.  Compaction is **pin-based**: each live stream pins
//! the segment holding its latest `Snapshot` (or its `Open`, before the
//! first snapshot); rotation deletes every segment older than the
//! minimum pin.  Pins only ever reference data the stream still needs,
//! so compaction never requires touching stream locks — the service can
//! hold a stream's state lock while logging without deadlocking against
//! rotation.  Across a restart the pin table is rebuilt by [`replay`]
//! and seeded into [`WalWriter::resume`], so the writer is
//! compaction-safe immediately — in particular a rotation fired in the
//! middle of the recovery [`WalWriter::checkpoint`] cannot reclaim
//! pre-restart segments that later-checkpointed streams still need.
//!
//! Segment files and `wal.meta` entries are made durable with a
//! directory fsync after every create/remove, so a synced record can
//! never be lost to a forgotten directory entry.
//!
//! A torn record at the tail of the **newest** segment (crash mid-write)
//! is detected by length/CRC, reported by [`replay`], and truncated away
//! when a writer [`WalWriter::resume`]s; corruption anywhere else is an
//! error.
//!
//! The state payload is deliberately the standalone
//! [`SessionState`] codec from [`crate::mp::stampi`] so the planned
//! hot-shard stream migration (ROADMAP) can hand the same bytes to a
//! peer instead of a disk.

use std::collections::BTreeMap;
use std::fs::{self, File, OpenOptions};
use std::io::{Read as _, Write as _};
use std::path::{Path, PathBuf};

use crate::mp::stampi::SessionState;
use crate::Real;

/// Segment header magic ("NATSA WAL geometry v1").
const SEG_MAGIC: &[u8; 4] = b"NWG1";
/// Format version byte.
const SEG_VERSION: u8 = 1;
/// Header: magic + version + dtype tag + max stream id (u64 LE).
const SEG_HEADER_LEN: u64 = 14;
/// Frame prefix: len + crc.
const FRAME_PREFIX: usize = 8;
/// Upper bound on a single record payload — anything larger is treated
/// as corruption rather than an allocation request.
const MAX_RECORD: u32 = 1 << 30;

const KIND_OPEN: u8 = 1;
const KIND_APPEND: u8 = 2;
const KIND_SNAPSHOT: u8 = 3;
const KIND_CLOSE: u8 = 4;

/// Tuning knobs for a shard WAL.
#[derive(Clone, Debug)]
pub struct WalOptions {
    /// Appends between per-stream snapshots (the service's cadence;
    /// stored here so writer and service agree in one place).
    pub snapshot_every: u32,
    /// Rotate to a new segment once the current one exceeds this size.
    pub segment_bytes: u64,
    /// `fsync` after every record (durability vs throughput).
    pub sync: bool,
}

impl Default for WalOptions {
    fn default() -> Self {
        WalOptions {
            snapshot_every: 256,
            segment_bytes: 1 << 20,
            sync: false,
        }
    }
}

/// Stream configuration as logged by an `Open` record — everything
/// needed to rebuild a session that never reached its first snapshot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamMeta {
    pub m: usize,
    pub excl: Option<usize>,
    pub max_history: Option<usize>,
    /// Placement epoch of this incarnation (router-issued, strictly
    /// increasing across migrations).  When a crash leaves a stream
    /// open in two shard directories — the window between the target's
    /// Open+Snapshot and the source's Close — recovery keeps the
    /// incarnation with the higher epoch and closes the other.
    pub epoch: u64,
}

/// One stream reconstructed by [`replay`]: its latest snapshot (if any)
/// plus the append packets logged after it, in order.  Closed streams
/// are never returned.
#[derive(Debug)]
pub struct ReplayedStream<T> {
    pub id: u64,
    /// Configuration from the `Open` record; carried even when a
    /// snapshot exists (the snapshot's own fields must agree).
    pub meta: StreamMeta,
    /// Placement epoch of this incarnation (from the `Open` record, or
    /// the latest `Snapshot` when compaction dropped the `Open`).
    pub epoch: u64,
    /// Latest snapshot: (next expected append seq, engine state).
    pub snapshot: Option<(u64, SessionState<T>)>,
    /// Append packets after the snapshot (or since `Open`): (seq, samples).
    pub appends: Vec<(u64, Vec<T>)>,
}

impl<T> ReplayedStream<T> {
    /// The service-level sequence number the stream expects next.
    pub fn next_seq(&self) -> u64 {
        if let Some(&(seq, _)) = self.appends.last() {
            seq + 1
        } else {
            self.snapshot.as_ref().map_or(0, |&(ns, _)| ns)
        }
    }
}

/// Everything [`replay`] learned from a WAL directory.
#[derive(Debug)]
pub struct Replay<T> {
    /// Open streams, ascending by id.
    pub streams: Vec<ReplayedStream<T>>,
    /// Stream ids that were closed (still visible in retained segments).
    pub closed: Vec<u64>,
    /// First LSN the writer may assign.
    pub next_lsn: u64,
    /// Segment id the writer should continue in / after.
    pub next_segment: u64,
    /// Per-stream compaction pins: stream id → segment holding its
    /// latest `Snapshot` (or `Open`).  [`WalWriter::resume`] seeds its
    /// pin table from this, so logging after a restart — including a
    /// stream-at-a-time [`WalWriter::checkpoint`] — can never trigger a
    /// compaction that reclaims segments a not-yet-resnapshotted stream
    /// still needs.
    pub pins: BTreeMap<u64, u64>,
    /// Highest placement epoch seen in any `Open` or `Snapshot` record
    /// (0 when none), including records of streams later closed.  The
    /// router's epoch allocator must restart strictly above the max of
    /// this over every shard directory.
    pub max_epoch: u64,
    /// Highest stream id ever seen in this directory (0 when none):
    /// max over retained record stream ids *and* every segment header's
    /// high-water field, so it survives compaction of Close records.
    /// Id allocators must restart strictly above it.
    pub max_stream: u64,
    /// Torn tail detected in the newest segment: (segment id, byte
    /// offset of the first bad byte).  [`WalWriter::resume`] truncates it.
    pub torn: Option<(u64, u64)>,
    /// Total records successfully decoded (diagnostics).
    pub records: u64,
}

/// Append-side handle for one shard's WAL.
pub struct WalWriter<T: Real> {
    dir: PathBuf,
    opts: WalOptions,
    file: File,
    seg_id: u64,
    seg_len: u64,
    next_lsn: u64,
    /// stream id -> segment holding its latest Snapshot (or Open).
    pins: BTreeMap<u64, u64>,
    /// Highest stream id ever logged here (carried into every new
    /// segment's header so it outlives compaction).
    max_stream: u64,
    _t: std::marker::PhantomData<T>,
}

// ---------------------------------------------------------------------
// CRC32 (IEEE, reflected) — table built once, no external dependency.
// ---------------------------------------------------------------------

fn crc32_table() -> &'static [u32; 256] {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    TABLE.get_or_init(|| {
        let mut table = [0u32; 256];
        for (n, slot) in table.iter_mut().enumerate() {
            let mut c = n as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        table
    })
}

/// CRC-32 (IEEE 802.3) of `buf`.
pub fn crc32(buf: &[u8]) -> u32 {
    let table = crc32_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in buf {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------
// Byte helpers (same conventions as the SessionState codec).
// ---------------------------------------------------------------------

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_opt(out: &mut Vec<u8>, v: Option<usize>) {
    match v {
        Some(x) => {
            out.push(1);
            put_u64(out, x as u64);
        }
        None => {
            out.push(0);
            put_u64(out, 0);
        }
    }
}

struct Cur<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> crate::Result<&'a [u8]> {
        anyhow::ensure!(
            self.at + n <= self.buf.len(),
            "wal record truncated at byte {} (+{n} > {})",
            self.at,
            self.buf.len()
        );
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self) -> crate::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u64(&mut self) -> crate::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn usize(&mut self) -> crate::Result<usize> {
        Ok(usize::try_from(self.u64()?)?)
    }

    fn opt(&mut self) -> crate::Result<Option<usize>> {
        let has = self.u8()? != 0;
        let v = self.usize()?;
        Ok(has.then_some(v))
    }

    fn done(&self) -> crate::Result<()> {
        anyhow::ensure!(
            self.at == self.buf.len(),
            "wal record has {} trailing bytes",
            self.buf.len() - self.at
        );
        Ok(())
    }
}

fn segment_path(dir: &Path, id: u64) -> PathBuf {
    dir.join(format!("seg-{id:012}.wal"))
}

/// Make directory-entry changes (segment create/remove) durable.  A
/// file's own fsync does not persist its directory entry; without this,
/// a crash could forget a just-created segment whose records were
/// already synced and acked.  No-op on platforms where directories
/// cannot be opened for syncing.
fn fsync_dir(dir: &Path) -> crate::Result<()> {
    #[cfg(unix)]
    File::open(dir)?.sync_all()?;
    #[cfg(not(unix))]
    let _ = dir;
    Ok(())
}

/// Ascending (id, path) of every segment file in `dir`.
fn list_segments(dir: &Path) -> crate::Result<Vec<(u64, PathBuf)>> {
    let mut segs = Vec::new();
    if !dir.exists() {
        return Ok(segs);
    }
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(id) = name
            .strip_prefix("seg-")
            .and_then(|s| s.strip_suffix(".wal"))
            .and_then(|s| s.parse::<u64>().ok())
        {
            segs.push((id, entry.path()));
        }
    }
    segs.sort();
    Ok(segs)
}

// ---------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------

impl<T: Real> WalWriter<T> {
    /// Open the append side of a WAL directory, continuing from what
    /// [`replay`] saw: the next record gets LSN `replay.next_lsn`, a
    /// fresh segment `replay.next_segment` is started, and a torn tail
    /// (if any) is truncated away first.
    ///
    /// The pin table is seeded from [`Replay::pins`], so every replayed
    /// stream keeps protecting its pre-restart segments until the
    /// caller logs a fresh `Snapshot` for it (see
    /// [`WalWriter::checkpoint`]) — logging (and any rotation it
    /// triggers) is compaction-safe from the first record, not only
    /// after a full checkpoint.
    pub fn resume(dir: &Path, opts: WalOptions, replay: &Replay<T>) -> crate::Result<Self> {
        fs::create_dir_all(dir)?;
        if let Some((seg, at)) = replay.torn {
            let path = segment_path(dir, seg);
            if at < SEG_HEADER_LEN {
                // The crash landed inside the segment header: nothing in
                // the file is usable, and a 0-length stub would read as
                // corruption once a newer segment exists.  Drop it.
                fs::remove_file(&path)?;
                fsync_dir(dir)?;
            } else {
                let f = OpenOptions::new().write(true).open(path)?;
                f.set_len(at)?;
                f.sync_all()?;
            }
        }
        let seg_id = replay.next_segment;
        let file = Self::new_segment(dir, seg_id, replay.max_stream)?;
        Ok(WalWriter {
            dir: dir.to_path_buf(),
            opts,
            file,
            seg_id,
            seg_len: SEG_HEADER_LEN,
            next_lsn: replay.next_lsn,
            pins: replay.pins.clone(),
            max_stream: replay.max_stream,
            _t: std::marker::PhantomData,
        })
    }

    fn new_segment(dir: &Path, id: u64, max_stream: u64) -> crate::Result<File> {
        let mut file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(segment_path(dir, id))?;
        let mut header = Vec::with_capacity(SEG_HEADER_LEN as usize);
        header.extend_from_slice(SEG_MAGIC);
        header.push(SEG_VERSION);
        header.push(T::BYTES as u8);
        header.extend_from_slice(&max_stream.to_le_bytes());
        file.write_all(&header)?;
        // The entry must be durable too: records synced into this file
        // are only recoverable if the file itself survives the crash.
        fsync_dir(dir)?;
        Ok(file)
    }

    /// LSN the next record will get (contiguity handle for tests).
    pub fn next_lsn(&self) -> u64 {
        self.next_lsn
    }

    /// Segment currently being written.
    pub fn segment(&self) -> u64 {
        self.seg_id
    }

    fn log(&mut self, kind: u8, stream: u64, body: &[u8]) -> crate::Result<u64> {
        self.max_stream = self.max_stream.max(stream);
        let lsn = self.next_lsn;
        let mut payload = Vec::with_capacity(17 + body.len());
        payload.push(kind);
        put_u64(&mut payload, lsn);
        put_u64(&mut payload, stream);
        payload.extend_from_slice(body);
        let mut frame = Vec::with_capacity(FRAME_PREFIX + payload.len());
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&crc32(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        self.file.write_all(&frame)?;
        if self.opts.sync {
            self.file.sync_data()?;
        }
        self.seg_len += frame.len() as u64;
        self.next_lsn += 1;
        if self.seg_len >= self.opts.segment_bytes {
            self.rotate()?;
        }
        Ok(lsn)
    }

    /// A stream was created.  Must be logged **before** the stream
    /// becomes visible to appends.
    pub fn log_open(&mut self, stream: u64, meta: StreamMeta) -> crate::Result<()> {
        let mut body = Vec::with_capacity(34);
        put_u64(&mut body, meta.m as u64);
        put_opt(&mut body, meta.excl);
        put_opt(&mut body, meta.max_history);
        put_u64(&mut body, meta.epoch);
        // Pin BEFORE logging: `log` may rotate-and-compact right after
        // writing the record, and compaction must already know this
        // segment is needed.
        self.pins.entry(stream).or_insert(self.seg_id);
        self.log(KIND_OPEN, stream, &body)?;
        Ok(())
    }

    /// One append packet.  Must be logged **before** the samples are
    /// applied to the engine, so a crash between log and apply replays
    /// the packet instead of losing it.
    pub fn log_append(&mut self, stream: u64, seq: u64, packet: &[T]) -> crate::Result<()> {
        let mut body = Vec::with_capacity(16 + 8 * packet.len());
        put_u64(&mut body, seq);
        put_u64(&mut body, packet.len() as u64);
        for &x in packet {
            put_u64(&mut body, x.to_f64s().to_bits());
        }
        self.log(KIND_APPEND, stream, &body)?;
        Ok(())
    }

    /// Full engine snapshot; subsumes every earlier record of `stream`
    /// and advances its compaction pin.  `epoch` is the placement epoch
    /// of the stream's current incarnation — carried in every snapshot
    /// so it survives compaction of the `Open` record.
    pub fn log_snapshot(
        &mut self,
        stream: u64,
        epoch: u64,
        next_seq: u64,
        state: &SessionState<T>,
    ) -> crate::Result<()> {
        let mut body = Vec::new();
        put_u64(&mut body, epoch);
        put_u64(&mut body, next_seq);
        let mut enc = Vec::new();
        state.encode(&mut enc);
        put_u64(&mut body, enc.len() as u64);
        body.extend_from_slice(&enc);
        // Pin BEFORE logging (see `log_open`); any rotation triggered by
        // this very record syncs it first (`rotate` -> `sync_data`), so
        // advancing the pin early never trades a durable snapshot for an
        // unsynced one.
        self.pins.insert(stream, self.seg_id);
        self.log(KIND_SNAPSHOT, stream, &body)?;
        Ok(())
    }

    /// The stream was closed; replay will never resurrect it.
    pub fn log_close(&mut self, stream: u64) -> crate::Result<()> {
        self.log(KIND_CLOSE, stream, &[])?;
        self.pins.remove(&stream);
        Ok(())
    }

    /// Log fresh snapshots for every restored stream after a restart,
    /// then [`Self::compact`].  This moves every pin into the current
    /// segment so all pre-restart segments are reclaimed — recovery
    /// leaves the directory holding exactly one snapshot per stream.
    /// Snapshots are written (and synced) before anything is deleted, so
    /// a crash mid-checkpoint only leaves redundant history behind; the
    /// pins seeded by [`Self::resume`] guarantee that even a rotation
    /// fired *between* these snapshots (oversized per-stream states,
    /// tiny `segment_bytes`) cannot reclaim a not-yet-resnapshotted
    /// stream's pre-restart history.
    pub fn checkpoint(&mut self, streams: &[(u64, u64, u64, SessionState<T>)]) -> crate::Result<()> {
        for (id, epoch, next_seq, state) in streams {
            self.log_snapshot(*id, *epoch, *next_seq, state)?;
        }
        self.file.sync_data()?;
        self.compact()
    }

    /// Start a new segment and reclaim everything no pin references.
    pub fn rotate(&mut self) -> crate::Result<()> {
        self.file.sync_data()?;
        self.seg_id += 1;
        self.file = Self::new_segment(&self.dir, self.seg_id, self.max_stream)?;
        self.seg_len = SEG_HEADER_LEN;
        self.compact()
    }

    /// Delete segments older than the minimum pin (all older segments
    /// when no stream pins anything).
    pub fn compact(&mut self) -> crate::Result<()> {
        let keep_from = self.pins.values().copied().min().unwrap_or(self.seg_id);
        let mut removed = false;
        for (id, path) in list_segments(&self.dir)? {
            if id < keep_from && id < self.seg_id {
                fs::remove_file(path)?;
                removed = true;
            }
        }
        if removed {
            fsync_dir(&self.dir)?;
        }
        Ok(())
    }

    /// Make everything written so far durable.
    pub fn sync(&mut self) -> crate::Result<()> {
        self.file.sync_data()?;
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Replay
// ---------------------------------------------------------------------

struct PendingStream<T> {
    meta: Option<StreamMeta>,
    epoch: u64,
    snapshot: Option<(u64, SessionState<T>)>,
    appends: Vec<(u64, Vec<T>)>,
}

/// Read a WAL directory back into per-stream restore instructions.
///
/// Tolerates (by design of pin-based compaction):
/// * records for streams whose `Open` was compacted away — the
///   retained `Snapshot` carries the full configuration;
/// * a torn record at the tail of the newest segment (reported in
///   [`Replay::torn`], truncated by [`WalWriter::resume`]).
///
/// Rejects: bad segment headers, dtype mismatches, CRC/length damage
/// anywhere but the newest tail, LSN gaps or regressions, appends whose
/// sequence numbers don't chain, and `Append`/`Snapshot` records after a
/// stream's `Close`.  An `Open` after a `Close` is legal: it starts a
/// fresh incarnation of the id (a stream migrated away and later back —
/// the Close retired the old incarnation, the Open carries a higher
/// placement epoch).
pub fn replay<T: Real>(dir: &Path) -> crate::Result<Replay<T>> {
    let segs = list_segments(dir)?;
    let mut streams: BTreeMap<u64, PendingStream<T>> = BTreeMap::new();
    let mut closed: Vec<u64> = Vec::new();
    let mut pins: BTreeMap<u64, u64> = BTreeMap::new();
    let mut max_stream = 0u64;
    let mut max_epoch = 0u64;
    let mut next_lsn: Option<u64> = None;
    let mut torn = None;
    let mut records = 0u64;

    for (k, (seg_id, path)) in segs.iter().enumerate() {
        let newest = k + 1 == segs.len();
        let mut buf = Vec::new();
        File::open(path)?.read_to_end(&mut buf)?;
        // Header.
        if buf.len() < SEG_HEADER_LEN as usize {
            anyhow::ensure!(newest, "segment {seg_id} has a truncated header mid-log");
            torn = Some((*seg_id, 0));
            break;
        }
        anyhow::ensure!(&buf[..4] == SEG_MAGIC, "segment {seg_id}: bad magic");
        anyhow::ensure!(buf[4] == SEG_VERSION, "segment {seg_id}: unknown version {}", buf[4]);
        anyhow::ensure!(
            buf[5] as usize == T::BYTES,
            "segment {seg_id}: dtype mismatch (stored {}-byte elements, expected {})",
            buf[5],
            T::BYTES
        );
        max_stream = max_stream.max(u64::from_le_bytes(buf[6..14].try_into().unwrap()));

        let mut at = SEG_HEADER_LEN as usize;
        while at < buf.len() {
            // Frame prefix + CRC; a short or damaged tail in the newest
            // segment is a torn write, anywhere else it is corruption.
            let frame_bad = |why: &str| -> crate::Result<()> {
                anyhow::ensure!(newest, "segment {seg_id} at byte {at}: {why} mid-log");
                Ok(())
            };
            if at + FRAME_PREFIX > buf.len() {
                frame_bad("truncated frame prefix")?;
                torn = Some((*seg_id, at as u64));
                break;
            }
            let len = u32::from_le_bytes(buf[at..at + 4].try_into().unwrap());
            let crc = u32::from_le_bytes(buf[at + 4..at + 8].try_into().unwrap());
            if len > MAX_RECORD {
                frame_bad("implausible record length")?;
                torn = Some((*seg_id, at as u64));
                break;
            }
            let start = at + FRAME_PREFIX;
            let end = start + len as usize;
            if end > buf.len() {
                frame_bad("truncated record")?;
                torn = Some((*seg_id, at as u64));
                break;
            }
            let payload = &buf[start..end];
            if crc32(payload) != crc {
                frame_bad("CRC mismatch")?;
                torn = Some((*seg_id, at as u64));
                break;
            }
            at = end;
            records += 1;

            let mut c = Cur { buf: payload, at: 0 };
            let kind = c.u8()?;
            let lsn = c.u64()?;
            let stream = c.u64()?;
            max_stream = max_stream.max(stream);
            match next_lsn {
                None => next_lsn = Some(lsn + 1),
                Some(expect) => {
                    anyhow::ensure!(
                        lsn == expect,
                        "LSN gap: expected {expect}, found {lsn} in segment {seg_id}"
                    );
                    next_lsn = Some(lsn + 1);
                }
            }
            anyhow::ensure!(
                !closed.contains(&stream) || kind == KIND_CLOSE || kind == KIND_OPEN,
                "record for stream {stream} after its Close (lsn {lsn})"
            );
            match kind {
                KIND_OPEN => {
                    let meta = StreamMeta {
                        m: c.usize()?,
                        excl: c.opt()?,
                        max_history: c.opt()?,
                        epoch: c.u64()?,
                    };
                    c.done()?;
                    anyhow::ensure!(
                        !streams.contains_key(&stream),
                        "duplicate Open for stream {stream} (lsn {lsn})"
                    );
                    // An Open after a Close re-incarnates the id (the
                    // stream migrated back to this shard); the Close
                    // retired the previous incarnation for good.
                    closed.retain(|&s| s != stream);
                    max_epoch = max_epoch.max(meta.epoch);
                    streams.insert(
                        stream,
                        PendingStream {
                            meta: Some(meta),
                            epoch: meta.epoch,
                            snapshot: None,
                            appends: Vec::new(),
                        },
                    );
                    pins.insert(stream, *seg_id);
                }
                KIND_APPEND => {
                    let seq = c.u64()?;
                    let count = c.usize()?;
                    anyhow::ensure!(
                        payload.len().saturating_sub(c.at) >= 8 * count,
                        "append packet truncated (lsn {lsn})"
                    );
                    let mut packet = Vec::with_capacity(count);
                    for _ in 0..count {
                        packet.push(T::of_f64(f64::from_bits(c.u64()?)));
                    }
                    c.done()?;
                    // An append for a stream we know nothing about is a
                    // pre-snapshot orphan left behind by compaction; the
                    // stream's pinned snapshot (later in LSN order)
                    // subsumes it.  Everything else must chain.
                    if let Some(ps) = streams.get_mut(&stream) {
                        // Compaction is segment-granular, so a stream
                        // whose Open is retained has its FULL history
                        // retained: sequence numbers must chain from 0
                        // (or from the latest snapshot's next_seq).
                        let expect = ps
                            .appends
                            .last()
                            .map(|&(s, _)| s + 1)
                            .or(ps.snapshot.as_ref().map(|&(ns, _)| ns))
                            .unwrap_or(0);
                        anyhow::ensure!(
                            seq == expect,
                            "stream {stream}: append seq {seq}, expected {expect} (lsn {lsn})"
                        );
                        ps.appends.push((seq, packet));
                    }
                }
                KIND_SNAPSHOT => {
                    let epoch = c.u64()?;
                    let ns = c.u64()?;
                    let slen = c.usize()?;
                    let state = SessionState::<T>::decode(c.take(slen)?)?;
                    c.done()?;
                    let meta = StreamMeta {
                        m: state.m,
                        excl: Some(state.excl),
                        max_history: state.max_history,
                        epoch,
                    };
                    max_epoch = max_epoch.max(epoch);
                    let ps = streams.entry(stream).or_insert(PendingStream {
                        meta: None,
                        epoch,
                        snapshot: None,
                        appends: Vec::new(),
                    });
                    ps.meta.get_or_insert(meta);
                    ps.epoch = epoch;
                    ps.snapshot = Some((ns, state));
                    ps.appends.clear(); // subsumed
                    pins.insert(stream, *seg_id);
                }
                KIND_CLOSE => {
                    c.done()?;
                    // Orphan closes (stream fully compacted away) are
                    // no-ops; live ones drop the stream.
                    streams.remove(&stream);
                    pins.remove(&stream);
                    if !closed.contains(&stream) {
                        closed.push(stream);
                    }
                }
                k => anyhow::bail!("unknown wal record kind {k} (lsn {lsn})"),
            }
        }
        if torn.is_some() {
            break;
        }
    }

    let next_segment = segs.last().map_or(0, |&(id, _)| id + 1);
    let streams = streams
        .into_iter()
        .map(|(id, ps)| {
            let meta = ps
                .meta
                .ok_or_else(|| anyhow::anyhow!("stream {id} replayed without Open or Snapshot"))?;
            Ok(ReplayedStream {
                id,
                meta,
                epoch: ps.epoch,
                snapshot: ps.snapshot,
                appends: ps.appends,
            })
        })
        .collect::<crate::Result<Vec<_>>>()?;
    Ok(Replay {
        streams,
        closed,
        next_lsn: next_lsn.unwrap_or(0),
        next_segment,
        pins,
        max_epoch,
        max_stream,
        torn,
        records,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mp::stampi::{Stampi, StampiConfig};
    use crate::timeseries::generator::{generate, Pattern};

    fn tempdir(tag: &str) -> PathBuf {
        static N: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
        let k = N.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let dir = std::env::temp_dir().join(format!(
            "natsa-wal-{tag}-{}-{k}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn empty_resume(dir: &Path, opts: WalOptions) -> WalWriter<f64> {
        let rp = replay::<f64>(dir).unwrap();
        WalWriter::resume(dir, opts, &rp).unwrap()
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // IEEE CRC-32 check values.
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn every_record_kind_round_trips_through_replay() {
        let dir = tempdir("kinds");
        let meta = StreamMeta { m: 8, excl: None, max_history: Some(64), epoch: 0 };
        let t = generate::<f64>(Pattern::RandomWalk, 64, 3);
        let mut engine = Stampi::<f64>::new(StampiConfig::new(8)).unwrap();
        for &x in &t {
            engine.append(x);
        }
        {
            let mut w = empty_resume(&dir, WalOptions::default());
            w.log_open(7, meta).unwrap();
            w.log_append(7, 0, &t[..10]).unwrap();
            w.log_append(7, 1, &t[10..20]).unwrap();
            w.log_snapshot(7, 0, 2, &engine.state()).unwrap();
            w.log_append(7, 2, &t[20..30]).unwrap();
            w.log_open(9, StreamMeta { m: 16, excl: Some(3), max_history: None, epoch: 0 }).unwrap();
            w.log_append(9, 0, &t[..5]).unwrap();
            w.log_open(11, meta).unwrap();
            w.log_close(11).unwrap();
            w.sync().unwrap();
        }
        let rp = replay::<f64>(&dir).unwrap();
        assert_eq!(rp.next_lsn, 9);
        assert_eq!(rp.records, 9);
        assert!(rp.torn.is_none());
        assert_eq!(rp.closed, vec![11]);
        assert_eq!(rp.streams.len(), 2);

        let s7 = &rp.streams[0];
        assert_eq!(s7.id, 7);
        assert_eq!(s7.meta, meta);
        let (ns, state) = s7.snapshot.as_ref().unwrap();
        assert_eq!(*ns, 2);
        assert_eq!(*state, engine.state());
        assert_eq!(s7.appends, vec![(2, t[20..30].to_vec())]);
        assert_eq!(s7.next_seq(), 3);

        let s9 = &rp.streams[1];
        assert_eq!(s9.meta.m, 16);
        assert_eq!(s9.meta.excl, Some(3));
        assert!(s9.snapshot.is_none());
        assert_eq!(s9.appends, vec![(0, t[..5].to_vec())]);
        assert_eq!(s9.next_seq(), 1);

        // Live streams pin segment 0 (everything fit in one segment);
        // the closed stream pins nothing; the id high-water sees all.
        assert_eq!(rp.pins, BTreeMap::from([(7, 0), (9, 0)]));
        assert_eq!(rp.max_stream, 11);
    }

    #[test]
    fn f32_packets_round_trip_bit_exactly_and_dtype_is_enforced() {
        let dir = tempdir("dtype");
        let t = generate::<f32>(Pattern::EcgLike, 40, 1);
        {
            let rp = replay::<f32>(&dir).unwrap();
            let mut w = WalWriter::<f32>::resume(&dir, WalOptions::default(), &rp).unwrap();
            w.log_open(1, StreamMeta { m: 4, excl: None, max_history: None, epoch: 0 }).unwrap();
            w.log_append(1, 0, &t).unwrap();
            w.sync().unwrap();
        }
        let rp = replay::<f32>(&dir).unwrap();
        let got = &rp.streams[0].appends[0].1;
        assert_eq!(got.len(), t.len());
        for (a, b) in got.iter().zip(&t) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Same directory read as f64 must refuse.
        let err = replay::<f64>(&dir).unwrap_err().to_string();
        assert!(err.contains("dtype mismatch"), "{err}");
    }

    #[test]
    fn rotation_pins_and_compaction_preserve_replay() {
        let dir = tempdir("rotate");
        let t = generate::<f64>(Pattern::SineWithAnomaly, 400, 5);
        let opts = WalOptions { segment_bytes: 512, ..WalOptions::default() };
        let mut engine = Stampi::<f64>::new(StampiConfig::new(8)).unwrap();
        {
            let mut w = empty_resume(&dir, opts);
            w.log_open(1, StreamMeta { m: 8, excl: None, max_history: None, epoch: 0 }).unwrap();
            let mut seq = 0u64;
            for chunk in t.chunks(16) {
                w.log_append(1, seq, chunk).unwrap();
                seq += 1;
                for &x in chunk {
                    engine.append(x);
                }
                if seq % 5 == 0 {
                    w.log_snapshot(1, 0, seq, &engine.state()).unwrap();
                }
            }
            w.sync().unwrap();
            assert!(w.segment() > 2, "segment_bytes=512 never rotated");
        }
        // Compaction must have deleted early segments...
        let segs = list_segments(&dir).unwrap();
        assert!(segs[0].0 > 0, "no segment was ever reclaimed: {segs:?}");
        // ...while replay still reconstructs the full engine state.
        let rp = replay::<f64>(&dir).unwrap();
        assert!(rp.torn.is_none());
        let s = &rp.streams[0];
        let (_, state) = s.snapshot.as_ref().expect("snapshots were logged");
        let mut rebuilt = Stampi::from_state(state.clone()).unwrap();
        for (_, packet) in &s.appends {
            rebuilt.extend(packet);
        }
        let (want, got) = (engine.profile(), rebuilt.profile());
        assert_eq!(want.p.len(), got.p.len());
        for (a, b) in want.p.iter().zip(got.p.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn torn_tail_is_reported_truncated_and_writable_again() {
        let dir = tempdir("torn");
        {
            let mut w = empty_resume(&dir, WalOptions::default());
            w.log_open(1, StreamMeta { m: 8, excl: None, max_history: None, epoch: 0 }).unwrap();
            w.log_append(1, 0, &[1.0, 2.0, 3.0]).unwrap();
            w.log_append(1, 1, &[4.0, 5.0]).unwrap();
            w.sync().unwrap();
        }
        // Tear the last record: chop 5 bytes off the newest segment.
        let (seg, path) = list_segments(&dir).unwrap().pop().unwrap();
        let len = fs::metadata(&path).unwrap().len();
        OpenOptions::new().write(true).open(&path).unwrap().set_len(len - 5).unwrap();

        let rp = replay::<f64>(&dir).unwrap();
        let (tseg, tat) = rp.torn.expect("torn tail undetected");
        assert_eq!(tseg, seg);
        assert_eq!(rp.streams[0].appends, vec![(0, vec![1.0, 2.0, 3.0])]);
        assert_eq!(rp.next_lsn, 2, "torn record must not consume an LSN");

        // Resume truncates the tear and the log accepts appends again.
        {
            let mut w = WalWriter::<f64>::resume(&dir, WalOptions::default(), &rp).unwrap();
            assert_eq!(fs::metadata(&path).unwrap().len(), tat);
            w.log_append(1, 1, &[6.0]).unwrap();
            w.sync().unwrap();
        }
        let rp2 = replay::<f64>(&dir).unwrap();
        assert!(rp2.torn.is_none());
        assert_eq!(rp2.streams[0].appends, vec![(0, vec![1.0, 2.0, 3.0]), (1, vec![6.0])]);
        assert_eq!(rp2.next_lsn, 3);
    }

    #[test]
    fn corruption_before_the_tail_is_an_error_not_a_truncation() {
        let dir = tempdir("corrupt");
        {
            let mut w = empty_resume(
                &dir,
                WalOptions { segment_bytes: 64, ..WalOptions::default() },
            );
            w.log_open(1, StreamMeta { m: 8, excl: None, max_history: None, epoch: 0 }).unwrap();
            for s in 0..6 {
                w.log_append(1, s, &[s as f64; 8]).unwrap();
            }
            w.sync().unwrap();
        }
        let segs = list_segments(&dir).unwrap();
        assert!(segs.len() > 1, "need multiple segments for this test");
        // Flip a payload byte in the FIRST (non-newest) segment.
        let path = &segs[0].1;
        let mut buf = fs::read(path).unwrap();
        let at = buf.len() - 3;
        buf[at] ^= 0xFF;
        fs::write(path, &buf).unwrap();
        let err = replay::<f64>(&dir).unwrap_err().to_string();
        assert!(err.contains("mid-log"), "{err}");
    }

    #[test]
    fn lsn_gaps_are_rejected() {
        let dir = tempdir("lsn");
        {
            let mut w = empty_resume(&dir, WalOptions::default());
            w.log_open(1, StreamMeta { m: 8, excl: None, max_history: None, epoch: 0 }).unwrap();
            w.log_append(1, 0, &[1.0]).unwrap();
            w.log_append(1, 1, &[2.0]).unwrap();
            w.sync().unwrap();
        }
        // Excise the middle record wholesale (frame stays well-formed,
        // LSN chain does not).
        let (_, path) = list_segments(&dir).unwrap().pop().unwrap();
        let buf = fs::read(&path).unwrap();
        let mut at = SEG_HEADER_LEN as usize;
        let mut bounds = Vec::new();
        while at < buf.len() {
            let len = u32::from_le_bytes(buf[at..at + 4].try_into().unwrap()) as usize;
            bounds.push((at, at + FRAME_PREFIX + len));
            at += FRAME_PREFIX + len;
        }
        let (cut_start, cut_end) = bounds[1];
        let mut cut = buf[..cut_start].to_vec();
        cut.extend_from_slice(&buf[cut_end..]);
        fs::write(&path, &cut).unwrap();
        let err = replay::<f64>(&dir).unwrap_err().to_string();
        assert!(err.contains("LSN gap"), "{err}");
    }

    #[test]
    fn checkpoint_after_restart_reclaims_all_history() {
        let dir = tempdir("checkpoint");
        let t = generate::<f64>(Pattern::RandomWalk, 300, 9);
        let opts = WalOptions { segment_bytes: 400, ..WalOptions::default() };
        {
            let mut w = empty_resume(&dir, opts.clone());
            w.log_open(1, StreamMeta { m: 8, excl: None, max_history: None, epoch: 0 }).unwrap();
            let mut engine = Stampi::<f64>::new(StampiConfig::new(8)).unwrap();
            for (s, chunk) in t.chunks(25).enumerate() {
                w.log_append(1, s as u64, chunk).unwrap();
                for &x in chunk {
                    engine.append(x);
                }
                w.log_snapshot(1, 0, s as u64 + 1, &engine.state()).unwrap();
            }
            w.sync().unwrap();
        }
        // "Restart": replay, rebuild, checkpoint, verify one snapshot
        // left and replay equivalence.
        let rp = replay::<f64>(&dir).unwrap();
        let s = &rp.streams[0];
        let mut rebuilt = Stampi::from_state(s.snapshot.as_ref().unwrap().1.clone()).unwrap();
        for (_, packet) in &s.appends {
            rebuilt.extend(packet);
        }
        let next_seq = s.next_seq();
        let lsn_before = rp.next_lsn;
        let resume_seg = rp.next_segment;
        let mut w = WalWriter::<f64>::resume(&dir, opts, &rp).unwrap();
        w.checkpoint(&[(1, 0, next_seq, rebuilt.state())]).unwrap();
        let segs = list_segments(&dir).unwrap();
        assert!(
            segs.iter().all(|&(id, _)| id >= resume_seg),
            "pre-restart segments survived the checkpoint: {segs:?}"
        );
        let rp2 = replay::<f64>(&dir).unwrap();
        assert_eq!(rp2.next_lsn, lsn_before + 1, "LSNs must keep chaining across restart");
        let s2 = &rp2.streams[0];
        assert!(s2.appends.is_empty());
        assert_eq!(s2.snapshot.as_ref().unwrap().1, rebuilt.state());
        assert_eq!(s2.next_seq(), next_seq);
    }

    /// The REVIEW.md high-severity crash window: `resume` used to start
    /// with an empty pin table, so the first `log_snapshot` of a
    /// stream-at-a-time checkpoint could rotate-and-compact away the
    /// pre-restart segments of every stream not yet re-snapshotted.  A
    /// crash in that window lost their acked data for good.  Pins are
    /// now seeded from the replay, so the mid-checkpoint state stays
    /// fully recoverable.
    #[test]
    fn seeded_pins_keep_mid_checkpoint_rotation_from_losing_streams() {
        let dir = tempdir("seedpins");
        {
            let mut w = empty_resume(&dir, WalOptions::default());
            w.log_open(1, StreamMeta { m: 8, excl: None, max_history: None, epoch: 0 }).unwrap();
            w.log_append(1, 0, &[1.0; 16]).unwrap();
            w.log_open(2, StreamMeta { m: 8, excl: None, max_history: None, epoch: 0 }).unwrap();
            w.log_append(2, 0, &[2.0; 16]).unwrap();
            w.sync().unwrap();
        }
        let rp = replay::<f64>(&dir).unwrap();
        assert_eq!(rp.streams.len(), 2);
        assert_eq!(rp.pins.len(), 2);
        // Restart with segments so small that the very first checkpoint
        // snapshot rotates (and therefore compacts) before stream 2's
        // snapshot exists anywhere.
        let resume_seg = rp.next_segment;
        let opts = WalOptions { segment_bytes: 64, ..WalOptions::default() };
        let mut w = WalWriter::<f64>::resume(&dir, opts, &rp).unwrap();
        let mut e1 = Stampi::<f64>::new(StampiConfig::new(8)).unwrap();
        e1.extend(&[1.0; 16]);
        w.log_snapshot(1, 0, 1, &e1.state()).unwrap();
        assert!(w.segment() > resume_seg, "snapshot was meant to force a rotation");
        // "Crash" here: stream 2 must still replay in full from its
        // pre-restart segments.
        let mid = replay::<f64>(&dir).unwrap();
        assert_eq!(
            mid.streams.iter().map(|s| s.id).collect::<Vec<_>>(),
            vec![1, 2],
            "mid-checkpoint rotation reclaimed a not-yet-snapshotted stream"
        );
        assert_eq!(mid.streams[1].appends, vec![(0, vec![2.0; 16])]);
        // Finishing the checkpoint reclaims the pre-restart history.
        let mut e2 = Stampi::<f64>::new(StampiConfig::new(8)).unwrap();
        e2.extend(&[2.0; 16]);
        w.checkpoint(&[(2, 0, 1, e2.state())]).unwrap();
        let segs = list_segments(&dir).unwrap();
        assert!(
            segs.iter().all(|&(id, _)| id >= resume_seg),
            "checkpoint completion failed to reclaim pre-restart segments: {segs:?}"
        );
        let fin = replay::<f64>(&dir).unwrap();
        assert_eq!(fin.streams.len(), 2);
        assert_eq!(fin.streams[0].snapshot.as_ref().unwrap().1, e1.state());
        assert_eq!(fin.streams[1].snapshot.as_ref().unwrap().1, e2.state());
    }

    /// REVIEW.md: closed stream ids used to be forgotten once their
    /// `Close` records were compacted away, letting a later restart
    /// re-issue them.  Segment headers now carry the id high-water.
    #[test]
    fn closed_ids_survive_compaction_in_segment_headers() {
        let dir = tempdir("highwater");
        let meta = StreamMeta { m: 8, excl: None, max_history: None, epoch: 0 };
        let mut e = Stampi::<f64>::new(StampiConfig::new(8)).unwrap();
        e.extend(&[1.0; 16]);
        {
            let mut w = empty_resume(&dir, WalOptions::default());
            w.log_open(1, meta).unwrap();
            w.log_open(9, meta).unwrap();
            w.log_close(9).unwrap();
            w.log_snapshot(1, 0, 0, &e.state()).unwrap();
            w.sync().unwrap();
        }
        let rp = replay::<f64>(&dir).unwrap();
        assert_eq!(rp.max_stream, 9);
        // The restart checkpoint compacts stream 9's Close away...
        let mut w = WalWriter::<f64>::resume(&dir, WalOptions::default(), &rp).unwrap();
        w.checkpoint(&[(1, 0, 0, e.state())]).unwrap();
        drop(w);
        let rp2 = replay::<f64>(&dir).unwrap();
        assert!(rp2.closed.is_empty(), "Close record was supposed to be compacted");
        // ...but the high-water survives in the new segment's header.
        assert_eq!(rp2.max_stream, 9, "closed id forgotten — ids could be reused");
    }

    #[test]
    fn replay_never_resurrects_a_closed_stream_even_across_checkpoints() {
        let dir = tempdir("closed");
        {
            let mut w = empty_resume(&dir, WalOptions::default());
            w.log_open(1, StreamMeta { m: 8, excl: None, max_history: None, epoch: 0 }).unwrap();
            w.log_append(1, 0, &[1.0, 2.0]).unwrap();
            let mut e = Stampi::<f64>::new(StampiConfig::new(8)).unwrap();
            e.extend(&[1.0, 2.0]);
            w.log_snapshot(1, 0, 1, &e.state()).unwrap();
            w.log_close(1).unwrap();
            w.sync().unwrap();
        }
        let rp = replay::<f64>(&dir).unwrap();
        assert!(rp.streams.is_empty());
        assert_eq!(rp.closed, vec![1]);
        // And a record landing after Close is corruption, not data.
        {
            let mut w = WalWriter::<f64>::resume(&dir, WalOptions::default(), &rp).unwrap();
            w.log_append(1, 1, &[3.0]).unwrap();
            w.sync().unwrap();
        }
        let err = replay::<f64>(&dir).unwrap_err().to_string();
        assert!(err.contains("after its Close"), "{err}");
    }

    /// An `Open` after a `Close` starts a fresh incarnation of the id:
    /// this is the migrate-away-and-back trace (A→B→A leaves A's
    /// directory with Open/…/Close/Open).  The re-opened stream replays
    /// with the new epoch and clean state; `max_epoch` sees every epoch
    /// ever logged, including the retired incarnation's.
    #[test]
    fn open_after_close_reincarnates_the_stream_with_its_new_epoch() {
        let dir = tempdir("reopen");
        let mut e = Stampi::<f64>::new(StampiConfig::new(8)).unwrap();
        e.extend(&[1.0; 12]);
        {
            let mut w = empty_resume(&dir, WalOptions::default());
            w.log_open(5, StreamMeta { m: 8, excl: None, max_history: None, epoch: 3 }).unwrap();
            w.log_append(5, 0, &[1.0, 2.0]).unwrap();
            w.log_close(5).unwrap();
            // Fresh incarnation, back from the peer shard with a
            // snapshot and a higher epoch.
            w.log_open(5, StreamMeta { m: 8, excl: None, max_history: None, epoch: 7 }).unwrap();
            w.log_snapshot(5, 7, 4, &e.state()).unwrap();
            w.log_append(5, 4, &[9.0]).unwrap();
            w.sync().unwrap();
        }
        let rp = replay::<f64>(&dir).unwrap();
        assert!(rp.closed.is_empty(), "re-open must clear the closed marker");
        assert_eq!(rp.streams.len(), 1);
        let s = &rp.streams[0];
        assert_eq!(s.id, 5);
        assert_eq!(s.epoch, 7);
        assert_eq!(s.meta.epoch, 7);
        assert_eq!(s.snapshot.as_ref().unwrap().0, 4);
        assert_eq!(s.appends, vec![(4, vec![9.0])]);
        assert_eq!(rp.max_epoch, 7);

        // Epoch survives compaction of the Open record: a checkpoint
        // rewrites the stream as a lone Snapshot, which carries it.
        let mut w = WalWriter::<f64>::resume(&dir, WalOptions::default(), &rp).unwrap();
        w.checkpoint(&[(5, 7, 5, e.state())]).unwrap();
        drop(w);
        let rp2 = replay::<f64>(&dir).unwrap();
        assert_eq!(rp2.streams[0].epoch, 7);
        assert_eq!(rp2.max_epoch, 7);
    }
}
