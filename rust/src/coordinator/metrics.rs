//! Lightweight run-time metrics for the analysis service.
//!
//! Lock-free counters + a fixed-bucket latency histogram.  No external
//! deps; everything is readable at any time from any thread.
//!
//! The sharded service keeps one `ServiceMetrics` **per shard** plus one
//! **aggregate** instance ticked alongside (both lock-free, so the
//! aggregate view needs no cross-shard reads); the invariant `aggregate
//! counter == Σ shard counters` is pinned by the cross-shard stress test.

use crate::sync::atomic::{AtomicU64, Ordering};

/// Service-level counters (one instance per shard + one aggregate).
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub jobs_rejected: AtomicU64,
    /// Jobs whose execution panicked (a subset of `jobs_failed`): the
    /// worker caught the panic, failed the job, and kept the shard
    /// alive.  Nonzero means an engine bug worth a look, not a dead
    /// shard.
    pub jobs_panicked: AtomicU64,
    /// WAL write failures.  The first failure on a shard disables its
    /// WAL for the rest of the run (availability over durability), so
    /// nonzero here means restart-recovery is stale until the next
    /// restart.
    pub wal_errors: AtomicU64,
    /// Sum of queue-wait nanoseconds over every *finished* job — failed
    /// ones included (divide by [`Self::finished`] for the mean).
    pub queue_wait_ns: AtomicU64,
    /// Sum of execution nanoseconds over every finished job, failed
    /// included.
    pub exec_ns: AtomicU64,
    /// End-to-end latency of every finished job, failed included: error
    /// load must show up in p50/p99, not hide behind `jobs_failed`
    /// (failed jobs used to skip the histogram entirely, skewing tail
    /// latency optimistic exactly when the service was unhealthy).
    pub latency: LatencyHistogram,
    /// Single-sample appends that rode a **shared** cross-stream row
    /// tile (lane width ≥ 2) instead of a width-1 tile of their own —
    /// the worker drain-and-coalesce fast path.  A subset of the
    /// appends counted in [`Self::coalesce_width`].
    pub appends_coalesced: AtomicU64,
    /// Lane-width distribution of executed appends: every coalescible
    /// append records the width of the tile it rode (serial appends —
    /// multi-sample packets, lone jobs, not-ready group members — record
    /// width 1), so `coalesce_width.count()` is the total append count
    /// and the histogram shape answers "is the steady state riding wide
    /// tiles?" directly.
    pub coalesce_width: WidthHistogram,
    /// Subscriber snapshot deliveries performed by fanout appends (one
    /// append computed once, delivered N times — this counts the N's).
    pub fanout_delivered: AtomicU64,
    /// Streams migrated **off** this shard by the elastic controller or
    /// `migrate_stream` (ticked on the source shard + the aggregate).
    pub streams_migrated: AtomicU64,
    /// Migrations that resolved a source but did not commit (stream
    /// closed mid-quiesce, placement raced, restore error).
    pub migration_failed: AtomicU64,
    /// Submissions refused by the AIMD admission window (a subset of
    /// the `Backpressure` errors callers observe; `jobs_rejected` also
    /// counts queue-full refusals).
    pub admission_rejected: AtomicU64,
    /// **Gauge** (not a counter): current AIMD congestion window in
    /// milli-jobs.  Published with [`Self::publish_gauge`] so the
    /// aggregate tracks Σ shard windows.
    pub cwnd_milli: AtomicU64,
    /// **Gauge**: current worker-pool size.  Published with
    /// [`Self::publish_gauge`]; the aggregate is the fleet-wide total.
    pub pool_workers: AtomicU64,
}

impl ServiceMetrics {
    pub fn in_flight(&self) -> u64 {
        self.jobs_submitted
            .load(Ordering::Relaxed)
            .saturating_sub(self.finished())
    }

    /// Jobs that ran to an outcome: completed + failed.
    pub fn finished(&self) -> u64 {
        self.jobs_completed.load(Ordering::Relaxed) + self.jobs_failed.load(Ordering::Relaxed)
    }

    /// Record one finished job (worker-side hook; ticks outcome counter,
    /// wait/exec sums and the latency histogram consistently).
    pub fn record_outcome(&self, failed: bool, queue_wait_s: f64, exec_s: f64) {
        if failed {
            self.jobs_failed.fetch_add(1, Ordering::Relaxed);
        } else {
            self.jobs_completed.fetch_add(1, Ordering::Relaxed);
        }
        self.exec_ns
            .fetch_add((exec_s * 1e9) as u64, Ordering::Relaxed);
        self.queue_wait_ns
            .fetch_add((queue_wait_s * 1e9) as u64, Ordering::Relaxed);
        self.latency.record(queue_wait_s + exec_s);
    }

    pub fn mean_exec_seconds(&self) -> f64 {
        let done = self.finished();
        if done == 0 {
            0.0
        } else {
            self.exec_ns.load(Ordering::Relaxed) as f64 / done as f64 * 1e-9
        }
    }

    /// One-line human summary.  Panic and WAL trouble only show up when
    /// present — a healthy service keeps the line short.
    pub fn summary(&self) -> String {
        let mut line = format!(
            "jobs: {} submitted, {} done, {} failed, {} rejected | in-flight {} | mean exec {:.3}s | p50 {:.3}s p99 {:.3}s",
            self.jobs_submitted.load(Ordering::Relaxed),
            self.jobs_completed.load(Ordering::Relaxed),
            self.jobs_failed.load(Ordering::Relaxed),
            self.jobs_rejected.load(Ordering::Relaxed),
            self.in_flight(),
            self.mean_exec_seconds(),
            self.latency.quantile(0.50),
            self.latency.quantile(0.99),
        );
        let panicked = self.jobs_panicked.load(Ordering::Relaxed);
        if panicked > 0 {
            line.push_str(&format!(" | {panicked} PANICKED"));
        }
        let wal = self.wal_errors.load(Ordering::Relaxed);
        if wal > 0 {
            line.push_str(&format!(" | {wal} WAL ERRORS (durability degraded)"));
        }
        let coalesced = self.appends_coalesced.load(Ordering::Relaxed);
        if coalesced > 0 {
            line.push_str(&format!(
                " | {coalesced} coalesced (mean width {:.1})",
                self.coalesce_width.mean()
            ));
        }
        let fanned = self.fanout_delivered.load(Ordering::Relaxed);
        if fanned > 0 {
            line.push_str(&format!(" | {fanned} fanout deliveries"));
        }
        let migrated = self.streams_migrated.load(Ordering::Relaxed);
        let mig_failed = self.migration_failed.load(Ordering::Relaxed);
        if migrated > 0 || mig_failed > 0 {
            line.push_str(&format!(" | {migrated} migrated ({mig_failed} failed)"));
        }
        let throttled = self.admission_rejected.load(Ordering::Relaxed);
        if throttled > 0 {
            line.push_str(&format!(
                " | {throttled} admission-rejected (cwnd {:.1})",
                self.cwnd_milli.load(Ordering::Relaxed) as f64 / 1000.0
            ));
        }
        line
    }

    /// Record one executed append's tile lane width (1 = serial path).
    /// Ticks [`Self::coalesce_width`], and [`Self::appends_coalesced`]
    /// when the append actually shared its tile.
    pub fn record_append_width(&self, width: usize) {
        self.coalesce_width.record(width);
        if width >= 2 {
            self.appends_coalesced.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Publish a **gauge** to a shard cell and its aggregate in one
    /// step: swap the shard's old value out and apply the wrapping
    /// delta to the aggregate.  The swap serializes concurrent
    /// publishers on the shard cell, so the deltas telescope exactly —
    /// under ANY interleaving (across shards *and* across writers to
    /// the same shard) the invariant `aggregate == Σ shard gauges`
    /// holds once every in-flight publish has landed: the same
    /// Σ-reconciliation contract the counters have.
    pub fn publish_gauge(shard: &AtomicU64, aggregate: &AtomicU64, value: u64) {
        let old = shard.swap(value, Ordering::Relaxed);
        aggregate.fetch_add(value.wrapping_sub(old), Ordering::Relaxed);
    }
}

/// Tile lane-width histogram: one bucket per possible width `1 ..=
/// BAND` (the kernel never runs wider sub-tiles; see
/// [`crate::mp::kernel::BAND`]).  Lock-free like [`LatencyHistogram`],
/// and exact — per-bucket counts are exposed so the aggregate == Σ
/// shards invariant can be reconciled bucket by bucket.
#[derive(Debug)]
pub struct WidthHistogram {
    buckets: [AtomicU64; crate::mp::kernel::BAND],
}

impl Default for WidthHistogram {
    fn default() -> Self {
        WidthHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

impl WidthHistogram {
    /// Record one append executed on a `width`-lane tile (clamped to
    /// the top bucket; width 0 is a caller bug, counted as 1).
    pub fn record(&self, width: usize) {
        let i = width.clamp(1, self.buckets.len()) - 1;
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
    }

    /// Appends recorded at exactly `width` lanes (0 when out of range).
    pub fn at(&self, width: usize) -> u64 {
        if width == 0 || width > self.buckets.len() {
            return 0;
        }
        self.buckets[width - 1].load(Ordering::Relaxed)
    }

    /// Total appends recorded.
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Appends that rode a shared tile (width ≥ 2).
    pub fn coalesced(&self) -> u64 {
        self.buckets[1..]
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .sum()
    }

    /// Mean lane width over recorded appends (0 when empty).
    pub fn mean(&self) -> f64 {
        let mut n = 0u64;
        let mut sum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            n += c;
            sum += c * (i as u64 + 1);
        }
        if n == 0 {
            0.0
        } else {
            sum as f64 / n as f64
        }
    }
}

/// Log-spaced latency histogram: 1 µs .. ~1000 s in 64 buckets.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; 64],
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

impl LatencyHistogram {
    fn bucket_of(seconds: f64) -> usize {
        // bucket = log2(us), clamped
        let us = (seconds * 1e6).max(1.0);
        (us.log2() as usize).min(63)
    }

    /// Upper edge (seconds) of bucket `i`.
    fn edge(i: usize) -> f64 {
        (1u64 << (i as u32 + 1).min(63)) as f64 * 1e-6
    }

    pub fn record(&self, seconds: f64) {
        self.buckets[Self::bucket_of(seconds)].fetch_add(1, Ordering::Relaxed);
    }

    /// Approximate quantile (upper bucket edge), 0 if empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let counts: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, c) in counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::edge(i);
            }
        }
        Self::edge(63)
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let h = LatencyHistogram::default();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3); // 1..100 ms
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!(p50 > 1e-3 && p50 < 0.2, "{p50}");
        assert!(p99 > 0.05 && p99 < 0.5, "{p99}");
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn metrics_in_flight_accounting() {
        let m = ServiceMetrics::default();
        m.jobs_submitted.store(5, Ordering::Relaxed);
        m.jobs_completed.store(2, Ordering::Relaxed);
        m.jobs_failed.store(1, Ordering::Relaxed);
        assert_eq!(m.in_flight(), 2);
        assert!(m.summary().contains("5 submitted"));
    }

    #[test]
    fn failed_jobs_are_visible_in_latency_and_exec() {
        // regression: failed jobs used to tick only jobs_failed, leaving
        // p50/p99 and the wait/exec sums blind to error load
        let m = ServiceMetrics::default();
        m.jobs_submitted.store(2, Ordering::Relaxed);
        m.record_outcome(false, 0.001, 0.002);
        m.record_outcome(true, 0.5, 0.25);
        assert_eq!(m.jobs_completed.load(Ordering::Relaxed), 1);
        assert_eq!(m.jobs_failed.load(Ordering::Relaxed), 1);
        assert_eq!(m.finished(), 2);
        assert_eq!(m.in_flight(), 0);
        assert_eq!(m.latency.count(), 2, "failed job missing from histogram");
        // the slow failure dominates the tail
        assert!(m.latency.quantile(0.99) > 0.5, "{}", m.latency.quantile(0.99));
        // mean exec averages over completed AND failed
        let want = (0.002 + 0.25) / 2.0;
        assert!((m.mean_exec_seconds() - want).abs() < 1e-4, "{}", m.mean_exec_seconds());
    }

    #[test]
    fn extreme_latencies_clamped() {
        let h = LatencyHistogram::default();
        h.record(0.0);
        h.record(1e9);
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn width_histogram_is_exact_and_clamped() {
        let h = WidthHistogram::default();
        let band = crate::mp::kernel::BAND;
        h.record(1);
        h.record(1);
        h.record(3);
        h.record(band);
        h.record(band + 5); // clamped into the top bucket
        h.record(0); // caller bug, counted as width 1
        assert_eq!(h.at(1), 3);
        assert_eq!(h.at(3), 1);
        assert_eq!(h.at(band), 2);
        assert_eq!(h.at(0), 0);
        assert_eq!(h.at(band + 1), 0);
        assert_eq!(h.count(), 6);
        assert_eq!(h.coalesced(), 3);
        let want = (3 + 3 + 2 * band) as f64 / 6.0;
        assert!((h.mean() - want).abs() < 1e-12, "{}", h.mean());
    }

    #[test]
    fn width_histogram_band_edge_buckets_stay_distinct() {
        // The top bucket is exactly BAND (the full-width tile): it must
        // not swallow the band-1 near-miss next to it, or the coalescing
        // acceptance bar ("majority of appends ride full tiles") would
        // pass on tiles that never actually filled.
        let h = WidthHistogram::default();
        let band = crate::mp::kernel::BAND;
        h.record(band - 1);
        h.record(band);
        assert_eq!(h.at(band - 1), 1);
        assert_eq!(h.at(band), 1);
        assert_eq!(h.coalesced(), 2);
        let want = (2 * band - 1) as f64 / 2.0;
        assert!((h.mean() - want).abs() < 1e-12, "{}", h.mean());
    }

    #[test]
    fn append_width_hook_ticks_coalesced_only_when_shared() {
        let m = ServiceMetrics::default();
        m.record_append_width(1);
        m.record_append_width(1);
        m.record_append_width(4);
        m.record_append_width(4);
        m.record_append_width(4);
        assert_eq!(m.coalesce_width.count(), 5);
        assert_eq!(m.appends_coalesced.load(Ordering::Relaxed), 3);
        assert!(m.summary().contains("3 coalesced"));
        m.fanout_delivered.fetch_add(7, Ordering::Relaxed);
        assert!(m.summary().contains("7 fanout deliveries"));
    }

    #[test]
    fn publish_gauge_tracks_latest_value_and_aggregate_delta() {
        let shard = AtomicU64::new(0);
        let agg = AtomicU64::new(0);
        ServiceMetrics::publish_gauge(&shard, &agg, 5);
        assert_eq!(shard.load(Ordering::Relaxed), 5);
        assert_eq!(agg.load(Ordering::Relaxed), 5);
        // A gauge goes DOWN: the aggregate must follow (wrapping delta).
        ServiceMetrics::publish_gauge(&shard, &agg, 2);
        assert_eq!(shard.load(Ordering::Relaxed), 2);
        assert_eq!(agg.load(Ordering::Relaxed), 2);
        ServiceMetrics::publish_gauge(&shard, &agg, 2);
        assert_eq!(agg.load(Ordering::Relaxed), 2, "idempotent republish");
    }

    #[test]
    fn publish_gauge_deltas_telescope_across_shards() {
        // Two shards publishing independently into one aggregate: after
        // any sequence, aggregate == Σ latest shard values.
        let (a, b) = (AtomicU64::new(0), AtomicU64::new(0));
        let agg = AtomicU64::new(0);
        let seq_a = [3u64, 7, 1, 1, 9];
        let seq_b = [10u64, 2, 2, 8, 4];
        for i in 0..seq_a.len() {
            ServiceMetrics::publish_gauge(&a, &agg, seq_a[i]);
            ServiceMetrics::publish_gauge(&b, &agg, seq_b[i]);
            assert_eq!(
                agg.load(Ordering::Relaxed),
                a.load(Ordering::Relaxed) + b.load(Ordering::Relaxed),
                "aggregate gauge must reconcile at step {i}"
            );
        }
    }

    #[test]
    fn publish_gauge_is_multi_writer_safe() {
        // cwnd gauges are published from submitters AND workers: after
        // all concurrent publishes land, aggregate == shard's final
        // value (deltas telescope through the serializing swap).
        let shard = std::sync::Arc::new(AtomicU64::new(0));
        let agg = std::sync::Arc::new(AtomicU64::new(0));
        let threads: Vec<_> = (0..4u64)
            .map(|t| {
                let (shard, agg) = (shard.clone(), agg.clone());
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        ServiceMetrics::publish_gauge(&shard, &agg, t * 1000 + i);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(
            agg.load(Ordering::Relaxed),
            shard.load(Ordering::Relaxed),
            "aggregate desynced from the one shard gauge"
        );
    }

    /// Test-only twin of [`ServiceMetrics`] carrying one deliberately
    /// unreconciled field.  It exists for `tools/lint`'s
    /// metrics-coverage pass (NL008): the analyzer skips `#[cfg(test)]`
    /// regions, so this struct is exempt as written — but the analyzer's
    /// own self-test splices the scratch field's line into the live
    /// struct and asserts the pass flags it.  Proof that the pass fails
    /// closed on the ship-an-unreconciled-counter mistake, kept here so
    /// the planted field can never drift from real field syntax.
    #[allow(dead_code)]
    #[derive(Debug, Default)]
    pub struct ServiceMetricsTwin {
        pub jobs_submitted: AtomicU64,
        pub jobs_completed: AtomicU64,
        pub scratch_unreconciled: AtomicU64,
    }

    #[test]
    fn twin_struct_scratch_field_stays_unwired() {
        // The twin's scratch counter is recorded nowhere and summed
        // nowhere — exactly the mistake NL008 exists to catch.  Pin
        // that it really is dead weight (ticking it changes nothing
        // observable), so the planted violation stays a violation.
        let t = ServiceMetricsTwin::default();
        t.scratch_unreconciled.fetch_add(42, Ordering::Relaxed);
        let m = ServiceMetrics::default();
        assert_eq!(m.in_flight(), 0);
        assert!(!m.summary().contains("42"));
    }

    #[test]
    fn width_histogram_reconciles_across_instances() {
        // The Σ-reconciliation contract, at histogram granularity: two
        // per-shard width histograms ticked independently must sum
        // bucket-by-bucket to the aggregate instance ticked alongside.
        let band = crate::mp::kernel::BAND;
        let (a, b) = (WidthHistogram::default(), WidthHistogram::default());
        let agg = WidthHistogram::default();
        for w in [1usize, 1, 2, band, band + 3] {
            a.record(w);
            agg.record(w);
        }
        for w in [1usize, 3, band - 1, band] {
            b.record(w);
            agg.record(w);
        }
        for w in 1..=band {
            assert_eq!(agg.at(w), a.at(w) + b.at(w), "bucket {w} skewed");
        }
        assert_eq!(agg.count(), a.count() + b.count());
        assert_eq!(agg.coalesced(), a.coalesced() + b.coalesced());
    }

    #[test]
    fn elastic_counters_surface_in_the_summary() {
        let m = ServiceMetrics::default();
        assert!(!m.summary().contains("migrated"), "healthy line stays short");
        assert!(!m.summary().contains("admission"));
        m.streams_migrated.fetch_add(2, Ordering::Relaxed);
        m.migration_failed.fetch_add(1, Ordering::Relaxed);
        assert!(m.summary().contains("2 migrated (1 failed)"));
        m.admission_rejected.fetch_add(4, Ordering::Relaxed);
        m.cwnd_milli.store(1500, Ordering::Relaxed);
        assert!(m.summary().contains("4 admission-rejected (cwnd 1.5)"));
    }
}
