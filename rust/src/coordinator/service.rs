//! The analysis service: a multi-client job queue over the NATSA engine.
//!
//! The accelerator itself computes one profile at a time per PU fleet;
//! a deployment wraps it in a service that accepts jobs from many clients,
//! applies backpressure when the queue is full, and reports metrics —
//! the same role the vLLM router plays for model replicas.  Workers run
//! the *native* functional engine by default (fast path); the PJRT engine
//! is exercised by the end-to-end example and integration tests.
//!
//! Two job kinds share the queue:
//!
//! * **batch** — [`AnalysisService::submit`]: one series, one profile.
//! * **stream** — [`AnalysisService::submit_stream`] opens a long-lived
//!   [`StreamSession`]; [`AnalysisService::append_stream`] enqueues sample
//!   batches against it (same bounded queue, same backpressure) and each
//!   append's [`JobResult`] carries the post-append profile snapshot;
//!   [`AnalysisService::snapshot_stream`] reads the live profile without
//!   queueing.  Appends to one stream are applied in submission order
//!   even across workers (per-stream sequence numbers), so a stream's
//!   profile is always that of its samples in arrival order.
//!
//! Design notes:
//! * `std::sync::mpsc` + worker threads (tokio is not in the offline
//!   vendor set; the queue semantics are identical for this shape),
//! * bounded queue => `submit` fails fast with [`SubmitError::Backpressure`]
//!   instead of buffering unboundedly,
//! * each job may carry its own window length and precision is fixed by
//!   the service's type parameter.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex};

use crate::coordinator::metrics::ServiceMetrics;
use crate::mp::MatrixProfile;
use crate::natsa::{NatsaConfig, NatsaEngine, StreamSession};
use crate::Real;

/// A submitted analysis job.
struct Job<T> {
    id: u64,
    payload: JobPayload<T>,
    submitted: std::time::Instant,
}

/// What a job asks for.
enum JobPayload<T> {
    /// One-shot batch profile.
    Batch { series: Arc<Vec<T>>, m: usize },
    /// Append samples to an open stream (applied in `seq` order).
    StreamAppend { stream: u64, samples: Vec<T>, seq: u64 },
}

/// Completed job result.  For stream appends, `profile` is the snapshot
/// right after the batch was applied (positions relative to the stream's
/// oldest retained window — see [`crate::mp::stampi::Stampi::profile`]).
#[derive(Clone, Debug)]
pub struct JobResult<T> {
    pub id: u64,
    pub profile: Result<MatrixProfile<T>, String>,
    pub queue_wait_s: f64,
    pub exec_s: f64,
}

/// Why a submission was rejected.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue full — caller should retry later (backpressure).
    Backpressure,
    /// Service is shutting down.
    Closed,
    /// The stream id is unknown or was closed.
    UnknownStream,
    /// The stream configuration was rejected (window/history bounds).
    Invalid(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Backpressure => write!(f, "queue full (backpressure)"),
            SubmitError::Closed => write!(f, "service closed"),
            SubmitError::UnknownStream => write!(f, "unknown or closed stream"),
            SubmitError::Invalid(why) => write!(f, "invalid stream config: {why}"),
        }
    }
}

/// One open stream: the session plus the apply-order bookkeeping.
struct StreamState<T> {
    session: StreamSession<T>,
    /// Next sequence number to apply (appends wait their turn on `cv`).
    next_seq: u64,
    /// Set by `close_stream`: wakes and fails any waiting appends.
    closed: bool,
}

struct StreamEntry<T> {
    state: Mutex<StreamState<T>>,
    cv: Condvar,
    /// Next sequence number to hand out.  Held across the (assign seq,
    /// enqueue) pair so queue order == seq order — the structural
    /// invariant the workers' turn-waiting relies on.
    submit_seq: Mutex<u64>,
}

struct Shared<T> {
    results: Mutex<HashMap<u64, JobResult<T>>>,
    cv: Condvar,
    metrics: ServiceMetrics,
    streams: Mutex<HashMap<u64, Arc<StreamEntry<T>>>>,
}

/// Multi-worker analysis service over the functional NATSA engine.
pub struct AnalysisService<T: Real> {
    tx: Option<SyncSender<Job<T>>>,
    shared: Arc<Shared<T>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
    next_stream_id: AtomicU64,
    config: NatsaConfig,
}

impl<T: Real> AnalysisService<T> {
    /// Start `workers` worker threads with a bounded queue of `depth`.
    pub fn start(config: NatsaConfig, workers: usize, depth: usize) -> Self {
        let (tx, rx) = sync_channel::<Job<T>>(depth);
        let rx = Arc::new(Mutex::new(rx));
        let shared = Arc::new(Shared {
            results: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
            metrics: ServiceMetrics::default(),
            streams: Mutex::new(HashMap::new()),
        });
        let mut handles = Vec::new();
        for _ in 0..workers.max(1) {
            let rx = rx.clone();
            let shared = shared.clone();
            handles.push(std::thread::spawn(move || {
                worker_loop(rx, shared, config);
            }));
        }
        AnalysisService {
            tx: Some(tx),
            shared,
            workers: handles,
            next_id: AtomicU64::new(1),
            next_stream_id: AtomicU64::new(1),
            config,
        }
    }

    /// Submit a batch job; fails fast under backpressure.
    pub fn submit(&self, series: Arc<Vec<T>>, m: usize) -> Result<u64, SubmitError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.enqueue(Job {
            id,
            payload: JobPayload::Batch { series, m },
            submitted: std::time::Instant::now(),
        })
    }

    /// Open a streaming session with window `m` (and an optional retained
    /// history bound in samples).  Returns the stream id to append to.
    pub fn submit_stream(&self, m: usize, max_history: Option<usize>) -> Result<u64, SubmitError> {
        let session = NatsaEngine::<T>::new(self.config)
            .open_stream_bounded(m, max_history)
            .map_err(|e| SubmitError::Invalid(e.to_string()))?;
        let id = self.next_stream_id.fetch_add(1, Ordering::Relaxed);
        let entry = Arc::new(StreamEntry {
            state: Mutex::new(StreamState { session, next_seq: 0, closed: false }),
            cv: Condvar::new(),
            submit_seq: Mutex::new(0),
        });
        self.shared.streams.lock().unwrap().insert(id, entry);
        Ok(id)
    }

    /// Enqueue a batch of samples against stream `stream`.  Returns a job
    /// id to [`Self::wait`] on; its result's profile is the post-append
    /// snapshot.  Appends from one client that are submitted in order are
    /// applied in order (per-stream sequencing).
    ///
    /// Two usage caveats, both consequences of appends being inherently
    /// sequential per stream while sharing the worker pool:
    /// * a client that *pipelines* many appends to one stream can park
    ///   several workers in turn-waiting (head-of-line blocking for
    ///   unrelated jobs) — await each append, or size `workers` for the
    ///   number of concurrently active streams (the planned sharded
    ///   multi-series service lifts this properly);
    /// * like batch jobs, every append's [`JobResult`] (which clones the
    ///   profile snapshot) is retained until [`Self::wait`]/[`Self::poll`]
    ///   consumes it — fire-and-forget callers should poll each id and
    ///   read state via [`Self::snapshot_stream`] instead.
    pub fn append_stream(&self, stream: u64, samples: &[T]) -> Result<u64, SubmitError> {
        let entry = self
            .shared
            .streams
            .lock()
            .unwrap()
            .get(&stream)
            .cloned()
            .ok_or(SubmitError::UnknownStream)?;
        // Hold the stream's seq lock across (assign seq, enqueue) so
        // queue order equals sequence order — the workers rely on it.
        let mut seq_guard = entry.submit_seq.lock().unwrap();
        let seq = *seq_guard;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let result = self.enqueue(Job {
            id,
            payload: JobPayload::StreamAppend { stream, samples: samples.to_vec(), seq },
            submitted: std::time::Instant::now(),
        });
        if result.is_ok() {
            *seq_guard += 1;
        }
        result
    }

    /// Read a stream's live profile without going through the queue.
    /// `None` if the stream is unknown or closed.
    pub fn snapshot_stream(&self, stream: u64) -> Option<MatrixProfile<T>> {
        let entry = self.shared.streams.lock().unwrap().get(&stream).cloned()?;
        let state = entry.state.lock().unwrap();
        Some(state.session.profile())
    }

    /// Close a stream: frees its state; queued/future appends against it
    /// fail with an error result.  Returns whether the id was open.
    pub fn close_stream(&self, stream: u64) -> bool {
        let entry = self.shared.streams.lock().unwrap().remove(&stream);
        match entry {
            Some(e) => {
                e.state.lock().unwrap().closed = true;
                e.cv.notify_all();
                true
            }
            None => false,
        }
    }

    fn enqueue(&self, job: Job<T>) -> Result<u64, SubmitError> {
        let id = job.id;
        match self.tx.as_ref().ok_or(SubmitError::Closed)?.try_send(job) {
            Ok(()) => {
                self.shared
                    .metrics
                    .jobs_submitted
                    .fetch_add(1, Ordering::Relaxed);
                Ok(id)
            }
            Err(TrySendError::Full(_)) => {
                self.shared
                    .metrics
                    .jobs_rejected
                    .fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Backpressure)
            }
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    /// Block until job `id` completes.
    pub fn wait(&self, id: u64) -> JobResult<T> {
        let mut results = self.shared.results.lock().unwrap();
        loop {
            if let Some(r) = results.remove(&id) {
                return r;
            }
            results = self.shared.cv.wait(results).unwrap();
        }
    }

    /// Non-blocking poll.
    pub fn poll(&self, id: u64) -> Option<JobResult<T>> {
        self.shared.results.lock().unwrap().remove(&id)
    }

    pub fn metrics(&self) -> &ServiceMetrics {
        &self.shared.metrics
    }

    /// Stop accepting jobs, drain the queue, join workers.
    pub fn shutdown(mut self) {
        self.tx.take(); // close channel
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop<T: Real>(
    rx: Arc<Mutex<Receiver<Job<T>>>>,
    shared: Arc<Shared<T>>,
    config: NatsaConfig,
) {
    let engine = NatsaEngine::<T>::new(config);
    loop {
        let job = match rx.lock().unwrap().recv() {
            Ok(j) => j,
            Err(_) => return, // channel closed
        };
        let mut queue_wait = job.submitted.elapsed().as_secs_f64();
        let start = std::time::Instant::now();
        let mut turn_wait = 0.0f64;
        let profile: Result<MatrixProfile<T>, String> = match job.payload {
            JobPayload::Batch { series, m } => engine
                .compute(&series, m)
                .map(|o| o.profile)
                .map_err(|e| e.to_string()),
            JobPayload::StreamAppend { stream, samples, seq } => {
                let (result, waited) = run_stream_append(&shared, stream, &samples, seq);
                // time parked waiting for this append's turn is queueing,
                // not execution — keep the metrics split honest
                turn_wait = waited;
                result
            }
        };
        queue_wait += turn_wait;
        let exec = (start.elapsed().as_secs_f64() - turn_wait).max(0.0);

        let failed = profile.is_err();
        let m = &shared.metrics;
        if failed {
            m.jobs_failed.fetch_add(1, Ordering::Relaxed);
        } else {
            m.jobs_completed.fetch_add(1, Ordering::Relaxed);
            m.exec_ns
                .fetch_add((exec * 1e9) as u64, Ordering::Relaxed);
            m.queue_wait_ns
                .fetch_add((queue_wait * 1e9) as u64, Ordering::Relaxed);
            m.latency.record(queue_wait + exec);
        }
        shared.results.lock().unwrap().insert(
            job.id,
            JobResult {
                id: job.id,
                profile,
                queue_wait_s: queue_wait,
                exec_s: exec,
            },
        );
        shared.cv.notify_all();
    }
}

/// Apply one append batch in sequence order and snapshot the profile.
/// Returns the result plus the seconds spent waiting for this append's
/// turn (reported as queueing, not execution).
fn run_stream_append<T: Real>(
    shared: &Shared<T>,
    stream: u64,
    samples: &[T],
    seq: u64,
) -> (Result<MatrixProfile<T>, String>, f64) {
    let entry = match shared.streams.lock().unwrap().get(&stream).cloned() {
        Some(e) => e,
        None => return (Err(format!("unknown or closed stream {stream}")), 0.0),
    };
    let wait_start = std::time::Instant::now();
    let mut state = entry.state.lock().unwrap();
    // Appends dequeued out of order (multiple workers) wait their turn;
    // `closed` breaks the wait so close_stream never strands a worker.
    while !state.closed && state.next_seq != seq {
        state = entry.cv.wait(state).unwrap();
    }
    let turn_wait = wait_start.elapsed().as_secs_f64();
    if state.closed {
        return (Err(format!("stream {stream} closed")), turn_wait);
    }
    state.session.extend(samples);
    let snapshot = state.session.profile();
    state.next_seq += 1;
    entry.cv.notify_all();
    (Ok(snapshot), turn_wait)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mp::{stomp, MpConfig};
    use crate::prop::Rng;
    use crate::timeseries::generator::{generate, Pattern};

    fn svc() -> AnalysisService<f64> {
        AnalysisService::start(NatsaConfig::default().with_threads(2), 2, 4)
    }

    #[test]
    fn submit_and_wait_roundtrip() {
        let s = svc();
        let series = Arc::new(generate::<f64>(Pattern::PlantedMotif, 1024, 3));
        let id = s.submit(series, 32).unwrap();
        let r = s.wait(id);
        let profile = r.profile.unwrap();
        assert_eq!(profile.len(), 1024 - 32 + 1);
        assert_eq!(s.metrics().jobs_completed.load(Ordering::Relaxed), 1);
        s.shutdown();
    }

    #[test]
    fn many_jobs_from_many_clients() {
        let s = Arc::new(AnalysisService::<f64>::start(
            NatsaConfig::default().with_threads(1),
            3,
            64,
        ));
        let mut ids = Vec::new();
        for k in 0..12 {
            let series = Arc::new(generate::<f64>(Pattern::RandomWalk, 512, k));
            ids.push(s.submit(series, 16).unwrap());
        }
        for id in ids {
            let r = s.wait(id);
            assert!(r.profile.is_ok());
        }
        assert_eq!(s.metrics().jobs_completed.load(Ordering::Relaxed), 12);
        assert_eq!(s.metrics().in_flight(), 0);
    }

    #[test]
    fn bad_job_reports_error_not_panic() {
        let s = svc();
        let id = s.submit(Arc::new(vec![1.0f64; 9]), 8).unwrap(); // nw(2) <= excl(2)
        let r = s.wait(id);
        assert!(r.profile.is_err());
        assert_eq!(s.metrics().jobs_failed.load(Ordering::Relaxed), 1);
        s.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // 1 worker, queue depth 1, slow-ish jobs: the 3rd+ submit in a
        // tight loop must eventually see Backpressure.
        let s = AnalysisService::<f64>::start(NatsaConfig::default().with_threads(1), 1, 1);
        let mut rng = Rng::new(9);
        let series = Arc::new(rng.gauss_vec(6000));
        let mut saw_backpressure = false;
        let mut accepted = Vec::new();
        for _ in 0..32 {
            match s.submit(series.clone(), 16) {
                Ok(id) => accepted.push(id),
                Err(SubmitError::Backpressure) => {
                    saw_backpressure = true;
                    break;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(saw_backpressure, "queue never filled");
        for id in accepted {
            let _ = s.wait(id);
        }
        assert!(s.metrics().jobs_rejected.load(Ordering::Relaxed) >= 1);
        s.shutdown();
    }

    #[test]
    fn shutdown_closes_submission() {
        let s = svc();
        let shared = s.shared.clone();
        s.shutdown();
        // after shutdown the channel is gone; metrics survive
        assert_eq!(shared.metrics.in_flight(), 0);
    }

    #[test]
    fn stream_appends_match_batch_profile() {
        let s = svc();
        let series = generate::<f64>(Pattern::EcgLike, 2048, 8);
        let m = 32;
        let stream = s.submit_stream(m, None).unwrap();
        // feed in uneven batches, awaiting each append (ordered by client)
        let mut last = None;
        for chunk in series.chunks(300) {
            let id = s.append_stream(stream, chunk).unwrap();
            last = Some(s.wait(id));
        }
        let streamed = last.unwrap().profile.unwrap();
        let want = stomp::matrix_profile(&series, MpConfig::new(m)).unwrap();
        assert_eq!(streamed.len(), want.len());
        assert!(
            streamed.max_abs_diff(&want) < 1e-6,
            "{}",
            streamed.max_abs_diff(&want)
        );
        // the live snapshot agrees with the last append's result
        let snap = s.snapshot_stream(stream).unwrap();
        assert!(snap.max_abs_diff(&streamed) < 1e-15);
        assert!(s.close_stream(stream));
        s.shutdown();
    }

    #[test]
    fn stream_appends_are_applied_in_order_across_workers() {
        // 3 workers racing on one stream: per-stream sequencing must keep
        // the profile equal to the in-order batch run even though jobs are
        // all enqueued before any completes.
        let s = AnalysisService::<f64>::start(NatsaConfig::default().with_threads(1), 3, 64);
        let series = generate::<f64>(Pattern::RandomWalk, 3000, 9);
        let m = 16;
        let stream = s.submit_stream(m, None).unwrap();
        let mut ids = Vec::new();
        for chunk in series.chunks(128) {
            ids.push(s.append_stream(stream, chunk).unwrap());
        }
        for id in ids {
            assert!(s.wait(id).profile.is_ok());
        }
        let got = s.snapshot_stream(stream).unwrap();
        let want = stomp::matrix_profile(&series, MpConfig::new(m)).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-7, "{}", got.max_abs_diff(&want));
        s.close_stream(stream);
        s.shutdown();
    }

    #[test]
    fn append_to_unknown_stream_is_rejected() {
        let s = svc();
        assert_eq!(
            s.append_stream(999, &[1.0, 2.0]),
            Err(SubmitError::UnknownStream)
        );
        s.shutdown();
    }

    #[test]
    fn closed_stream_fails_pending_and_future_appends() {
        let s = svc();
        let stream = s.submit_stream(16, None).unwrap();
        let id = s.append_stream(stream, &generate::<f64>(Pattern::RandomWalk, 64, 1)).unwrap();
        let _ = s.wait(id);
        assert!(s.close_stream(stream));
        assert!(!s.close_stream(stream)); // idempotent: already gone
        assert_eq!(
            s.append_stream(stream, &[1.0]),
            Err(SubmitError::UnknownStream)
        );
        assert!(s.snapshot_stream(stream).is_none());
        s.shutdown();
    }

    #[test]
    fn stream_with_bounded_history_reports_suffix_profile() {
        let s = svc();
        let m = 16;
        let stream = s.submit_stream(m, Some(256)).unwrap();
        let series = generate::<f64>(Pattern::RandomWalk, 2000, 10);
        let id = s.append_stream(stream, &series).unwrap();
        let snap = s.wait(id).profile.unwrap();
        assert_eq!(snap.len(), 256 - m + 1);
        // a bound too small to admit a pair is rejected at open time
        assert!(matches!(
            s.submit_stream(16, Some(8)),
            Err(SubmitError::Invalid(_))
        ));
        s.close_stream(stream);
        s.shutdown();
    }

    #[test]
    fn batch_and_stream_jobs_share_metrics() {
        let s = svc();
        let stream = s.submit_stream(16, None).unwrap();
        let a = s.append_stream(stream, &generate::<f64>(Pattern::RandomWalk, 200, 2)).unwrap();
        let b = s.submit(Arc::new(generate::<f64>(Pattern::RandomWalk, 256, 3)), 16).unwrap();
        let _ = s.wait(a);
        let _ = s.wait(b);
        assert_eq!(s.metrics().jobs_completed.load(Ordering::Relaxed), 2);
        assert_eq!(s.metrics().in_flight(), 0);
        s.close_stream(stream);
        s.shutdown();
    }
}
